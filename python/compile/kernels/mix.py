"""Fused axpy gossip mixing kernel.

SGP / OSGP / D-PSGD mix a worker's parameters with a received message as a
two-term convex (column-stochastic) combination:

    x' = a * x + b * y        (paper Alg. 2 line 7 with one in-neighbor)

and the push-sum weight update is the same combination on scalars. The fused
kernel is also used by the SlowMo exact-average reduction tree, where each
combine step is a = b = 1 (sum) followed by a final 1/m scale, expressed as
``axpy_mix(acc, x, 1.0, 1.0)`` / ``axpy_mix(acc, acc, 1/m, 0.0)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import as_scalar, pick_block, scalar_spec, vec_spec


def _kernel(x_ref, y_ref, a_ref, b_ref, out_ref):
    out_ref[...] = a_ref[0] * x_ref[...] + b_ref[0] * y_ref[...]


def axpy_mix(x, y, a, b, *, block_elems=None, interpret=True):
    """Return ``a*x + b*y`` over flat ``f32[d]`` vectors."""
    d = x.shape[0]
    block = pick_block(d, block_elems)
    return pl.pallas_call(
        _kernel,
        grid=(d // block,),
        in_specs=[vec_spec(block), vec_spec(block),
                  scalar_spec(), scalar_spec()],
        out_specs=vec_spec(block),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(x, y, as_scalar(a), as_scalar(b))
