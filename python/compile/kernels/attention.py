"""Tiled causal attention kernel (flash-style online softmax).

The paper's WMT'16 workload is a big transformer whose V100 hot-spot is the
attention matmul chain. DESIGN.md SS5 (Hardware-Adaptation): instead of the
CUDA warp/WMMA tiling of flash attention, we tile for the TPU memory
hierarchy -- (Bq x Dh) query tiles resident in VMEM, an inner loop streaming
(Bk x Dh) key/value tiles, accumulating with the online-softmax recurrence so
the (S x S) score matrix never materializes in HBM.

Differentiation: ``pallas_call`` has no automatic transpose rule, so the
public entry :func:`causal_attention` wraps the kernel in ``jax.custom_vjp``
with the forward pass running the Pallas kernel (saving the logsumexp
statistics) and the backward pass using the closed-form XLA recomputation
from flash-attention's backward derivation. This keeps the L2 training graph
fully differentiable while the forward hot loop stays a Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int,
                scale: float):
    """One (head, q-block) grid step.

    Block shapes (leading singleton = the head block):
      q_ref: (1, Bq, Dh); k_ref/v_ref: (1, S, Dh) streamed in Bk chunks by the
      in-kernel loop; o_ref: (1, Bq, Dh); lse_ref: (1, Bq).
    """
    _, bq, dh = q_ref.shape
    s = k_ref.shape[1]
    q_blk = pl.program_id(1)
    q = q_ref[0] * scale  # (Bq, Dh)
    q_pos = q_blk * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        scores = q @ k.T  # (Bq, Bk)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        scores = jnp.where(q_pos >= k_pos, scores, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(scores, axis=1))
        correction = jnp.exp(m_i - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l_i * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # Causality: the query block at index q_blk only attends to key blocks
    # 0..(q_blk+1)*bq/block_k; streaming all blocks and masking is simpler
    # under interpret=True, and on a real-TPU schedule the loop bound would
    # be clipped by the index map instead (same arithmetic, fewer tiles).
    n_kb = s // block_k
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[0] = acc / l_i[:, None]
    lse_ref[0] = m_i + jnp.log(l_i)


def _attention_fwd_pallas(q, k, v, *, block_q: int, block_k: int,
                          interpret: bool):
    """Run the kernel. q/k/v: (H, S, Dh) f32. Returns (out, lse)."""
    h, s, dh = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"seq len {s} must be divisible by blocks "
                         f"({block_q}, {block_k})")
    scale = 1.0 / (dh ** 0.5)
    grid = (h, s // block_q)
    kernel = functools.partial(_fwd_kernel, block_k=block_k, scale=scale)
    out_shape = (
        jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
        jax.ShapeDtypeStruct((h, s), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, block_q), lambda hh, qq: (hh, qq)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def causal_attention(q, k, v, block_q=128, block_k=128, interpret=True):
    """Causal multi-head attention, Pallas forward / XLA backward.

    Args:
      q, k, v: ``f32[H, S, Dh]``.
    Returns:
      ``f32[H, S, Dh]`` attention output.
    """
    out, _ = _attention_fwd_pallas(q, k, v, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    return out


def _fwd_rule(q, k, v, block_q, block_k, interpret):
    out, lse = _attention_fwd_pallas(q, k, v, block_q=block_q,
                                     block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _bwd_rule(block_q, block_k, interpret, res, d_out):
    q, k, v, out, lse = res
    h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = q_pos >= k_pos
    # Recompute probabilities from the saved logsumexp (flash-style bwd).
    p = jnp.where(mask[None], jnp.exp(scores - lse[:, :, None]), 0.0)
    dv = jnp.einsum("hqk,hqd->hkd", p, d_out)
    dp = jnp.einsum("hqd,hkd->hqk", d_out, v)
    delta = jnp.sum(d_out * out, axis=-1, keepdims=True)  # (H, S, 1)
    ds = p * (dp - delta)
    dq = jnp.einsum("hqk,hkd->hqd", ds, k) * scale
    dk = jnp.einsum("hqk,hqd->hkd", ds, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_fwd_rule, _bwd_rule)
