"""Pure-jnp correctness oracles for every Layer-1 kernel.

These are the ground truth against which ``python/tests/test_kernels.py``
checks the Pallas kernels (allclose over randomized shape/seed sweeps), and
they double as the spec the Rust mirror optimizers (`rust/src/optim/`) are
tested against via golden vectors exported by ``python/tests/test_golden.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def slowmo_update(x0, xt, u, gamma, alpha, beta):
    """Paper Eq. 2-3 (slow momentum update + outer iterate step)."""
    u_new = beta * u + (x0 - xt) / gamma
    x_new = x0 - alpha * gamma * u_new
    return x_new, u_new


def nesterov_step(x, h, g, gamma, beta0, wd=0.0):
    """Nesterov-momentum SGD with L2 weight decay (paper Alg. 2/4 inner)."""
    g = g + wd * x
    h_new = beta0 * h + g
    x_new = x - gamma * (beta0 * h_new + g)
    return x_new, h_new


def adam_step(x, h, v, g, gamma, beta1, beta2, eps, step):
    """Adam with bias correction (paper Table C.1); ``step`` is 1-based."""
    h_new = beta1 * h + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    h_hat = h_new / (1.0 - beta1 ** step)
    v_hat = v_new / (1.0 - beta2 ** step)
    x_new = x - gamma * h_hat / (jnp.sqrt(v_hat) + eps)
    return x_new, h_new, v_new


def axpy_mix(x, y, a, b):
    """Gossip mixing / push-sum combine: ``a*x + b*y``."""
    return a * x + b * y


def causal_attention(q, k, v):
    """Dense causal attention over ``f32[H, S, Dh]``."""
    h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    scores = jnp.where(mask[None], scores, -jnp.inf)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
