"""Fused Adam inner step with bias correction (paper Table C.1).

    h_{k+1} = beta1 * h_k + (1 - beta1) * g
    v_{k+1} = beta2 * v_k + (1 - beta2) * g^2
    h_hat   = h_{k+1} / (1 - beta1^l)
    v_hat   = v_{k+1} / (1 - beta2^l)
    x_{k+1} = x_k - gamma * h_hat / (sqrt(v_hat) + eps)

``l`` is the *global* step counter: when the SlowMo buffer strategy is
"maintain" (the paper's default for Adam / WMT), l = t*tau + k keeps counting
across outer iterations; when "reset", l restarts at 1 each outer loop. The
counter is a runtime input so one compiled artifact serves both strategies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import as_scalar, pick_block, scalar_spec, vec_spec


def _kernel(x_ref, h_ref, v_ref, g_ref, gamma_ref, beta1_ref, beta2_ref,
            eps_ref, step_ref, x_out_ref, h_out_ref, v_out_ref):
    gamma = gamma_ref[0]
    beta1 = beta1_ref[0]
    beta2 = beta2_ref[0]
    eps = eps_ref[0]
    step = step_ref[0]  # l >= 1, as f32
    g = g_ref[...]
    h_new = beta1 * h_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    h_out_ref[...] = h_new
    v_out_ref[...] = v_new
    bc1 = 1.0 - jnp.power(beta1, step)
    bc2 = 1.0 - jnp.power(beta2, step)
    h_hat = h_new / bc1
    v_hat = v_new / bc2
    x_out_ref[...] = x_ref[...] - gamma * h_hat / (jnp.sqrt(v_hat) + eps)


def adam_step(x, h, v, g, gamma, beta1, beta2, eps, step, *,
              block_elems=None, interpret=True):
    """One fused Adam step; returns ``(x_next, h_next, v_next)``.

    ``step`` is the 1-based global Adam step counter (runtime scalar).
    """
    d = x.shape[0]
    block = pick_block(d, block_elems)
    out_shape = tuple(jax.ShapeDtypeStruct((d,), jnp.float32)
                      for _ in range(3))
    return pl.pallas_call(
        _kernel,
        grid=(d // block,),
        in_specs=[vec_spec(block)] * 4 + [scalar_spec()] * 5,
        out_specs=tuple(vec_spec(block) for _ in range(3)),
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, v, g, as_scalar(gamma), as_scalar(beta1), as_scalar(beta2),
      as_scalar(eps), as_scalar(step))
