"""Shared helpers for the Layer-1 Pallas kernels.

All elementwise optimizer kernels operate on flat ``f32[d]`` parameter
vectors. The Layer-2 export path pads ``d`` up to a multiple of the VMEM
block so every grid step is full (no masking needed); the padding tail is
provably inert under every optimizer update (zero gradient -> zero momentum
-> zero update), which ``python/tests/test_padding.py`` asserts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 512 x 128 f32 lanes = 256 KiB per operand in VMEM. See kernels/__init__.py.
BLOCK_ELEMS = 65536


def pick_block(d: int, block_elems: int | None) -> int:
    """Choose the 1-D VMEM block size for a flat vector of length ``d``.

    ``block_elems=None`` requests whole-array (single grid step) execution,
    which is the fastest layout for the CPU-PJRT interpret path; an explicit
    block must divide ``d`` exactly.
    """
    if block_elems is None or block_elems >= d:
        return d
    if d % block_elems != 0:
        raise ValueError(
            f"flat length {d} is not a multiple of block {block_elems}; "
            "pad the parameter vector first (see compile.model.pad_len)"
        )
    return block_elems


def vec_spec(block: int) -> pl.BlockSpec:
    """BlockSpec for a flat vector tiled 1-D along the grid."""
    return pl.BlockSpec((block,), lambda i: (i,))


def scalar_spec() -> pl.BlockSpec:
    """BlockSpec for a broadcast ``f32[1]`` runtime scalar (lr, beta, ...).

    Every grid step maps to the same single-element block, emulating the
    SMEM-resident scalar operand a real TPU kernel would use.
    """
    return pl.BlockSpec((1,), lambda i: (0,))


def as_scalar(x) -> jax.Array:
    """Coerce a python float / 0-d array to the ``f32[1]`` scalar layout."""
    return jnp.asarray(x, dtype=jnp.float32).reshape(1)
