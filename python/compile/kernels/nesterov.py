"""Fused Nesterov-momentum SGD inner step (paper Alg. 2/4, Table C.1).

The base-optimizer update the paper uses on every worker for the image
tasks is SGD with Nesterov momentum and (decoupled) weight decay:

    g'     = g + wd * x                       (L2 regularization)
    h_{k+1} = beta0 * h_k + g'
    d      = beta0 * h_{k+1} + g'             (Nesterov look-ahead direction)
    x_{k+1} = x_k - gamma * d

Fusing the three statements keeps the HBM traffic at 3 reads + 2 writes per
element, matching the fused `foreach` optimizer loop PyTorch gives the
original paper on V100s.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import as_scalar, pick_block, scalar_spec, vec_spec


def _kernel(x_ref, h_ref, g_ref, gamma_ref, beta0_ref, wd_ref,
            x_out_ref, h_out_ref):
    gamma = gamma_ref[0]
    beta0 = beta0_ref[0]
    wd = wd_ref[0]
    g = g_ref[...] + wd * x_ref[...]
    h_new = beta0 * h_ref[...] + g
    h_out_ref[...] = h_new
    x_out_ref[...] = x_ref[...] - gamma * (beta0 * h_new + g)


def nesterov_step(x, h, g, gamma, beta0, wd=0.0, *, block_elems=None,
                  interpret=True):
    """One fused Nesterov-SGD step; returns ``(x_next, h_next)``."""
    d = x.shape[0]
    block = pick_block(d, block_elems)
    out_shape = (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
    )
    return pl.pallas_call(
        _kernel,
        grid=(d // block,),
        in_specs=[vec_spec(block), vec_spec(block), vec_spec(block),
                  scalar_spec(), scalar_spec(), scalar_spec()],
        out_specs=(vec_spec(block), vec_spec(block)),
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, g, as_scalar(gamma), as_scalar(beta0), as_scalar(wd))
