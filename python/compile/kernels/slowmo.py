"""Fused SlowMo outer update (paper Eq. 2-3) as a Pallas kernel.

One outer iteration of Algorithm 1 ends with, on every worker (identical
inputs after the exact-average, so the result stays synchronized):

    u_{t+1}   = beta * u_t + (x_{t,0} - x_{t,tau}) / gamma_t      (Eq. 2)
    x_{t+1,0} = x_{t,0} - alpha * gamma_t * u_{t+1}               (Eq. 3)

The fused kernel reads ``x0, xt, u`` once each and writes ``x', u'`` once
each: 3 reads + 2 writes = 5d * 4 bytes of HBM traffic per call, vs. 7d for
the unfused two-statement jnp version (which re-reads u' and x0). The kernel
is bandwidth-bound; DESIGN.md SS8 carries the roofline estimate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import as_scalar, pick_block, scalar_spec, vec_spec


def _kernel(x0_ref, xt_ref, u_ref, gamma_ref, alpha_ref, beta_ref,
            x_out_ref, u_out_ref):
    gamma = gamma_ref[0]
    alpha = alpha_ref[0]
    beta = beta_ref[0]
    x0 = x0_ref[...]
    # Eq. 2: the (x0 - xt) difference is rescaled by 1/gamma to make the slow
    # buffer invariant to the fast-lr schedule.
    u_new = beta * u_ref[...] + (x0 - xt_ref[...]) / gamma
    u_out_ref[...] = u_new
    # Eq. 3: outer step uses the *product* of slow and fast learning rates.
    x_out_ref[...] = x0 - alpha * gamma * u_new


def slowmo_update(x0, xt, u, gamma, alpha, beta, *, block_elems=None,
                  interpret=True):
    """Apply the fused SlowMo outer update.

    Args:
      x0: ``f32[d]`` outer iterate x_{t,0}.
      xt: ``f32[d]`` averaged inner result x_{t,tau}.
      u:  ``f32[d]`` slow momentum buffer u_t.
      gamma, alpha, beta: runtime scalars (python float or ``f32[1]``).
      block_elems: VMEM block (None = whole array; fastest on CPU PJRT).
      interpret: must stay True for CPU-PJRT execution (no Mosaic).

    Returns:
      ``(x_next, u_next)`` both ``f32[d]``.
    """
    d = x0.shape[0]
    block = pick_block(d, block_elems)
    grid = (d // block,)
    out_shape = (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[vec_spec(block), vec_spec(block), vec_spec(block),
                  scalar_spec(), scalar_spec(), scalar_spec()],
        out_specs=(vec_spec(block), vec_spec(block)),
        out_shape=out_shape,
        interpret=interpret,
    )(x0, xt, u, as_scalar(gamma), as_scalar(alpha), as_scalar(beta))
