"""Layer-1 Pallas kernels for the SlowMo reproduction.

Every kernel here is the arithmetic hot-spot of one piece of the SlowMo
framework (Wang et al., ICLR 2020):

- :mod:`.slowmo`    -- fused slow-momentum outer update (paper Eq. 2-3).
- :mod:`.nesterov`  -- fused Nesterov-momentum SGD inner step (Alg. 2/4).
- :mod:`.adam`      -- fused Adam inner step with bias correction (Table C.1).
- :mod:`.mix`       -- fused axpy gossip mixing / push-sum combine.
- :mod:`.attention` -- tiled causal attention for the L2 transformer.

All kernels are written with TPU-shaped BlockSpecs (VMEM tiles that are
multiples of the 8x128 f32 register tile) but are lowered with
``interpret=True`` so the emitted HLO contains no Mosaic custom-calls and can
be executed by the CPU PJRT client that the Rust Layer-3 coordinator uses.

Correctness oracles for every kernel live in :mod:`.ref` and are enforced by
``python/tests/test_kernels.py``.
"""

# Default 1-D VMEM block for elementwise optimizer kernels: 65536 f32
# = 512 x 128 lanes = 256 KiB per operand. Chosen in DESIGN.md SS5 so that the
# worst-case kernel (adam: 4 in + 3 out operands) stays under 2 MiB of VMEM
# working set per grid step, leaving room for double buffering in a 16 MiB
# VMEM budget.
BLOCK_ELEMS = 65536

from . import adam, attention, mix, nesterov, ref, slowmo  # noqa: E402,F401
