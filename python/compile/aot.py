"""AOT export: lower every Layer-2 graph to HLO text + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:
  <preset>.train.hlo.txt / <preset>.eval.hlo.txt   model graphs
  opt.{nesterov,adam,slowmo,axpy}.d<d>.hlo.txt     optimizer graphs per d
  init.<preset>.f32                                 initial flat params (LE)
  manifest.json                                     machine-readable index
  golden.json                                       kernel golden vectors for
                                                    the Rust mirror tests

Usage: ``python -m compile.aot --out-dir ../artifacts [--group default]``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

try:
    from . import optim, presets
    from .kernels import ref
except ImportError:
    # Run as a plain script (`python python/compile/aot.py`, the form the
    # Makefile and ROADMAP document) rather than `python -m compile.aot`:
    # put the package root on sys.path and import absolutely.
    import sys

    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    from compile import optim, presets
    from compile.kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    Large constants MUST be printed in full: the default printer elides
    them as ``constant({...})`` and xla_extension's text parser silently
    zero-fills the elision, corrupting the graph (caught by
    rust/tests/runtime_smoke.rs and guarded here).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line etc.) are unknown to the
    # 0.5.1-era text parser; strip metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    if "{...}" in text:
        raise RuntimeError("HLO printer elided a large constant")
    return text


def _io_desc(avals) -> list[dict]:
    out = []
    for i, a in enumerate(avals):
        out.append({"index": i, "shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def lower_fn(fn, example_args):
    # keep_unused: the Rust runtime feeds every manifest input; letting jit
    # prune unused args (e.g. quad-eval's noise) would desync the
    # signature.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    flat_out = jax.tree_util.tree_leaves(out_avals)
    return text, _io_desc(example_args), _io_desc(flat_out)


def batch_args(name: str):
    """Example (abstract) batch inputs for a preset's train/eval graphs."""
    family, cfg = presets.PRESETS[name]
    if family == "lm":
        tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
        return (tok, tok)
    if family == "mlp":
        return (jax.ShapeDtypeStruct((cfg.batch, cfg.in_dim), jnp.float32),
                jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    if family == "cnn":
        return (jax.ShapeDtypeStruct((cfg.batch, cfg.hw, cfg.hw, cfg.in_ch),
                                     jnp.float32),
                jax.ShapeDtypeStruct((cfg.batch,), jnp.int32))
    if family == "quad":
        vec = jax.ShapeDtypeStruct((cfg.dim,), jnp.float32)
        return (vec, vec)
    raise KeyError(family)


def data_desc(name: str) -> dict:
    """What the Rust data generator needs to synthesize batches."""
    family, cfg = presets.PRESETS[name]
    if family == "lm":
        return {"kind": "lm", "vocab": cfg.vocab, "seq_len": cfg.seq_len,
                "batch": cfg.batch}
    if family == "mlp":
        return {"kind": "class", "in_dim": cfg.in_dim,
                "classes": cfg.classes, "batch": cfg.batch}
    if family == "cnn":
        return {"kind": "image", "hw": cfg.hw, "in_ch": cfg.in_ch,
                "classes": cfg.classes, "batch": cfg.batch}
    if family == "quad":
        return {"kind": "quad", "dim": cfg.dim, "cond": cfg.cond}
    raise KeyError(family)


def export_preset(name: str, out_dir: str, manifest: dict) -> int:
    spec = presets.spec_for(name)
    d = spec.flat_len
    train_fn, eval_fn = presets.fns_for(name)
    flat = jax.ShapeDtypeStruct((d,), jnp.float32)
    args = (flat,) + batch_args(name)

    entry: dict = {
        "family": presets.PRESETS[name][0],
        "flat_len": d,
        "raw_len": spec.raw_len,
        "data": data_desc(name),
        "params": spec.describe(),
    }
    for kind, fn in (("train", train_fn), ("eval", eval_fn)):
        fname = f"{name}.{kind}.hlo.txt"
        text, ins, outs = lower_fn(fn, args)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry[kind] = {"file": fname, "inputs": ins, "outputs": outs}
        print(f"  {fname}: {len(text)} chars, {len(ins)} in / {len(outs)} out")

    # Initial parameters: raw little-endian f32, generated here so every
    # Rust worker starts from the same point (paper assumption x_0 shared).
    init = np.asarray(spec.init_flat(jax.random.PRNGKey(0)),
                      dtype="<f4")
    init_file = f"init.{name}.f32"
    init.tofile(os.path.join(out_dir, init_file))
    entry["init_file"] = init_file
    manifest["presets"][name] = entry
    return d


def export_optim(d: int, out_dir: str, manifest: dict) -> None:
    vec = jax.ShapeDtypeStruct((d,), jnp.float32)
    sc = jax.ShapeDtypeStruct((1,), jnp.float32)
    graphs = {
        "nesterov": (optim.nesterov_step, (vec, vec, vec, sc, sc, sc)),
        "adam": (optim.adam_step, (vec, vec, vec, vec, sc, sc, sc, sc, sc)),
        "slowmo": (optim.slowmo_update, (vec, vec, vec, sc, sc, sc)),
        "axpy": (optim.axpy_mix, (vec, vec, sc, sc)),
    }
    entry = {}
    for gname, (fn, args) in graphs.items():
        fname = f"opt.{gname}.d{d}.hlo.txt"
        text, ins, outs = lower_fn(fn, args)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry[gname] = {"file": fname, "inputs": ins, "outputs": outs}
    manifest["optim"][str(d)] = entry
    print(f"  optimizer graphs for d={d}")


def export_golden(out_dir: str, seed: int = 1234) -> None:
    """Small golden vectors so the Rust mirror optimizers can be verified
    bit-for-bit against the jnp oracle without a Python runtime. The seed
    is threaded through --golden-seed so rust/tests/golden.rs fixtures can
    be regenerated (or re-rolled) with one documented command."""
    rng = np.random.RandomState(seed)
    d = 16

    def vec():
        return rng.randn(d).astype(np.float32)

    cases = {}
    x0, xt, u = vec(), vec(), vec()
    xn, un = ref.slowmo_update(jnp.array(x0), jnp.array(xt), jnp.array(u),
                               0.05, 1.0, 0.7)
    cases["slowmo"] = {
        "in": {"x0": x0.tolist(), "xt": xt.tolist(), "u": u.tolist(),
               "gamma": 0.05, "alpha": 1.0, "beta": 0.7},
        "out": {"x": np.asarray(xn).tolist(), "u": np.asarray(un).tolist()},
    }
    x, h, g = vec(), vec(), vec()
    xn, hn = ref.nesterov_step(jnp.array(x), jnp.array(h), jnp.array(g),
                               0.1, 0.9, 1e-4)
    cases["nesterov"] = {
        "in": {"x": x.tolist(), "h": h.tolist(), "g": g.tolist(),
               "gamma": 0.1, "beta0": 0.9, "wd": 1e-4},
        "out": {"x": np.asarray(xn).tolist(), "h": np.asarray(hn).tolist()},
    }
    x, h, g = vec(), vec(), vec()
    v = np.abs(vec())
    xn, hn, vn = ref.adam_step(jnp.array(x), jnp.array(h), jnp.array(v),
                               jnp.array(g), 1e-3, 0.9, 0.98, 1e-8, 7.0)
    cases["adam"] = {
        "in": {"x": x.tolist(), "h": h.tolist(), "v": v.tolist(),
               "g": g.tolist(), "gamma": 1e-3, "beta1": 0.9, "beta2": 0.98,
               "eps": 1e-8, "step": 7.0},
        "out": {"x": np.asarray(xn).tolist(), "h": np.asarray(hn).tolist(),
                "v": np.asarray(vn).tolist()},
    }
    x, y = vec(), vec()
    cases["axpy"] = {
        "in": {"x": x.tolist(), "y": y.tolist(), "a": 0.25, "b": 0.75},
        "out": {"z": np.asarray(ref.axpy_mix(
            jnp.array(x), jnp.array(y), 0.25, 0.75)).tolist()},
    }

    # Hierarchical two-level run: unequal groups "0-0|1-3" of m=4 worker
    # vectors, reduced with the |G|*g/m weighted two-level mean (the op
    # order rust/src/slowmo/hier.rs's distributed reduce and
    # topology::Groups::weighted_mean mirror: sequential f32 group sums,
    # per-group 1/|G| scale, |G|*g/m weighting, sequential sum over
    # groups, 1/g scale), then one slow-momentum update on the result.
    m_workers = 4
    groups = [[0], [1, 2, 3]]
    xs = [vec() for _ in range(m_workers)]
    acc = np.zeros(d, dtype=np.float32)
    for grp in groups:
        gm = np.zeros(d, dtype=np.float32)
        for w in grp:
            gm = (gm + xs[w]).astype(np.float32)
        gm = (gm * np.float32(1.0 / len(grp))).astype(np.float32)
        factor = np.float32(len(grp) * len(groups)) / np.float32(m_workers)
        if factor != np.float32(1.0):
            gm = (gm * factor).astype(np.float32)
        acc = (acc + gm).astype(np.float32)
    xbar = (acc * np.float32(1.0 / len(groups))).astype(np.float32)
    x0, u = vec(), vec()
    xn, un = ref.slowmo_update(jnp.array(x0), jnp.array(xbar),
                               jnp.array(u), 0.05, 1.0, 0.7)
    cases["hier"] = {
        "in": {"xs": [x.tolist() for x in xs], "groups": "0-0|1-3",
               "x0": x0.tolist(), "u": u.tolist(),
               "gamma": 0.05, "alpha": 1.0, "beta": 0.7},
        "out": {"xbar": xbar.tolist(), "x": np.asarray(xn).tolist(),
                "u": np.asarray(un).tolist()},
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(cases, f)
    print("  golden.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--group", default="default",
                    choices=sorted(presets.GROUPS))
    ap.add_argument("--preset", action="append", default=[],
                    help="extra presets to export (repeatable)")
    ap.add_argument("--golden-seed", type=int, default=1234,
                    help="RNG seed for the golden.json fixtures "
                         "(1234 is the committed baseline)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = list(dict.fromkeys(presets.GROUPS[args.group] + args.preset))

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest: dict = {"version": 1, "presets": {}, "optim": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        manifest.setdefault("presets", {})
        manifest.setdefault("optim", {})

    dims = set()
    for name in names:
        print(f"preset {name}")
        dims.add(export_preset(name, args.out_dir, manifest))
    for d in sorted(dims):
        export_optim(d, args.out_dir, manifest)
    export_golden(args.out_dir, args.golden_seed)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
