"""Layer-2 optimizer graphs: thin jax wrappers over the Layer-1 kernels.

Each function here becomes one AOT artifact per distinct flat parameter
length ``d``. Scalars (learning rate, momenta, step counter) are runtime
inputs so a single compiled executable serves every hyperparameter setting
and learning-rate schedule -- critical for the Fig. 3 / Fig. B.2 sweeps,
which reuse one artifact across the whole grid.
"""

from __future__ import annotations

from .kernels import adam as adam_k
from .kernels import mix as mix_k
from .kernels import nesterov as nesterov_k
from .kernels import slowmo as slowmo_k

# None => whole-array single-block execution (fastest for CPU PJRT; the
# blocked variant is exercised by the pytest sweep and the perf ablation).
DEFAULT_BLOCK = None


def nesterov_step(x, h, g, gamma, beta0, wd):
    """(x, h, g, gamma[1], beta0[1], wd[1]) -> (x', h')."""
    return nesterov_k.nesterov_step(x, h, g, gamma, beta0, wd,
                                    block_elems=DEFAULT_BLOCK)


def adam_step(x, h, v, g, gamma, beta1, beta2, eps, step):
    """(x, h, v, g, scalars...) -> (x', h', v')."""
    return adam_k.adam_step(x, h, v, g, gamma, beta1, beta2, eps, step,
                            block_elems=DEFAULT_BLOCK)


def slowmo_update(x0, xt, u, gamma, alpha, beta):
    """(x0, xt, u, gamma[1], alpha[1], beta[1]) -> (x', u')."""
    return slowmo_k.slowmo_update(x0, xt, u, gamma, alpha, beta,
                                  block_elems=DEFAULT_BLOCK)


def axpy_mix(x, y, a, b):
    """(x, y, a[1], b[1]) -> a*x + b*y."""
    return mix_k.axpy_mix(x, y, a, b, block_elems=DEFAULT_BLOCK)
