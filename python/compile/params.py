"""Flat-parameter packing for Layer-2 models.

The Rust coordinator treats every model as a single flat ``f32[d]`` buffer:
one PJRT literal per worker for parameters, gradients and each optimizer
buffer. This module maps named parameter tensors onto slices of that vector,
pads ``d`` up to an alignment multiple (so the blocked Pallas optimizer
kernels tile exactly), and provides initializers.

The padding tail is inert: it is never read by the model, gets zero
gradients, and every optimizer update maps zero (grad, buffers) to zero
update -- asserted in python/tests/test_models.py::test_padding_inert.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

# Flat length alignment. 128 matches the TPU lane width; the AOT exporter can
# additionally request 65536-alignment when emitting blocked optimizer
# kernels (see compile.kernels.common.BLOCK_ELEMS).
ALIGN = 128


def pad_len(n: int, align: int = ALIGN) -> int:
    """Round ``n`` up to a multiple of ``align``."""
    return int(math.ceil(n / align) * align)


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: tuple[int, ...]
    offset: int
    init: str  # "normal:<std>" | "zeros" | "ones"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


class ParamSpec:
    """Ordered collection of named tensors packed into one flat vector."""

    def __init__(self, align: int = ALIGN):
        self._entries: list[ParamEntry] = []
        self._cursor = 0
        self._align = align

    def add(self, name: str, shape: tuple[int, ...], init: str) -> None:
        if any(e.name == name for e in self._entries):
            raise ValueError(f"duplicate parameter name {name!r}")
        entry = ParamEntry(name, tuple(shape), self._cursor, init)
        self._entries.append(entry)
        self._cursor += entry.size

    @property
    def raw_len(self) -> int:
        return self._cursor

    @property
    def flat_len(self) -> int:
        return pad_len(self._cursor, self._align)

    @property
    def entries(self) -> list[ParamEntry]:
        return list(self._entries)

    def unpack(self, flat: jax.Array) -> dict[str, jax.Array]:
        """Slice the flat vector into the named tensors (inside the graph)."""
        out = {}
        for e in self._entries:
            out[e.name] = jax.lax.dynamic_slice(
                flat, (e.offset,), (e.size,)).reshape(e.shape)
        return out

    def init_flat(self, key: jax.Array) -> jax.Array:
        """Materialize the initial flat parameter vector."""
        flat = jnp.zeros((self.flat_len,), jnp.float32)
        keys = jax.random.split(key, max(len(self._entries), 1))
        for e, k in zip(self._entries, keys):
            if e.init == "zeros":
                continue
            if e.init == "ones":
                vals = jnp.ones(e.size, jnp.float32)
            elif e.init.startswith("normal:"):
                std = float(e.init.split(":", 1)[1])
                vals = std * jax.random.normal(k, (e.size,), jnp.float32)
            elif e.init.startswith("uniform:"):
                lim = float(e.init.split(":", 1)[1])
                vals = jax.random.uniform(k, (e.size,), jnp.float32,
                                          -lim, lim)
            else:
                raise ValueError(f"unknown init {e.init!r}")
            flat = jax.lax.dynamic_update_slice(flat, vals, (e.offset,))
        return flat

    def describe(self) -> list[dict]:
        """Manifest-friendly description of the packing."""
        return [
            {"name": e.name, "shape": list(e.shape), "offset": e.offset,
             "size": e.size, "init": e.init}
            for e in self._entries
        ]


def make_loss_and_grad(loss_fn: Callable) -> Callable:
    """Wrap ``loss_fn(flat, *batch) -> loss`` into ``-> (loss, grads)``."""
    vag = jax.value_and_grad(loss_fn)

    def train_step(flat, *batch):
        loss, grads = vag(flat, *batch)
        return loss, grads

    return train_step
