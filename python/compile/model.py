"""Layer-2 model definitions (JAX, compiled AOT; never run at train time).

Four model families, all expressed over a single flat ``f32[d]`` parameter
vector (see :mod:`compile.params`):

- :class:`LMConfig` / transformer language model -- the WMT'16-analog task
  (paper Table 1 row 3, Table 2b, Fig. 2c/3b). GPT-style causal decoder with
  the Pallas attention kernel (``use_pallas_attention``).
- :class:`MLPConfig` / MLP classifier -- the CIFAR-10-analog task.
- :class:`CNNConfig` / small conv net -- CIFAR-like image task, exercising
  conv workloads (ResNet-18 stand-in at CPU-budget scale).
- :class:`QuadConfig` / quadratic objective -- the smooth (non-)convex
  workload used to validate Theorem 1 / Corollary 1 rates (bench `theory`).

Each family exposes ``spec(cfg)`` (parameter packing), ``train(cfg)``
(``(flat, *batch) -> (loss, grads)``) and ``evaluate(cfg)``
(``(flat, *batch) -> (loss, metric)``).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.attention import causal_attention
from .params import ParamSpec


# --------------------------------------------------------------------------
# Transformer language model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    """GPT-style causal LM. Sizes chosen per preset in compile.presets."""
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    batch: int = 8
    mlp_ratio: int = 4
    use_pallas_attention: bool = False
    attn_block: int = 64

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def lm_spec(cfg: LMConfig) -> ParamSpec:
    s = ParamSpec()
    d, v = cfg.d_model, cfg.vocab
    s.add("tok_embed", (v, d), "normal:0.02")
    s.add("pos_embed", (cfg.seq_len, d), "normal:0.02")
    proj_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        s.add(p + "ln1.scale", (d,), "ones")
        s.add(p + "ln1.bias", (d,), "zeros")
        s.add(p + "attn.wqkv", (d, 3 * d), "normal:0.02")
        s.add(p + "attn.bqkv", (3 * d,), "zeros")
        s.add(p + "attn.wo", (d, d), f"normal:{proj_std}")
        s.add(p + "attn.bo", (d,), "zeros")
        s.add(p + "ln2.scale", (d,), "ones")
        s.add(p + "ln2.bias", (d,), "zeros")
        s.add(p + "mlp.wi", (d, cfg.mlp_ratio * d), "normal:0.02")
        s.add(p + "mlp.bi", (cfg.mlp_ratio * d,), "zeros")
        s.add(p + "mlp.wo", (cfg.mlp_ratio * d, d), f"normal:{proj_std}")
        s.add(p + "mlp.bo", (d,), "zeros")
    s.add("ln_f.scale", (d,), "ones")
    s.add("ln_f.bias", (d,), "zeros")
    s.add("unembed", (d, v), "normal:0.02")
    return s


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _dense_attention(q, k, v):
    """(B, H, S, Dh) dense causal attention -- the XLA-fused fallback."""
    s = q.shape[2]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def _lm_logits(cfg: LMConfig, spec: ParamSpec, flat, tokens):
    """tokens: i32[B, S] -> logits f32[B, S, V]."""
    p = spec.unpack(flat)
    b, s = tokens.shape
    x = p["tok_embed"][tokens] + p["pos_embed"][None, :, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        hgt = _layer_norm(x, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        qkv = hgt @ p[pre + "attn.wqkv"] + p[pre + "attn.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(
                0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        if cfg.use_pallas_attention:
            # Fold batch into the head grid dimension: the Pallas kernel
            # treats dim 0 as an independent grid axis, so (B*H, S, Dh) runs
            # each (batch, head) pair as its own tile schedule.
            fold = lambda t: t.reshape(b * cfg.n_heads, s, cfg.d_head)
            out = causal_attention(fold(q), fold(k), fold(v),
                                   cfg.attn_block, cfg.attn_block)
            out = out.reshape(b, cfg.n_heads, s, cfg.d_head)
        else:
            out = _dense_attention(q, k, v)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + out @ p[pre + "attn.wo"] + p[pre + "attn.bo"]
        hgt = _layer_norm(x, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        hgt = jax.nn.gelu(hgt @ p[pre + "mlp.wi"] + p[pre + "mlp.bi"])
        x = x + hgt @ p[pre + "mlp.wo"] + p[pre + "mlp.bo"]
    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    return x @ p["unembed"]


def _token_nll(logits, targets, label_smoothing=0.0):
    """Mean token cross-entropy; label smoothing per the WMT setup (0.1)."""
    v = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jax.nn.one_hot(targets, v, dtype=jnp.float32)
    if label_smoothing > 0.0:
        tgt = (1.0 - label_smoothing) * tgt + label_smoothing / v
    return -jnp.mean(jnp.sum(tgt * logp, axis=-1))


def lm_train(cfg: LMConfig, label_smoothing: float = 0.0):
    spec = lm_spec(cfg)

    def loss_fn(flat, tokens, targets):
        logits = _lm_logits(cfg, spec, flat, tokens)
        return _token_nll(logits, targets, label_smoothing)

    vag = jax.value_and_grad(loss_fn)

    def step(flat, tokens, targets):
        loss, grads = vag(flat, tokens, targets)
        return loss, grads

    return step


def lm_eval(cfg: LMConfig):
    spec = lm_spec(cfg)

    def step(flat, tokens, targets):
        logits = _lm_logits(cfg, spec, flat, tokens)
        nll = _token_nll(logits, targets, 0.0)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
        return nll, correct

    return step


# --------------------------------------------------------------------------
# MLP classifier (CIFAR-10 / ImageNet analog at CPU scale)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 512
    hidden: tuple[int, ...] = (256, 128)
    classes: int = 10
    batch: int = 32


def mlp_spec(cfg: MLPConfig) -> ParamSpec:
    s = ParamSpec()
    dims = (cfg.in_dim,) + tuple(cfg.hidden) + (cfg.classes,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        std = (2.0 / a) ** 0.5  # He init for the relu stack
        s.add(f"fc{i}.w", (a, b), f"normal:{std}")
        s.add(f"fc{i}.b", (b,), "zeros")
    return s


def _mlp_logits(cfg: MLPConfig, spec: ParamSpec, flat, x):
    p = spec.unpack(flat)
    n = len(cfg.hidden) + 1
    for i in range(n):
        x = x @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
        if i + 1 < n:
            x = jax.nn.relu(x)
    return x


def _class_nll(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def mlp_train(cfg: MLPConfig):
    spec = mlp_spec(cfg)

    def loss_fn(flat, x, y):
        return _class_nll(_mlp_logits(cfg, spec, flat, x), y)

    vag = jax.value_and_grad(loss_fn)

    def step(flat, x, y):
        return vag(flat, x, y)

    return step


def mlp_eval(cfg: MLPConfig):
    spec = mlp_spec(cfg)

    def step(flat, x, y):
        logits = _mlp_logits(cfg, spec, flat, x)
        loss = _class_nll(logits, y)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, correct

    return step


# --------------------------------------------------------------------------
# Small CNN (conv workload; ResNet stand-in at CPU budget)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CNNConfig:
    hw: int = 16          # input is (hw, hw, in_ch)
    in_ch: int = 3
    channels: tuple[int, ...] = (16, 32)
    classes: int = 10
    batch: int = 32


def cnn_spec(cfg: CNNConfig) -> ParamSpec:
    s = ParamSpec()
    cin = cfg.in_ch
    for i, cout in enumerate(cfg.channels):
        std = (2.0 / (9 * cin)) ** 0.5
        s.add(f"conv{i}.w", (3, 3, cin, cout), f"normal:{std}")
        s.add(f"conv{i}.b", (cout,), "zeros")
        cin = cout
    # Each conv is followed by 2x2 avg-pool; final feature map is flattened.
    final_hw = cfg.hw // (2 ** len(cfg.channels))
    feat = final_hw * final_hw * cfg.channels[-1]
    std = (2.0 / feat) ** 0.5
    s.add("head.w", (feat, cfg.classes), f"normal:{std}")
    s.add("head.b", (cfg.classes,), "zeros")
    return s


def _cnn_logits(cfg: CNNConfig, spec: ParamSpec, flat, x):
    p = spec.unpack(flat)
    for i in range(len(cfg.channels)):
        x = jax.lax.conv_general_dilated(
            x, p[f"conv{i}.w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p[f"conv{i}.b"])
        x = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    x = x.reshape(x.shape[0], -1)
    return x @ p["head.w"] + p["head.b"]


def cnn_train(cfg: CNNConfig):
    spec = cnn_spec(cfg)

    def loss_fn(flat, x, y):
        return _class_nll(_cnn_logits(cfg, spec, flat, x), y)

    vag = jax.value_and_grad(loss_fn)

    def step(flat, x, y):
        return vag(flat, x, y)

    return step


def cnn_eval(cfg: CNNConfig):
    spec = cnn_spec(cfg)

    def step(flat, x, y):
        logits = _cnn_logits(cfg, spec, flat, x)
        loss = _class_nll(logits, y)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, correct

    return step


# --------------------------------------------------------------------------
# Quadratic objective (theory-validation workload)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuadConfig:
    """f_i(x) = 0.5 * (x - c_i)^T diag(lam) (x - c_i), stochastic gradients
    g = grad + noise. The worker-specific centers c_i realize the zeta^2
    heterogeneity bound of Corollary 1; `batch` slots carry the noise draw
    so the artifact signature matches the classifier graphs.
    """
    dim: int = 4096
    cond: float = 100.0  # eigenvalue spread lam in [1, cond] (log-spaced)
    batch: int = 1


def quad_spec(cfg: QuadConfig) -> ParamSpec:
    s = ParamSpec()
    s.add("x", (cfg.dim,), "normal:1.0")
    return s


def _quad_lam(cfg: QuadConfig):
    return jnp.logspace(0.0, jnp.log10(cfg.cond), cfg.dim)


def quad_train(cfg: QuadConfig):
    """(flat, center[dim], noise[dim]) -> (loss, grads).

    `center` encodes the worker's local objective; `noise` is the stochastic
    gradient perturbation (generated Rust-side from the seeded RNG so runs
    are bit-deterministic).
    """
    spec = quad_spec(cfg)
    lam = _quad_lam(cfg)

    def step(flat, center, noise):
        x = spec.unpack(flat)["x"]
        diff = x - center
        loss = 0.5 * jnp.sum(lam * diff * diff) / cfg.dim
        grad_x = lam * diff / cfg.dim + noise
        grads = jnp.zeros_like(flat)
        grads = jax.lax.dynamic_update_slice(grads, grad_x, (0,))
        return loss, grads

    return step


def quad_eval(cfg: QuadConfig):
    spec = quad_spec(cfg)
    lam = _quad_lam(cfg)

    def step(flat, center, noise):
        x = spec.unpack(flat)["x"]
        diff = x - center
        loss = 0.5 * jnp.sum(lam * diff * diff) / cfg.dim
        gnorm = jnp.sum((lam * diff / cfg.dim) ** 2)
        return loss, gnorm

    return step
