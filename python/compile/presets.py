"""Model presets: the concrete workloads each experiment uses.

DESIGN.md SS2 maps the paper's testbeds onto these CPU-scale stand-ins. The
Rust side selects a preset by name; `aot.py --preset <name>` (or `all`,
`default`) exports its artifacts.

Parameter counts (unpadded):
  cifar-mlp   ~ 1.7M     (CIFAR-10 / ResNet-18 analog)
  cifar-cnn   ~ 30K      (conv workload variant)
  imagenet-mlp~ 4.3M     (ImageNet / ResNet-50 analog)
  wmt-lm      ~ 3.2M     (WMT'16 / big-transformer analog)
  lm-tiny     ~ 0.8M     (CI-speed transformer)
  lm-e2e      ~ 12.6M    (end-to-end example default)
  lm-100m     ~ 101M     (full-scale single-run demo)
  quad        4K         (theory validation)
"""

from __future__ import annotations

from . import model as M

# name -> (family, cfg)
PRESETS: dict[str, tuple[str, object]] = {
    "cifar-mlp": ("mlp", M.MLPConfig(in_dim=512, hidden=(1024, 512),
                                     classes=10, batch=32)),
    "cifar-cnn": ("cnn", M.CNNConfig(hw=16, in_ch=3, channels=(16, 32),
                                     classes=10, batch=32)),
    "imagenet-mlp": ("mlp", M.MLPConfig(in_dim=1024, hidden=(1280, 640),
                                        classes=100, batch=32)),
    "wmt-lm": ("lm", M.LMConfig(vocab=512, d_model=192, n_layers=4,
                                n_heads=6, seq_len=64, batch=8)),
    "lm-tiny": ("lm", M.LMConfig(vocab=256, d_model=96, n_layers=2,
                                 n_heads=4, seq_len=32, batch=4)),
    "lm-tiny-pallas": ("lm", M.LMConfig(vocab=256, d_model=96, n_layers=2,
                                        n_heads=4, seq_len=32, batch=4,
                                        use_pallas_attention=True,
                                        attn_block=32)),
    "lm-e2e": ("lm", M.LMConfig(vocab=512, d_model=384, n_layers=6,
                                n_heads=6, seq_len=128, batch=8)),
    "lm-100m": ("lm", M.LMConfig(vocab=8192, d_model=768, n_layers=12,
                                 n_heads=12, seq_len=256, batch=4)),
    "quad": ("quad", M.QuadConfig(dim=4096, cond=100.0)),
}

# Export groups.
GROUPS = {
    "default": ["cifar-mlp", "cifar-cnn", "imagenet-mlp", "wmt-lm",
                "lm-tiny", "lm-tiny-pallas", "quad"],
    "e2e": ["lm-e2e"],
    "big": ["lm-100m"],
    "all": ["cifar-mlp", "cifar-cnn", "imagenet-mlp", "wmt-lm", "lm-tiny",
            "lm-tiny-pallas", "lm-e2e", "quad"],
}


def spec_for(name: str):
    family, cfg = PRESETS[name]
    if family == "lm":
        return M.lm_spec(cfg)
    if family == "mlp":
        return M.mlp_spec(cfg)
    if family == "cnn":
        return M.cnn_spec(cfg)
    if family == "quad":
        return M.quad_spec(cfg)
    raise KeyError(family)


def fns_for(name: str):
    """Return (train_fn, eval_fn) for a preset."""
    family, cfg = PRESETS[name]
    if family == "lm":
        ls = 0.1 if name.startswith("wmt") else 0.0
        return M.lm_train(cfg, label_smoothing=ls), M.lm_eval(cfg)
    if family == "mlp":
        return M.mlp_train(cfg), M.mlp_eval(cfg)
    if family == "cnn":
        return M.cnn_train(cfg), M.cnn_eval(cfg)
    if family == "quad":
        return M.quad_train(cfg), M.quad_eval(cfg)
    raise KeyError(family)
