"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is checked against the pure-jnp oracle in
``compile.kernels.ref`` over a randomized sweep of shapes, block sizes and
hyperparameter values (hypothesis-style: the sweep is seeded and exhaustive
over the cartesian grid below, so failures are reproducible).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import adam, attention, mix, nesterov, ref, slowmo

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


DIMS = [1, 7, 128, 1000, 65536, 65536 * 2 + 4096]
BLOCKS = [None, 4096, 65536]
SEEDS = [0, 1]


def dim_block_cases():
    for d, blk, seed in itertools.product(DIMS, BLOCKS, SEEDS):
        if blk is not None and d % blk != 0:
            continue  # kernels require exact tiling; padding handled at L2
        yield d, blk, seed


@pytest.mark.parametrize("d,blk,seed", list(dim_block_cases()))
def test_slowmo_update_matches_ref(d, blk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x0, xt, u = (rand(k, d) for k in ks)
    gamma, alpha, beta = 0.05, 1.0, 0.7
    got_x, got_u = slowmo.slowmo_update(x0, xt, u, gamma, alpha, beta,
                                        block_elems=blk)
    want_x, want_u = ref.slowmo_update(x0, xt, u, gamma, alpha, beta)
    np.testing.assert_allclose(got_u, want_u, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_x, want_x, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gamma,alpha,beta", [
    (0.1, 1.0, 0.0),    # beta=0: plain averaging step
    (0.1, 0.5, 0.0),    # Lookahead-style alpha<1
    (1e-3, 1.0, 0.95),  # small lr, heavy slow momentum
    (1.0, 2.0, 0.4),
])
def test_slowmo_update_hyperparam_sweep(gamma, alpha, beta):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    x0, xt, u = (rand(k, 1024) for k in ks)
    got_x, got_u = slowmo.slowmo_update(x0, xt, u, gamma, alpha, beta)
    want_x, want_u = ref.slowmo_update(x0, xt, u, gamma, alpha, beta)
    np.testing.assert_allclose(got_u, want_u, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_x, want_x, rtol=1e-5, atol=1e-5)


def test_slowmo_beta0_alpha1_is_plain_average_adopt():
    """SlowMo with beta=0, alpha=1 must set x' = xt exactly (Local SGD)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    x0, xt = rand(ks[0], 512), rand(ks[1], 512)
    u = jnp.zeros(512)
    x_new, _ = slowmo.slowmo_update(x0, xt, u, 0.05, 1.0, 0.0)
    np.testing.assert_allclose(x_new, xt, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("d,blk,seed", list(dim_block_cases()))
def test_nesterov_matches_ref(d, blk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed + 100), 3)
    x, h, g = (rand(k, d) for k in ks)
    got = nesterov.nesterov_step(x, h, g, 0.1, 0.9, 1e-4, block_elems=blk)
    want = ref.nesterov_step(x, h, g, 0.1, 0.9, 1e-4)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_nesterov_no_momentum_is_sgd():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x, g = rand(ks[0], 256), rand(ks[1], 256)
    x_new, h_new = nesterov.nesterov_step(x, jnp.zeros(256), g, 0.2, 0.0)
    np.testing.assert_allclose(x_new, x - 0.2 * g, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(h_new, g, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("d,blk,seed", list(dim_block_cases()))
def test_adam_matches_ref(d, blk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed + 200), 4)
    x, h, g = (rand(k, d) for k in ks[:3])
    v = jnp.abs(rand(ks[3], d))
    args = (x, h, v, g, 1e-3, 0.9, 0.98, 1e-8, 5.0)
    got = adam.adam_step(*args, block_elems=blk)
    want = ref.adam_step(*args)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("step", [1.0, 2.0, 100.0, 10000.0])
def test_adam_bias_correction_steps(step):
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    x, g = rand(ks[0], 512), rand(ks[1], 512)
    h = jnp.zeros(512)
    v = jnp.zeros(512)
    got = adam.adam_step(x, h, v, g, 1e-3, 0.9, 0.98, 1e-8, step)
    want = ref.adam_step(x, h, v, g, 1e-3, 0.9, 0.98, 1e-8, step)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adam_first_step_direction_is_sign_like():
    """At step 1 from zero buffers the Adam update is ~ -lr * sign(g)."""
    g = jnp.array([3.0, -2.0, 0.5, -0.1] * 64)
    x = jnp.zeros(256)
    x_new, _, _ = adam.adam_step(x, x, x, g, 1e-3, 0.9, 0.98, 1e-12, 1.0)
    np.testing.assert_allclose(x_new, -1e-3 * jnp.sign(g), rtol=1e-3,
                               atol=1e-6)


@pytest.mark.parametrize("d,blk,seed", list(dim_block_cases()))
def test_axpy_mix_matches_ref(d, blk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed + 300), 2)
    x, y = rand(ks[0], d), rand(ks[1], d)
    got = mix.axpy_mix(x, y, 0.5, 0.5, block_elems=blk)
    np.testing.assert_allclose(got, ref.axpy_mix(x, y, 0.5, 0.5),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("a,b", [(1.0, 0.0), (0.0, 1.0), (1.0, 1.0),
                                 (0.25, 0.75), (-1.0, 2.0)])
def test_axpy_mix_coefficients(a, b):
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    x, y = rand(ks[0], 1024), rand(ks[1], 1024)
    np.testing.assert_allclose(mix.axpy_mix(x, y, a, b),
                               a * x + b * y, rtol=1e-5, atol=1e-6)


ATTN_SHAPES = [
    (1, 128, 32, 128, 128),
    (2, 256, 64, 128, 128),
    (4, 128, 16, 64, 64),
    (2, 256, 32, 64, 128),
]


@pytest.mark.parametrize("h,s,dh,bq,bk", ATTN_SHAPES)
def test_attention_matches_ref(h, s, dh, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(h * 1000 + s), 3)
    q, k, v = (rand(kk, h, s, dh) for kk in ks)
    got = attention.causal_attention(q, k, v, bq, bk)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    q, k, v = (rand(kk, 2, 128, 32) for kk in ks)
    out1 = attention.causal_attention(q, k, v)
    k2 = k.at[:, 64:].add(10.0)
    v2 = v.at[:, 64:].add(-3.0)
    out2 = attention.causal_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :64], out2[:, :64],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, 64:], out2[:, 64:], atol=1e-3)


def test_attention_grads_match_ref():
    """custom_vjp backward must match autodiff through the dense oracle."""
    ks = jax.random.split(jax.random.PRNGKey(33), 3)
    q, k, v = (rand(kk, 2, 128, 16) for kk in ks)

    def loss_pallas(q, k, v):
        return jnp.sum(attention.causal_attention(q, k, v, 64, 64) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.causal_attention(q, k, v) ** 2)

    g_got = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g_want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_got, g_want):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)


def test_kernels_compose_slowmo_round_trip():
    """tau nesterov steps + average + slowmo == the oracle end to end."""
    d, m, tau = 2048, 4, 3
    key = jax.random.PRNGKey(5)
    x0 = rand(key, d)
    gamma, beta0, alpha, beta = 0.05, 0.9, 1.0, 0.7
    xs = [x0 for _ in range(m)]
    hs = [jnp.zeros(d) for _ in range(m)]
    xs_ref, hs_ref = list(xs), list(hs)
    gkey = jax.random.split(key, m * tau)
    for k in range(tau):
        for i in range(m):
            g = rand(gkey[k * m + i], d)
            xs[i], hs[i] = nesterov.nesterov_step(xs[i], hs[i], g, gamma,
                                                  beta0)
            xs_ref[i], hs_ref[i] = ref.nesterov_step(xs_ref[i], hs_ref[i],
                                                     g, gamma, beta0)
    # Exact average via the mix kernel reduction.
    acc = xs[0]
    for x in xs[1:]:
        acc = mix.axpy_mix(acc, x, 1.0, 1.0)
    xt = mix.axpy_mix(acc, acc, 1.0 / m, 0.0)
    xt_ref = sum(xs_ref) / m
    np.testing.assert_allclose(xt, xt_ref, rtol=1e-5, atol=1e-5)
    u = jnp.zeros(d)
    x_new, u_new = slowmo.slowmo_update(x0, xt, u, gamma, alpha, beta)
    x_new_ref, u_new_ref = ref.slowmo_update(x0, xt_ref, u, gamma, alpha,
                                             beta)
    np.testing.assert_allclose(x_new, x_new_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(u_new, u_new_ref, rtol=1e-5, atol=1e-5)
