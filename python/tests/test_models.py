"""Layer-2 model correctness: shapes, gradients, padding invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params, presets


TINY_LM = M.LMConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                     seq_len=16, batch=2)
TINY_MLP = M.MLPConfig(in_dim=20, hidden=(16,), classes=5, batch=4)
TINY_CNN = M.CNNConfig(hw=8, in_ch=3, channels=(4, 8), classes=5, batch=4)
TINY_QUAD = M.QuadConfig(dim=32, cond=10.0)


def _lm_batch(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    tok = jax.random.randint(k, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    return tok, jnp.roll(tok, -1, axis=1)


def _mlp_batch(cfg, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(ks[0], (cfg.batch, cfg.in_dim))
    y = jax.random.randint(ks[1], (cfg.batch,), 0, cfg.classes)
    return x, y


def _cnn_batch(cfg, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(ks[0], (cfg.batch, cfg.hw, cfg.hw, cfg.in_ch))
    y = jax.random.randint(ks[1], (cfg.batch,), 0, cfg.classes)
    return x, y


# ---------------------------------------------------------------- ParamSpec

def test_param_spec_packing_offsets_are_contiguous():
    s = M.mlp_spec(TINY_MLP)
    offset = 0
    for e in s.entries:
        assert e.offset == offset
        offset += e.size
    assert s.raw_len == offset
    assert s.flat_len % params.ALIGN == 0
    assert s.flat_len >= s.raw_len


def test_param_spec_rejects_duplicates():
    s = params.ParamSpec()
    s.add("w", (2, 2), "zeros")
    with pytest.raises(ValueError):
        s.add("w", (3,), "zeros")


def test_param_spec_unpack_round_trip():
    s = M.mlp_spec(TINY_MLP)
    flat = s.init_flat(jax.random.PRNGKey(0))
    tensors = s.unpack(flat)
    assert set(tensors) == {e.name for e in s.entries}
    for e in s.entries:
        assert tensors[e.name].shape == e.shape
    # ones-init entries must be exactly ones, zeros exactly zero
    rebuilt = jnp.zeros_like(flat)
    for e in s.entries:
        rebuilt = jax.lax.dynamic_update_slice(
            rebuilt, tensors[e.name].reshape(-1), (e.offset,))
    np.testing.assert_allclose(rebuilt[:s.raw_len], flat[:s.raw_len])


def test_init_flat_padding_is_zero():
    s = M.lm_spec(TINY_LM)
    flat = s.init_flat(jax.random.PRNGKey(0))
    assert flat.shape == (s.flat_len,)
    np.testing.assert_array_equal(flat[s.raw_len:], 0.0)


def test_pad_len():
    assert params.pad_len(0) == 0
    assert params.pad_len(1) == 128
    assert params.pad_len(128) == 128
    assert params.pad_len(129) == 256
    assert params.pad_len(1000, 64) == 1024


# ---------------------------------------------------------------- LM model

def test_lm_train_shapes_and_finite():
    spec = M.lm_spec(TINY_LM)
    step = M.lm_train(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    loss, grads = step(flat, *_lm_batch(TINY_LM))
    assert loss.shape == ()
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(grads))


def test_lm_initial_loss_near_uniform():
    """Fresh model must predict ~uniform: loss ~= log(vocab)."""
    spec = M.lm_spec(TINY_LM)
    step = M.lm_train(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(1))
    loss, _ = step(flat, *_lm_batch(TINY_LM))
    assert abs(float(loss) - np.log(TINY_LM.vocab)) < 0.5


def test_lm_padding_inert():
    """Gradient w.r.t. the padding tail must be exactly zero."""
    spec = M.lm_spec(TINY_LM)
    step = M.lm_train(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    _, grads = step(flat, *_lm_batch(TINY_LM))
    np.testing.assert_array_equal(np.asarray(grads[spec.raw_len:]), 0.0)


def test_lm_gradient_descends():
    spec = M.lm_spec(TINY_LM)
    step = M.lm_train(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    batch = _lm_batch(TINY_LM)
    l0, g = step(flat, *batch)
    l1, _ = step(flat - 0.1 * g, *batch)
    assert float(l1) < float(l0)


def test_lm_pallas_attention_matches_dense():
    cfg_d = M.LMConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                       seq_len=32, batch=2, use_pallas_attention=False)
    cfg_p = M.LMConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                       seq_len=32, batch=2, use_pallas_attention=True,
                       attn_block=16)
    spec = M.lm_spec(cfg_d)
    flat = spec.init_flat(jax.random.PRNGKey(3))
    batch = _lm_batch(cfg_d, seed=5)
    l_d, g_d = M.lm_train(cfg_d)(flat, *batch)
    l_p, g_p = M.lm_train(cfg_p)(flat, *batch)
    np.testing.assert_allclose(float(l_d), float(l_p), rtol=1e-4)
    np.testing.assert_allclose(g_d, g_p, rtol=1e-3, atol=1e-4)


def test_lm_eval_counts_correct_tokens():
    spec = M.lm_spec(TINY_LM)
    ev = M.lm_eval(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    tok, tgt = _lm_batch(TINY_LM)
    nll, correct = ev(flat, tok, tgt)
    total = TINY_LM.batch * TINY_LM.seq_len
    assert 0.0 <= float(correct) <= total


def test_lm_label_smoothing_increases_loss_floor():
    spec = M.lm_spec(TINY_LM)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    batch = _lm_batch(TINY_LM)
    l0, _ = M.lm_train(TINY_LM, label_smoothing=0.0)(flat, *batch)
    l1, _ = M.lm_train(TINY_LM, label_smoothing=0.1)(flat, *batch)
    # At near-uniform predictions the two are close; they must differ once
    # trained. Just check both are finite and smoothing changes the value.
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))


# ---------------------------------------------------------------- MLP / CNN

@pytest.mark.parametrize("family,cfg,batch_fn,train_fn,eval_fn", [
    ("mlp", TINY_MLP, _mlp_batch, M.mlp_train, M.mlp_eval),
    ("cnn", TINY_CNN, _cnn_batch, M.cnn_train, M.cnn_eval),
])
def test_classifier_train_eval(family, cfg, batch_fn, train_fn, eval_fn):
    spec = (M.mlp_spec if family == "mlp" else M.cnn_spec)(cfg)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    x, y = batch_fn(cfg)
    loss, grads = train_fn(cfg)(flat, x, y)
    assert np.isfinite(float(loss))
    assert grads.shape == flat.shape
    # initial loss ~ log(classes); He-init at tiny widths is noisy, so the
    # band is generous -- the point is "not wildly off uniform".
    assert abs(float(loss) - np.log(cfg.classes)) < 2.0
    l2, correct = eval_fn(cfg)(flat, x, y)
    assert 0 <= float(correct) <= cfg.batch
    # descend
    l3, _ = train_fn(cfg)(flat - 0.5 * grads, x, y)
    assert float(l3) < float(loss)


def test_mlp_finite_difference_gradcheck():
    cfg = M.MLPConfig(in_dim=6, hidden=(5,), classes=3, batch=3)
    spec = M.mlp_spec(cfg)
    step = M.mlp_train(cfg)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    x, y = _mlp_batch(cfg)
    loss, grads = step(flat, x, y)
    rng = np.random.RandomState(0)
    for idx in rng.choice(spec.raw_len, size=8, replace=False):
        e = np.zeros(spec.flat_len, np.float32)
        eps = 1e-3
        e[idx] = eps
        lp, _ = step(flat + e, x, y)
        lm, _ = step(flat - e, x, y)
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - float(grads[idx])) < 5e-3, idx


# ---------------------------------------------------------------- Quadratic

def test_quad_gradient_exact():
    cfg = TINY_QUAD
    spec = M.quad_spec(cfg)
    step = M.quad_train(cfg)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    center = jnp.zeros(cfg.dim)
    noise = jnp.zeros(cfg.dim)
    loss, grads = step(flat, center, noise)
    lam = np.logspace(0, np.log10(cfg.cond), cfg.dim)
    x = np.asarray(flat[:cfg.dim])
    np.testing.assert_allclose(float(loss),
                               0.5 * np.sum(lam * x * x) / cfg.dim,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(grads[:cfg.dim]),
                               lam * x / cfg.dim, rtol=1e-5, atol=1e-7)


def test_quad_noise_added_to_grad():
    cfg = TINY_QUAD
    spec = M.quad_spec(cfg)
    step = M.quad_train(cfg)
    flat = spec.init_flat(jax.random.PRNGKey(0))
    center = jnp.zeros(cfg.dim)
    noise = jnp.ones(cfg.dim)
    _, g0 = step(flat, center, jnp.zeros(cfg.dim))
    _, g1 = step(flat, center, noise)
    np.testing.assert_allclose(np.asarray(g1[:cfg.dim] - g0[:cfg.dim]),
                               1.0, rtol=1e-6)


def test_quad_minimum_at_center():
    cfg = TINY_QUAD
    spec = M.quad_spec(cfg)
    ev = M.quad_eval(cfg)
    center = jax.random.normal(jax.random.PRNGKey(4), (cfg.dim,))
    flat = jnp.zeros(spec.flat_len).at[:cfg.dim].set(center)
    loss, gnorm = ev(flat, center, jnp.zeros(cfg.dim))
    assert float(loss) < 1e-10
    assert float(gnorm) < 1e-12


# ---------------------------------------------------------------- Presets

def test_all_presets_have_specs():
    for name in presets.PRESETS:
        spec = presets.spec_for(name)
        assert spec.flat_len > 0
        assert spec.flat_len % params.ALIGN == 0


def test_preset_param_counts_documented():
    """Sanity-pin the rough parameter counts DESIGN.md quotes."""
    approx = {
        "cifar-mlp": 1.6e6,
        "imagenet-mlp": 4.3e6,
        "wmt-lm": 2.2e6,
        "lm-tiny": 0.3e6,
    }
    for name, want in approx.items():
        got = presets.spec_for(name).raw_len
        assert 0.4 * want < got < 2.5 * want, (name, got, want)
