//! Paper-faithfulness pass: the §4 headline claim, seed-swept.
//!
//! SlowMo's central empirical claim is that adding slow momentum on top
//! of a communication-efficient base improves optimization at an equal
//! step budget (Table 1 / Fig. 2). On the heterogeneous quad workload —
//! worker objectives offset from a shared optimum, evaluated against the
//! *global* objective — the ordering the paper reports must hold for
//! every seed, strictly:
//!
//!   final loss(base + slowmo:β≥0.5)  <  final loss(base + avg)
//!                                    <  final loss(bare base)
//!
//! where `avg` (= `slowmo:0`) is periodic parameter averaging (Local
//! SGD) and the bare base never communicates at all. Seeds sweep through
//! [`slowmo::testkit::forall_seeded`], so a failure report prints the
//! offending seed (reproduce by asserting that exact seed locally; the
//! sweep itself re-rolls with `SLOWMO_TEST_SEED`).

use slowmo::algorithms::AlgoSel;
use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::testkit::{default_cases, forall_seeded, test_seed, UsizeIn};
use slowmo::trainer::Schedule;

fn session() -> Option<Session> {
    match Session::native_only() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: no artifacts");
            None
        }
    }
}

/// One quad run at `seed`: Local base, m=8, equal step budget, final
/// validation loss against the global objective.
fn final_loss(s: &Session, seed: u64, slowmo: Option<SlowMoCfg>) -> f64 {
    let r = s
        .train("quad")
        .algo_sel(AlgoSel::with_inner(
            "local",
            InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 },
        ))
        .workers(8)
        .steps(384)
        .seed(seed)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .run()
        .unwrap();
    assert!(r.final_eval_loss.is_finite(), "seed {seed}: non-finite loss");
    r.final_eval_loss
}

#[test]
fn slowmo_beats_avg_beats_bare_base_on_every_seed() {
    let Some(s) = session() else { return };
    let tau = 16;
    // Each case is three full runs; cap the sweep so the suite stays
    // CI-sized (SLOWMO_PROP_CASES still scales it down, and the seed
    // space re-rolls with SLOWMO_TEST_SEED).
    let cases = default_cases().min(8);
    forall_seeded(
        "slowmo < avg < bare (final global loss, equal steps)",
        &UsizeIn(0, 1_000_000),
        test_seed(),
        cases,
        |&seed| {
            let seed = seed as u64;
            let bare = final_loss(&s, seed, None);
            let avg = final_loss(
                &s,
                seed,
                Some(SlowMoCfg::new(1.0, 0.0, tau)
                    .with_buffers(BufferStrategy::Maintain)),
            );
            let slow = final_loss(
                &s,
                seed,
                Some(SlowMoCfg::new(1.0, 0.6, tau)
                    .with_buffers(BufferStrategy::Maintain)),
            );
            // Print the cell so a failing seed report carries context.
            if !(slow < avg && avg < bare) {
                eprintln!(
                    "seed {seed}: slowmo {slow:.6} | avg {avg:.6} | \
                     bare {bare:.6}"
                );
            }
            slow < avg && avg < bare
        },
    );
}

#[test]
fn hierarchical_slowmo_keeps_the_headline_claim() {
    // The two-level variant (g=2 groups) must preserve the paper's
    // ordering against the same baselines — hierarchy trades bytes, not
    // the optimization win.
    let Some(s) = session() else { return };
    let tau = 16;
    let seed = 7;
    let bare = final_loss(&s, seed, None);
    let avg = final_loss(
        &s,
        seed,
        Some(SlowMoCfg::new(1.0, 0.0, tau)
            .with_buffers(BufferStrategy::Maintain)),
    );
    let hier = s
        .train("quad")
        .algo_sel(AlgoSel::with_inner(
            "local",
            InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 },
        ))
        .workers(8)
        .steps(384)
        .seed(seed)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.6, tau)
            .with_buffers(BufferStrategy::Maintain))
        .groups("2")
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .run()
        .unwrap();
    assert!(
        hier.final_eval_loss < avg && avg < bare,
        "hier {} | avg {avg} | bare {bare}",
        hier.final_eval_loss
    );
}
