//! End-to-end trainer integration over the real PJRT artifacts: tiny
//! budgets, every model family, PJRT kernels, gossip + SlowMo combined.

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::runtime::{artifacts_dir, Engine, Manifest};
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::{train, AlgoSpec, Schedule, TrainCfg};
use std::sync::Arc;

fn setup() -> Option<(Manifest, Arc<Engine>)> {
    let dir = artifacts_dir();
    let Ok(m) = Manifest::load(&dir) else {
        eprintln!("SKIP: no artifacts at {dir}");
        return None;
    };
    Some((m, Engine::cpu(&dir).unwrap()))
}

fn base_cfg(preset: &str, algo: AlgoSpec, steps: u64) -> TrainCfg {
    TrainCfg {
        preset: preset.into(),
        m: 2,
        steps,
        seed: 0,
        algo,
        slowmo: None,
        sched: Schedule::Const(0.05),
        heterogeneity: 0.5,
        eval_every: 0,
        eval_batches: 2,
        force_pjrt: true,
        native_kernels: false,
        cost: CostModel::ethernet_10g(),
        compute_time_s: 0.0,
        record_gradnorm: false,
    }
}

#[test]
fn mlp_sgp_slowmo_descends_via_pjrt() {
    let Some((m, e)) = setup() else { return };
    let mut cfg = base_cfg(
        "cifar-mlp",
        AlgoSpec::Sgp(InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 }),
        24,
    );
    cfg.slowmo = Some(SlowMoCfg::new(1.0, 0.7, 6));
    cfg.sched = Schedule::Const(0.08);
    let r = train(&cfg, &m, Some(&e)).unwrap();
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
    assert!(r.bytes_sent > 0);
}

#[test]
fn cnn_local_adam_descends() {
    let Some((m, e)) = setup() else { return };
    let mut cfg = base_cfg(
        "cifar-cnn",
        AlgoSpec::Local(InnerOpt::adam_default()),
        16,
    );
    cfg.slowmo = Some(
        SlowMoCfg::new(1.0, 0.5, 4).with_buffers(BufferStrategy::Maintain),
    );
    cfg.sched = Schedule::Const(2e-3);
    let r = train(&cfg, &m, Some(&e)).unwrap();
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn lm_eval_metric_in_range() {
    let Some((m, e)) = setup() else { return };
    let mut cfg = base_cfg(
        "lm-tiny",
        AlgoSpec::Local(InnerOpt::adam_default()),
        12,
    );
    cfg.sched = Schedule::Const(1e-3);
    cfg.eval_every = 6;
    let r = train(&cfg, &m, Some(&e)).unwrap();
    assert!(r.eval_curve.len() >= 2);
    for p in &r.eval_curve {
        assert!(p.loss_mean.is_finite());
        assert!((0.0..=1.0).contains(&p.metric_mean),
                "token acc {}", p.metric_mean);
        assert!(p.loss_min <= p.loss_mean && p.loss_mean <= p.loss_max);
    }
}

#[test]
fn pallas_attention_artifact_trains_and_matches_dense_variant() {
    // lm-tiny vs lm-tiny-pallas share init + data; one train step must
    // produce near-identical losses (the Pallas attention kernel is
    // numerically equivalent to the dense path).
    let Some((m, e)) = setup() else { return };
    let mut dense = base_cfg(
        "lm-tiny",
        AlgoSpec::Local(InnerOpt::adam_default()),
        4,
    );
    dense.m = 1;
    dense.sched = Schedule::Const(1e-3);
    let mut pallas = dense.clone();
    pallas.preset = "lm-tiny-pallas".into();
    let rd = train(&dense, &m, Some(&e)).unwrap();
    let rp = train(&pallas, &m, Some(&e)).unwrap();
    for (a, b) in rd.train_curve.iter().zip(&rp.train_curve) {
        assert!((a.1 - b.1).abs() < 2e-3 * (a.1.abs() + 1.0),
                "dense {a:?} vs pallas {b:?}");
    }
}

#[test]
fn pjrt_and_native_optimizer_kernels_agree_end_to_end() {
    let Some((m, e)) = setup() else { return };
    let mk = |native: bool| {
        let mut cfg = base_cfg(
            "cifar-cnn",
            AlgoSpec::Local(InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 }),
            12,
        );
        cfg.slowmo = Some(SlowMoCfg::new(1.0, 0.6, 4));
        cfg.native_kernels = native;
        cfg.sched = Schedule::Const(0.05);
        cfg
    };
    let a = train(&mk(false), &m, Some(&e)).unwrap();
    let b = train(&mk(true), &m, Some(&e)).unwrap();
    for (x, y) in a.train_curve.iter().zip(&b.train_curve) {
        assert!(
            (x.1 - y.1).abs() < 1e-4 * (y.1.abs() + 1.0),
            "pjrt {x:?} vs native {y:?}"
        );
    }
}

#[test]
fn quad_pjrt_matches_native_model_path() {
    let Some((m, e)) = setup() else { return };
    let mk = |force_pjrt: bool| {
        let mut cfg = base_cfg(
            "quad",
            AlgoSpec::Local(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }),
            16,
        );
        cfg.force_pjrt = force_pjrt;
        cfg.native_kernels = true;
        cfg.sched = Schedule::Const(0.3);
        cfg.heterogeneity = 1.0;
        cfg
    };
    let a = train(&mk(true), &m, Some(&e)).unwrap();
    let b = train(&mk(false), &m, Some(&e)).unwrap();
    for (x, y) in a.train_curve.iter().zip(&b.train_curve) {
        assert!(
            (x.1 - y.1).abs() < 1e-4 * (y.1.abs() + 1.0),
            "pjrt {x:?} vs native {y:?}"
        );
    }
}

#[test]
fn eval_every_produces_expected_checkpoints() {
    let Some((m, e)) = setup() else { return };
    let mut cfg = base_cfg(
        "quad",
        AlgoSpec::Local(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }),
        20,
    );
    cfg.force_pjrt = false;
    cfg.native_kernels = true;
    cfg.eval_every = 8;
    let r = train(&cfg, &m, Some(&e)).unwrap();
    let steps: Vec<u64> = r.eval_curve.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![8, 16, 20]);
}
