//! End-to-end trainer integration over the real PJRT artifacts: tiny
//! budgets, every model family, PJRT kernels, gossip + SlowMo combined.
//! All runs go through the session/builder API.

use slowmo::algorithms::AlgoSel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::{Session, TrainBuilder};
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::Schedule;

fn setup() -> Option<Session> {
    match Session::open() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#})");
            None
        }
    }
}

fn base<'s>(
    s: &'s Session,
    preset: &str,
    algo: AlgoSel,
    steps: u64,
) -> TrainBuilder<'s> {
    s.train(preset)
        .algo_sel(algo)
        .workers(2)
        .steps(steps)
        .schedule(Schedule::Const(0.05))
        .eval_batches(2)
        .force_pjrt(true)
        .pjrt_kernels()
}

#[test]
fn mlp_sgp_slowmo_descends_via_pjrt() {
    let Some(s) = setup() else { return };
    let r = base(
        &s,
        "cifar-mlp",
        AlgoSel::with_inner(
            "sgp",
            InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 },
        ),
        24,
    )
    .slowmo(0.7, 6)
    .schedule(Schedule::Const(0.08))
    .run()
    .unwrap();
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
    assert!(r.bytes_sent > 0);
}

#[test]
fn cnn_local_adam_descends() {
    let Some(s) = setup() else { return };
    let r = base(
        &s,
        "cifar-cnn",
        AlgoSel::with_inner("local", InnerOpt::adam_default()),
        16,
    )
    .slowmo_cfg(
        SlowMoCfg::new(1.0, 0.5, 4).with_buffers(BufferStrategy::Maintain),
    )
    .schedule(Schedule::Const(2e-3))
    .run()
    .unwrap();
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn lm_eval_metric_in_range() {
    let Some(s) = setup() else { return };
    let r = base(
        &s,
        "lm-tiny",
        AlgoSel::with_inner("local", InnerOpt::adam_default()),
        12,
    )
    .schedule(Schedule::Const(1e-3))
    .eval_every(6)
    .run()
    .unwrap();
    assert!(r.eval_curve.len() >= 2);
    for p in &r.eval_curve {
        assert!(p.loss_mean.is_finite());
        assert!((0.0..=1.0).contains(&p.metric_mean),
                "token acc {}", p.metric_mean);
        assert!(p.loss_min <= p.loss_mean && p.loss_mean <= p.loss_max);
    }
}

#[test]
fn pallas_attention_artifact_trains_and_matches_dense_variant() {
    // lm-tiny vs lm-tiny-pallas share init + data; one train step must
    // produce near-identical losses (the Pallas attention kernel is
    // numerically equivalent to the dense path).
    let Some(s) = setup() else { return };
    let mk = |preset: &str| {
        base(
            &s,
            preset,
            AlgoSel::with_inner("local", InnerOpt::adam_default()),
            4,
        )
        .workers(1)
        .schedule(Schedule::Const(1e-3))
    };
    let rd = mk("lm-tiny").run().unwrap();
    let rp = mk("lm-tiny-pallas").run().unwrap();
    for (a, b) in rd.train_curve.iter().zip(&rp.train_curve) {
        assert!((a.1 - b.1).abs() < 2e-3 * (a.1.abs() + 1.0),
                "dense {a:?} vs pallas {b:?}");
    }
}

#[test]
fn pjrt_and_native_optimizer_kernels_agree_end_to_end() {
    let Some(s) = setup() else { return };
    let mk = |native: bool| {
        base(
            &s,
            "cifar-cnn",
            AlgoSel::with_inner(
                "local",
                InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 },
            ),
            12,
        )
        .slowmo(0.6, 4)
        .native_kernels(native)
        .schedule(Schedule::Const(0.05))
    };
    let a = mk(false).run().unwrap();
    let b = mk(true).run().unwrap();
    for (x, y) in a.train_curve.iter().zip(&b.train_curve) {
        assert!(
            (x.1 - y.1).abs() < 1e-4 * (y.1.abs() + 1.0),
            "pjrt {x:?} vs native {y:?}"
        );
    }
}

#[test]
fn quad_pjrt_matches_native_model_path() {
    let Some(s) = setup() else { return };
    let mk = |force_pjrt: bool| {
        base(
            &s,
            "quad",
            AlgoSel::with_inner(
                "local",
                InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
            ),
            16,
        )
        .force_pjrt(force_pjrt)
        .native_kernels(true)
        .schedule(Schedule::Const(0.3))
        .heterogeneity(1.0)
    };
    let a = mk(true).run().unwrap();
    let b = mk(false).run().unwrap();
    for (x, y) in a.train_curve.iter().zip(&b.train_curve) {
        assert!(
            (x.1 - y.1).abs() < 1e-4 * (y.1.abs() + 1.0),
            "pjrt {x:?} vs native {y:?}"
        );
    }
}

#[test]
fn eval_every_produces_expected_checkpoints() {
    let Some(s) = setup() else { return };
    let r = base(
        &s,
        "quad",
        AlgoSel::with_inner(
            "local",
            InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
        ),
        20,
    )
    .force_pjrt(false)
    .native_kernels(true)
    .eval_every(8)
    .run()
    .unwrap();
    let steps: Vec<u64> = r.eval_curve.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![8, 16, 20]);
}
