//! Steady-state allocation gate: after a warmup pass, one inner step of
//! the hot path (`local` / `ar` × `none` / `ef:topk` / `demo`) makes
//! ZERO heap allocations. A counting `GlobalAlloc` wraps the system
//! allocator; every cell runs its workers between two barriers and the
//! global alloc counter must not move across the measured window.
//!
//! Design notes:
//! - One `#[global_allocator]` per test binary, and the counter is
//!   process-global — so this file holds a SINGLE `#[test]` that walks
//!   all cells serially. Parallel libtest threads would cross-contaminate
//!   the count.
//! - `ar` cells run on the `threaded` fabric: the sim backend's mpsc
//!   mailboxes allocate a node per send by design, while the threaded
//!   per-link `VecDeque`s retain capacity. The pools' contract is the
//!   same on both backends; the gate pins the backend that is supposed
//!   to be allocation-free.
//! - Warmup is generous (32 steps): the FIFO pools rotate buffers
//!   through every role, so each buffer must serve the largest role once
//!   before the steady state is reached.
//! - The gradient buffer is precomputed and reused — the trainer's
//!   `train_step` owns gradient allocation; this gate pins the
//!   algorithm step path itself (codec encode/decode, collectives,
//!   inner optimizer, fabric routing).

use slowmo::algorithms::{AllReduce, BaseAlgorithm, Ctx, Local, WorkerState};
use slowmo::compress::{CompressRegistry, CompressState, Compressor};
use slowmo::exec::{run_workers, Barrier, ExecMode};
use slowmo::net::{CostModel, Fabric};
use slowmo::optim::kernels::{InnerOpt, Kernels};
use slowmo::util::Scratch;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Counts every allocation event (alloc / alloc_zeroed / realloc) from
/// any thread; frees are not counted — the gate is about *acquiring*
/// heap memory in the steady state.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::SeqCst)
}

const M: usize = 4;
const D: usize = 4096;
const WARMUP: u64 = 32;
const MEASURE: u64 = 8;

/// Run one cell: warm every worker up, barrier, snapshot the global
/// counter, run `MEASURE` lockstep steps on all workers, barrier, and
/// return how many allocation events the whole fleet produced.
fn steady_state_allocs(
    algo: &dyn BaseAlgorithm,
    codec: Option<&dyn Compressor>,
) -> u64 {
    let fabric = Fabric::with_mode(M, CostModel::free(), ExecMode::Threaded);
    let kernels = Kernels::Native;
    let barrier = Barrier::new(M);
    let snap = AtomicU64::new(0);
    let deltas = run_workers(M, |w| {
        let init: Vec<f32> =
            (0..D).map(|i| ((i + w) as f32 * 0.01).sin()).collect();
        let mut state = WorkerState::new(&init, algo.inner());
        state.comp = CompressState::new(7, w as u64);
        let mut ctx = Ctx {
            worker: w,
            m: M,
            fabric: &fabric,
            kernels: &kernels,
            compress: codec,
            scope: None,
            clock: 0.0,
            scratch: Scratch::new(),
        };
        let g: Vec<f32> =
            (0..D).map(|i| ((i * 7 + w) as f32 * 0.001).cos()).collect();
        let mut k = 0u64;
        for _ in 0..WARMUP {
            algo.step(&mut ctx, &mut state, &g, 0.05, k).unwrap();
            k += 1;
        }
        barrier.wait();
        if w == 0 {
            snap.store(alloc_events(), Ordering::SeqCst);
        }
        barrier.wait();
        for _ in 0..MEASURE {
            algo.step(&mut ctx, &mut state, &g, 0.05, k).unwrap();
            k += 1;
        }
        barrier.wait();
        if w == 0 {
            alloc_events() - snap.load(Ordering::SeqCst)
        } else {
            0
        }
    });
    deltas[0]
}

#[test]
fn steady_state_inner_step_is_allocation_free() {
    let reg = CompressRegistry::builtin();
    let codecs: Vec<(&str, Option<Arc<dyn Compressor>>)> = vec![
        ("none", None),
        ("ef:topk:0.25",
         Some(reg.build(&reg.parse("ef:topk:0.25").unwrap()).unwrap())),
        ("demo:0.25,64",
         Some(reg.build(&reg.parse("demo:0.25,64").unwrap()).unwrap())),
    ];
    let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 };
    let algos: Vec<(&str, Box<dyn BaseAlgorithm>)> = vec![
        ("local", Box::new(Local::new(inner))),
        ("ar", Box::new(AllReduce::new(inner))),
    ];
    for (aname, algo) in &algos {
        for (cname, codec) in &codecs {
            let delta =
                steady_state_allocs(algo.as_ref(), codec.as_deref());
            assert_eq!(
                delta, 0,
                "[alloc-gate] {aname} x {cname}: {delta} heap \
                 allocation event(s) across {MEASURE} steady-state \
                 steps on {M} workers (d={D}) — the hot path must not \
                 touch the allocator after warmup"
            );
        }
    }
}
