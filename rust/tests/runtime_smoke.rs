//! Integration: PJRT engine executes the AOT artifacts end to end.
//!
//! Requires `make artifacts` to have run (skips otherwise, with a loud
//! message, so `cargo test` before artifact export doesn't hard-fail).

use slowmo::optim;
use slowmo::runtime::engine::Arg;
use slowmo::runtime::{artifacts_dir, Engine, Manifest};
use slowmo::util::allclose;

fn setup() -> Option<(Manifest, std::sync::Arc<Engine>)> {
    let dir = artifacts_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!("SKIP: no artifacts at {dir} (run `make artifacts`)");
        return None;
    };
    let engine = Engine::cpu(&dir).expect("pjrt cpu client");
    Some((manifest, engine))
}

#[test]
fn quad_train_executes_and_matches_closed_form() {
    let Some((m, eng)) = setup() else { return };
    let p = m.preset("quad").expect("quad preset");
    let exe = eng.load(&p.train).expect("compile quad.train");
    let d = p.flat_len;
    let dim = match p.data {
        slowmo::runtime::DataDesc::Quad { dim, .. } => dim,
        _ => panic!(),
    };
    let params = m.load_init(p).expect("init vector");
    let center = vec![0.0f32; dim];
    let noise = vec![0.0f32; dim];
    let out = exe
        .exec(&[
            Arg::F32(&params, &[d]),
            Arg::F32(&center, &[dim]),
            Arg::F32(&noise, &[dim]),
        ])
        .expect("execute");
    assert_eq!(out.len(), 2);
    let loss = out[0][0];
    let grads = &out[1];
    assert_eq!(grads.len(), d);
    // Closed form: loss = 0.5/dim * sum lam_i x_i^2, lam log-spaced 1..cond.
    let mut want_loss = 0.0f64;
    for i in 0..dim {
        let lam = 10f64.powf(2.0 * i as f64 / (dim - 1) as f64);
        let x = params[i] as f64;
        want_loss += 0.5 * lam * x * x / dim as f64;
        let want_g = lam * x / dim as f64;
        assert!(
            (grads[i] as f64 - want_g).abs() < 1e-4 * want_g.abs() + 1e-6,
            "grad[{i}]"
        );
    }
    assert!(
        (loss as f64 - want_loss).abs() < 1e-3 * want_loss,
        "loss {loss} vs {want_loss}"
    );
}

#[test]
fn optimizer_artifacts_match_native_mirrors() {
    let Some((m, eng)) = setup() else { return };
    let d = 4096; // quad preset's flat_len
    let opt = m.optim_for(d).expect("optim graphs for d=4096");

    let mut rng = slowmo::rng::Xoshiro256::seed_from(11);
    let mut x = vec![0.0f32; d];
    let mut h = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut h, 0.5);
    rng.fill_normal(&mut g, 1.0);
    let sc = |v: f32| vec![v];

    // nesterov
    let exe = eng.load(&opt.graphs["nesterov"]).unwrap();
    let out = exe
        .exec(&[
            Arg::F32(&x, &[d]),
            Arg::F32(&h, &[d]),
            Arg::F32(&g, &[d]),
            Arg::F32(&sc(0.1), &[1]),
            Arg::F32(&sc(0.9), &[1]),
            Arg::F32(&sc(1e-4), &[1]),
        ])
        .unwrap();
    let mut xn = x.clone();
    let mut hn = h.clone();
    optim::nesterov_step(&mut xn, &mut hn, &g, 0.1, 0.9, 1e-4);
    assert!(allclose(&out[0], &xn, 1e-5, 1e-6), "nesterov x");
    assert!(allclose(&out[1], &hn, 1e-5, 1e-6), "nesterov h");

    // adam
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.5);
    for val in v.iter_mut() {
        *val = val.abs();
    }
    let exe = eng.load(&opt.graphs["adam"]).unwrap();
    let out = exe
        .exec(&[
            Arg::F32(&x, &[d]),
            Arg::F32(&h, &[d]),
            Arg::F32(&v, &[d]),
            Arg::F32(&g, &[d]),
            Arg::F32(&sc(1e-3), &[1]),
            Arg::F32(&sc(0.9), &[1]),
            Arg::F32(&sc(0.98), &[1]),
            Arg::F32(&sc(1e-8), &[1]),
            Arg::F32(&sc(5.0), &[1]),
        ])
        .unwrap();
    let (mut xa, mut ha, mut va) = (x.clone(), h.clone(), v.clone());
    optim::adam_step(&mut xa, &mut ha, &mut va, &g, 1e-3, 0.9, 0.98, 1e-8,
                     5.0);
    assert!(allclose(&out[0], &xa, 1e-5, 1e-6), "adam x");
    assert!(allclose(&out[1], &ha, 1e-5, 1e-6), "adam h");
    assert!(allclose(&out[2], &va, 1e-5, 1e-6), "adam v");

    // slowmo
    let exe = eng.load(&opt.graphs["slowmo"]).unwrap();
    let out = exe
        .exec(&[
            Arg::F32(&x, &[d]),
            Arg::F32(&g, &[d]), // reuse g as "xt"
            Arg::F32(&h, &[d]), // reuse h as "u"
            Arg::F32(&sc(0.05), &[1]),
            Arg::F32(&sc(1.0), &[1]),
            Arg::F32(&sc(0.7), &[1]),
        ])
        .unwrap();
    let mut xs = x.clone();
    let mut us = h.clone();
    optim::slowmo_update(&mut xs, &g, &mut us, 0.05, 1.0, 0.7);
    assert!(allclose(&out[0], &xs, 1e-4, 1e-5), "slowmo x");
    assert!(allclose(&out[1], &us, 1e-4, 1e-4), "slowmo u");

    // axpy
    let exe = eng.load(&opt.graphs["axpy"]).unwrap();
    let out = exe
        .exec(&[
            Arg::F32(&x, &[d]),
            Arg::F32(&g, &[d]),
            Arg::F32(&sc(0.25), &[1]),
            Arg::F32(&sc(0.75), &[1]),
        ])
        .unwrap();
    let mut z = vec![0.0f32; d];
    optim::axpy_mix(&mut z, &x, &g, 0.25, 0.75);
    assert!(allclose(&out[0], &z, 1e-6, 1e-7), "axpy");
}

#[test]
fn lm_tiny_train_step_descends() {
    let Some((m, eng)) = setup() else { return };
    let p = m.preset("lm-tiny").expect("lm-tiny preset");
    let exe = eng.load(&p.train).unwrap();
    let d = p.flat_len;
    let (vocab, seq, batch) = match p.data {
        slowmo::runtime::DataDesc::Lm { vocab, seq_len, batch } => {
            (vocab, seq_len, batch)
        }
        _ => panic!(),
    };
    let mut params = m.load_init(p).unwrap();
    let mut rng = slowmo::rng::Xoshiro256::seed_from(3);
    let tokens: Vec<i32> = (0..batch * seq)
        .map(|_| rng.below(vocab as u64) as i32)
        .collect();
    let targets = tokens.clone();
    let shape = [batch, seq];
    let run = |params: &[f32]| {
        let out = exe
            .exec(&[
                Arg::F32(params, &[d]),
                Arg::I32(&tokens, &shape),
                Arg::I32(&targets, &shape),
            ])
            .unwrap();
        (out[0][0], out[1].clone())
    };
    let (loss0, grads) = run(&params);
    assert!(loss0.is_finite());
    // Initial loss near log(vocab) = log(256) ≈ 5.55.
    assert!((loss0 - (vocab as f32).ln()).abs() < 1.0, "loss0 {loss0}");
    for (p, g) in params.iter_mut().zip(&grads) {
        *p -= 0.5 * g;
    }
    let (loss1, _) = run(&params);
    assert!(loss1 < loss0, "{loss1} !< {loss0}");
}

#[test]
fn engine_caches_compiled_executables() {
    let Some((m, eng)) = setup() else { return };
    let p = m.preset("quad").unwrap();
    let before = eng.cached_count();
    let _a = eng.load(&p.eval).unwrap();
    let _b = eng.load(&p.eval).unwrap();
    assert_eq!(eng.cached_count(), before + 1);
}

#[test]
fn engine_rejects_bad_args() {
    let Some((m, eng)) = setup() else { return };
    let p = m.preset("quad").unwrap();
    let exe = eng.load(&p.train).unwrap();
    // Wrong arity.
    assert!(exe.exec(&[]).is_err());
    // Wrong element count.
    let tiny = vec![0.0f32; 3];
    assert!(exe
        .exec(&[
            Arg::F32(&tiny, &[3]),
            Arg::F32(&tiny, &[3]),
            Arg::F32(&tiny, &[3])
        ])
        .is_err());
}

#[test]
fn concurrent_execution_from_worker_threads() {
    let Some((m, eng)) = setup() else { return };
    let p = m.preset("quad").unwrap();
    let exe = eng.load(&p.eval).unwrap();
    let d = p.flat_len;
    let dim = 4096;
    let params = m.load_init(p).unwrap();
    let zeros = vec![0.0f32; dim];
    let results = slowmo::exec::run_workers(4, |_| {
        let out = exe
            .exec(&[
                Arg::F32(&params, &[d]),
                Arg::F32(&zeros, &[dim]),
                Arg::F32(&zeros, &[dim]),
            ])
            .unwrap();
        out[0][0]
    });
    for r in &results[1..] {
        assert_eq!(*r, results[0]);
    }
}
