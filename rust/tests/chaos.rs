//! Chaos-fabric integration tests: determinism of seeded degradation,
//! elastic membership at outer boundaries (fail + rejoin without
//! deadlock, exact survivor averages), and push-sum robustness on the
//! real threaded fabric under chaos delays.
//!
//! The chaos seed threads through `testkit::chaos_seed()`
//! (SLOWMO_CHAOS_SEED) so the whole suite re-rolls with one env var.

use slowmo::algorithms::{BaseAlgorithm, Ctx, Local, Sgp, WorkerState};
use slowmo::compress::{site, Demo, ErrorFeedback, TopK};
use slowmo::exec::run_workers;
use slowmo::net::{ChaosCfg, ChaosPlan, CostModel, Fabric, FaultWindow};
use slowmo::optim::kernels::{InnerOpt, Kernels};
use slowmo::session::Session;
use slowmo::slowmo::{
    outer_update, outer_update_c, outer_update_g, OuterRegistry,
    OuterState, SlowMoCfg,
};
use slowmo::testkit::chaos_seed;
use slowmo::topology::{ExponentialGraph, Groups, TierTree};
use slowmo::trainer::{Schedule, TrainResult};
use std::sync::Arc;

fn sgd() -> InnerOpt {
    InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }
}

fn degraded() -> ChaosCfg {
    ChaosCfg {
        seed: chaos_seed(),
        delay_mean_s: 2e-3,
        delay_max_s: 20e-3,
        drop_prob: 0.1,
        reorder_window: 4,
        stragglers: vec![(1, 3.0)],
        ..ChaosCfg::default()
    }
}

// ------------------------------------------------- membership unit level

/// One boundary with worker 3 down: survivors get the exact mean over
/// survivors, the down worker is untouched, nobody deadlocks.
#[test]
fn outer_average_is_exact_over_survivors() {
    let m = 4;
    let d = 16;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 3,
                    fail_at: 0,
                    rejoin_at: 2,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    // alpha=1, beta=0: the boundary adopts the survivor average directly.
    let cfg = SlowMoCfg::new(1.0, 0.0, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let init = vec![1.0f32; d];
    let inputs: Vec<Vec<f32>> = (0..m)
        .map(|w| (0..d).map(|i| (w * d + i) as f32 * 0.01).collect())
        .collect();
    // Exact survivor mean, computed in f64.
    let want: Vec<f32> = (0..d)
        .map(|i| {
            ((0..3).map(|w| f64::from(inputs[w][i])).sum::<f64>() / 3.0)
                as f32
        })
        .collect();
    let out = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        st.x.copy_from_slice(&inputs[w]);
        let mut ou = OuterState::new(&init, &*rule);
        // Seed x0 with the survivor inputs' role: x0 stays `init`; with
        // alpha=1, beta=0 the update lands exactly on the average.
        outer_update(&cfg, &*rule, &algo, &fabric, &kernels, w, &mut st,
                     &mut ou, 0.1, 0.0, Some(&*plan))
            .unwrap();
        st
    });
    for (w, st) in out.iter().enumerate().take(3) {
        for (a, b) in st.x.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-6 + 1e-5 * b.abs(),
                "worker {w}: {a} vs {b}"
            );
        }
    }
    assert_eq!(out[3].x, inputs[3], "down worker must be untouched");
}

/// Fail at boundary 1, rejoin two boundaries later (boundary 3): the run
/// completes without deadlock and the rejoiner adopts the survivors'
/// outer state bit-for-bit.
#[test]
fn worker_rejoins_two_boundaries_later() {
    let m = 4;
    let d = 8;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 2,
                    fail_at: 1,
                    rejoin_at: 3,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.6, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let init = vec![2.0f32; d];
    let out = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        let mut ou = OuterState::new(&init, &*rule);
        for t in 0..4u64 {
            // Simulate divergent inner progress before each boundary.
            for (i, x) in st.x.iter_mut().enumerate() {
                *x -= 0.01 * (w as f32 + 1.0) * (t as f32 + 1.0)
                    + 0.001 * i as f32;
            }
            outer_update(&cfg, &*rule, &algo, &fabric, &kernels, w,
                         &mut st, &mut ou, 0.1, 0.0, Some(&*plan))
                .unwrap();
        }
        (st, ou)
    });
    for (_, ou) in &out {
        assert_eq!(ou.t, 4, "all workers advanced all boundaries");
    }
    // After the rejoin boundary (t=3) everyone is synchronized again.
    for (w, (st, ou)) in out.iter().enumerate().skip(1) {
        assert_eq!(st.x, out[0].0.x, "x diverged on worker {w}");
        assert_eq!(ou.x0, out[0].1.x0, "x0 diverged on worker {w}");
        assert_eq!(ou.u(), out[0].1.u(), "u diverged on worker {w}");
    }
}

// ------------------------------------- blocking-boundary time accounting

/// Regression (straggler amplification): a blocking outer boundary is a
/// barrier, so EVERY worker is charged the latest arrival stamp before
/// the collective — one slow worker stalls the whole ring. The
/// per-worker clocks must show that stall; previously each worker left
/// the boundary from its own arrival time, under-reporting every fast
/// worker's simulated wait.
#[test]
fn blocking_boundary_amplifies_straggler_stalls() {
    let m = 4;
    let d = 8;
    let fabric = Fabric::new(m, CostModel::free());
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.0, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let init = vec![1.0f32; d];
    // Worker 1 needs 4 compute-units per round, the rest 1; free links
    // isolate the barrier charge from transfer costs.
    let compute = [1.0f64, 4.0, 1.0, 1.0];
    let clocks = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        let mut ou = OuterState::new(&init, &*rule);
        let mut clock = 0.0;
        for _ in 0..3 {
            clock += compute[w];
            clock = outer_update(&cfg, &*rule, &algo, &fabric, &kernels,
                                 w, &mut st, &mut ou, 0.1, clock, None)
                .unwrap();
        }
        clock
    });
    // Quantified: every worker exits every boundary at the straggler's
    // stamp — 4.0 per round, 12.0 total, not its own 3.0.
    for (w, &c) in clocks.iter().enumerate() {
        assert_eq!(c, 12.0, "worker {w} exited at {c}, want 12.0");
    }
}

/// End-to-end: a chaos straggler moves simulated time by exactly its
/// extra compute (the barrier re-syncs everyone, collective charges
/// cancel) and never changes the math.
#[test]
fn straggler_scales_sim_time_without_touching_math() {
    let Some(s) = session() else { return };
    let run = |factor: f64| -> TrainResult {
        let chaos = (factor > 1.0).then(|| ChaosCfg {
            seed: chaos_seed(),
            stragglers: vec![(1, factor)],
            ..ChaosCfg::default()
        });
        quad_chaos(&s, 32, chaos)
    };
    let calm = run(1.0);
    let slow = run(4.0);
    assert_eq!(calm.final_params, slow.final_params,
               "a straggler must move time, never math");
    // 32 steps at 1e-4 s, worker 1 slowed 4x: + 3 * 32 * 1e-4 s on the
    // critical path, and nothing else — the barrier charges every
    // boundary from the straggler's stamp in both runs.
    let extra = slow.sim_time - calm.sim_time;
    assert!((extra - 9.6e-3).abs() < 1e-9,
            "sim-time delta {extra} != straggler compute surplus");
}

// ------------------------------------------- push-sum on the real fabric

/// Blocking SGP on a chaos fabric (delays + reordering + drops): push-sum
/// mass stays m, consensus lands on the initial average, and the chaos
/// run's consensus matches the calm run's — delays never change the math.
#[test]
fn sgp_push_sum_tolerates_chaos_fabric() {
    let m = 4;
    let d = 4;
    let steps = 60;
    let run = |chaos: Option<Arc<ChaosPlan>>| -> Vec<WorkerState> {
        let cost = CostModel::free();
        let fabric = match chaos {
            Some(plan) => Fabric::with_chaos(m, cost, plan),
            None => Fabric::new(m, cost),
        };
        let topo = Arc::new(ExponentialGraph::new(m));
        let algo = Sgp::new(sgd(), topo);
        let kernels = Kernels::Native;
        run_workers(m, |w| {
            let init = vec![w as f32; d];
            let mut st = WorkerState::new(&init, algo.inner());
            let mut ctx = Ctx {
                worker: w,
                m,
                fabric: &fabric,
                kernels: &kernels,
                compress: None,
                scope: None,
                clock: 0.0,
                scratch: slowmo::util::Scratch::new(),
            };
            for k in 0..steps {
                algo.step(&mut ctx, &mut st, &[0.0; 4], 0.1, k).unwrap();
            }
            st
        })
    };
    let cost = CostModel::free();
    let plan =
        Arc::new(ChaosPlan::new(degraded(), m, &cost).unwrap());
    let calm = run(None);
    let chaotic = run(Some(Arc::clone(&plan)));
    let mass: f64 = chaotic.iter().map(|s| s.w).sum();
    assert!((mass - m as f64).abs() < 1e-9, "push-sum mass {mass}");
    // Zero gradients: gossip only mixes; consensus = mean of inits = 1.5.
    for (a, b) in calm.iter().zip(&chaotic) {
        assert_eq!(a.x, b.x, "chaos delays must not change the math");
        assert_eq!(a.w, b.w);
        for &z in &b.z {
            assert!((z - 1.5).abs() < 1e-3, "consensus z={z}");
        }
    }
    assert!(plan.retransmits() > 0, "drop_prob=0.1 must retransmit");
}

// ------------------------------------------------------------ end-to-end

fn session() -> Option<Session> {
    match Session::native_only() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: no artifacts");
            None
        }
    }
}

fn quad_chaos(
    s: &Session,
    steps: u64,
    chaos: Option<ChaosCfg>,
) -> TrainResult {
    s.train("quad")
        .algo("local")
        .inner(sgd())
        .workers(4)
        .steps(steps)
        .seed(11)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.6, 4))
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-4)
        .record_params(true)
        .chaos_opt(chaos)
        .run()
        .unwrap()
}

/// Acceptance: a fixed seed is fully deterministic — identical final
/// parameters, byte counts, retransmits, and simulated times.
#[test]
fn chaos_runs_are_bit_deterministic() {
    let Some(s) = session() else { return };
    let a = quad_chaos(&s, 32, Some(degraded()));
    let b = quad_chaos(&s, 32, Some(degraded()));
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.bytes_sent, b.bytes_sent);
    assert_eq!(a.retransmits, b.retransmits);
    assert_eq!(a.sim_time, b.sim_time);
    assert_eq!(a.train_curve, b.train_curve);
}

/// Acceptance: a worker failing mid-phase and rejoining two boundaries
/// later completes end-to-end without deadlock, deterministically.
#[test]
fn fault_and_rejoin_end_to_end() {
    let Some(s) = session() else { return };
    let mut cfg = degraded();
    cfg.faults = vec![FaultWindow { worker: 2, fail_at: 1, rejoin_at: 3 }];
    let a = quad_chaos(&s, 32, Some(cfg.clone()));
    let b = quad_chaos(&s, 32, Some(cfg));
    assert_eq!(a.steps_run, 32);
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.sim_time, b.sim_time);
    // The survivor-averaged trajectory differs from the calm run's.
    let calm = quad_chaos(&s, 32, None);
    assert_ne!(calm.final_params, a.final_params);
}

/// Acceptance: every registered outer rule — momentum-free, single- and
/// two-buffer state alike — survives the fail-and-rejoin path
/// deterministically (the rejoin wire format is state-shape-agnostic).
#[test]
fn fault_and_rejoin_every_outer_rule() {
    let Some(s) = session() else { return };
    for spec in ["slowmo:0.6", "avg", "lookahead:0.5", "nesterov:0.9",
                 "adam:0.9,0.95"] {
        let sel = s.outer_registry().parse(spec).unwrap();
        let mut chaos = degraded();
        chaos.faults =
            vec![FaultWindow { worker: 2, fail_at: 1, rejoin_at: 3 }];
        let run = || -> TrainResult {
            s.train("quad")
                .algo("local")
                .inner(sgd())
                .workers(4)
                .steps(32)
                .seed(11)
                .slowmo_cfg(SlowMoCfg::with_outer(sel.clone(), 4))
                .schedule(Schedule::Const(0.2))
                .heterogeneity(1.0)
                .eval_batches(1)
                .cost(CostModel::ethernet_10g())
                .compute_time(1e-4)
                .record_params(true)
                .chaos(chaos.clone())
                .run()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.steps_run, 32, "{spec}: run did not complete");
        assert_eq!(a.final_params, b.final_params,
                   "{spec}: non-deterministic");
        assert_eq!(a.sim_time, b.sim_time, "{spec}");
        assert_eq!(a.outer.as_deref(), Some(spec));
        // The survivor-averaged trajectory differs from the calm run's.
        let calm = s
            .train("quad")
            .algo("local")
            .inner(sgd())
            .workers(4)
            .steps(32)
            .seed(11)
            .slowmo_cfg(SlowMoCfg::with_outer(sel.clone(), 4))
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::ethernet_10g())
            .compute_time(1e-4)
            .record_params(true)
            .run()
            .unwrap();
        assert_ne!(calm.final_params, a.final_params, "{spec}");
    }
}

// ------------------------------------------ compression × elastic faults

fn ef_topk(frac: f32) -> ErrorFeedback {
    ErrorFeedback {
        inner: Arc::new(TopK { frac }),
    }
}

/// Elastic membership rescales error-feedback residuals by the
/// live-count ratio, exactly like outer-rule state.
#[test]
fn membership_change_rescales_ef_residuals() {
    let m = 2;
    let d = 4;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 1,
                    fail_at: 0,
                    rejoin_at: u64::MAX,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.0, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let codec = ef_topk(0.5);
    let init = vec![0.0f32; d];
    let mut st = WorkerState::new(&init, algo.inner());
    // Pre-existing residual mass from the m=2 regime. The survivor's
    // group is a singleton, so no transcode runs (nothing is on the
    // wire) — the membership change (2 -> 1 live) still halves the
    // residual, exactly like OuterOpt::scale_state.
    st.comp.set_residual(site::OUTER, vec![2.0; d]);
    let mut ou = OuterState::new(&init, &*rule);
    outer_update_c(&cfg, &*rule, &algo, &fabric, &kernels, 0, &mut st,
                   &mut ou, 1.0, 0.0, Some(&*plan), Some(&codec))
        .unwrap();
    assert_eq!(
        st.comp.residual_opt(site::OUTER).unwrap(),
        &vec![1.0; d],
        "residual must be halved by the 2 -> 1 membership change"
    );
}

/// Fail-and-rejoin with `ef:topk` active: the rejoin transfer round-trips
/// the leader's residual buffer bit-for-bit (appended to the rule state,
/// same state-shape-agnostic wire format), and the run deadlock-free
/// re-synchronizes x0 across all workers.
#[test]
fn rejoin_round_trips_ef_residuals_bitwise() {
    let m = 3;
    let d = 8;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 2,
                    fail_at: 0,
                    rejoin_at: 1,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.5, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let codec = ef_topk(0.25);
    let init = vec![1.0f32; d];
    let out = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        let mut ou = OuterState::new(&init, &*rule);
        for t in 0..2u64 {
            // Divergent inner progress before each boundary, so the
            // topk residuals are non-trivial.
            for (i, x) in st.x.iter_mut().enumerate() {
                *x -= 0.01 * (w as f32 + 1.0) * (t as f32 + 1.0)
                    + 0.003 * i as f32;
            }
            outer_update_c(&cfg, &*rule, &algo, &fabric, &kernels, w,
                           &mut st, &mut ou, 0.1, 0.0, Some(&*plan),
                           Some(&codec))
                .unwrap();
        }
        (st, ou)
    });
    for (_, ou) in &out {
        assert_eq!(ou.t, 2, "all workers advanced both boundaries");
    }
    // Post-rejoin: every worker holds the identical outer state.
    for (st, ou) in &out[1..] {
        assert_eq!(st.x, out[0].0.x);
        assert_eq!(ou.x0, out[0].1.x0);
    }
    // The rejoiner (worker 2) pulled the leader's (worker 0, lowest
    // contributor rank) OUTER residual, bit for bit. The other survivor
    // keeps its own, different residual.
    let leader = out[0].0.comp.residual_opt(site::OUTER).unwrap();
    assert!(leader.iter().any(|&v| v != 0.0), "test needs a residual");
    assert_eq!(out[2].0.comp.residual_opt(site::OUTER).unwrap(), leader);
    assert_ne!(out[1].0.comp.residual_opt(site::OUTER).unwrap(), leader);
}

/// The demo codec's *frequency* residuals are state the elastic
/// machinery owns just like ef's spatial ones: a membership change
/// rescales them by the live-count ratio (valid because the DCT is
/// linear — scaling coefficients scales the signal).
#[test]
fn membership_change_rescales_demo_frequency_residuals() {
    let m = 2;
    let d = 4;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 1,
                    fail_at: 0,
                    rejoin_at: u64::MAX,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.0, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let codec = Demo::new(0.5, 2);
    let init = vec![0.0f32; d];
    let mut st = WorkerState::new(&init, algo.inner());
    st.comp.set_residual(site::OUTER, vec![2.0; d]);
    let mut ou = OuterState::new(&init, &*rule);
    outer_update_c(&cfg, &*rule, &algo, &fabric, &kernels, 0, &mut st,
                   &mut ou, 1.0, 0.0, Some(&*plan), Some(&codec))
        .unwrap();
    assert_eq!(
        st.comp.residual_opt(site::OUTER).unwrap(),
        &vec![1.0; d],
        "frequency residual must be halved by the 2 -> 1 change"
    );
}

/// Fail-and-rejoin with `demo` active: the rejoin transfer round-trips
/// the leader's frequency-residual buffer bit-for-bit through the same
/// state-shape-agnostic wire format `ef` uses (`ef_bufs` = 1).
#[test]
fn rejoin_round_trips_demo_frequency_residuals_bitwise() {
    let m = 3;
    let d = 8;
    let cost = CostModel::free();
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 2,
                    fail_at: 0,
                    rejoin_at: 1,
                }],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.5, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let codec = Demo::new(0.25, 4);
    let init = vec![1.0f32; d];
    let out = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        let mut ou = OuterState::new(&init, &*rule);
        for t in 0..2u64 {
            // Divergent inner progress before each boundary. The
            // worker-dependent factor multiplies a *non-affine* shape:
            // an affine displacement would put all worker-dependence in
            // the transmitted DC coefficient and leave the dropped
            // (residual) coefficients identical across workers.
            for (i, x) in st.x.iter_mut().enumerate() {
                *x -= 0.01 * (w as f32 + 1.0) * (t as f32 + 1.0)
                    * (1.0 + 0.3 * (i as f32).sin())
                    + 0.003 * i as f32;
            }
            outer_update_c(&cfg, &*rule, &algo, &fabric, &kernels, w,
                           &mut st, &mut ou, 0.1, 0.0, Some(&*plan),
                           Some(&codec))
                .unwrap();
        }
        (st, ou)
    });
    for (_, ou) in &out {
        assert_eq!(ou.t, 2, "all workers advanced both boundaries");
    }
    for (st, ou) in &out[1..] {
        assert_eq!(st.x, out[0].0.x);
        assert_eq!(ou.x0, out[0].1.x0);
    }
    // The rejoiner (worker 2) pulled the leader's (worker 0) OUTER
    // frequency residual, bit for bit; the other survivor keeps its own,
    // different residual.
    let leader = out[0].0.comp.residual_opt(site::OUTER).unwrap();
    assert!(leader.iter().any(|&v| v != 0.0), "test needs a residual");
    assert_eq!(out[2].0.comp.residual_opt(site::OUTER).unwrap(), leader);
    assert_ne!(out[1].0.comp.residual_opt(site::OUTER).unwrap(), leader);
}

/// End-to-end acceptance: `--compress ef:topk --chaos fault=...` — the
/// run completes, stays bit-deterministic under a fixed seed, and sends
/// strictly fewer bytes than the uncompressed run.
#[test]
fn fault_and_rejoin_with_ef_topk_end_to_end() {
    let Some(s) = session() else { return };
    let run = |compress: Option<&str>| -> TrainResult {
        let mut chaos = degraded();
        chaos.faults =
            vec![FaultWindow { worker: 2, fail_at: 1, rejoin_at: 3 }];
        let mut b = s
            .train("quad")
            .algo("local")
            .inner(sgd())
            .workers(4)
            .steps(32)
            .seed(11)
            .slowmo_cfg(SlowMoCfg::new(1.0, 0.6, 4))
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::ethernet_10g())
            .compute_time(1e-4)
            .record_params(true)
            .chaos(chaos);
        if let Some(spec) = compress {
            b = b.compress(spec);
        }
        b.run().unwrap()
    };
    let a = run(Some("ef:topk:0.3"));
    let b = run(Some("ef:topk:0.3"));
    assert_eq!(a.steps_run, 32, "run did not complete");
    assert_eq!(a.final_params, b.final_params, "non-deterministic");
    assert_eq!(a.bytes_sent, b.bytes_sent);
    assert_eq!(a.sim_time, b.sim_time);
    assert_eq!(a.retransmits, b.retransmits);
    assert_eq!(a.compress.as_deref(), Some("ef:topk:0.3"));
    let raw = run(None);
    assert!(a.bytes_sent < raw.bytes_sent,
            "{} !< {}", a.bytes_sent, raw.bytes_sent);
    assert!(a.bytes_saved > 0);
    assert_eq!(raw.bytes_saved, 0);
}

/// Faults require SlowMo boundaries and a communication-free base.
#[test]
fn fault_injection_is_validated() {
    let Some(s) = session() else { return };
    let cfg = ChaosCfg {
        faults: vec![FaultWindow { worker: 1, fail_at: 0, rejoin_at: 2 }],
        ..ChaosCfg::default()
    };
    // No SlowMo: rejected.
    let err = s
        .train("quad")
        .algo("local")
        .inner(sgd())
        .workers(4)
        .steps(8)
        .schedule(Schedule::Const(0.1))
        .chaos(cfg.clone())
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("SlowMo"), "{err}");
    // Gossip base: rejected.
    let err = s
        .train("quad")
        .algo("sgp")
        .inner(sgd())
        .workers(4)
        .steps(8)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.0, 4))
        .schedule(Schedule::Const(0.1))
        .chaos(cfg)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("communication-free"), "{err}");
}

// ------------------------------------------- hierarchy × elastic faults

fn quad_hier_chaos(
    s: &Session,
    steps: u64,
    groups: Option<&str>,
    chaos: Option<ChaosCfg>,
) -> TrainResult {
    let mut b = s
        .train("quad")
        .algo("local")
        .inner(sgd())
        .workers(4)
        .steps(steps)
        .seed(11)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.6, 4))
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-4)
        .record_params(true)
        .chaos_opt(chaos);
    if let Some(spec) = groups {
        b = b.groups(spec);
    }
    b.run().unwrap()
}

/// Fail-and-rejoin composes with the two-level reduce: the run completes
/// without deadlock, is bit-deterministic, and one group (g=1) stays
/// bitwise identical to the flat elastic path — fault machinery
/// included.
#[test]
fn hier_fault_and_rejoin_end_to_end() {
    let Some(s) = session() else { return };
    let mut cfg = degraded();
    // Worker 3 (group {2,3} under g=2) fails and rejoins: its group-mate
    // 2 is the rejoin shipper over the fast link.
    cfg.faults = vec![FaultWindow { worker: 3, fail_at: 1, rejoin_at: 3 }];
    let a = quad_hier_chaos(&s, 32, Some("2"), Some(cfg.clone()));
    let b = quad_hier_chaos(&s, 32, Some("2"), Some(cfg.clone()));
    assert_eq!(a.steps_run, 32, "run did not complete");
    assert_eq!(a.final_params, b.final_params, "non-deterministic");
    assert_eq!(a.sim_time, b.sim_time);
    assert_eq!(a.bytes_inter, b.bytes_inter);
    assert!(a.algo.contains("+hier(g2)"), "{}", a.algo);
    // The survivor-weighted trajectory differs from the calm hier run's.
    let calm = quad_hier_chaos(&s, 32, Some("2"), None);
    assert_ne!(calm.final_params, a.final_params);
    // g=1 under the same fault plan is the flat elastic path, bitwise.
    let flat = quad_hier_chaos(&s, 32, None, Some(cfg.clone()));
    let g1 = quad_hier_chaos(&s, 32, Some("1"), Some(cfg));
    assert_eq!(g1.final_params, flat.final_params);
    assert_eq!(g1.sim_time, flat.sim_time);
    assert_eq!(g1.bytes_sent, flat.bytes_sent);
}

/// A whole group down: the boundary average weights the surviving
/// groups' live counts, and a rejoiner whose group has no live member
/// pulls its state from the globally lowest survivor instead.
#[test]
fn hier_whole_group_outage_falls_back_to_global_shipper() {
    let m = 4;
    let d = 6;
    let cost = CostModel::free();
    let tree =
        TierTree::from_groups(Arc::new(Groups::parse("0-1|2-3", m).unwrap()));
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![
                    FaultWindow { worker: 2, fail_at: 0, rejoin_at: 1 },
                    FaultWindow { worker: 3, fail_at: 0, rejoin_at: 2 },
                ],
                ..ChaosCfg::default()
            },
            m,
            &cost,
        )
        .unwrap(),
    );
    let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
    let algo = Local::new(sgd());
    let kernels = Kernels::Native;
    let cfg = SlowMoCfg::new(1.0, 0.5, 4);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let init = vec![1.0f32; d];
    let out = run_workers(m, |w| {
        let mut st = WorkerState::new(&init, algo.inner());
        let mut ou = OuterState::new(&init, &*rule);
        for t in 0..3u64 {
            for (i, x) in st.x.iter_mut().enumerate() {
                *x -= 0.01 * (w as f32 + 1.0) * (t as f32 + 1.0)
                    + 0.001 * i as f32;
            }
            outer_update_g(&cfg, &*rule, &algo, &fabric, &kernels, w,
                           &mut st, &mut ou, 0.1, 0.0, Some(&*plan),
                           Some(&tree), None)
                .unwrap();
        }
        (st, ou)
    });
    for (_, ou) in &out {
        assert_eq!(ou.t, 3, "all workers advanced all boundaries");
    }
    // Boundary 0: group {2,3} fully down (boundary average over group
    // {0,1} alone). Boundary 1: worker 2 rejoins — its group has no live
    // member, so worker 0 ships. Boundary 2: worker 3 rejoins from its
    // now-live group-mate 2. After boundary 2 everyone is synchronized.
    for (w, (st, ou)) in out.iter().enumerate().skip(1) {
        assert_eq!(st.x, out[0].0.x, "x diverged on worker {w}");
        assert_eq!(ou.x0, out[0].1.x0, "x0 diverged on worker {w}");
        assert_eq!(ou.u(), out[0].1.u(), "u diverged on worker {w}");
    }
}

/// tau_inner intra-group averages cannot combine with fault windows —
/// membership is only defined at outer boundaries.
#[test]
fn tau_inner_with_faults_is_rejected() {
    let Some(s) = session() else { return };
    let cfg = ChaosCfg {
        faults: vec![FaultWindow { worker: 1, fail_at: 0, rejoin_at: 2 }],
        ..ChaosCfg::default()
    };
    let err = s
        .train("quad")
        .algo("local")
        .inner(sgd())
        .workers(4)
        .steps(8)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.5, 4))
        .groups("2")
        .tau_inner(2)
        .schedule(Schedule::Const(0.1))
        .chaos(cfg)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("tau_inner"), "{err}");
}

/// Long soak for the CI chaos job: multiple overlapping-in-time fault
/// windows across a longer run, still deterministic and deadlock-free.
#[test]
#[ignore = "slow chaos soak — run via `cargo test -- --include-ignored`"]
fn chaos_soak_multiple_fault_windows() {
    let Some(s) = session() else { return };
    let mut cfg = degraded();
    cfg.faults = vec![
        FaultWindow { worker: 2, fail_at: 1, rejoin_at: 3 },
        FaultWindow { worker: 3, fail_at: 2, rejoin_at: 6 },
        FaultWindow { worker: 2, fail_at: 8, rejoin_at: 10 },
    ];
    let a = quad_chaos(&s, 256, Some(cfg.clone()));
    let b = quad_chaos(&s, 256, Some(cfg));
    assert_eq!(a.steps_run, 256);
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.sim_time, b.sim_time);
    // Local base never touches the gossip lane, so there is nothing to
    // retransmit — the collective chaos charge shows up in sim_time only.
    assert_eq!(a.retransmits, 0);
}

/// Hierarchy sweep for the CI chaos job: every registered outer rule ×
/// every partition shape of m=4 (flat anchor, 2 groups, unequal groups,
/// singletons), each under a degraded network with a fail-and-rejoin
/// window — deterministic, deadlock-free, and g=1 bitwise equal to the
/// flat elastic path per rule.
#[test]
#[ignore = "slow hierarchy/chaos sweep — run via `cargo test -- --include-ignored`"]
fn hier_chaos_sweep_every_rule_and_partition() {
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let run = |groups: Option<&str>| -> TrainResult {
            let mut chaos = degraded();
            chaos.faults =
                vec![FaultWindow { worker: 3, fail_at: 1, rejoin_at: 3 }];
            let mut b = s
                .train("quad")
                .algo("local")
                .inner(sgd())
                .workers(4)
                .steps(64)
                .seed(11)
                .slowmo_cfg(SlowMoCfg::with_outer(sel.clone(), 4))
                .schedule(Schedule::Const(0.2))
                .heterogeneity(1.0)
                .eval_batches(1)
                .cost(CostModel::ethernet_10g())
                .compute_time(1e-4)
                .record_params(true)
                .chaos(chaos);
            if let Some(spec) = groups {
                b = b.groups(spec);
            }
            b.run().unwrap()
        };
        let flat = run(None);
        for spec in ["1", "2", "0-0|1-3", "4"] {
            let a = run(Some(spec));
            let b = run(Some(spec));
            assert_eq!(a.steps_run, 64, "{key}/{spec}: incomplete");
            assert_eq!(a.final_params, b.final_params,
                       "{key}/{spec}: non-deterministic");
            assert_eq!(a.sim_time, b.sim_time, "{key}/{spec}");
            assert_eq!(a.bytes_inter, b.bytes_inter, "{key}/{spec}");
            if spec == "1" {
                assert_eq!(a.final_params, flat.final_params,
                           "{key}: g=1 must be the flat elastic path");
                assert_eq!(a.bytes_sent, flat.bytes_sent, "{key}");
            }
        }
    }
}
