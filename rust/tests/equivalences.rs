//! Framework-equivalence tests (paper §2): the special cases the SlowMo
//! framework must recover *exactly*, plus determinism guarantees. All run
//! on the native quad fast path (no PJRT needed) so they are fast and
//! bit-deterministic.

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::runtime::{artifacts_dir, Manifest};
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::{train, AlgoSpec, Schedule, TrainCfg, TrainResult};

fn manifest() -> Option<Manifest> {
    Manifest::load(&artifacts_dir()).ok()
}

fn quad_cfg(m: usize, steps: u64, algo: AlgoSpec,
            slowmo: Option<SlowMoCfg>) -> TrainCfg {
    TrainCfg {
        preset: "quad".into(),
        m,
        steps,
        seed: 11,
        algo,
        slowmo,
        sched: Schedule::Const(0.2),
        heterogeneity: 1.0,
        eval_every: 0,
        eval_batches: 1,
        force_pjrt: false,
        native_kernels: true,
        cost: CostModel::free(),
        compute_time_s: 1e-6,
        record_gradnorm: false,
    }
}

fn run(cfg: &TrainCfg) -> TrainResult {
    train(cfg, &manifest().unwrap(), None).unwrap()
}

fn sgd() -> InnerOpt {
    InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }
}

#[test]
fn runs_are_bit_deterministic() {
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = quad_cfg(4, 64, AlgoSpec::Local(sgd()),
                       Some(SlowMoCfg::new(1.0, 0.7, 8)));
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.train_curve, b.train_curve);
    assert_eq!(a.best_train_loss, b.best_train_loss);
}

#[test]
fn slowmo_tau1_beta0_equals_allreduce_sgd() {
    // Paper §2: base=SGD (no local momentum), τ=1, α=1, β=0 recovers
    // large mini-batch (AR) SGD. Parameter-averaging every step with
    // identical starting points == gradient-averaging every step.
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let a = run(&quad_cfg(
        4, 48, AlgoSpec::Local(sgd()),
        Some(SlowMoCfg::new(1.0, 0.0, 1)
            .with_buffers(BufferStrategy::Maintain)),
    ));
    let b = run(&quad_cfg(4, 48, AlgoSpec::AllReduce(sgd()), None));
    // The two runs window their train curves differently (τ=1 vs the
    // default 16), but over 48 steps both are means of the same per-step
    // loss sequence — compare the global means and the best losses.
    let gmean = |r: &TrainResult| {
        let xs: Vec<f64> = r.train_curve.iter().map(|&(_, l)| l).collect();
        slowmo::util::mean(&xs)
    };
    let (ma, mb) = (gmean(&a), gmean(&b));
    assert!((ma - mb).abs() < 1e-4 * mb.abs().max(1.0),
            "global means diverge: {ma} vs {mb}");
}

#[test]
fn slowmo_beta0_equals_local_sgd_baseline() {
    // SlowMo(α=1, β=0) over Local SGD == Local SGD with periodic
    // averaging (Alg. 4): adding the wrapper with β=0 must not change
    // anything vs the direct characterization.
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    // Direct Local SGD with period τ via DoubleAvg with only param
    // averaging... (no such direct impl — the framework equivalence IS the
    // implementation). Instead verify: τ=1 vs τ=8 differ, and β=0 vs β>0
    // differ — i.e. the wrapper's knobs are live.
    let t1 = run(&quad_cfg(4, 64, AlgoSpec::Local(sgd()),
                           Some(SlowMoCfg::new(1.0, 0.0, 1))));
    let t8 = run(&quad_cfg(4, 64, AlgoSpec::Local(sgd()),
                           Some(SlowMoCfg::new(1.0, 0.0, 8))));
    let t8b = run(&quad_cfg(4, 64, AlgoSpec::Local(sgd()),
                            Some(SlowMoCfg::new(1.0, 0.7, 8))));
    assert_ne!(t1.train_curve, t8.train_curve);
    assert_ne!(t8.train_curve, t8b.train_curve);
}

#[test]
fn slowmo_improves_local_sgd_on_heterogeneous_quad() {
    // The BMUF effect (paper Table 1 Local SGD rows): with heterogeneous
    // worker objectives and sparse averaging, slow momentum reaches a
    // lower loss for the same step budget.
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let tau = 16;
    let base = run(&quad_cfg(
        8, 512, AlgoSpec::Local(sgd()),
        Some(SlowMoCfg::new(1.0, 0.0, tau)
            .with_buffers(BufferStrategy::Maintain)),
    ));
    let slow = run(&quad_cfg(
        8, 512, AlgoSpec::Local(sgd()),
        Some(SlowMoCfg::new(1.0, 0.6, tau)
            .with_buffers(BufferStrategy::Maintain)),
    ));
    assert!(
        slow.best_train_loss < base.best_train_loss,
        "slowmo {} !< base {}",
        slow.best_train_loss,
        base.best_train_loss
    );
}

#[test]
fn single_worker_lookahead_converges() {
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let r = run(&quad_cfg(
        1, 256, AlgoSpec::Local(sgd()),
        Some(SlowMoCfg::new(0.5, 0.0, 8)
            .with_buffers(BufferStrategy::Maintain)),
    ));
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    // The quad spectrum spans 1..100 over 4096 dims, so the low-λ tail
    // converges slowly; a robust 40%+ decrease is the signal here.
    assert!(last < 0.6 * first, "{first} -> {last}");
}

#[test]
fn all_base_algorithms_decrease_quad_loss() {
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    for algo in [
        AlgoSpec::Local(sgd()),
        AlgoSpec::Sgp(sgd()),
        AlgoSpec::Osgp(sgd()),
        AlgoSpec::Dpsgd(sgd()),
        AlgoSpec::AllReduce(sgd()),
        AlgoSpec::DoubleAvg(sgd(), 8),
    ] {
        let name = format!("{algo:?}");
        let r = run(&quad_cfg(4, 128, algo, None));
        let first = r.train_curve.first().unwrap().1;
        let last = r.train_curve.last().unwrap().1;
        assert!(last < first, "{name}: {first} -> {last}");
    }
}

#[test]
fn noaverage_variant_close_to_full_slowmo_on_quad() {
    // §6: removing the exact average degrades only slightly.
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let full = run(&quad_cfg(4, 256, AlgoSpec::Sgp(sgd()),
                             Some(SlowMoCfg::new(1.0, 0.6, 16))));
    let noavg = run(&quad_cfg(
        4, 256, AlgoSpec::Sgp(sgd()),
        Some(SlowMoCfg::new(1.0, 0.6, 16).no_average()),
    ));
    // Both converge; noaverage within 3x of full's loss.
    assert!(noavg.best_train_loss < 3.0 * full.best_train_loss + 1e-6,
            "noavg {} vs full {}", noavg.best_train_loss,
            full.best_train_loss);
}

#[test]
fn gossip_sends_fewer_bytes_than_allreduce() {
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let sgp = run(&quad_cfg(4, 64, AlgoSpec::Sgp(sgd()), None));
    let ar = run(&quad_cfg(4, 64, AlgoSpec::AllReduce(sgd()), None));
    assert!(sgp.bytes_sent < ar.bytes_sent,
            "sgp {} !< ar {}", sgp.bytes_sent, ar.bytes_sent);
}

#[test]
fn sim_time_reflects_cost_model() {
    if manifest().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut cfg = quad_cfg(4, 32, AlgoSpec::AllReduce(sgd()), None);
    cfg.cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
    cfg.compute_time_s = 0.01;
    let r = train(&cfg, &manifest().unwrap(), None).unwrap();
    // 32 steps × (10 ms compute + allreduce(4096 f32, m=4)).
    let per = cfg.cost.allreduce_time(4096, 4) + 0.01;
    let want = 32.0 * per;
    assert!((r.sim_time - want).abs() < 0.2 * want,
            "sim {} vs want {}", r.sim_time, want);
}
