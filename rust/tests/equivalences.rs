//! Framework-equivalence tests (paper §2): the special cases the SlowMo
//! framework must recover *exactly*, plus determinism guarantees. All run
//! on the native quad fast path through an engine-free
//! [`Session`] (no PJRT needed) so they are fast and bit-deterministic.

use slowmo::algorithms::AlgoSel;
use slowmo::exec::ExecMode;
use slowmo::net::{ChaosCfg, CostModel};
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, OuterSel, SlowMoCfg};
use slowmo::testkit::chaos_seed;
use slowmo::trainer::{Schedule, StateMode, TrainResult};

fn session() -> Option<Session> {
    match Session::native_only() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: no artifacts");
            None
        }
    }
}

fn quad(
    s: &Session,
    m: usize,
    steps: u64,
    algo: AlgoSel,
    slowmo: Option<SlowMoCfg>,
) -> TrainResult {
    quadx(s, m, steps, algo, slowmo, None)
}

fn quadx(
    s: &Session,
    m: usize,
    steps: u64,
    algo: AlgoSel,
    slowmo: Option<SlowMoCfg>,
    chaos: Option<ChaosCfg>,
) -> TrainResult {
    s.train("quad")
        .algo_sel(algo)
        .workers(m)
        .steps(steps)
        .seed(11)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .record_params(true)
        .chaos_opt(chaos)
        .run()
        .unwrap()
}

fn sgd() -> InnerOpt {
    InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }
}

fn local() -> AlgoSel {
    AlgoSel::with_inner("local", sgd())
}

#[test]
fn runs_are_bit_deterministic() {
    let Some(s) = session() else { return };
    let a = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.7, 8)));
    let b = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.7, 8)));
    assert_eq!(a.train_curve, b.train_curve);
    assert_eq!(a.best_train_loss, b.best_train_loss);
}

#[test]
fn slowmo_tau1_beta0_equals_allreduce_sgd() {
    // Paper §2: base=SGD (no local momentum), τ=1, α=1, β=0 recovers
    // large mini-batch (AR) SGD. Parameter-averaging every step with
    // identical starting points == gradient-averaging every step.
    let Some(s) = session() else { return };
    let a = quad(
        &s, 4, 48, local(),
        Some(SlowMoCfg::new(1.0, 0.0, 1)
            .with_buffers(BufferStrategy::Maintain)),
    );
    let b = quad(&s, 4, 48, AlgoSel::with_inner("ar", sgd()), None);
    // The two runs window their train curves differently (τ=1 vs the
    // default 16), but over 48 steps both are means of the same per-step
    // loss sequence — compare the global means and the best losses.
    let gmean = |r: &TrainResult| {
        let xs: Vec<f64> = r.train_curve.iter().map(|&(_, l)| l).collect();
        slowmo::util::mean(&xs)
    };
    let (ma, mb) = (gmean(&a), gmean(&b));
    assert!((ma - mb).abs() < 1e-4 * mb.abs().max(1.0),
            "global means diverge: {ma} vs {mb}");
}

#[test]
fn slowmo_beta0_equals_local_sgd_baseline() {
    // SlowMo(α=1, β=0) over Local SGD == Local SGD with periodic
    // averaging (Alg. 4): adding the wrapper with β=0 must not change
    // anything vs the direct characterization. Verify the wrapper's
    // knobs are live: τ=1 vs τ=8 differ, and β=0 vs β>0 differ.
    let Some(s) = session() else { return };
    let t1 = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.0, 1)));
    let t8 = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.0, 8)));
    let t8b = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.7, 8)));
    assert_ne!(t1.train_curve, t8.train_curve);
    assert_ne!(t8.train_curve, t8b.train_curve);
}

#[test]
fn slowmo_improves_local_sgd_on_heterogeneous_quad() {
    // The BMUF effect (paper Table 1 Local SGD rows): with heterogeneous
    // worker objectives and sparse averaging, slow momentum reaches a
    // lower loss for the same step budget.
    let Some(s) = session() else { return };
    let tau = 16;
    let base = quad(
        &s, 8, 512, local(),
        Some(SlowMoCfg::new(1.0, 0.0, tau)
            .with_buffers(BufferStrategy::Maintain)),
    );
    let slow = quad(
        &s, 8, 512, local(),
        Some(SlowMoCfg::new(1.0, 0.6, tau)
            .with_buffers(BufferStrategy::Maintain)),
    );
    assert!(
        slow.best_train_loss < base.best_train_loss,
        "slowmo {} !< base {}",
        slow.best_train_loss,
        base.best_train_loss
    );
}

#[test]
fn single_worker_lookahead_converges() {
    let Some(s) = session() else { return };
    let r = quad(
        &s, 1, 256, local(),
        Some(SlowMoCfg::new(0.5, 0.0, 8)
            .with_buffers(BufferStrategy::Maintain)),
    );
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    // The quad spectrum spans 1..100 over 4096 dims, so the low-λ tail
    // converges slowly; a robust 40%+ decrease is the signal here.
    assert!(last < 0.6 * first, "{first} -> {last}");
}

#[test]
fn all_base_algorithms_decrease_quad_loss() {
    // Every registered spec string builds through the registry and
    // descends on the quad workload.
    let Some(s) = session() else { return };
    for spec in ["local", "sgp", "osgp", "dpsgd", "ar", "doubleavg:8"] {
        let mut sel = s.registry().parse(spec).unwrap();
        sel.inner = sgd();
        let r = quad(&s, 4, 128, sel, None);
        let first = r.train_curve.first().unwrap().1;
        let last = r.train_curve.last().unwrap().1;
        assert!(last < first, "{spec}: {first} -> {last}");
    }
}

#[test]
fn noaverage_variant_close_to_full_slowmo_on_quad() {
    // §6: removing the exact average degrades only slightly.
    let Some(s) = session() else { return };
    let sgp = AlgoSel::with_inner("sgp", sgd());
    let full = quad(&s, 4, 256, sgp.clone(),
                    Some(SlowMoCfg::new(1.0, 0.6, 16)));
    let noavg = quad(
        &s, 4, 256, sgp,
        Some(SlowMoCfg::new(1.0, 0.6, 16).no_average()),
    );
    // Both converge; noaverage within 3x of full's loss.
    assert!(noavg.best_train_loss < 3.0 * full.best_train_loss + 1e-6,
            "noavg {} vs full {}", noavg.best_train_loss,
            full.best_train_loss);
}

// ---------------------------------------------------- outer rule registry
// The pluggable OuterOpt redesign must not move a single bit: the
// `slowmo` registry key is the old hardcoded rule, and `avg` is the α=1,
// β=0 special case implemented with the identical fp operations.

#[test]
fn outer_slowmo_key_is_bitwise_identical_to_legacy_alias() {
    let Some(s) = session() else { return };
    let legacy =
        quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.7, 8)));
    let keyed = quad(
        &s, 4, 64, local(),
        Some(SlowMoCfg::with_outer(
            OuterSel::with_args("slowmo", &[0.7]),
            8,
        )),
    );
    assert_eq!(legacy.final_params, keyed.final_params);
    assert_eq!(legacy.train_curve, keyed.train_curve);
    assert_eq!(legacy.sim_time, keyed.sim_time);
    assert_eq!(legacy.bytes_sent, keyed.bytes_sent);
    assert_eq!(legacy.algo, keyed.algo, "display names must agree");
    // The builder's spec-string path lands on the same bits too.
    let spec = s
        .train("quad")
        .algo_sel(local())
        .workers(4)
        .steps(64)
        .seed(11)
        .outer("slowmo:0.7")
        .tau(8)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .record_params(true)
        .run()
        .unwrap();
    assert_eq!(legacy.final_params, spec.final_params);
    assert_eq!(legacy.train_curve, spec.train_curve);
}

#[test]
fn outer_avg_is_bitwise_identical_to_slowmo_beta0() {
    let Some(s) = session() else { return };
    let b0 = quad(&s, 4, 64, local(), Some(SlowMoCfg::new(1.0, 0.0, 8)));
    let avg = quad(
        &s, 4, 64, local(),
        Some(SlowMoCfg::with_outer(OuterSel::new("avg"), 8)),
    );
    assert_eq!(b0.final_params, avg.final_params);
    assert!(b0.final_params.is_some());
    assert_eq!(b0.train_curve, avg.train_curve);
    assert_eq!(b0.sim_time, avg.sim_time);
}

#[test]
fn all_outer_rules_descend_on_quad() {
    // Every registered outer rule builds through the registry, completes
    // a run, reports its spec in the result, and improves on the initial
    // loss window.
    let Some(s) = session() else { return };
    for spec in ["slowmo:0.7", "avg", "lookahead:0.5", "nesterov:0.9",
                 "adam:0.9,0.95"] {
        let sel = s.outer_registry().parse(spec).unwrap();
        let r = quad(&s, 4, 128, local(),
                     Some(SlowMoCfg::with_outer(sel, 8)));
        assert_eq!(r.steps_run, 128, "{spec}");
        assert_eq!(r.outer.as_deref(), Some(spec));
        let first = r.train_curve.first().unwrap().1;
        let last = r.train_curve.last().unwrap().1;
        assert!(last.is_finite(), "{spec}: non-finite loss");
        assert!(last < first, "{spec}: {first} -> {last}");
    }
}

#[test]
fn gossip_sends_fewer_bytes_than_allreduce() {
    let Some(s) = session() else { return };
    let sgp = quad(&s, 4, 64, AlgoSel::with_inner("sgp", sgd()), None);
    let ar = quad(&s, 4, 64, AlgoSel::with_inner("ar", sgd()), None);
    assert!(sgp.bytes_sent < ar.bytes_sent,
            "sgp {} !< ar {}", sgp.bytes_sent, ar.bytes_sent);
}

// ---------------------------------------------------------------- chaos
// Delays may only move simulated time, never math: each framework special
// case must produce bitwise-identical final parameters with a (faultless)
// ChaosPlan enabled, at a strictly larger simulated wall-clock.

/// Network chaos for cases that communicate (delays, drops, reordering).
fn net_chaos() -> ChaosCfg {
    ChaosCfg {
        seed: chaos_seed(),
        delay_mean_s: 2e-3,
        delay_max_s: 20e-3,
        drop_prob: 0.1,
        reorder_window: 4,
        stragglers: vec![(1, 2.0)],
        ..ChaosCfg::default()
    }
}

fn assert_time_only(calm: &TrainResult, chaotic: &TrainResult) {
    assert_eq!(
        calm.final_params, chaotic.final_params,
        "chaos changed the math"
    );
    assert!(calm.final_params.is_some());
    assert_eq!(calm.train_curve, chaotic.train_curve);
    assert!(
        chaotic.sim_time > calm.sim_time,
        "chaos must cost simulated time: {} !> {}",
        chaotic.sim_time,
        calm.sim_time
    );
}

#[test]
fn bmuf_is_bitwise_identical_under_chaos() {
    // BMUF: Local base + slow momentum (paper §2, Chen & Huo 2016).
    let Some(s) = session() else { return };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
    let calm = quadx(&s, 4, 64, local(), slowmo.clone(), None);
    let chaotic = quadx(&s, 4, 64, local(), slowmo, Some(net_chaos()));
    assert_time_only(&calm, &chaotic);
}

#[test]
fn lookahead_is_bitwise_identical_under_chaos() {
    // Lookahead: m=1, α∈(0,1], β=0 — no communication at all, so the
    // chaos charge comes from a straggler slowdown on the only worker.
    let Some(s) = session() else { return };
    let slowmo = Some(
        SlowMoCfg::new(0.5, 0.0, 8)
            .with_buffers(BufferStrategy::Maintain),
    );
    let chaos = ChaosCfg {
        seed: chaos_seed(),
        stragglers: vec![(0, 2.5)],
        ..ChaosCfg::default()
    };
    let calm = quadx(&s, 1, 64, local(), slowmo.clone(), None);
    let chaotic = quadx(&s, 1, 64, local(), slowmo, Some(chaos));
    assert_time_only(&calm, &chaotic);
}

#[test]
fn allreduce_sgd_is_bitwise_identical_under_chaos() {
    // AR-SGD: gradient allreduce every step (τ=1 anchor).
    let Some(s) = session() else { return };
    let ar = AlgoSel::with_inner("ar", sgd());
    let calm = quadx(&s, 4, 48, ar.clone(), None, None);
    let chaotic = quadx(&s, 4, 48, ar, None, Some(net_chaos()));
    assert_time_only(&calm, &chaotic);
    // Goodput is identical too — retransmissions are counted separately.
    assert_eq!(calm.bytes_sent, chaotic.bytes_sent);
}

#[test]
fn sim_time_reflects_cost_model() {
    let Some(s) = session() else { return };
    let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
    let r = s
        .train("quad")
        .algo_sel(AlgoSel::with_inner("ar", sgd()))
        .workers(4)
        .steps(32)
        .seed(11)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(cost.clone())
        .compute_time(0.01)
        .run()
        .unwrap();
    // 32 steps × (10 ms compute + allreduce(4096 f32, m=4)).
    let per = cost.allreduce_time(4096, 4) + 0.01;
    let want = 32.0 * per;
    assert!((r.sim_time - want).abs() < 0.2 * want,
            "sim {} vs want {}", r.sim_time, want);
}

// ----------------------------------------------------- compression layer
// The compress subsystem's equivalence obligations: the `none` codec (and
// a builder that never mentions compression) is bit-identical to the
// pre-subsystem path on every lane, and `ef:topk:1.0` (keep-everything
// error feedback) matches `none` exactly — its encode/decode round-trip
// is value-preserving by construction, so the documented ulp bound is 0.

fn quadc(
    s: &Session,
    m: usize,
    steps: u64,
    algo: AlgoSel,
    slowmo: Option<SlowMoCfg>,
    compress: Option<&str>,
) -> TrainResult {
    let mut b = s
        .train("quad")
        .algo_sel(algo)
        .workers(m)
        .steps(steps)
        .seed(11)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-6)
        .record_params(true);
    if let Some(spec) = compress {
        b = b.compress(spec);
    }
    b.run().unwrap()
}

#[test]
fn compress_none_is_bitwise_identical_to_presubsystem_path() {
    // AR (per-step gradient collective), SGP (gossip lane) and
    // Local+SlowMo (outer-boundary collective): `compress = none` must
    // not move a bit — parameters, curves, bytes and simulated time all
    // identical to a run that never mentions compression.
    let Some(s) = session() else { return };
    let cells: [(AlgoSel, Option<SlowMoCfg>); 3] = [
        (AlgoSel::with_inner("ar", sgd()), None),
        (AlgoSel::with_inner("sgp", sgd()), None),
        (local(), Some(SlowMoCfg::new(1.0, 0.7, 8))),
    ];
    for (algo, slowmo) in cells {
        let bare = quadc(&s, 4, 48, algo.clone(), slowmo.clone(), None);
        let none = quadc(&s, 4, 48, algo, slowmo, Some("none"));
        assert_eq!(bare.final_params, none.final_params);
        assert_eq!(bare.train_curve, none.train_curve);
        assert_eq!(bare.bytes_sent, none.bytes_sent);
        assert_eq!(bare.sim_time, none.sim_time);
        assert_eq!(none.bytes_saved, 0);
        // The identity codec is not reported as a codec.
        assert_eq!(none.compress, None);
        assert!(!none.algo.contains("none"), "{}", none.algo);
    }
}

#[test]
fn ef_topk_keep_everything_matches_none_exactly() {
    // ef:topk:1.0 keeps every coordinate: encode/decode is value-exact
    // and the residual is identically zero, so the whole run matches the
    // uncompressed one bit for bit (documented ulp bound: 0). Only the
    // reporting differs: the codec is named, and the dense index+value
    // fallback keeps bytes at the raw size.
    let Some(s) = session() else { return };
    for (algo, slowmo) in [
        (AlgoSel::with_inner("ar", sgd()), None),
        (local(), Some(SlowMoCfg::new(1.0, 0.7, 8))),
    ] {
        let bare = quadc(&s, 4, 48, algo.clone(), slowmo.clone(), None);
        let ef = quadc(&s, 4, 48, algo, slowmo, Some("ef:topk:1.0"));
        assert_eq!(bare.final_params, ef.final_params);
        assert_eq!(bare.train_curve, ef.train_curve);
        assert_eq!(bare.bytes_sent, ef.bytes_sent, "dense fallback");
        assert_eq!(ef.compress.as_deref(), Some("ef:topk:1"));
        assert!(ef.algo.contains("ef:topk:1"), "{}", ef.algo);
    }
}

#[test]
fn lossy_compression_strictly_cuts_bytes_and_time() {
    // The acceptance frontier: every lossy codec sends strictly fewer
    // bytes than raw f32 on the same run, reports the savings, and
    // finishes sooner on the α-β network.
    let Some(s) = session() else { return };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
    let raw = quadc(&s, 4, 48, local(), slowmo.clone(), None);
    for spec in ["fp16", "bf16", "topk:0.1", "ef:topk:0.1", "randk:0.1",
                 "signsgd", "ef:signsgd", "demo:0.1"] {
        let r = quadc(&s, 4, 48, local(), slowmo.clone(), Some(spec));
        assert!(r.bytes_sent < raw.bytes_sent,
                "{spec}: {} !< {}", r.bytes_sent, raw.bytes_sent);
        assert!(r.bytes_saved > 0, "{spec}");
        assert!(r.sim_time < raw.sim_time, "{spec}");
        assert_eq!(r.compress.as_deref(), Some(spec));
    }
}

#[test]
fn demo_keep_all_matches_none_within_ulp_bound() {
    // demo:1.0 transmits every DCT coefficient, so the only deviation
    // from the uncompressed run is the forward+inverse transform's f32
    // rounding (<= ~1.2e-7·max|x| per transcode, measured; the property
    // suite pins 1e-6). Over 6 outer boundaries the drift on the final
    // parameters stays within a small multiple of that bound — this is
    // the codec's documented, *pinned* ulp envelope, where `ef:topk:1.0`
    // above is exactly 0.
    let Some(s) = session() else { return };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
    let bare = quadc(&s, 4, 48, local(), slowmo.clone(), None);
    let demo = quadc(&s, 4, 48, local(), slowmo.clone(), Some("demo:1.0"));
    let mag = bare
        .final_params
        .iter()
        .fold(0.0f32, |m, v| m.max(v.abs()));
    for (i, (a, b)) in
        bare.final_params.iter().zip(&demo.final_params).enumerate()
    {
        assert!(
            (a - b).abs() <= mag * 1e-5 + 1e-6,
            "param {i}: {a} vs {b} (mag {mag})"
        );
    }
    // Keep-all demo pays dense-fallback bytes, exactly like ef:topk:1.0.
    assert_eq!(demo.bytes_sent, bare.bytes_sent, "dense fallback");
    assert_eq!(demo.compress.as_deref(), Some("demo:1"));
}

#[test]
fn demo_runs_are_bit_deterministic_including_residual_state() {
    // Same seed ⇒ bit-identical runs with the frequency-residual codec
    // active: parameters, bytes, simulated time and the full curve. The
    // residual state's determinism is covered directly by the property
    // suite; here it shows transitively (it feeds every boundary).
    let Some(s) = session() else { return };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
    for spec in ["demo:0.1", "demo:0.25,32"] {
        let a = quadc(&s, 4, 48, local(), slowmo.clone(), Some(spec));
        let b = quadc(&s, 4, 48, local(), slowmo.clone(), Some(spec));
        assert_eq!(a.final_params, b.final_params, "{spec}");
        assert_eq!(a.train_curve, b.train_curve, "{spec}");
        assert_eq!(a.bytes_sent, b.bytes_sent, "{spec}");
        assert_eq!(a.sim_time, b.sim_time, "{spec}");
    }
}

#[test]
fn compressed_runs_are_bit_deterministic() {
    // Seeded determinism holds with compression on — including randk,
    // whose index streams derive from (run seed, worker, site, counter).
    let Some(s) = session() else { return };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
    for spec in ["ef:topk:0.25", "randk:0.25", "ef:signsgd"] {
        let a = quadc(&s, 4, 48, local(), slowmo.clone(), Some(spec));
        let b = quadc(&s, 4, 48, local(), slowmo.clone(), Some(spec));
        assert_eq!(a.final_params, b.final_params, "{spec}");
        assert_eq!(a.bytes_sent, b.bytes_sent, "{spec}");
        assert_eq!(a.sim_time, b.sim_time, "{spec}");
    }
}

// ----------------------------------------------------- hierarchy layer
// The two-level redesign's equivalence obligations: one group IS the
// flat topology (bitwise, for every registered outer rule), m singleton
// groups with tau_inner=1 degenerate to the flat path, the g=2 reduce
// computes the same mean up to fp association, and chaos still moves
// only simulated time.

/// Quad run with an optional hierarchy: `groups = (spec, two_level)`.
fn quadg(
    s: &Session,
    m: usize,
    steps: u64,
    slowmo: Option<SlowMoCfg>,
    groups: Option<(&str, bool)>,
    tau_inner: u64,
    chaos: Option<ChaosCfg>,
) -> TrainResult {
    let mut b = s
        .train("quad")
        .algo_sel(local())
        .workers(m)
        .steps(steps)
        .seed(11)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-6)
        .record_params(true)
        .chaos_opt(chaos);
    if let Some((spec, two_level)) = groups {
        b = if two_level {
            b.groups(spec)
        } else {
            b.groups_flat(spec)
        };
        if tau_inner > 0 {
            b = b.tau_inner(tau_inner);
        }
    }
    b.run().unwrap()
}

#[test]
fn hier_g1_is_bitwise_identical_to_flat_for_every_outer_rule() {
    // One group is the flat topology: same transcode, same ring, same
    // collective ids — every registered outer rule must land on the
    // identical bits, bytes and simulated time.
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let cfg = SlowMoCfg::with_outer(sel, 8);
        let flat = quadg(&s, 4, 64, Some(cfg.clone()), None, 0, None);
        let g1 =
            quadg(&s, 4, 64, Some(cfg), Some(("1", true)), 0, None);
        assert_eq!(g1.final_params, flat.final_params, "{key}");
        assert!(g1.final_params.is_some());
        assert_eq!(g1.train_curve, flat.train_curve, "{key}");
        assert_eq!(g1.sim_time, flat.sim_time, "{key}");
        assert_eq!(g1.bytes_sent, flat.bytes_sent, "{key}");
        assert_eq!(g1.bytes_inter, 0, "{key}: g=1 has no inter links");
        assert_eq!(g1.groups.as_deref(), Some("0-3"), "{key}");
    }
}

#[test]
fn quorum_m_staleness_0_is_bitwise_identical_to_blocking() {
    // q = m admits every worker into the ring, so the semi-synchronous
    // machinery must vanish: for every registered outer rule the run
    // lands on identical bits, bytes and simulated time as the blocking
    // path (the arrival-stamp exchange rides the zero-cost control
    // lane, so it cannot perturb accounting either).
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let cfg = SlowMoCfg::with_outer(sel, 8);
        let blocking = quadg(&s, 4, 64, Some(cfg.clone()), None, 0, None);
        let semisync = quadg(
            &s,
            4,
            64,
            Some(cfg.with_quorum(4).with_staleness(0)),
            None,
            0,
            None,
        );
        assert_eq!(semisync.final_params, blocking.final_params, "{key}");
        assert!(semisync.final_params.is_some());
        assert_eq!(semisync.train_curve, blocking.train_curve, "{key}");
        assert_eq!(semisync.sim_time, blocking.sim_time, "{key}");
        assert_eq!(semisync.bytes_sent, blocking.bytes_sent, "{key}");
        assert_eq!(semisync.quorum_misses, 0, "{key}");
        assert_eq!(semisync.stale_folds, 0, "{key}");
    }
}

#[test]
fn hier_gm_with_tau_inner_1_degenerates_to_flat() {
    // m singleton groups: intra stages and tau_inner averages are
    // no-ops, the leader ring is the full flat ring — identical math,
    // bytes and (with the default equal-tier link) simulated time; every
    // boundary byte crossed a group boundary so it all counts as inter.
    let Some(s) = session() else { return };
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let flat = quadg(&s, 4, 64, Some(cfg.clone()), None, 0, None);
    let gm =
        quadg(&s, 4, 64, Some(cfg), Some(("4", true)), 1, None);
    assert_eq!(gm.final_params, flat.final_params);
    assert_eq!(gm.train_curve, flat.train_curve);
    assert_eq!(gm.bytes_sent, flat.bytes_sent);
    assert_eq!(gm.sim_time, flat.sim_time);
    assert_eq!(
        gm.bytes_inter, gm.bytes_sent,
        "singleton groups make every byte inter-group"
    );
    assert!(gm.algo.contains("+hier(g4,ti1)"), "{}", gm.algo);
}

#[test]
fn hier_two_groups_same_mean_fewer_inter_bytes() {
    // g=2: the weighted two-level reduce computes the same average up to
    // fp association (close final params / losses), while moving
    // strictly fewer bytes over the slow links than flat SlowMo on the
    // same partition — at *equal* total steps.
    let Some(s) = session() else { return };
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let flat_tiered =
        quadg(&s, 4, 64, Some(cfg.clone()), Some(("2", false)), 0, None);
    let hier =
        quadg(&s, 4, 64, Some(cfg), Some(("2", true)), 0, None);
    assert_eq!(hier.steps_run, flat_tiered.steps_run);
    let (a, b) = (
        hier.final_params.as_ref().unwrap(),
        flat_tiered.final_params.as_ref().unwrap(),
    );
    assert!(
        slowmo::util::allclose(a, b, 1e-4, 1e-5),
        "two-level mean drifted from the flat mean"
    );
    assert!(
        (hier.final_eval_loss - flat_tiered.final_eval_loss).abs()
            <= 1e-3 * flat_tiered.final_eval_loss.abs().max(1e-6),
        "{} vs {}",
        hier.final_eval_loss,
        flat_tiered.final_eval_loss
    );
    assert!(
        hier.bytes_inter < flat_tiered.bytes_inter,
        "{} !< {}",
        hier.bytes_inter,
        flat_tiered.bytes_inter
    );
    assert!(flat_tiered.bytes_inter > 0);
    assert!(hier.algo.contains("+hier(g2)"), "{}", hier.algo);
    assert!(flat_tiered.algo.contains("+tiered(g2)"),
            "{}", flat_tiered.algo);
}

#[test]
fn hier_slow_inter_link_wins_on_sim_time() {
    // With a genuinely slow inter-group link, the hierarchy's smaller
    // leader ring beats the flat global ring in simulated time — the
    // paper-motivating tradeoff, at identical step budgets.
    let Some(s) = session() else { return };
    let run = |two_level: bool| {
        let b = s
            .train("quad")
            .algo_sel(local())
            .workers(4)
            .steps(64)
            .seed(11)
            .slowmo_cfg(SlowMoCfg::new(1.0, 0.7, 8))
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::ethernet_10g())
            .compute_time(1e-6)
            .inter_link(5e-4, 1.25e8);
        if two_level {
            b.groups("2").run().unwrap()
        } else {
            b.groups_flat("2").run().unwrap()
        }
    };
    let flat = run(false);
    let hier = run(true);
    assert!(
        hier.sim_time < flat.sim_time,
        "hier {} !< flat {}",
        hier.sim_time,
        flat.sim_time
    );
    assert!(hier.bytes_inter < flat.bytes_inter);
}

#[test]
fn faultless_chaos_with_hierarchy_moves_time_not_math() {
    // The chaos contract composes with the two-level reduce: seeded
    // delays/drops/stragglers change only simulated time and retransmit
    // counts — never the bits.
    let Some(s) = session() else { return };
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let calm = quadg(
        &s, 4, 64, Some(cfg.clone()), Some(("2", true)), 2, None,
    );
    let chaotic = quadg(
        &s, 4, 64, Some(cfg), Some(("2", true)), 2, Some(net_chaos()),
    );
    assert_time_only(&calm, &chaotic);
    assert_eq!(calm.bytes_inter, chaotic.bytes_inter);
}

#[test]
fn tau_inner_stays_off_the_slow_links() {
    // The fast intra-group average adds intra bytes only: inter traffic
    // is identical with and without it, total bytes strictly higher.
    let Some(s) = session() else { return };
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let plain =
        quadg(&s, 4, 64, Some(cfg.clone()), Some(("2", true)), 0, None);
    let ti = quadg(&s, 4, 64, Some(cfg), Some(("2", true)), 2, None);
    assert_eq!(ti.bytes_inter, plain.bytes_inter);
    assert!(ti.bytes_sent > plain.bytes_sent);
}

#[test]
fn faultless_chaos_with_compression_moves_time_not_math() {
    // The chaos contract composes with compression: the codec is applied
    // before the fabric, so seeded delays/drops still change only
    // simulated time and retransmit counts.
    let Some(s) = session() else { return };
    let sgp = AlgoSel::with_inner("sgp", sgd());
    let chaos = ChaosCfg {
        seed: chaos_seed(),
        delay_mean_s: 2e-3,
        delay_max_s: 20e-3,
        drop_prob: 0.1,
        reorder_window: 4,
        ..ChaosCfg::default()
    };
    let run = |chaos: Option<ChaosCfg>| {
        s.train("quad")
            .algo_sel(sgp.clone())
            .workers(4)
            .steps(48)
            .seed(11)
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::ethernet_10g())
            .compute_time(1e-6)
            .record_params(true)
            .compress("ef:topk:0.25")
            .chaos_opt(chaos)
            .run()
            .unwrap()
    };
    let calm = run(None);
    let chaotic = run(Some(chaos));
    assert_eq!(calm.final_params, chaotic.final_params);
    assert_eq!(calm.bytes_sent, chaotic.bytes_sent);
    assert!(chaotic.sim_time > calm.sim_time);
}

// ------------------------------------------------- execution backends
// The threaded backend's contract: real concurrent transfers, same
// math bit for bit. Both backends share every simulated-time and byte
// computation — only the transport differs — so parameters, curves,
// sim_time and bytes must all be identical. dpsgd (two in-edges merged
// in arrival order) and osgp (opportunistic drains) are
// scheduling-dependent in *both* modes and so carry no bitwise promise;
// see ROADMAP §Execution backends.

/// Quad run on an explicit execution backend.
fn quade(
    s: &Session,
    m: usize,
    steps: u64,
    algo: AlgoSel,
    slowmo: Option<SlowMoCfg>,
    compress: Option<&str>,
    mode: ExecMode,
) -> TrainResult {
    let mut b = s
        .train("quad")
        .algo_sel(algo)
        .workers(m)
        .steps(steps)
        .seed(11)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-6)
        .record_params(true)
        .exec(mode);
    if let Some(spec) = compress {
        b = b.compress(spec);
    }
    b.run().unwrap()
}

fn assert_backends_agree(sim: &TrainResult, thr: &TrainResult, tag: &str) {
    assert_eq!(sim.exec, "sim", "{tag}");
    assert_eq!(thr.exec, "threaded", "{tag}");
    assert_eq!(sim.final_params, thr.final_params, "{tag}: params");
    assert!(sim.final_params.is_some(), "{tag}");
    assert_eq!(sim.train_curve, thr.train_curve, "{tag}: train curve");
    assert_eq!(
        sim.eval_curve.len(),
        thr.eval_curve.len(),
        "{tag}: eval points"
    );
    for (a, b) in sim.eval_curve.iter().zip(&thr.eval_curve) {
        assert_eq!(a.step, b.step, "{tag}");
        assert_eq!(
            a.loss_mean.to_bits(),
            b.loss_mean.to_bits(),
            "{tag}: eval loss at step {}",
            a.step
        );
    }
    assert_eq!(sim.sim_time, thr.sim_time, "{tag}: sim time");
    assert_eq!(sim.bytes_sent, thr.bytes_sent, "{tag}: bytes");
}

#[test]
fn threaded_matches_sim_for_every_outer_rule() {
    // The whole OuterRegistry lands on identical bits under the
    // threaded fabric: the outer boundary is a ring allreduce with a
    // fixed chunk-reduction order, so transport concurrency must not
    // show up in the math.
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let cfg = SlowMoCfg::with_outer(sel, 8);
        let sim = quade(&s, 4, 64, local(), Some(cfg.clone()), None,
                        ExecMode::Sim);
        let thr = quade(&s, 4, 64, local(), Some(cfg), None,
                        ExecMode::Threaded);
        assert_backends_agree(&sim, &thr, key);
    }
}

#[test]
fn threaded_matches_sim_across_deterministic_bases() {
    // Every base algorithm whose receive pattern is order-insensitive
    // (in-degree ≤ 1 gossip, fixed-order ring collectives) is bitwise
    // identical across backends.
    let Some(s) = session() else { return };
    for spec in ["local", "sgp", "ar", "doubleavg:8"] {
        let mut sel = s.registry().parse(spec).unwrap();
        sel.inner = sgd();
        let slowmo = Some(SlowMoCfg::new(1.0, 0.6, 8));
        let sim = quade(&s, 4, 64, sel.clone(), slowmo.clone(), None,
                        ExecMode::Sim);
        let thr =
            quade(&s, 4, 64, sel, slowmo, None, ExecMode::Threaded);
        assert_backends_agree(&sim, &thr, spec);
    }
}

#[test]
fn threaded_matches_sim_with_compression() {
    // The codec sits above the fabric, so compression composes with the
    // threaded transport without moving a bit.
    let Some(s) = session() else { return };
    for spec in ["fp16", "ef:topk:0.25"] {
        let slowmo = Some(SlowMoCfg::new(1.0, 0.7, 8));
        let sim = quade(&s, 4, 48, local(), slowmo.clone(), Some(spec),
                        ExecMode::Sim);
        let thr = quade(&s, 4, 48, local(), slowmo, Some(spec),
                        ExecMode::Threaded);
        assert_backends_agree(&sim, &thr, spec);
    }
}

#[test]
fn threaded_rejects_chaos() {
    // Chaos charges simulated time; the threaded backend measures a
    // real clock, so the combination is a hard configuration error, not
    // a silent no-op.
    let Some(s) = session() else { return };
    let err = s
        .train("quad")
        .algo_sel(local())
        .workers(4)
        .steps(16)
        .seed(11)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .exec(ExecMode::Threaded)
        .chaos_opt(Some(net_chaos()))
        .run()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sim-only"), "{msg}");
}

// ---------------------- scale fabric: N-level trees + shared state
// The depth-2 tree reduce and the copy-on-write shared layout are both
// *representation* changes: where they overlap with an existing path
// they must land on identical bits, and where they cannot run they must
// fail loudly at build time.

/// Quad run with an explicit state layout and optional tier topology.
/// `tiered` charges a slow pod-crossing link above the rack link so the
/// tree's latency win is observable.
fn quads(
    s: &Session,
    m: usize,
    steps: u64,
    slowmo: Option<SlowMoCfg>,
    groups: Option<(&str, bool)>,
    state: StateMode,
    tiered: bool,
) -> TrainResult {
    let mut b = s
        .train("quad")
        .algo_sel(local())
        .workers(m)
        .steps(steps)
        .seed(11)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(1e-6)
        .record_params(true)
        .state(state);
    if tiered {
        b = b.inter_link(5e-4, 1.25e8).tier_link(2e-3, 6.25e7);
    }
    if let Some((spec, two_level)) = groups {
        b = if two_level {
            b.groups(spec)
        } else {
            b.groups_flat(spec)
        };
    }
    b.run().unwrap()
}

#[test]
fn tree_trivial_top_matches_two_level_math_for_every_outer_rule() {
    // A depth-2 tree whose top tier is one group covering everything
    // computes exactly the two-level average: the top-tier allreduce
    // runs over members that already hold identical bits, and the
    // descent re-broadcasts those same bits. So for every registered
    // outer rule the math is bitwise-identical — but NOT free: the
    // descent moves a redundant broadcast the depth-1 path never sends.
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let cfg = SlowMoCfg::with_outer(sel, 8);
        let d1 = quadg(&s, 4, 64, Some(cfg.clone()),
                       Some(("0-1|2-3", true)), 0, None);
        let d2 = quadg(&s, 4, 64, Some(cfg),
                       Some(("0-1|2-3;0-3", true)), 0, None);
        assert_eq!(d2.final_params, d1.final_params, "{key}");
        assert!(d2.final_params.is_some(), "{key}");
        assert_eq!(d2.train_curve, d1.train_curve, "{key}");
        assert!(
            d2.bytes_sent > d1.bytes_sent,
            "{key}: the trivial top tier must cost extra broadcast \
             bytes ({} !> {})",
            d2.bytes_sent,
            d1.bytes_sent
        );
        assert!(d2.algo.contains(",d2"), "{key}: {}", d2.algo);
        assert!(!d1.algo.contains(",d2"), "{key}: {}", d1.algo);
        assert_eq!(d2.groups.as_deref(), Some("0-1|2-3;0-3"), "{key}");
    }
}

#[test]
fn deep_tree_recovers_global_mean_and_beats_flat_time() {
    // m=8 in 4 racks × 2 pods with a genuinely slow pod link: the
    // depth-2 reduce computes the same global average up to fp
    // association while crossing the slow tier O(pods) times instead of
    // O(m) — so it wins simulated time against flat SlowMo charged on
    // the identical fabric, at equal step budgets.
    let Some(s) = session() else { return };
    let spec = "0-1|2-3|4-5|6-7;0-3|4-7";
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let flat = quads(&s, 8, 64, Some(cfg.clone()),
                     Some((spec, false)), StateMode::Dense, true);
    let tree = quads(&s, 8, 64, Some(cfg),
                     Some((spec, true)), StateMode::Dense, true);
    assert_eq!(tree.steps_run, flat.steps_run);
    let (a, b) = (
        tree.final_params.as_ref().unwrap(),
        flat.final_params.as_ref().unwrap(),
    );
    assert!(
        slowmo::util::allclose(a, b, 1e-4, 1e-5),
        "depth-2 mean drifted from the flat mean"
    );
    assert!(
        tree.sim_time < flat.sim_time,
        "tree {} !< flat {}",
        tree.sim_time,
        flat.sim_time
    );
    assert!(tree.algo.contains("+hier(g4,d2)"), "{}", tree.algo);
    assert!(flat.algo.contains("+tiered(g4,d2)"), "{}", flat.algo);
}

#[test]
fn shared_state_is_bitwise_identical_to_dense_for_every_outer_rule() {
    // The copy-on-write layout is a memory optimization, not an
    // algorithm: for every registered outer rule the shared run lands
    // on the dense run's exact bits, bytes and simulated time.
    let Some(s) = session() else { return };
    let keys: Vec<String> = s
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = s.outer_registry().parse(key).unwrap();
        let cfg = SlowMoCfg::with_outer(sel, 8);
        let dense = quads(&s, 4, 64, Some(cfg.clone()), None,
                          StateMode::Dense, false);
        let shared = quads(&s, 4, 64, Some(cfg), None,
                           StateMode::Shared, false);
        assert_eq!(shared.final_params, dense.final_params, "{key}");
        assert!(shared.final_params.is_some(), "{key}");
        assert_eq!(shared.train_curve, dense.train_curve, "{key}");
        assert_eq!(shared.sim_time, dense.sim_time, "{key}");
        assert_eq!(shared.bytes_sent, dense.bytes_sent, "{key}");
        assert_eq!(shared.state, "shared", "{key}");
        assert_eq!(dense.state, "dense", "{key}");
    }
}

#[test]
fn shared_state_is_bitwise_identical_to_dense_on_the_tree() {
    // The shared layout composes with the depth-2 tree reduce — the
    // copy-on-write vectors flow through ascent, cascade and leaf
    // broadcast without moving a bit, a byte or a tick.
    let Some(s) = session() else { return };
    let spec = "0-1|2-3|4-5|6-7;0-3|4-7";
    let cfg = SlowMoCfg::new(1.0, 0.7, 8);
    let dense = quads(&s, 8, 64, Some(cfg.clone()),
                      Some((spec, true)), StateMode::Dense, true);
    let shared = quads(&s, 8, 64, Some(cfg),
                       Some((spec, true)), StateMode::Shared, true);
    assert_eq!(shared.final_params, dense.final_params);
    assert!(shared.final_params.is_some());
    assert_eq!(shared.train_curve, dense.train_curve);
    assert_eq!(shared.sim_time, dense.sim_time);
    assert_eq!(shared.bytes_sent, dense.bytes_sent);
    assert_eq!(shared.bytes_inter, dense.bytes_inter);
    assert_eq!(shared.state, "shared");
}

#[test]
fn shared_state_rejects_unsupported_combinations() {
    // Shared state is a sim-only layout with provable-elision
    // preconditions; every unsupported combination is a build-time hard
    // error naming the conflict, never a silent dense fallback.
    let Some(s) = session() else { return };
    let base = || {
        s.train("quad")
            .algo_sel(local())
            .workers(4)
            .steps(16)
            .seed(11)
            .slowmo_cfg(SlowMoCfg::new(1.0, 0.7, 8))
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::free())
            .compute_time(1e-6)
            .state(StateMode::Shared)
    };
    fn msg(b: slowmo::session::TrainBuilder<'_>) -> String {
        format!("{:#}", b.run().unwrap_err())
    }
    let threaded = msg(base().exec(ExecMode::Threaded));
    assert!(threaded.contains("sim-only"), "{threaded}");
    let avg = msg(base().buffers(BufferStrategy::Average));
    assert!(avg.contains("Average"), "{avg}");
    let chaos = msg(base().chaos_opt(Some(net_chaos())));
    assert!(chaos.contains("chaos"), "{chaos}");
    let quorum = msg(
        base().slowmo_cfg(SlowMoCfg::new(1.0, 0.7, 8).with_quorum(2)),
    );
    assert!(quorum.contains("quorum"), "{quorum}");
}

#[test]
fn static_gossip_degenerates_to_exponential_at_m2() {
    // At m=2 the time-varying exponential graph has a single offset, so
    // the frozen-ring variant is the same communication pattern bit for
    // bit; at m=4 the offsets diverge (1,2,1,2,… vs always 1) — same
    // bytes, different mixing.
    let Some(s) = session() else { return };
    let algo = |spec: &str| {
        let mut sel = s.registry().parse(spec).unwrap();
        sel.inner = sgd();
        sel
    };
    let slowmo = Some(SlowMoCfg::new(1.0, 0.6, 8));
    let exp2 = quadx(&s, 2, 64, algo("sgp"), slowmo.clone(), None);
    let ring2 =
        quadx(&s, 2, 64, algo("sgp-static"), slowmo.clone(), None);
    assert_eq!(ring2.final_params, exp2.final_params);
    assert!(ring2.final_params.is_some());
    assert_eq!(ring2.train_curve, exp2.train_curve);
    assert_eq!(ring2.bytes_sent, exp2.bytes_sent);
    assert!(ring2.algo.contains("sgp-static"), "{}", ring2.algo);
    let exp4 = quadx(&s, 4, 64, algo("sgp"), slowmo.clone(), None);
    let ring4 = quadx(&s, 4, 64, algo("sgp-static"), slowmo, None);
    assert_eq!(ring4.bytes_sent, exp4.bytes_sent);
    assert_ne!(
        ring4.final_params, exp4.final_params,
        "m=4: frozen ring must mix differently from the \
         time-varying graph"
    );
}

#[test]
#[ignore] // expensive: m=32 × repeated runs; run with --ignored
fn threaded_high_concurrency_stress() {
    // Far more workers than cores: the spin-then-yield receive path
    // must stay deterministic under heavy oversubscription. Repeated
    // same-seed threaded runs are bit-identical, and all equal sim.
    let Some(s) = session() else { return };
    let sgp = AlgoSel::with_inner("sgp", sgd());
    let slowmo = Some(SlowMoCfg::new(1.0, 0.6, 8));
    let sim = quade(&s, 32, 96, sgp.clone(), slowmo.clone(), None,
                    ExecMode::Sim);
    for round in 0..3 {
        let thr = quade(&s, 32, 96, sgp.clone(), slowmo.clone(), None,
                        ExecMode::Threaded);
        assert_backends_agree(&sim, &thr, &format!("round {round}"));
    }
}
