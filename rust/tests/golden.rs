//! Golden-vector verification: the Rust mirror optimizers must match the
//! pure-jnp oracle bit-for-bit-ish (f32 rounding), via the vectors the AOT
//! exporter dumped into artifacts/golden.json.
//!
//! Regenerate the fixtures with one command (seed 1234 is the committed
//! baseline; see ROADMAP.md "Testing"):
//!
//!   python python/compile/aot.py --out-dir artifacts --golden-seed 1234

use slowmo::jsonx::{parse, Json};
use slowmo::optim;
use slowmo::optim::kernels::Kernels;
use slowmo::runtime::artifacts_dir;
use slowmo::slowmo::{OuterRegistry, OuterSel};
use slowmo::topology::Groups;
use slowmo::util::allclose;

fn golden() -> Option<Json> {
    let path = format!("{}/golden.json", artifacts_dir());
    let text = std::fs::read_to_string(&path).ok()?;
    Some(parse(&text).expect("golden.json parses"))
}

fn vecf(case: &Json, key: &str) -> Vec<f32> {
    case.path(key)
        .and_then(|v| v.as_f32_vec())
        .unwrap_or_else(|| panic!("missing {key}"))
}

fn scalar(case: &Json, key: &str) -> f32 {
    case.path(key).and_then(|v| v.as_f64()).unwrap() as f32
}

#[test]
fn slowmo_update_matches_jnp_oracle() {
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let c = g.get("slowmo").unwrap();
    let mut x0 = vecf(c, "in.x0");
    let xt = vecf(c, "in.xt");
    let mut u = vecf(c, "in.u");
    optim::slowmo_update(
        &mut x0,
        &xt,
        &mut u,
        scalar(c, "in.gamma"),
        scalar(c, "in.alpha"),
        scalar(c, "in.beta"),
    );
    assert!(allclose(&x0, &vecf(c, "out.x"), 1e-6, 1e-7), "x mismatch");
    assert!(allclose(&u, &vecf(c, "out.u"), 1e-6, 1e-7), "u mismatch");
}

#[test]
fn outer_registry_slowmo_rule_matches_jnp_oracle() {
    // The registry-built `slowmo` rule is the same kernel the oracle
    // fixtures were generated against — the golden vectors hold
    // unchanged through the OuterOpt indirection.
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let c = g.get("slowmo").unwrap();
    let mut x0 = vecf(c, "in.x0");
    let xt = vecf(c, "in.xt");
    let sel = OuterSel::slowmo(scalar(c, "in.alpha"), scalar(c, "in.beta"));
    let rule = OuterRegistry::builtin().build(&sel).unwrap();
    let mut st = rule.init(x0.len());
    st.bufs[0] = vecf(c, "in.u");
    rule.step(&mut x0, &xt, &mut st, scalar(c, "in.gamma"), 0,
              &Kernels::Native)
        .unwrap();
    assert!(allclose(&x0, &vecf(c, "out.x"), 1e-6, 1e-7), "x mismatch");
    assert!(allclose(&st.bufs[0], &vecf(c, "out.u"), 1e-6, 1e-7),
            "u mismatch");
}

#[test]
fn hier_two_level_run_matches_oracle() {
    // The two-level fixture: unequal groups, the |G|·g/m weighted mean,
    // then one slow-momentum update on the reduced average — pins the
    // exact op order the distributed reduce mirrors.
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let Some(c) = g.get("hier") else {
        eprintln!(
            "SKIP: golden.json predates the hier fixture — regenerate \
             with `python python/compile/aot.py --out-dir artifacts \
             --golden-seed 1234`"
        );
        return;
    };
    let spec = c
        .path("in.groups")
        .and_then(|v| v.as_str())
        .expect("hier fixture names its partition");
    let xs: Vec<Vec<f32>> = c
        .path("in.xs")
        .and_then(|v| v.as_arr())
        .expect("hier fixture carries worker vectors")
        .iter()
        .map(|v| v.as_f32_vec().expect("worker vector"))
        .collect();
    let groups = Groups::parse(spec, xs.len()).unwrap();
    let xbar = groups.weighted_mean(&xs);
    assert!(
        allclose(&xbar, &vecf(c, "out.xbar"), 1e-6, 1e-7),
        "two-level weighted mean mismatch"
    );
    let mut x0 = vecf(c, "in.x0");
    let mut u = vecf(c, "in.u");
    optim::slowmo_update(
        &mut x0,
        &xbar,
        &mut u,
        scalar(c, "in.gamma"),
        scalar(c, "in.alpha"),
        scalar(c, "in.beta"),
    );
    assert!(allclose(&x0, &vecf(c, "out.x"), 1e-6, 1e-7), "x mismatch");
    assert!(allclose(&u, &vecf(c, "out.u"), 1e-6, 1e-7), "u mismatch");
}

#[test]
fn nesterov_matches_jnp_oracle() {
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let c = g.get("nesterov").unwrap();
    let mut x = vecf(c, "in.x");
    let mut h = vecf(c, "in.h");
    let gr = vecf(c, "in.g");
    optim::nesterov_step(
        &mut x,
        &mut h,
        &gr,
        scalar(c, "in.gamma"),
        scalar(c, "in.beta0"),
        scalar(c, "in.wd"),
    );
    assert!(allclose(&x, &vecf(c, "out.x"), 1e-6, 1e-7), "x mismatch");
    assert!(allclose(&h, &vecf(c, "out.h"), 1e-6, 1e-7), "h mismatch");
}

#[test]
fn adam_matches_jnp_oracle() {
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let c = g.get("adam").unwrap();
    let mut x = vecf(c, "in.x");
    let mut h = vecf(c, "in.h");
    let mut v = vecf(c, "in.v");
    let gr = vecf(c, "in.g");
    optim::adam_step(
        &mut x,
        &mut h,
        &mut v,
        &gr,
        scalar(c, "in.gamma"),
        scalar(c, "in.beta1"),
        scalar(c, "in.beta2"),
        scalar(c, "in.eps"),
        scalar(c, "in.step"),
    );
    assert!(allclose(&x, &vecf(c, "out.x"), 1e-5, 1e-7), "x mismatch");
    assert!(allclose(&h, &vecf(c, "out.h"), 1e-6, 1e-7), "h mismatch");
    assert!(allclose(&v, &vecf(c, "out.v"), 1e-6, 1e-7), "v mismatch");
}

#[test]
fn axpy_matches_jnp_oracle() {
    let Some(g) = golden() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let c = g.get("axpy").unwrap();
    let x = vecf(c, "in.x");
    let y = vecf(c, "in.y");
    let mut out = vec![0.0; x.len()];
    optim::axpy_mix(&mut out, &x, &y, scalar(c, "in.a"), scalar(c, "in.b"));
    assert!(allclose(&out, &vecf(c, "out.z"), 1e-6, 1e-7));
}
