//! Integration tests for the session/builder API surface: registry
//! round-trips from *outside* the crate, custom algorithm registration by
//! string key, and observer-driven streaming / early stopping. Runs use
//! the native quad fast path (engine-free session), skipping when no
//! artifacts are exported.

use slowmo::algorithms::{AlgoCtx, BaseAlgorithm, Ctx, WorkerState};
use slowmo::net::CostModel;
use slowmo::optim::kernels::{InnerOpt, Kernels};
use slowmo::session::{Session, TrainBuilder};
use slowmo::slowmo::{OuterOpt, OuterOptState, SlowMoCfg};
use slowmo::trainer::{
    OuterEvent, Recorder, RunControl, RunObserver, Schedule, StepEvent,
};
use std::sync::Arc;

fn session() -> Option<Session> {
    match Session::native_only() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: no artifacts");
            None
        }
    }
}

fn quad<'s>(s: &'s Session, steps: u64) -> TrainBuilder<'s> {
    s.train("quad")
        .algo_sel(slowmo::algorithms::AlgoSel::with_inner(
            "local",
            InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
        ))
        .workers(2)
        .steps(steps)
        .seed(5)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
}

#[test]
fn every_registered_key_builds_and_runs_name_round_trip() {
    let Some(s) = session() else { return };
    for key in s.registry().keys() {
        let sel = s.registry().parse(key).unwrap();
        let algo = s.registry().build(&sel, 4).unwrap();
        assert!(
            algo.name().starts_with(key),
            "{} does not round-trip key {key}",
            algo.name()
        );
    }
}

/// A deliberately simple out-of-crate algorithm: plain SGD on `state.x`,
/// no communication. Proves the registry's factory surface is sufficient
/// for algorithms defined outside the crate (the DeMo-style extension
/// path).
struct Anchor {
    inner: InnerOpt,
}

impl BaseAlgorithm for Anchor {
    fn name(&self) -> String {
        "anchor-sgd".into()
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn step(
        &self,
        _ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        _k: u64,
    ) -> anyhow::Result<()> {
        for (x, gi) in state.x.iter_mut().zip(g) {
            *x -= gamma * gi;
        }
        state.z.copy_from_slice(&state.x);
        Ok(())
    }

    fn lockstep(&self) -> bool {
        false
    }

    fn comm_elems_per_step(&self, _d: usize) -> usize {
        0
    }
}

#[test]
fn custom_out_of_crate_algorithm_runs_by_string_key() {
    let Some(mut s) = session() else { return };
    s.registry_mut().register(
        "anchor",
        "test-only plain SGD defined outside the crate",
        false,
        |c: &AlgoCtx| Arc::new(Anchor { inner: c.inner }) as Arc<dyn BaseAlgorithm>,
    );
    // Reachable through the spec-string path, exactly like built-ins.
    let r = s
        .train("quad")
        .algo("anchor")
        .workers(2)
        .steps(64)
        .seed(5)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .run()
        .unwrap();
    assert!(r.algo.starts_with("anchor"), "{}", r.algo);
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
    // And it wraps in SlowMo like any other base algorithm.
    let r = s
        .train("quad")
        .algo("anchor")
        .workers(2)
        .steps(64)
        .slowmo(0.5, 8)
        .schedule(Schedule::Const(0.2))
        .cost(CostModel::free())
        .compute_time(1e-6)
        .run()
        .unwrap();
    assert!(r.algo.contains("slowmo"), "{}", r.algo);
}

/// A deliberately simple out-of-crate outer rule: pull x0 halfway toward
/// the average, no state buffers. Proves the OuterRegistry's factory
/// surface is sufficient for rules defined outside the crate (the
/// DeMo-style extension path, mirroring `Anchor` for base algorithms).
struct HalfPull;

impl OuterOpt for HalfPull {
    fn key(&self) -> String {
        "halfpull".into()
    }

    fn params(&self) -> String {
        String::new()
    }

    fn n_bufs(&self) -> usize {
        0
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        _state: &mut OuterOptState,
        _gamma: f32,
        _t: u64,
        _kernels: &Kernels,
    ) -> anyhow::Result<()> {
        for (a, b) in x0.iter_mut().zip(xt) {
            *a = 0.5 * *a + 0.5 * b;
        }
        Ok(())
    }
}

#[test]
fn custom_out_of_crate_outer_rule_runs_by_string_key() {
    let Some(mut s) = session() else { return };
    s.outer_registry_mut().register(
        "halfpull",
        "test-only half-pull rule defined outside the crate",
        &[],
        |_| Ok(std::sync::Arc::new(HalfPull) as std::sync::Arc<dyn OuterOpt>),
    );
    let r = s
        .train("quad")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 })
        .workers(2)
        .steps(64)
        .seed(5)
        .outer("halfpull")
        .tau(8)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::free())
        .compute_time(1e-6)
        .run()
        .unwrap();
    assert!(r.algo.contains("halfpull"), "{}", r.algo);
    assert_eq!(r.outer.as_deref(), Some("halfpull"));
    let first = r.train_curve.first().unwrap().1;
    let last = r.train_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
    // Unknown keys still fail hard through the same path.
    assert!(s
        .train("quad")
        .algo("local")
        .outer("nope")
        .run()
        .is_err());
}

#[test]
fn custom_out_of_crate_compressor_runs_by_string_key() {
    use slowmo::compress::{CompressState, Compressor, Wire};

    /// A deliberately simple out-of-crate codec: keep every even
    /// coordinate (half the values, half the bytes). Proves the
    /// CompressRegistry's factory surface is sufficient for codecs
    /// defined outside the crate, mirroring `Anchor` / `HalfPull`.
    struct EvenOnly;

    impl Compressor for EvenOnly {
        fn key(&self) -> String {
            "evenonly".into()
        }

        fn params(&self) -> String {
            String::new()
        }

        fn encode(
            &self,
            x: &[f32],
            _st: &mut CompressState,
            _site: u64,
        ) -> Wire {
            let data: Vec<f32> =
                x.iter().step_by(2).copied().collect();
            Wire {
                data,
                d: x.len(),
                wire_bytes: self.wire_bytes(x.len()),
            }
        }

        fn decode(&self, wire: &Wire, out: &mut [f32]) {
            out.fill(0.0);
            for (j, &v) in wire.data.iter().enumerate() {
                out[2 * j] = v;
            }
        }

        fn wire_bytes(&self, d: usize) -> u64 {
            d.div_ceil(2) as u64 * 4
        }
    }

    let Some(mut s) = session() else { return };
    s.compress_registry_mut().register(
        "evenonly",
        "test-only even-coordinate codec defined outside the crate",
        &[],
        false,
        |_, _| {
            Ok(std::sync::Arc::new(EvenOnly)
                as std::sync::Arc<dyn Compressor>)
        },
    );
    let run = |spec: Option<&str>| {
        let mut b = s
            .train("quad")
            .algo("local")
            .inner(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 })
            .workers(2)
            .steps(64)
            .seed(5)
            .slowmo(0.5, 8)
            .schedule(Schedule::Const(0.2))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(CostModel::ethernet_10g())
            .compute_time(1e-6);
        if let Some(spec) = spec {
            b = b.compress(spec);
        }
        b.run().unwrap()
    };
    let raw = run(None);
    let r = run(Some("evenonly"));
    assert!(r.algo.contains("evenonly"), "{}", r.algo);
    assert_eq!(r.compress.as_deref(), Some("evenonly"));
    assert!(r.bytes_sent < raw.bytes_sent);
    assert!(r.bytes_saved > 0);
    // And it wraps in error feedback like any other inner codec.
    let ef = run(Some("ef:evenonly"));
    assert_eq!(ef.compress.as_deref(), Some("ef:evenonly"));
    assert!(ef.bytes_sent < raw.bytes_sent);
    // Unknown keys still fail hard through the same path.
    assert!(s
        .train("quad")
        .algo("local")
        .compress("nope")
        .run()
        .is_err());
}

struct StopAfter {
    after: u64,
    seen: u64,
}

impl RunObserver for StopAfter {
    fn on_step(&mut self, _ev: &StepEvent) -> RunControl {
        self.seen += 1;
        if self.seen >= self.after {
            RunControl::Stop
        } else {
            RunControl::Continue
        }
    }
}

#[test]
fn observer_early_stop_halts_quad_run() {
    let Some(s) = session() else { return };
    let full = quad(&s, 200).run().unwrap();
    assert_eq!(full.steps_run, 200);
    assert_eq!(full.steps, 200);

    let mut obs = StopAfter { after: 25, seen: 0 };
    let stopped = quad(&s, 200).run_observed(&mut obs).unwrap();
    // The stop lands at the next checkpoint (default granularity 16
    // without SlowMo): strictly fewer steps than requested, but at least
    // as many as the observer saw.
    assert!(stopped.steps_run < 200,
            "run was not halted: {}", stopped.steps_run);
    assert!(stopped.steps_run >= 25);
    assert!(obs.seen < 200, "observer saw {} steps", obs.seen);
    assert_eq!(stopped.steps, 200); // requested budget is preserved
    assert!(stopped.train_curve.len() < full.train_curve.len());
}

#[test]
fn observer_early_stop_respects_custom_granularity() {
    let Some(s) = session() else { return };
    let mut obs = StopAfter { after: 10, seen: 0 };
    let r = quad(&s, 100)
        .stop_check_every(20)
        .run_observed(&mut obs)
        .unwrap();
    assert_eq!(r.steps_run, 20);
}

#[test]
fn observer_early_stop_with_slowmo_collectives_stays_aligned() {
    // Lockstep-sensitive variant: the SlowMo exact average is a blocking
    // collective, so a misaligned stop would deadlock or panic. Four
    // workers, stop requested from an outer-boundary callback.
    struct StopAtOuter(u64);
    impl RunObserver for StopAtOuter {
        fn on_outer_boundary(&mut self, ev: &OuterEvent) -> RunControl {
            if ev.outer_t >= self.0 {
                RunControl::Stop
            } else {
                RunControl::Continue
            }
        }
    }
    let Some(s) = session() else { return };
    let mut obs = StopAtOuter(2);
    let r = quad(&s, 160)
        .workers(4)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.5, 8))
        .run_observed(&mut obs)
        .unwrap();
    // Second boundary fires at k=15; the stop lands at the next τ
    // checkpoint (k=16).
    assert_eq!(r.steps_run, 16);
}

#[test]
fn observer_streams_all_event_kinds() {
    let Some(s) = session() else { return };
    let mut rec = Recorder::new();
    let r = quad(&s, 40)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.5, 10))
        .eval_every(10)
        .run_observed(&mut rec)
        .unwrap();
    assert_eq!(r.steps_run, 40);
    assert_eq!(rec.steps.len(), 40);
    assert_eq!(rec.outers.len(), 4); // k = 9, 19, 29, 39
    assert_eq!(rec.evals.len(), 4); // steps 10, 20, 30, 40
    assert_eq!(rec.evals.last().unwrap().step, 40);
    // Streamed losses match what the worker recorded.
    assert!(rec.steps.iter().all(|e| e.loss.is_finite()));
    assert!(rec.steps.windows(2).all(|w| w[1].step == w[0].step + 1));
}

#[test]
fn session_caches_models_and_inits_across_runs() {
    let Some(s) = session() else { return };
    let m1 = s.model("quad", false).unwrap();
    let m2 = s.model("quad", false).unwrap();
    assert!(Arc::ptr_eq(&m1, &m2), "model executor must be cached");
    let i1 = s.init("quad").unwrap();
    let i2 = s.init("quad").unwrap();
    assert!(Arc::ptr_eq(&i1, &i2), "init vector must be cached");
}
