//! Property tests for the collective/partition primitives, the
//! push-sum delay-tolerance claim, the compression codecs and the
//! semi-synchronous quorum-boundary bitwise contracts, built on the
//! seeded `testkit` mini-framework (override seeds with
//! SLOWMO_TEST_SEED / case counts with SLOWMO_PROP_CASES).

use slowmo::exec::run_workers;
use slowmo::net::collectives::chunk_ranges;
use slowmo::net::{ring_allreduce_mean, CostModel, Fabric};
use slowmo::rng::stream;
use slowmo::testkit::{default_cases, forall_seeded, test_seed, WorkerVecs};

// ------------------------------------------------------------ chunk_ranges

fn is_exact_partition(len: usize, m: usize) -> bool {
    let r = chunk_ranges(len, m);
    r.len() == m
        && r.first().map(|&(s, _)| s == 0).unwrap_or(false)
        && r.last().map(|&(_, e)| e == len).unwrap_or(false)
        && r.windows(2).all(|w| w[0].1 == w[1].0)
        && r.iter().all(|&(s, e)| s <= e)
}

#[test]
fn chunk_ranges_always_partition_exactly() {
    // Exhaustive over the whole small domain — cheaper than sampling.
    for len in 0..=257 {
        for m in 1..=12 {
            assert!(is_exact_partition(len, m), "len={len} m={m}");
        }
    }
}

#[test]
fn chunk_ranges_m_exceeding_len_yields_empty_chunks() {
    for (len, m) in [(0usize, 1usize), (0, 8), (3, 7), (1, 2), (5, 8)] {
        let r = chunk_ranges(len, m);
        let empties = r.iter().filter(|&&(s, e)| s == e).count();
        assert_eq!(empties, m.saturating_sub(len), "len={len} m={m}");
        assert!(r.iter().all(|&(s, e)| e - s <= 1), "len={len} m={m}");
    }
}

// ------------------------------------------------- ring allreduce == mean

/// Per-element f64 reference mean and accumulated absolute magnitude
/// Σ|x| — the right scale for an ulp bound under cancellation.
fn mean_and_mag(vecs: &[Vec<f32>]) -> (Vec<f64>, Vec<f64>) {
    let m = vecs.len();
    let d = vecs.first().map(|v| v.len()).unwrap_or(0);
    let mut mean = vec![0.0f64; d];
    let mut mag = vec![0.0f64; d];
    for v in vecs {
        for i in 0..d {
            mean[i] += f64::from(v[i]);
            mag[i] += f64::from(v[i]).abs();
        }
    }
    for v in mean.iter_mut() {
        *v /= m as f64;
    }
    (mean, mag)
}

/// The result must be the exact mean up to an ulp-scaled tolerance:
/// an m-term f32 summation has forward error <= (m-1)·eps·Σ|x|, and the
/// final 1/m multiply adds <= eps·|mean| <= eps·Σ|x| — so m·eps·Σ|x|
/// bounds the whole schedule.
fn within_ulp_bound(out: &[f32], mean: &[f64], mag: &[f64], m: usize) -> bool {
    out.len() == mean.len()
        && out.iter().zip(mean.iter().zip(mag)).all(|(&o, (&w, &g))| {
            let tol =
                (m as f64) * f64::from(f32::EPSILON) * g.max(1e-6);
            (f64::from(o) - w).abs() <= tol
        })
}

fn allreduce_matches_mean(vecs: &[Vec<f32>]) -> bool {
    let m = vecs.len();
    let (mean, mag) = mean_and_mag(vecs);
    let fabric = Fabric::new(m, CostModel::free());
    let outs = run_workers(m, |w| {
        let mut x = vecs[w].clone();
        ring_allreduce_mean(&fabric, w, &mut x, 0.0);
        x
    });
    outs.iter().all(|out| within_ulp_bound(out, &mean, &mag, m))
}

#[test]
fn ring_allreduce_equals_exact_mean_randomized() {
    let gen = WorkerVecs { m_range: (1, 8), d_range: (0, 257), scale: 2.0 };
    for (i, seed) in [test_seed(), test_seed() ^ 0x9E37_79B9, 42]
        .into_iter()
        .enumerate()
    {
        forall_seeded(
            &format!("ring-allreduce == elementwise mean [sweep {i}]"),
            &gen,
            seed,
            default_cases(), // scaled by SLOWMO_PROP_CASES
            |vecs| allreduce_matches_mean(vecs),
        );
    }
}

#[test]
#[ignore = "slow property sweep — run via `cargo test -- --include-ignored`"]
fn ring_allreduce_equals_exact_mean_exhaustive() {
    // Heavier sweep for the CI chaos/property job: every m in 1..=8 with
    // many random lengths (incl. the empty vector and len < m).
    let gen = WorkerVecs { m_range: (1, 8), d_range: (0, 257), scale: 2.0 };
    for round in 0..8u64 {
        forall_seeded(
            &format!("ring-allreduce exhaustive [round {round}]"),
            &gen,
            test_seed().wrapping_add(round),
            2 * default_cases(), // scaled by SLOWMO_PROP_CASES
            |vecs| allreduce_matches_mean(vecs),
        );
    }
}

// --------------------------------------------- push-sum delay invariance

/// Single-threaded push-sum simulator over a ring with chaos-style
/// delivery: each round every node halves its biased mass (p·x, p·w) with
/// its successor; a message is held for a seeded lag of up to `max_lag`
/// rounds, and each delivery round merges in a seeded, permuted order
/// (bounded reordering). Returns the per-node de-biased values after
/// `rounds` mixing rounds plus a drain.
fn push_sum(m: usize, rounds: u64, seed: u64, max_lag: u64) -> Vec<f64> {
    struct Msg {
        to: usize,
        x: f64,
        w: f64,
        deliver_at: u64,
    }
    let mut x: Vec<f64> =
        (0..m).map(|i| (i as f64) * 1.75 - (m as f64) * 0.5).collect();
    let total0: f64 = x.iter().sum();
    let mut wt = vec![1.0f64; m];
    let mut pending: Vec<Msg> = Vec::new();
    // A final lag-free tail lets every delayed share land and mix.
    let tail = 4 * (max_lag + 1) + 64;
    for k in 0..rounds + tail {
        for i in 0..m {
            let lag = if k < rounds && max_lag > 0 {
                stream(seed, "pushsum.lag", i as u64, k, 0).below(max_lag + 1)
            } else {
                0
            };
            pending.push(Msg {
                to: (i + 1) % m,
                x: x[i] * 0.5,
                w: wt[i] * 0.5,
                deliver_at: k + 1 + lag,
            });
            x[i] *= 0.5;
            wt[i] *= 0.5;
        }
        // Deliver everything due, in a seeded permuted order.
        let mut due: Vec<usize> = (0..pending.len())
            .filter(|&i| pending[i].deliver_at <= k + 1)
            .collect();
        let mut rng = stream(seed, "pushsum.perm", k, 0, 0);
        rng.shuffle(&mut due);
        for &i in &due {
            let msg = &pending[i];
            x[msg.to] += msg.x;
            wt[msg.to] += msg.w;
        }
        pending.retain(|msg| msg.deliver_at > k + 1);

        // Invariants: mass sums to m, value sum is conserved, including
        // whatever is still in flight.
        let w_total: f64 = wt.iter().sum::<f64>()
            + pending.iter().map(|p| p.w).sum::<f64>();
        assert!(
            (w_total - m as f64).abs() < 1e-9,
            "push-sum mass broken at round {k}: {w_total}"
        );
        let x_total: f64 = x.iter().sum::<f64>()
            + pending.iter().map(|p| p.x).sum::<f64>();
        assert!(
            (x_total - total0).abs() < 1e-9 * (1.0 + total0.abs()),
            "push-sum value sum broken at round {k}: {x_total} vs {total0}"
        );
    }
    assert!(pending.is_empty(), "drain left messages in flight");
    x.iter().zip(&wt).map(|(&xi, &wi)| xi / wi).collect()
}

#[test]
fn push_sum_invariant_under_delays_and_reordering() {
    // The docstring claim in net/fabric.rs: push-sum is correct for
    // arbitrarily delayed messages. Weights always sum to m (asserted
    // inside the simulator every round) and the delayed, reordered run
    // converges to the same average as the undelayed run.
    for m in [2usize, 3, 5, 8] {
        let mean = (0..m)
            .map(|i| (i as f64) * 1.75 - (m as f64) * 0.5)
            .sum::<f64>()
            / m as f64;
        let calm = push_sum(m, 600, test_seed(), 0);
        let chaotic = push_sum(m, 600, test_seed(), 3);
        for i in 0..m {
            assert!(
                (calm[i] - mean).abs() < 1e-6,
                "calm node {i}: {} vs {mean}",
                calm[i]
            );
            assert!(
                (chaotic[i] - mean).abs() < 1e-6,
                "delayed node {i}: {} vs {mean}",
                chaotic[i]
            );
            assert!(
                (chaotic[i] - calm[i]).abs() < 1e-6,
                "delayed vs calm consensus differ at node {i}"
            );
        }
    }
}

// --------------------------------------------------- compression codecs
// Encode→decode round-trip bounds for every built-in compressor, plus
// the registry-wide wire_bytes() <= 4·d honesty bound. All seeded through
// testkit::forall (SLOWMO_TEST_SEED / SLOWMO_PROP_CASES), with shrinking
// toward minimal failing vectors.

use slowmo::compress::{
    site, CompressRegistry, CompressState, Compressor,
};
use slowmo::testkit::{forall, VecF32};

fn vecs() -> VecF32 {
    VecF32 { min_len: 1, max_len: 300, scale: 2.0 }
}

fn round_trip(c: &dyn Compressor, x: &[f32]) -> Vec<f32> {
    let mut st = CompressState::new(test_seed(), 0);
    let wire = c.encode(x, &mut st, site::GRAD);
    assert_eq!(
        wire.wire_bytes,
        c.wire_bytes(x.len()),
        "encode must report the same wire size the cost model charges"
    );
    let mut out = vec![0.0f32; x.len()];
    c.decode(&wire, &mut out);
    out
}

fn build(spec: &str) -> std::sync::Arc<dyn Compressor> {
    let r = CompressRegistry::builtin();
    r.build(&r.parse(spec).unwrap()).unwrap()
}

#[test]
fn fp16_round_trip_within_half_ulp() {
    let c = build("fp16");
    forall("fp16 round-trip ulp bound", &vecs(), |x| {
        let y = round_trip(c.as_ref(), x);
        // Normal halves: rel error <= 2^-11; subnormals: abs <= 2^-25.
        x.iter().zip(&y).all(|(&a, &b)| {
            (b - a).abs() <= a.abs() * 4.9e-4 + 3.1e-8
        })
    });
}

#[test]
fn bf16_round_trip_within_half_ulp() {
    let c = build("bf16");
    forall("bf16 round-trip ulp bound", &vecs(), |x| {
        let y = round_trip(c.as_ref(), x);
        // bf16 keeps 8 mantissa bits: rel error <= 2^-8.
        x.iter().zip(&y).all(|(&a, &b)| {
            (b - a).abs() <= a.abs() * 4e-3 + 1e-37
        })
    });
}

#[test]
fn topk_preserves_the_largest_support_exactly() {
    let c = build("topk:0.3");
    forall("topk support preservation", &vecs(), |x| {
        let y = round_trip(c.as_ref(), x);
        let d = x.len();
        let k = ((0.3f64 * d as f64).ceil() as usize).clamp(1, d);
        let kept: Vec<usize> =
            (0..d).filter(|&i| y[i] != 0.0).collect();
        // Kept coordinates carry the original values bit-for-bit.
        if !kept.iter().all(|&i| y[i] == x[i]) {
            return false;
        }
        // No more than k survive (fewer only when x itself has zeros —
        // a kept zero decodes to 0 and is indistinguishable from
        // dropped here).
        if kept.len() > k {
            return false;
        }
        // Support optimality, unconditionally: every kept |value| >=
        // every dropped one (a flipped selection comparator fails this).
        let min_kept = kept
            .iter()
            .map(|&i| x[i].abs())
            .fold(f32::INFINITY, f32::min);
        let max_dropped = (0..d)
            .filter(|i| !kept.contains(i))
            .map(|i| x[i].abs())
            .fold(0.0f32, f32::max);
        min_kept >= max_dropped
    });
}

#[test]
fn randk_rescale_is_exact_on_kept_coords() {
    let c = build("randk:0.3");
    forall("randk kept-coordinate rescale", &vecs(), |x| {
        let y = round_trip(c.as_ref(), x);
        let d = x.len();
        let k = ((0.3f64 * d as f64).ceil() as usize).clamp(1, d);
        let scale = d as f32 / k as f32;
        let nonzero = (0..d).filter(|&i| y[i] != 0.0).count();
        nonzero <= k
            && (0..d).all(|i| y[i] == 0.0 || y[i] == x[i] * scale)
    });
}

#[test]
fn signsgd_agrees_in_sign_with_uniform_chunk_magnitude() {
    let c = build("signsgd:32");
    forall("signsgd sign agreement", &vecs(), |x| {
        let y = round_trip(c.as_ref(), x);
        x.iter().zip(&y).all(|(&a, &b)| {
            if a > 0.0 {
                b >= 0.0
            } else if a < 0.0 {
                b <= 0.0
            } else {
                // Zeros encode as +scale (sign convention).
                b >= 0.0
            }
        })
    });
}

#[test]
fn ef_residual_equals_dropped_mass() {
    // One EF step: decoded + residual == input (exactly, in f64).
    let c = build("ef:topk:0.25");
    forall("ef residual accounting", &vecs(), |x| {
        let mut st = CompressState::new(test_seed(), 0);
        let mut y = x.clone();
        c.transcode(&mut y, &mut st, site::OUTER);
        let r = st.residual_opt(site::OUTER).unwrap();
        x.iter().zip(&y).zip(r).all(|((&a, &b), &rv)| {
            // b + rv == a up to one f32 rounding of the subtraction.
            (f64::from(b) + f64::from(rv) - f64::from(a)).abs()
                <= f64::from(a.abs()) * 1e-6 + 1e-7
        })
    });
}

// ------------------------------------------- DCT / demo codec lane
// The frequency-domain subsystem: orthonormal DCT-II/III round-trip and
// Parseval bounds for the kernel pair, and the demo codec's contracts —
// keep-all ≈ identity, exact (bitwise) residual accounting, and seeded
// bit-determinism of the encode + residual state.

use slowmo::optim::kernels::{dct2_chunked, dct3_chunked, DctPlans};

#[test]
fn dct_forward_inverse_round_trip_ulp_bound() {
    // f32 basis + f64 accumulation measured at <= 1.2e-7·max|x| worst
    // case over this length range; 1e-6 leaves ~8x margin.
    let plans = DctPlans::new();
    forall("dct2/dct3 round-trip", &vecs(), |x| {
        let d = x.len();
        let mut f = vec![0.0f32; d];
        let mut y = vec![0.0f32; d];
        dct2_chunked(&plans, x, &mut f, 64);
        dct3_chunked(&plans, &f, &mut y, 64);
        let mag = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        x.iter()
            .zip(&y)
            .all(|(&a, &b)| (b - a).abs() <= mag * 1e-6 + 1e-7)
    });
}

#[test]
fn dct_parseval_energy_preservation() {
    // The basis is orthonormal, so per-chunk (and hence total) energy is
    // preserved: ||dct2(x)||² == ||x||² within accumulation error.
    let plans = DctPlans::new();
    forall("dct2 Parseval", &vecs(), |x| {
        let d = x.len();
        let mut f = vec![0.0f32; d];
        dct2_chunked(&plans, x, &mut f, 64);
        let ex: f64 = x.iter().map(|&v| f64::from(v).powi(2)).sum();
        let ef: f64 = f.iter().map(|&v| f64::from(v).powi(2)).sum();
        (ex - ef).abs() <= ex * 1e-6 + 1e-12
    });
}

#[test]
fn demo_keep_all_round_trips_within_ulp_bound() {
    // demo:1.0 transmits every coefficient: the transcode is exactly
    // dct3(dct2(x)) — identity within the round-trip bound — and the
    // frequency residual is identically zero.
    let c = build("demo:1.0");
    forall("demo keep-all ≈ identity", &vecs(), |x| {
        let mut st = CompressState::new(test_seed(), 0);
        let mut y = x.clone();
        c.transcode(&mut y, &mut st, site::OUTER);
        let r = st.residual_opt(site::OUTER).unwrap();
        if r.iter().any(|&v| v != 0.0) {
            return false;
        }
        let mag = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        x.iter()
            .zip(&y)
            .all(|(&a, &b)| (b - a).abs() <= mag * 1e-6 + 1e-7)
    });
}

#[test]
fn demo_residual_accounting_is_an_exact_spectrum_partition() {
    // From a fresh state, the transmitted coefficients and the new
    // residual partition dct2(x) *bitwise*: every coefficient lands in
    // exactly one of the two, unmodified.
    let c = build("demo:0.25");
    let plans = DctPlans::new();
    forall("demo residual partition", &vecs(), |x| {
        let d = x.len();
        let mut st = CompressState::new(test_seed(), 0);
        let wire = c.encode(x, &mut st, site::OUTER);
        let mut f = vec![0.0f32; d];
        dct2_chunked(&plans, x, &mut f, 64);
        let r = st.residual_opt(site::OUTER).unwrap();
        let k = wire.data.len() / 2;
        let mut kept = vec![false; d];
        for j in 0..k {
            let i = wire.data[j].to_bits() as usize;
            if i >= d
                || wire.data[k + j].to_bits() != f[i].to_bits()
                || r[i] != 0.0
            {
                return false;
            }
            kept[i] = true;
        }
        kept.iter()
            .enumerate()
            .all(|(i, &was)| was || r[i].to_bits() == f[i].to_bits())
    });
}

#[test]
fn demo_encode_and_residual_state_are_bit_deterministic() {
    let c = build("demo:0.1");
    forall("demo bit-determinism", &vecs(), |x| {
        let once = |_| {
            let mut st = CompressState::new(test_seed(), 0);
            // Two messages so the second encode exercises the carried
            // residual, not just the fresh-state path.
            c.encode(x, &mut st, site::OUTER);
            let wire = c.encode(x, &mut st, site::OUTER);
            let bits: Vec<u32> =
                wire.data.iter().map(|v| v.to_bits()).collect();
            let res: Vec<u32> = st
                .residual_opt(site::OUTER)
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect();
            (bits, wire.wire_bytes, res)
        };
        once(0) == once(1)
    });
}

// ---------------------------------------------------- group partitions
// The hierarchical-topology invariants: every accepted Groups spec
// partitions 0..m exactly once; malformed specs are hard parse errors
// naming the offending token; and the distributed two-level reduce
// agrees with the serial weighted-mean reference within an ulp bound.

use slowmo::topology::Groups;
use slowmo::testkit::{Pair, UsizeIn};

#[test]
fn groups_count_spec_partitions_exactly_once_randomized() {
    // Randomized (m, g): an accepted count spec covers every worker
    // exactly once with consistent group_of/members views; rejections
    // happen only for g = 0 or g > m.
    forall(
        "groups count spec partitions 0..m",
        &Pair(UsizeIn(1, 64), UsizeIn(0, 80)),
        |&(m, g)| match Groups::parse(&g.to_string(), m) {
            Ok(gr) => {
                let mut seen = vec![0usize; m];
                for gi in 0..gr.g() {
                    let members = gr.members(gi);
                    if members.is_empty() {
                        return false;
                    }
                    for &w in members {
                        if w >= m || gr.group_of(w) != gi {
                            return false;
                        }
                        seen[w] += 1;
                    }
                }
                gr.g() == g
                    && gr.m() == m
                    && seen.iter().all(|&c| c == 1)
            }
            Err(e) => (g == 0 || g > m) && e.contains("group count"),
        },
    );
}

#[test]
fn groups_range_spec_round_trips_through_canonical_form() {
    // Randomized partitions: cut 0..m at seeded points, render as a
    // range spec, parse it back, and check the exact-partition property
    // plus spec() round-trip stability.
    forall(
        "groups range spec round-trips",
        &Pair(UsizeIn(1, 48), UsizeIn(0, 1_000_000)),
        |&(m, salt)| {
            let mut rng = stream(salt as u64, "groups-cuts", m as u64, 0, 0);
            let mut cuts: Vec<usize> = (1..m)
                .filter(|_| rng.below(3) == 0)
                .collect();
            cuts.push(m);
            cuts.dedup();
            let mut spec_parts = Vec::new();
            let mut start = 0;
            for &end in &cuts {
                spec_parts.push(format!("{}-{}", start, end - 1));
                start = end;
            }
            let spec = spec_parts.join("|");
            let Ok(gr) = Groups::parse(&spec, m) else {
                return false;
            };
            let mut seen = vec![0usize; m];
            for gi in 0..gr.g() {
                for &w in gr.members(gi) {
                    seen[w] += 1;
                }
            }
            seen.iter().all(|&c| c == 1)
                && Groups::parse(&gr.spec(), m) == Ok(gr)
        },
    );
}

#[test]
fn groups_malformed_specs_name_the_offending_token() {
    for (m, spec, needle) in [
        (4, "0", ">= 1"),
        (4, "9", "exceeds m=4"),
        (8, "0-3|3-7", "overlap at worker 3"),
        (8, "0-2|4-7", "worker 3"),
        (8, "0-3|4-9", "4-9"),
        (4, "3-1|0|2", "inverted"),
        (4, "0-x|1-3", "0-x"),
        (4, "", "expected"),
    ] {
        let e = Groups::parse(spec, m).unwrap_err();
        assert!(e.contains(needle), "{spec:?}: {e}");
    }
}

#[test]
fn two_level_weighted_mean_matches_exact_mean_randomized() {
    // The serial reference (which the distributed two-level reduce
    // mirrors and the golden fixture pins) equals the exact global mean
    // within the same m·eps·Σ|x| ulp bound as the flat ring.
    let gen = WorkerVecs { m_range: (1, 9), d_range: (1, 97), scale: 2.0 };
    forall_seeded(
        "two-level weighted mean == exact mean",
        &gen,
        test_seed() ^ 0x5EED,
        default_cases(),
        |vecs| {
            let m = vecs.len();
            let (mean, mag) = mean_and_mag(vecs);
            // Sweep a few partitions of this m, including unequal ones.
            for g in 1..=m {
                let gr = Groups::even(m, g).unwrap();
                let out = gr.weighted_mean(vecs);
                // The two-stage schedule adds a scale and a g-term sum on
                // top of the flat bound — 4x covers it comfortably.
                if !within_ulp_bound(&out, &mean, &mag, 4 * m) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn distributed_two_level_reduce_matches_serial_reference() {
    // The fabric-level two-level reduce (intra rings -> weighted leader
    // ring -> broadcast) must land on the same mean as the serial
    // reference, and bit-identically across workers.
    use slowmo::net::Fabric;
    let gen = WorkerVecs { m_range: (2, 8), d_range: (1, 65), scale: 2.0 };
    forall_seeded(
        "distributed two-level == serial weighted mean",
        &gen,
        test_seed() ^ 0x600D,
        default_cases() / 2,
        |vecs| {
            let m = vecs.len();
            let (mean, mag) = mean_and_mag(vecs);
            for g in 1..=m {
                let gr = std::sync::Arc::new(Groups::even(m, g).unwrap());
                let fabric = Fabric::new(m, CostModel::free());
                let live: Vec<usize> = (0..m).collect();
                let outs = run_workers(m, |w| {
                    let mut x = vecs[w].clone();
                    let mut comp =
                        slowmo::compress::CompressState::default();
                    slowmo::slowmo::hier::test_two_level_average(
                        &fabric, &gr, w, &live, &mut x, &mut comp,
                    )
                    .unwrap();
                    x
                });
                for out in &outs {
                    if out != &outs[0] {
                        return false;
                    }
                    if !within_ulp_bound(out, &mean, &mag, 4 * m) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn wire_bytes_never_exceed_raw_for_any_registered_key() {
    // The honesty bound the cost model relies on: no registered codec —
    // at default arguments or the extreme frac=1.0 — may charge more
    // than raw f32 (sparse codecs fall back to dense accounting).
    let r = CompressRegistry::builtin();
    let mut specs: Vec<String> =
        r.keys().iter().map(|k| k.to_string()).collect();
    specs.extend(
        ["topk:1.0", "randk:1.0", "signsgd:1", "ef:topk:1.0",
         "demo:1.0", "demo:1.0,1", "demo:0.5,7"]
            .iter()
            .map(|s| s.to_string()),
    );
    for spec in &specs {
        // `ef` needs an inner codec at parse time; give it one.
        let spec =
            if spec == "ef" { "ef:topk:0.1" } else { spec.as_str() };
        let c = r.build(&r.parse(spec).unwrap()).unwrap();
        for d in 0..=130usize {
            assert!(
                c.wire_bytes(d) <= d as u64 * 4,
                "{spec}: wire_bytes({d}) = {} > {}",
                c.wire_bytes(d),
                d * 4
            );
        }
    }
}

// ------------------------------------------- semi-synchronous boundaries
// Bitwise contracts for the q-of-m quorum boundary: the s=1 fold must
// equal a reference serial computation (ring mean, STALE_LAMBDA
// down-weighting, the outer rule's exact f32 op order), and the s=0
// drop must be the elastic fault-window machinery under another name.

use slowmo::algorithms::{BaseAlgorithm, Local, WorkerState};
use slowmo::net::{ChaosCfg, ChaosPlan, FaultWindow};
use slowmo::optim::kernels::{InnerOpt, Kernels};
use slowmo::slowmo::{
    outer_update, OuterRegistry, OuterSel, OuterState, SlowMoCfg,
    STALE_LAMBDA,
};
use std::sync::Arc;

/// Fixed m=3 (exactly one quorum-late worker), random d and values.
fn trio() -> WorkerVecs {
    WorkerVecs { m_range: (3, 3), d_range: (1, 129), scale: 2.0 }
}

#[test]
fn staleness_fold_matches_reference_serial_computation_bitwise() {
    // m=3, q=2, s=1, `avg` rule: arrival stamps are the worker ids, so
    // worker 2 misses boundary 0 and its snapshot folds into boundary
    // 1's average. The two-boundary trajectory must be BITWISE equal to
    // a serial f32 reference mirroring the implementation's op order:
    // n=2 ring mean (a+b)*0.5, fold acc = x̄·q then += λ·stale then
    // /weight, and the avg rule's un = (x0-x̄)/γ; x0 -= γ·un (which is
    // NOT a plain copy — γ·((x0-x̄)/γ) ≠ x0-x̄ in general).
    let cfg = SlowMoCfg::with_outer(OuterSel::new("avg"), 4)
        .with_quorum(2)
        .with_staleness(1);
    let rule = OuterRegistry::builtin().build(&cfg.outer).unwrap();
    let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
    let kernels = Kernels::Native;
    let gamma = 0.1f32;
    forall_seeded(
        "s=1 fold == serial reference",
        &trio(),
        test_seed(),
        default_cases() / 2,
        |vecs| {
            let d = vecs[0].len();
            let init = vec![1.0f32; d];
            let fabric = Fabric::new(3, CostModel::free());
            let out = run_workers(3, |w| {
                let mut st = WorkerState::new(&init, algo.inner());
                st.x.copy_from_slice(&vecs[w]);
                let mut ou = OuterState::new(&init, &*rule);
                let mut clock = w as f64;
                for _ in 0..2 {
                    clock = outer_update(
                        &cfg, &*rule, &algo, &fabric, &kernels, w,
                        &mut st, &mut ou, gamma, clock, None,
                    )
                    .unwrap();
                }
                (st, ou)
            });
            // Serial reference in the implementation's exact op order.
            let step = |x0: &mut [f32], xt: &[f32]| {
                for (a, &b) in x0.iter_mut().zip(xt) {
                    let un = (*a - b) / gamma;
                    *a -= gamma * un;
                }
            };
            let mut x0 = init.clone();
            // Boundary 0: quorum ring {0,1}; the n=2 ring mean is
            // (a+b)*0.5 on both members (f32 addition commutes bitwise).
            let xbar0: Vec<f32> = (0..d)
                .map(|i| (vecs[0][i] + vecs[1][i]) * 0.5)
                .collect();
            step(&mut x0, &xbar0);
            // Boundary 1: both ring members carry x0 bit-for-bit, so
            // the ring mean is x0 itself ((a+a)*0.5 == a exactly); then
            // worker 2's boundary-0 snapshot folds in, down-weighted.
            let xbar1: Vec<f32> = (0..d)
                .map(|i| {
                    let mut acc = x0[i] * 2.0;
                    acc += STALE_LAMBDA * vecs[2][i];
                    let mut weight = 2.0f32;
                    weight += STALE_LAMBDA;
                    acc / weight
                })
                .collect();
            step(&mut x0, &xbar1);
            out.iter()
                .all(|(st, ou)| ou.t == 2 && st.x == x0 && ou.x0 == x0)
                && out[2].1.quorum_misses == 1
                && out[2].1.stale_folds == 1
        },
    );
}

#[test]
fn quorum_drop_matches_elastic_fault_window_bitwise() {
    // The s=0 semantics claim: a quorum-late worker IS an elastic
    // fault-window outage of one boundary. Run A: q=2, no chaos (worker
    // 2's arrival stamp makes it late at boundary 0, it resyncs at
    // boundary 1). Run B: blocking boundaries with an explicit
    // FaultWindow covering boundary 0 and the same arrival stamps.
    // Every worker's (x, x0, u, t, clock) must match bitwise across the
    // two runs — including the late worker's pulled rejoin state.
    let cfg_q = SlowMoCfg::new(1.0, 0.5, 4).with_quorum(2);
    let cfg_f = SlowMoCfg::new(1.0, 0.5, 4);
    let reg = OuterRegistry::builtin();
    let rule_q = reg.build(&cfg_q.outer).unwrap();
    let rule_f = reg.build(&cfg_f.outer).unwrap();
    let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
    let kernels = Kernels::Native;
    let plan = Arc::new(
        ChaosPlan::new(
            ChaosCfg {
                faults: vec![FaultWindow {
                    worker: 2,
                    fail_at: 0,
                    rejoin_at: 1,
                }],
                ..ChaosCfg::default()
            },
            3,
            &CostModel::free(),
        )
        .unwrap(),
    );
    forall_seeded(
        "s=0 drop == elastic fault window",
        &trio(),
        test_seed(),
        default_cases() / 2,
        |vecs| {
            let d = vecs[0].len();
            let init = vec![1.0f32; d];
            let run = |quorum: bool| {
                let fabric = if quorum {
                    Fabric::new(3, CostModel::free())
                } else {
                    Fabric::with_chaos(
                        3,
                        CostModel::free(),
                        Arc::clone(&plan),
                    )
                };
                let (cfg, rule) = if quorum {
                    (&cfg_q, &rule_q)
                } else {
                    (&cfg_f, &rule_f)
                };
                run_workers(3, |w| {
                    let mut st = WorkerState::new(&init, algo.inner());
                    st.x.copy_from_slice(&vecs[w]);
                    let mut ou = OuterState::new(&init, &**rule);
                    let mut clock = w as f64;
                    for t in 0..2u32 {
                        // Divergent inner progress before each boundary
                        // (identical in both runs; the down worker's is
                        // discarded by the rejoin pull either way).
                        for (i, x) in st.x.iter_mut().enumerate() {
                            *x -= 0.01
                                * (w as f32 + 1.0)
                                * (t as f32 + 1.0)
                                + 0.001 * i as f32;
                        }
                        let chaos =
                            if quorum { None } else { Some(&*plan) };
                        clock = outer_update(
                            cfg, &**rule, &algo, &fabric, &kernels, w,
                            &mut st, &mut ou, 0.1, clock, chaos,
                        )
                        .unwrap();
                    }
                    (st, ou, clock)
                })
            };
            let a = run(true);
            let b = run(false);
            a.iter().zip(&b).all(|((sa, oa, ca), (sb, ob, cb))| {
                sa.x == sb.x
                    && oa.x0 == ob.x0
                    && oa.u() == ob.u()
                    && oa.t == ob.t
                    && ca == cb
            })
        },
    );
}
