//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf).
mod common;
fn main() {
    let env = common::env();
    slowmo::bench::micro::run(&env).unwrap();
}
