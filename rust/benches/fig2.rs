//! Regenerates paper Figure 2 / B.1 (validation + training curves).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    slowmo::bench::experiments::fig2(&env, &tasks).unwrap();
}
