//! Regenerates paper Figure B.2 (alpha x beta sweep).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    // B.2a: CIFAR with OSGP base; B.2b: LM with Adam base.
    slowmo::bench::experiments::figb2(&env, &tasks[0], &[0.5, 1.0],
                                      &[0.0, 0.2, 0.4, 0.6, 0.8]).unwrap();
    slowmo::bench::experiments::figb2(&env, &tasks[2], &[0.5, 1.0],
                                      &[0.1, 0.3, 0.5]).unwrap();
}
