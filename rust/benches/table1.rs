//! Regenerates paper Table 1 (and Table B.1's NLL columns).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    slowmo::bench::experiments::table1(&env, &tasks).unwrap();
}
