//! Shared bench-entry plumbing (included by each bench target via `mod`).
//!
//! Scale comes from SLOWMO_SCALE (ci|quick|standard|full, default ci);
//! each bench regenerates one paper table/figure via bench::experiments.
use slowmo::bench::{Env, Scale};

pub fn env() -> Env {
    let scale = std::env::var("SLOWMO_SCALE")
        .ok()
        .and_then(|s| s.parse::<Scale>().ok())
        .unwrap_or(Scale::Ci);
    Env::load(scale).expect("run `make artifacts` first")
}

pub fn tasks(env: &Env) -> Vec<slowmo::bench::experiments::TaskSpec> {
    use slowmo::bench::experiments::TaskSpec;
    vec![TaskSpec::cifar(), TaskSpec::imagenet(), TaskSpec::wmt(env.scale)]
}
