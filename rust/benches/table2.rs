//! Regenerates paper Table 2 (time/iteration, analytic cost model).
mod common;
fn main() {
    let env = common::env();
    slowmo::bench::experiments::table2(&env).unwrap();
}
