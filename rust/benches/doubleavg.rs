//! Regenerates the §4 double-averaging comparison.
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    slowmo::bench::experiments::doubleavg(&env, &tasks[1]).unwrap();
}
