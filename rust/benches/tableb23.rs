//! Regenerates paper Tables B.2/B.3 (buffer strategies).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    // B.2: ImageNet/Nesterov; B.3: WMT/Adam.
    slowmo::bench::experiments::tableb23(&env, &tasks[1]).unwrap();
    slowmo::bench::experiments::tableb23(&env, &tasks[2]).unwrap();
}
