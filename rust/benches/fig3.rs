//! Regenerates paper Figure 3 (effect of tau).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    // The paper sweeps tau on ImageNet (3a) and WMT (3b).
    slowmo::bench::experiments::fig3(&env, &tasks[1]).unwrap();
    slowmo::bench::experiments::fig3(&env, &tasks[2]).unwrap();
}
