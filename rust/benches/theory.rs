//! Empirically validates Theorem 1 / Corollaries 1-2 on the quadratic
//! workload (linear speedup in m; tau effect; Lookahead case).
mod common;
fn main() {
    let env = common::env();
    slowmo::bench::experiments::theory(&env).unwrap();
}
