//! Regenerates paper Table B.4 (multi-seed std devs on CIFAR).
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    slowmo::bench::experiments::tableb4(&env, &tasks[0]).unwrap();
}
