//! Regenerates the §6 SGP-SlowMo-noaverage comparison.
mod common;
fn main() {
    let env = common::env();
    let tasks = common::tasks(&env);
    slowmo::bench::experiments::noaverage(&env, &tasks[1]).unwrap();
}
