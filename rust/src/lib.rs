//! # slowmo — SlowMo distributed training framework (ICLR 2020 reproduction)
//!
//! A three-layer reproduction of *SlowMo: Improving Communication-Efficient
//! Distributed SGD with Slow Momentum* (Wang, Tantia, Ballas & Rabbat):
//!
//! - **Layer 3 (this crate)** — the distributed coordinator: worker threads,
//!   gossip/allreduce fabric over time-varying exponential topologies, the
//!   τ-step inner scheduler and the SlowMo outer-momentum controller.
//! - **Layer 2** — JAX model/optimizer graphs AOT-lowered to HLO text
//!   (`python/compile/`), executed here via the PJRT CPU client
//!   ([`runtime`]). Python never runs on the training path.
//! - **Layer 1** — Pallas kernels for the optimizer/attention hot-spots
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algorithms;
pub mod bench;
pub mod benchkit;
pub mod clix;
pub mod configx;
pub mod data;
pub mod exec;
pub mod jsonx;
pub mod net;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod slowmo;
pub mod testkit;
pub mod topology;
pub mod trainer;
pub mod util;
