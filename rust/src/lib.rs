//! # slowmo — SlowMo distributed training framework (ICLR 2020 reproduction)
//!
//! A three-layer reproduction of *SlowMo: Improving Communication-Efficient
//! Distributed SGD with Slow Momentum* (Wang, Tantia, Ballas & Rabbat):
//!
//! - **Layer 3 (this crate)** — the distributed coordinator: worker threads,
//!   gossip/allreduce fabric over time-varying exponential topologies, the
//!   τ-step inner scheduler and the SlowMo outer-momentum controller.
//! - **Layer 2** — JAX model/optimizer graphs AOT-lowered to HLO text
//!   (`python/compile/`), executed here via the PJRT CPU client
//!   ([`runtime`]). Python never runs on the training path.
//! - **Layer 1** — Pallas kernels for the optimizer/attention hot-spots
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! ## Running experiments
//!
//! The canonical entry point is the [`session`] API: a
//! [`session::Session`] loads the artifacts once (manifest, PJRT engine,
//! model executors, optimizer kernels, init vectors are all cached across
//! runs) and the fluent [`session::TrainBuilder`] describes each run:
//!
//! ```no_run
//! use slowmo::session::Session;
//!
//! let session = Session::open()?;
//! let result = session
//!     .train("cifar-mlp")          // preset from `slowmo info`
//!     .algo("sgp")                 // any key in the AlgoRegistry
//!     .slowmo(0.7, 12)             // β=0.7, τ=12 (α=1, paper default)
//!     .workers(8)
//!     .run()?;
//! println!("{}: best loss {:.4}", result.algo, result.best_train_loss);
//! # anyhow::Ok(())
//! ```
//!
//! Base algorithms live in a string-keyed
//! [`algorithms::AlgoRegistry`] — registering a new
//! [`algorithms::BaseAlgorithm`] factory under a key makes it reachable
//! from the CLI (`--algo`), TOML configs, the bench harness and the
//! builder (see ROADMAP.md "Adding an algorithm"). The outer update rule
//! applied at SlowMo boundaries is pluggable the same way through the
//! [`slowmo::OuterRegistry`] (`--outer`, `[outer]` tables,
//! `TrainBuilder::outer`; see ROADMAP.md "Adding an outer optimizer"):
//! `slowmo` is the paper's rule, with `avg`, `lookahead`, `nesterov` and
//! `adam` built in. Communication compression (quantize / sparsify /
//! error-feedback) is a third registry surface ([`compress`]):
//! `--compress`, `[compress]` tables and `TrainBuilder::compress` select
//! a codec applied to every message lane with honest wire-byte
//! accounting. Live runs stream through the
//! [`trainer::RunObserver`] trait (`on_step`, `on_outer_boundary`,
//! `on_eval`) for progress reporting, metric streaming and early
//! stopping.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algorithms;
pub mod bench;
pub mod benchkit;
pub mod clix;
pub mod compress;
pub mod configx;
pub mod data;
pub mod exec;
pub mod jsonx;
pub mod net;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod session;
pub mod slowmo;
pub mod testkit;
pub mod topology;
pub mod trainer;
pub mod util;
