//! Property-based testing mini-framework (proptest replacement).
//!
//! Provides seeded generators and a `forall` runner with shrinking for the
//! coordinator invariant tests (topology stochasticity, collective
//! correctness, optimizer equivalences). Failures print the seed + case so
//! they are reproducible; shrinking bisects sized inputs toward minimal
//! counterexamples.

use crate::rng::Xoshiro256;

/// A generator produces a case from an RNG and can try to shrink it.
pub trait Gen {
    type Item: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Item;
    /// Candidate smaller versions of a failing case (best-first).
    fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
        let _ = item;
        Vec::new()
    }
}

/// Number of cases per property (override with SLOWMO_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("SLOWMO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn env_seed(var: &str) -> Option<u64> {
    let s = std::env::var(var).ok()?;
    let s = s.trim();
    let parsed = if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    };
    // An explicitly-set-but-garbled seed must fail loudly: silently
    // falling back to the default would make a "reproduction" run lie.
    Some(parsed.unwrap_or_else(|| {
        panic!("{var}={s:?} is not a valid u64 seed (decimal or 0x-hex)")
    }))
}

/// Base seed for the property suites (override with SLOWMO_TEST_SEED,
/// hex `0x...` or decimal). Failure reports print the effective seed, so
/// a failing CI sweep is reproduced by exporting the same value locally.
pub fn test_seed() -> u64 {
    env_seed("SLOWMO_TEST_SEED").unwrap_or(0xC0FFEE)
}

/// Seed threaded into every `ChaosCfg` the test suites build (override
/// with SLOWMO_CHAOS_SEED; defaults to [`test_seed`]). Keeping one knob
/// for both suites means a single env var re-rolls the whole chaos run.
pub fn chaos_seed() -> u64 {
    env_seed("SLOWMO_CHAOS_SEED").unwrap_or_else(test_seed)
}

/// Run `prop` over `cases` generated inputs; panic with a reproducible
/// report (seed, case index, shrunk input) on the first failure.
pub fn forall<G: Gen>(name: &str, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    forall_seeded(name, gen, test_seed(), default_cases(), prop)
}

pub fn forall_seeded<G: Gen>(
    name: &str,
    gen: &G,
    seed: u64,
    cases: usize,
    prop: impl Fn(&G::Item) -> bool,
) {
    let mut rng = Xoshiro256::seed_from(seed);
    for case_idx in 0..cases {
        let case = gen.generate(&mut rng);
        if !prop(&case) {
            let shrunk = shrink_loop(gen, case.clone(), &prop);
            panic!(
                "property {name:?} failed (seed={seed:#x}, case={case_idx})\n\
                 original: {case:?}\nshrunk:   {shrunk:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Item,
    prop: &impl Fn(&G::Item) -> bool,
) -> G::Item {
    // Up to 200 shrink steps: take the first smaller case that still fails.
    for _ in 0..200 {
        let mut improved = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    failing
}

// ----------------------------------------------------------- primitive gens

/// usize in [lo, hi] (inclusive). Shrinks toward lo.
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Item = usize;

    fn generate(&self, rng: &mut Xoshiro256) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, &item: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if item > self.0 {
            out.push(self.0);
            let mid = self.0 + (item - self.0) / 2;
            if mid != self.0 && mid != item {
                out.push(mid);
            }
            out.push(item - 1);
        }
        out
    }
}

/// f32 vector with length in [min_len, max_len], values N(0, scale).
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f32,
}

impl Gen for VecF32 {
    type Item = Vec<f32>;

    fn generate(&self, rng: &mut Xoshiro256) -> Vec<f32> {
        let n = self.min_len
            + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, self.scale);
        v
    }

    fn shrink(&self, item: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if item.len() > self.min_len {
            // Halve the tail.
            let keep = (item.len() / 2).max(self.min_len);
            out.push(item[..keep].to_vec());
        }
        // Zero out values (simplest content).
        if item.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; item.len()]);
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Item = (A::Item, B::Item);

    fn generate(&self, rng: &mut Xoshiro256) -> Self::Item {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
        let mut out: Vec<Self::Item> = self
            .0
            .shrink(&item.0)
            .into_iter()
            .map(|a| (a, item.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink(&item.1)
                .into_iter()
                .map(|b| (item.0.clone(), b)),
        );
        out
    }
}

/// Vector of m f32-vectors of equal length (worker parameter sets).
pub struct WorkerVecs {
    pub m_range: (usize, usize),
    pub d_range: (usize, usize),
    pub scale: f32,
}

impl Gen for WorkerVecs {
    type Item = Vec<Vec<f32>>;

    fn generate(&self, rng: &mut Xoshiro256) -> Vec<Vec<f32>> {
        let m = self.m_range.0
            + rng.below((self.m_range.1 - self.m_range.0 + 1) as u64) as usize;
        let d = self.d_range.0
            + rng.below((self.d_range.1 - self.d_range.0 + 1) as u64) as usize;
        (0..m)
            .map(|_| {
                let mut v = vec![0.0; d];
                rng.fill_normal(&mut v, self.scale);
                v
            })
            .collect()
    }

    fn shrink(&self, item: &Vec<Vec<f32>>) -> Vec<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        if item.len() > self.m_range.0 {
            out.push(item[..item.len() - 1].to_vec());
        }
        if let Some(first) = item.first() {
            if first.len() > self.d_range.0 {
                let keep = (first.len() / 2).max(self.d_range.0);
                out.push(item.iter().map(|v| v[..keep].to_vec()).collect());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_default_without_env() {
        // The env vars are unset in CI; the defaults anchor the suites.
        if std::env::var("SLOWMO_TEST_SEED").is_err() {
            assert_eq!(test_seed(), 0xC0FFEE);
        }
        if std::env::var("SLOWMO_CHAOS_SEED").is_err()
            && std::env::var("SLOWMO_TEST_SEED").is_err()
        {
            assert_eq!(chaos_seed(), 0xC0FFEE);
        }
        assert_eq!(env_seed("SLOWMO_NO_SUCH_VAR"), None);
    }

    #[test]
    fn usize_gen_in_range() {
        let g = UsizeIn(2, 9);
        let mut rng = Xoshiro256::seed_from(1);
        for _ in 0..1000 {
            let x = g.generate(&mut rng);
            assert!((2..=9).contains(&x));
        }
    }

    #[test]
    fn usize_shrinks_toward_lo() {
        let g = UsizeIn(2, 100);
        let c = g.shrink(&50);
        assert!(c.contains(&2));
        assert!(c.iter().all(|&x| x < 50));
    }

    #[test]
    fn vec_gen_lengths() {
        let g = VecF32 { min_len: 1, max_len: 8, scale: 1.0 };
        let mut rng = Xoshiro256::seed_from(2);
        for _ in 0..200 {
            let v = g.generate(&mut rng);
            assert!((1..=8).contains(&v.len()));
        }
    }

    #[test]
    fn forall_passes_valid_property() {
        forall("sum-commutes", &VecF32 { min_len: 0, max_len: 32, scale: 1.0 },
               |v| {
                   let fwd: f32 = v.iter().sum();
                   let rev: f32 = v.iter().rev().sum();
                   (fwd - rev).abs() <= 1e-3
               });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall("always-false", &UsizeIn(0, 10), |_| false);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property "len < 4" fails for generated vecs of len >= 4; the
        // shrunk case should have exactly the minimal failing size.
        let g = VecF32 { min_len: 0, max_len: 64, scale: 1.0 };
        let res = std::panic::catch_unwind(|| {
            forall_seeded("short", &g, 7, 64, |v| v.len() < 4)
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // shrunk case should be small: len 4..=7 after halving steps
        let shrunk = msg.split("shrunk:").nth(1).unwrap();
        let commas = shrunk.matches(',').count();
        assert!(commas <= 7, "shrunk case too large: {shrunk}");
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = Pair(UsizeIn(0, 10), UsizeIn(0, 10));
        let shrunk = g.shrink(&(5, 5));
        assert!(shrunk.iter().any(|&(a, b)| a < 5 && b == 5));
        assert!(shrunk.iter().any(|&(a, b)| a == 5 && b < 5));
    }

    #[test]
    fn worker_vecs_shapes() {
        let g = WorkerVecs { m_range: (2, 5), d_range: (1, 16), scale: 1.0 };
        let mut rng = Xoshiro256::seed_from(3);
        for _ in 0..100 {
            let w = g.generate(&mut rng);
            assert!((2..=5).contains(&w.len()));
            let d = w[0].len();
            assert!(w.iter().all(|v| v.len() == d));
        }
    }
}
