//! Optimizer kernel dispatch: native Rust mirrors or the AOT Pallas/XLA
//! artifacts via PJRT.
//!
//! Both engines compute identical math (asserted by `rust/tests/` golden
//! and equivalence tests). The PJRT path is the architecture's hot path
//! (L1 Pallas kernels lowered to HLO); the native path is the baseline the
//! perf pass compares against and the engine unit tests run on.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::runtime::engine::{Arg, ExecHandle};
use crate::runtime::{Engine, Manifest};

/// Hyperparameters of the inner (base) optimizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InnerOpt {
    /// SGD with Nesterov momentum + L2 weight decay (paper image tasks).
    Nesterov { beta0: f32, wd: f32 },
    /// Adam (paper WMT task). `beta1/beta2/eps` per Kingma & Ba.
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl InnerOpt {
    pub fn nesterov_default() -> Self {
        InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 }
    }

    pub fn adam_default() -> Self {
        InnerOpt::Adam { beta1: 0.9, beta2: 0.98, eps: 1e-8 }
    }

    pub fn uses_second_moment(&self) -> bool {
        matches!(self, InnerOpt::Adam { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            InnerOpt::Nesterov { .. } => "nesterov-sgd",
            InnerOpt::Adam { .. } => "adam",
        }
    }
}

/// Precomputed orthonormal DCT basis for one transform length.
///
/// `basis[k*n + i] = c_k · cos(π(2i+1)k / 2n)` with `c_0 = √(1/n)` and
/// `c_k = √(2/n)` for `k > 0`. The matrix is orthogonal, so the inverse
/// transform (DCT-III) is the transpose of the same table — one plan
/// serves both directions. Coefficients are stored in f32 but every
/// transform accumulates in f64, which keeps the forward∘inverse
/// round-trip and Parseval error near 1e-7 relative (pinned at 1e-6 by
/// the property suite to leave margin).
pub struct DctPlan {
    n: usize,
    basis: Vec<f32>,
}

impl DctPlan {
    pub fn new(n: usize) -> Self {
        let mut basis = vec![0.0f32; n * n];
        for k in 0..n {
            let c = if k == 0 {
                (1.0 / n as f64).sqrt()
            } else {
                (2.0 / n as f64).sqrt()
            };
            for (i, b) in basis[k * n..(k + 1) * n].iter_mut().enumerate() {
                let theta = std::f64::consts::PI * (2 * i + 1) as f64
                    * k as f64
                    / (2 * n) as f64;
                *b = (c * theta.cos()) as f32;
            }
        }
        DctPlan { n, basis }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Forward orthonormal DCT-II: `out[k] = Σ_i basis[k,i] · x[i]`.
    /// Allocation-free; `x` and `out` must both have length `n`.
    pub fn dct2(&self, x: &[f32], out: &mut [f32]) {
        let n = self.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), n);
        for (k, o) in out.iter_mut().enumerate() {
            let row = &self.basis[k * n..(k + 1) * n];
            let mut acc = 0.0f64;
            for (b, v) in row.iter().zip(x) {
                acc += *b as f64 * *v as f64;
            }
            *o = acc as f32;
        }
    }

    /// Inverse orthonormal DCT-III (transpose of the forward basis):
    /// `out[i] = Σ_k basis[k,i] · x[k]`. Allocation-free.
    pub fn dct3(&self, x: &[f32], out: &mut [f32]) {
        let n = self.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), n);
        for (i, o) in out.iter_mut().enumerate() {
            let mut a = 0.0f64;
            for (k, v) in x.iter().enumerate() {
                a += self.basis[k * n + i] as f64 * *v as f64;
            }
            *o = a as f32;
        }
    }
}

/// Lazy per-length [`DctPlan`] cache. Codecs transform fixed-size chunks
/// (plus one trailing partial chunk), so at most two plans are ever live
/// per (codec, tensor-length) pair; the `Mutex` makes the cache shareable
/// from `&self` codec methods, and `Arc` lets transforms run after the
/// lock is dropped.
pub struct DctPlans {
    plans: Mutex<BTreeMap<usize, Arc<DctPlan>>>,
}

impl DctPlans {
    pub fn new() -> Self {
        DctPlans { plans: Mutex::new(BTreeMap::new()) }
    }

    /// Fetch (or build and cache) the plan for length `n`.
    pub fn get(&self, n: usize) -> Arc<DctPlan> {
        let mut plans = self.plans.lock().unwrap();
        plans
            .entry(n)
            .or_insert_with(|| Arc::new(DctPlan::new(n)))
            .clone()
    }
}

impl Default for DctPlans {
    fn default() -> Self {
        Self::new()
    }
}

/// Chunked forward DCT-II: transform each `chunk`-sized slice of `x`
/// independently into the matching slice of `out` (the trailing partial
/// chunk gets its own shorter plan). Allocation-free after the plans for
/// the lengths involved are cached.
pub fn dct2_chunked(plans: &DctPlans, x: &[f32], out: &mut [f32], chunk: usize) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert!(chunk >= 1);
    for (xs, os) in x.chunks(chunk).zip(out.chunks_mut(chunk)) {
        plans.get(xs.len()).dct2(xs, os);
    }
}

/// Chunked inverse DCT-III, the exact inverse of [`dct2_chunked`] with
/// the same `chunk`.
pub fn dct3_chunked(plans: &DctPlans, x: &[f32], out: &mut [f32], chunk: usize) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert!(chunk >= 1);
    for (xs, os) in x.chunks(chunk).zip(out.chunks_mut(chunk)) {
        plans.get(xs.len()).dct3(xs, os);
    }
}

/// Kernel execution backend.
pub enum Kernels {
    /// Pure-Rust in-place mirrors (see [`crate::optim`]).
    Native,
    /// AOT artifacts executed on PJRT.
    Pjrt {
        nesterov: ExecHandle,
        adam: ExecHandle,
        slowmo: ExecHandle,
        axpy: ExecHandle,
    },
}

impl Kernels {
    /// Load the PJRT optimizer kernels for flat length `d`.
    pub fn pjrt(engine: &Engine, manifest: &Manifest, d: usize) -> Result<Self> {
        let opt = manifest.optim_for(d)?;
        Ok(Kernels::Pjrt {
            nesterov: engine.load(&opt.graphs["nesterov"])?,
            adam: engine.load(&opt.graphs["adam"])?,
            slowmo: engine.load(&opt.graphs["slowmo"])?,
            axpy: engine.load(&opt.graphs["axpy"])?,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Kernels::Native => "native",
            Kernels::Pjrt { .. } => "pjrt",
        }
    }

    /// One inner-optimizer step on (x, h, v) given grads.
    /// `adam_step` is the 1-based Adam counter (ignored for Nesterov).
    pub fn inner_step(
        &self,
        inner: &InnerOpt,
        x: &mut Vec<f32>,
        h: &mut Vec<f32>,
        v: &mut Vec<f32>,
        g: &[f32],
        gamma: f32,
        adam_step: u64,
    ) -> Result<()> {
        match (self, inner) {
            (Kernels::Native, InnerOpt::Nesterov { beta0, wd }) => {
                if h.is_empty() && !x.is_empty() {
                    // Shared-state lean layout: the momentum buffer is
                    // elided, legal only for beta0 = 0 (where the fused
                    // kernel writes h but never reads it — x is
                    // bitwise-identical; see optim::nesterov_step_nomom).
                    anyhow::ensure!(
                        *beta0 == 0.0,
                        "momentum buffer elided but beta0={beta0} != 0 \
                         (lean state layout requires a momentum-free \
                         inner optimizer)"
                    );
                    super::nesterov_step_nomom(x, g, gamma, *wd);
                } else {
                    super::nesterov_step(x, h, g, gamma, *beta0, *wd);
                }
                Ok(())
            }
            (Kernels::Native, InnerOpt::Adam { beta1, beta2, eps }) => {
                super::adam_step(
                    x, h, v, g, gamma, *beta1, *beta2, *eps,
                    adam_step as f32,
                );
                Ok(())
            }
            (
                Kernels::Pjrt { nesterov, .. },
                InnerOpt::Nesterov { beta0, wd },
            ) => {
                let d = x.len();
                let out = nesterov.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(h, &[d]),
                    Arg::F32(g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[*beta0], &[1]),
                    Arg::F32(&[*wd], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x = it.next().unwrap();
                *h = it.next().unwrap();
                Ok(())
            }
            (Kernels::Pjrt { adam, .. }, InnerOpt::Adam { beta1, beta2, eps }) => {
                let d = x.len();
                let out = adam.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(h, &[d]),
                    Arg::F32(v, &[d]),
                    Arg::F32(g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[*beta1], &[1]),
                    Arg::F32(&[*beta2], &[1]),
                    Arg::F32(&[*eps], &[1]),
                    Arg::F32(&[adam_step as f32], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x = it.next().unwrap();
                *h = it.next().unwrap();
                *v = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// SlowMo outer update (Eq. 2–3): updates `x0` and `u` in place.
    pub fn slowmo_update(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        u: &mut Vec<f32>,
        gamma: f32,
        alpha: f32,
        beta: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::slowmo_update(x0, xt, u, gamma, alpha, beta);
                Ok(())
            }
            Kernels::Pjrt { slowmo, .. } => {
                let d = x0.len();
                let out = slowmo.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(xt, &[d]),
                    Arg::F32(u, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[alpha], &[1]),
                    Arg::F32(&[beta], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *u = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Outer-Nesterov update on the displacement pseudo-gradient
    /// (`nesterov` outer rule): updates `x0` and `u` in place. The native
    /// path runs the fused mirror; the PJRT path materializes the
    /// pseudo-gradient and reuses the AOT `nesterov` graph with wd=0.
    pub fn outer_nesterov(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        u: &mut Vec<f32>,
        gamma: f32,
        beta: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::outer_nesterov_step(x0, xt, u, gamma, beta);
                Ok(())
            }
            Kernels::Pjrt { nesterov, .. } => {
                let d = x0.len();
                let g: Vec<f32> = x0
                    .iter()
                    .zip(xt)
                    .map(|(a, b)| (a - b) / gamma)
                    .collect();
                let out = nesterov.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(u, &[d]),
                    Arg::F32(&g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[beta], &[1]),
                    Arg::F32(&[0.0], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *u = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Outer-Adam update on the displacement pseudo-gradient (`adam`
    /// outer rule): updates `x0` and the two moment buffers in place.
    /// `step` is the 1-based outer iteration count (bias correction).
    /// The native path runs the fused mirror; the PJRT path materializes
    /// the pseudo-gradient and reuses the AOT `adam` graph.
    #[allow(clippy::too_many_arguments)]
    pub fn outer_adam(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        gamma: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        step: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::outer_adam_step(x0, xt, m, v, gamma, beta1, beta2,
                                       eps, step);
                Ok(())
            }
            Kernels::Pjrt { adam, .. } => {
                let d = x0.len();
                let g: Vec<f32> = x0
                    .iter()
                    .zip(xt)
                    .map(|(a, b)| (a - b) / gamma)
                    .collect();
                let out = adam.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(m, &[d]),
                    Arg::F32(v, &[d]),
                    Arg::F32(&g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[beta1], &[1]),
                    Arg::F32(&[beta2], &[1]),
                    Arg::F32(&[eps], &[1]),
                    Arg::F32(&[step], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *m = it.next().unwrap();
                *v = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Chunked forward DCT-II into `out` (see [`dct2_chunked`]). There is
    /// no AOT DCT graph — the transform feeds the frequency-domain codec
    /// on the host-side wire path, not the device-side optimizer path —
    /// so both backends run the native kernel; the dispatch method exists
    /// so call sites stay backend-agnostic and the micro bench measures
    /// the same entry point the codec uses.
    pub fn dct2(
        &self,
        plans: &DctPlans,
        x: &[f32],
        out: &mut [f32],
        chunk: usize,
    ) -> Result<()> {
        dct2_chunked(plans, x, out, chunk);
        Ok(())
    }

    /// Chunked inverse DCT-III into `out` (see [`dct3_chunked`]); native
    /// on both backends for the same reason as [`Kernels::dct2`].
    pub fn dct3(
        &self,
        plans: &DctPlans,
        x: &[f32],
        out: &mut [f32],
        chunk: usize,
    ) -> Result<()> {
        dct3_chunked(plans, x, out, chunk);
        Ok(())
    }

    /// Gossip mixing `x <- a*x + b*y`.
    pub fn axpy(
        &self,
        x: &mut Vec<f32>,
        y: &[f32],
        a: f32,
        b: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::axpy_mix_inplace(x, y, a, b);
                Ok(())
            }
            Kernels::Pjrt { axpy, .. } => {
                let d = x.len();
                let out = axpy.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(y, &[d]),
                    Arg::F32(&[a], &[1]),
                    Arg::F32(&[b], &[1]),
                ])?;
                *x = out.into_iter().next().unwrap();
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inner_opt_names_and_moments() {
        assert_eq!(InnerOpt::nesterov_default().name(), "nesterov-sgd");
        assert_eq!(InnerOpt::adam_default().name(), "adam");
        assert!(InnerOpt::adam_default().uses_second_moment());
        assert!(!InnerOpt::nesterov_default().uses_second_moment());
    }

    #[test]
    fn native_kernels_match_direct_calls() {
        let k = Kernels::Native;
        let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 };
        let mut x = vec![1.0f32; 8];
        let mut h = vec![0.0f32; 8];
        let mut v = vec![];
        let g = vec![0.5f32; 8];
        k.inner_step(&inner, &mut x, &mut h, &mut v, &g, 0.1, 1).unwrap();
        let mut x2 = vec![1.0f32; 8];
        let mut h2 = vec![0.0f32; 8];
        crate::optim::nesterov_step(&mut x2, &mut h2, &g, 0.1, 0.9, 0.0);
        assert_eq!(x, x2);
        assert_eq!(h, h2);
    }

    #[test]
    fn native_outer_kernels_match_direct_calls() {
        let k = Kernels::Native;
        let x_init = vec![2.0f32, 1.0, 0.5, -1.0];
        let xt = vec![1.0f32, 1.0, 0.0, 0.0];

        let mut x = x_init.clone();
        let mut u = vec![0.1f32; 4];
        k.outer_nesterov(&mut x, &xt, &mut u, 0.2, 0.7).unwrap();
        let mut x2 = x_init.clone();
        let mut u2 = vec![0.1f32; 4];
        crate::optim::outer_nesterov_step(&mut x2, &xt, &mut u2, 0.2, 0.7);
        assert_eq!(x, x2);
        assert_eq!(u, u2);

        let mut x = x_init.clone();
        let mut m = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        k.outer_adam(&mut x, &xt, &mut m, &mut v, 0.2, 0.9, 0.95, 1e-8,
                     1.0)
            .unwrap();
        let mut x2 = x_init;
        let mut m2 = vec![0.0f32; 4];
        let mut v2 = vec![0.0f32; 4];
        crate::optim::outer_adam_step(&mut x2, &xt, &mut m2, &mut v2, 0.2,
                                      0.9, 0.95, 1e-8, 1.0);
        assert_eq!(x, x2);
        assert_eq!(m, m2);
        assert_eq!(v, v2);
    }

    #[test]
    fn empty_momentum_buffer_dispatches_to_nomom() {
        // Lean layout: an empty h with beta0=0 runs the momentum-free
        // kernel and leaves x bitwise-identical to the dense path.
        let k = Kernels::Native;
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 1e-4 };
        let g = vec![0.5f32, -0.25, 0.125];
        let mut x = vec![1.0f32, 2.0, -3.0];
        let mut h: Vec<f32> = vec![];
        let mut v: Vec<f32> = vec![];
        k.inner_step(&inner, &mut x, &mut h, &mut v, &g, 0.1, 1).unwrap();
        assert!(h.is_empty(), "lean path must not grow h");
        let mut x2 = vec![1.0f32, 2.0, -3.0];
        let mut h2 = vec![0.0f32; 3];
        let mut v2: Vec<f32> = vec![];
        k.inner_step(&inner, &mut x2, &mut h2, &mut v2, &g, 0.1, 1)
            .unwrap();
        assert_eq!(x, x2);
        // Eliding h with real momentum is a hard error, not silent drift.
        let bad = InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 };
        let mut h3: Vec<f32> = vec![];
        let e = k
            .inner_step(&bad, &mut x, &mut h3, &mut v, &g, 0.1, 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("beta0"), "{e}");
    }

    fn lcg_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        // Tiny deterministic generator, enough for kernel smoke tests
        // (the property suite drives the real randomized coverage).
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s = s
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                let u = ((s >> 40) as f32) / ((1u64 << 24) as f32);
                (u * 2.0 - 1.0) * scale
            })
            .collect()
    }

    #[test]
    fn dct_round_trips_within_bound() {
        let plans = DctPlans::new();
        for &n in &[1usize, 2, 3, 7, 64, 65, 128, 300] {
            let x = lcg_vec(n as u64, n, 2.0);
            let mut f = vec![0.0f32; n];
            let mut y = vec![0.0f32; n];
            plans.get(n).dct2(&x, &mut f);
            plans.get(n).dct3(&f, &mut y);
            let mag = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
            for (a, b) in x.iter().zip(&y) {
                assert!(
                    (a - b).abs() <= 1e-6 * mag,
                    "n={n}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn dct_preserves_energy_parseval() {
        let plans = DctPlans::new();
        for &n in &[1usize, 5, 64, 200] {
            let x = lcg_vec(7 + n as u64, n, 3.0);
            let mut f = vec![0.0f32; n];
            plans.get(n).dct2(&x, &mut f);
            let ex: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            let ef: f64 = f.iter().map(|v| (*v as f64).powi(2)).sum();
            assert!(
                (ex - ef).abs() <= 1e-6 * ex.max(1e-12),
                "n={n}: {ex} vs {ef}"
            );
        }
    }

    #[test]
    fn dct_basis_is_orthonormal() {
        let n = 16;
        let plan = DctPlan::new(n);
        assert_eq!(plan.len(), n);
        assert!(!plan.is_empty());
        for k in 0..n {
            for j in 0..n {
                let dot: f64 = (0..n)
                    .map(|i| {
                        plan.basis[k * n + i] as f64
                            * plan.basis[j * n + i] as f64
                    })
                    .sum();
                let want = if k == j { 1.0 } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-6,
                    "rows {k},{j}: dot {dot}"
                );
            }
        }
    }

    #[test]
    fn chunked_dct_matches_per_chunk_and_handles_tail() {
        let plans = DctPlans::new();
        // 150 = 2 full chunks of 64 + a partial chunk of 22.
        let x = lcg_vec(42, 150, 1.5);
        let mut f = vec![0.0f32; 150];
        dct2_chunked(&plans, &x, &mut f, 64);
        let mut want = vec![0.0f32; 150];
        plans.get(64).dct2(&x[..64], &mut want[..64]);
        plans.get(64).dct2(&x[64..128], &mut want[64..128]);
        plans.get(22).dct2(&x[128..], &mut want[128..]);
        assert_eq!(f, want);

        let mut y = vec![0.0f32; 150];
        dct3_chunked(&plans, &f, &mut y, 64);
        let mag = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= 1e-6 * mag);
        }
    }

    #[test]
    fn dct_plan_cache_reuses_plans() {
        let plans = DctPlans::new();
        let a = plans.get(64);
        let b = plans.get(64);
        assert!(Arc::ptr_eq(&a, &b));
        let c = plans.get(32);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn kernels_dct_dispatch_routes_native() {
        let k = Kernels::Native;
        let plans = DctPlans::new();
        let x = lcg_vec(9, 96, 1.0);
        let mut f = vec![0.0f32; 96];
        let mut want = vec![0.0f32; 96];
        k.dct2(&plans, &x, &mut f, 32).unwrap();
        dct2_chunked(&plans, &x, &mut want, 32);
        assert_eq!(f, want);
        let mut y = vec![0.0f32; 96];
        k.dct3(&plans, &f, &mut y, 32).unwrap();
        dct3_chunked(&plans, &f, &mut want, 32);
        assert_eq!(y, want);
    }

    #[test]
    fn native_adam_and_slowmo_and_axpy() {
        let k = Kernels::Native;
        let inner = InnerOpt::adam_default();
        let mut x = vec![0.0f32; 4];
        let mut h = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        k.inner_step(&inner, &mut x, &mut h, &mut v, &g, 1e-3, 1).unwrap();
        assert!(x.iter().all(|&xi| xi < 0.0));

        let mut x0 = vec![1.0f32; 4];
        let mut u = vec![0.0f32; 4];
        k.slowmo_update(&mut x0, &x, &mut u, 0.1, 1.0, 0.0).unwrap();
        assert!(crate::util::allclose(&x0, &x, 1e-6, 1e-7));

        let mut a = vec![2.0f32; 4];
        k.axpy(&mut a, &[4.0; 4], 0.5, 0.25).unwrap();
        assert!(crate::util::allclose(&a, &[2.0; 4], 1e-6, 1e-7));
    }
}
