//! Optimizer kernel dispatch: native Rust mirrors or the AOT Pallas/XLA
//! artifacts via PJRT.
//!
//! Both engines compute identical math (asserted by `rust/tests/` golden
//! and equivalence tests). The PJRT path is the architecture's hot path
//! (L1 Pallas kernels lowered to HLO); the native path is the baseline the
//! perf pass compares against and the engine unit tests run on.

use anyhow::Result;

use crate::runtime::engine::{Arg, ExecHandle};
use crate::runtime::{Engine, Manifest};

/// Hyperparameters of the inner (base) optimizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InnerOpt {
    /// SGD with Nesterov momentum + L2 weight decay (paper image tasks).
    Nesterov { beta0: f32, wd: f32 },
    /// Adam (paper WMT task). `beta1/beta2/eps` per Kingma & Ba.
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl InnerOpt {
    pub fn nesterov_default() -> Self {
        InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 }
    }

    pub fn adam_default() -> Self {
        InnerOpt::Adam { beta1: 0.9, beta2: 0.98, eps: 1e-8 }
    }

    pub fn uses_second_moment(&self) -> bool {
        matches!(self, InnerOpt::Adam { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            InnerOpt::Nesterov { .. } => "nesterov-sgd",
            InnerOpt::Adam { .. } => "adam",
        }
    }
}

/// Kernel execution backend.
pub enum Kernels {
    /// Pure-Rust in-place mirrors (see [`crate::optim`]).
    Native,
    /// AOT artifacts executed on PJRT.
    Pjrt {
        nesterov: ExecHandle,
        adam: ExecHandle,
        slowmo: ExecHandle,
        axpy: ExecHandle,
    },
}

impl Kernels {
    /// Load the PJRT optimizer kernels for flat length `d`.
    pub fn pjrt(engine: &Engine, manifest: &Manifest, d: usize) -> Result<Self> {
        let opt = manifest.optim_for(d)?;
        Ok(Kernels::Pjrt {
            nesterov: engine.load(&opt.graphs["nesterov"])?,
            adam: engine.load(&opt.graphs["adam"])?,
            slowmo: engine.load(&opt.graphs["slowmo"])?,
            axpy: engine.load(&opt.graphs["axpy"])?,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Kernels::Native => "native",
            Kernels::Pjrt { .. } => "pjrt",
        }
    }

    /// One inner-optimizer step on (x, h, v) given grads.
    /// `adam_step` is the 1-based Adam counter (ignored for Nesterov).
    pub fn inner_step(
        &self,
        inner: &InnerOpt,
        x: &mut Vec<f32>,
        h: &mut Vec<f32>,
        v: &mut Vec<f32>,
        g: &[f32],
        gamma: f32,
        adam_step: u64,
    ) -> Result<()> {
        match (self, inner) {
            (Kernels::Native, InnerOpt::Nesterov { beta0, wd }) => {
                super::nesterov_step(x, h, g, gamma, *beta0, *wd);
                Ok(())
            }
            (Kernels::Native, InnerOpt::Adam { beta1, beta2, eps }) => {
                super::adam_step(
                    x, h, v, g, gamma, *beta1, *beta2, *eps,
                    adam_step as f32,
                );
                Ok(())
            }
            (
                Kernels::Pjrt { nesterov, .. },
                InnerOpt::Nesterov { beta0, wd },
            ) => {
                let d = x.len();
                let out = nesterov.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(h, &[d]),
                    Arg::F32(g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[*beta0], &[1]),
                    Arg::F32(&[*wd], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x = it.next().unwrap();
                *h = it.next().unwrap();
                Ok(())
            }
            (Kernels::Pjrt { adam, .. }, InnerOpt::Adam { beta1, beta2, eps }) => {
                let d = x.len();
                let out = adam.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(h, &[d]),
                    Arg::F32(v, &[d]),
                    Arg::F32(g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[*beta1], &[1]),
                    Arg::F32(&[*beta2], &[1]),
                    Arg::F32(&[*eps], &[1]),
                    Arg::F32(&[adam_step as f32], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x = it.next().unwrap();
                *h = it.next().unwrap();
                *v = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// SlowMo outer update (Eq. 2–3): updates `x0` and `u` in place.
    pub fn slowmo_update(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        u: &mut Vec<f32>,
        gamma: f32,
        alpha: f32,
        beta: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::slowmo_update(x0, xt, u, gamma, alpha, beta);
                Ok(())
            }
            Kernels::Pjrt { slowmo, .. } => {
                let d = x0.len();
                let out = slowmo.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(xt, &[d]),
                    Arg::F32(u, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[alpha], &[1]),
                    Arg::F32(&[beta], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *u = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Outer-Nesterov update on the displacement pseudo-gradient
    /// (`nesterov` outer rule): updates `x0` and `u` in place. The native
    /// path runs the fused mirror; the PJRT path materializes the
    /// pseudo-gradient and reuses the AOT `nesterov` graph with wd=0.
    pub fn outer_nesterov(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        u: &mut Vec<f32>,
        gamma: f32,
        beta: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::outer_nesterov_step(x0, xt, u, gamma, beta);
                Ok(())
            }
            Kernels::Pjrt { nesterov, .. } => {
                let d = x0.len();
                let g: Vec<f32> = x0
                    .iter()
                    .zip(xt)
                    .map(|(a, b)| (a - b) / gamma)
                    .collect();
                let out = nesterov.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(u, &[d]),
                    Arg::F32(&g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[beta], &[1]),
                    Arg::F32(&[0.0], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *u = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Outer-Adam update on the displacement pseudo-gradient (`adam`
    /// outer rule): updates `x0` and the two moment buffers in place.
    /// `step` is the 1-based outer iteration count (bias correction).
    /// The native path runs the fused mirror; the PJRT path materializes
    /// the pseudo-gradient and reuses the AOT `adam` graph.
    #[allow(clippy::too_many_arguments)]
    pub fn outer_adam(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        gamma: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        step: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::outer_adam_step(x0, xt, m, v, gamma, beta1, beta2,
                                       eps, step);
                Ok(())
            }
            Kernels::Pjrt { adam, .. } => {
                let d = x0.len();
                let g: Vec<f32> = x0
                    .iter()
                    .zip(xt)
                    .map(|(a, b)| (a - b) / gamma)
                    .collect();
                let out = adam.exec(&[
                    Arg::F32(x0, &[d]),
                    Arg::F32(m, &[d]),
                    Arg::F32(v, &[d]),
                    Arg::F32(&g, &[d]),
                    Arg::F32(&[gamma], &[1]),
                    Arg::F32(&[beta1], &[1]),
                    Arg::F32(&[beta2], &[1]),
                    Arg::F32(&[eps], &[1]),
                    Arg::F32(&[step], &[1]),
                ])?;
                let mut it = out.into_iter();
                *x0 = it.next().unwrap();
                *m = it.next().unwrap();
                *v = it.next().unwrap();
                Ok(())
            }
        }
    }

    /// Gossip mixing `x <- a*x + b*y`.
    pub fn axpy(
        &self,
        x: &mut Vec<f32>,
        y: &[f32],
        a: f32,
        b: f32,
    ) -> Result<()> {
        match self {
            Kernels::Native => {
                super::axpy_mix_inplace(x, y, a, b);
                Ok(())
            }
            Kernels::Pjrt { axpy, .. } => {
                let d = x.len();
                let out = axpy.exec(&[
                    Arg::F32(x, &[d]),
                    Arg::F32(y, &[d]),
                    Arg::F32(&[a], &[1]),
                    Arg::F32(&[b], &[1]),
                ])?;
                *x = out.into_iter().next().unwrap();
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inner_opt_names_and_moments() {
        assert_eq!(InnerOpt::nesterov_default().name(), "nesterov-sgd");
        assert_eq!(InnerOpt::adam_default().name(), "adam");
        assert!(InnerOpt::adam_default().uses_second_moment());
        assert!(!InnerOpt::nesterov_default().uses_second_moment());
    }

    #[test]
    fn native_kernels_match_direct_calls() {
        let k = Kernels::Native;
        let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 };
        let mut x = vec![1.0f32; 8];
        let mut h = vec![0.0f32; 8];
        let mut v = vec![];
        let g = vec![0.5f32; 8];
        k.inner_step(&inner, &mut x, &mut h, &mut v, &g, 0.1, 1).unwrap();
        let mut x2 = vec![1.0f32; 8];
        let mut h2 = vec![0.0f32; 8];
        crate::optim::nesterov_step(&mut x2, &mut h2, &g, 0.1, 0.9, 0.0);
        assert_eq!(x, x2);
        assert_eq!(h, h2);
    }

    #[test]
    fn native_outer_kernels_match_direct_calls() {
        let k = Kernels::Native;
        let x_init = vec![2.0f32, 1.0, 0.5, -1.0];
        let xt = vec![1.0f32, 1.0, 0.0, 0.0];

        let mut x = x_init.clone();
        let mut u = vec![0.1f32; 4];
        k.outer_nesterov(&mut x, &xt, &mut u, 0.2, 0.7).unwrap();
        let mut x2 = x_init.clone();
        let mut u2 = vec![0.1f32; 4];
        crate::optim::outer_nesterov_step(&mut x2, &xt, &mut u2, 0.2, 0.7);
        assert_eq!(x, x2);
        assert_eq!(u, u2);

        let mut x = x_init.clone();
        let mut m = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        k.outer_adam(&mut x, &xt, &mut m, &mut v, 0.2, 0.9, 0.95, 1e-8,
                     1.0)
            .unwrap();
        let mut x2 = x_init;
        let mut m2 = vec![0.0f32; 4];
        let mut v2 = vec![0.0f32; 4];
        crate::optim::outer_adam_step(&mut x2, &xt, &mut m2, &mut v2, 0.2,
                                      0.9, 0.95, 1e-8, 1.0);
        assert_eq!(x, x2);
        assert_eq!(m, m2);
        assert_eq!(v, v2);
    }

    #[test]
    fn native_adam_and_slowmo_and_axpy() {
        let k = Kernels::Native;
        let inner = InnerOpt::adam_default();
        let mut x = vec![0.0f32; 4];
        let mut h = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        k.inner_step(&inner, &mut x, &mut h, &mut v, &g, 1e-3, 1).unwrap();
        assert!(x.iter().all(|&xi| xi < 0.0));

        let mut x0 = vec![1.0f32; 4];
        let mut u = vec![0.0f32; 4];
        k.slowmo_update(&mut x0, &x, &mut u, 0.1, 1.0, 0.0).unwrap();
        assert!(crate::util::allclose(&x0, &x, 1e-6, 1e-7));

        let mut a = vec![2.0f32; 4];
        k.axpy(&mut a, &[4.0; 4], 0.5, 0.25).unwrap();
        assert!(crate::util::allclose(&a, &[2.0; 4], 1e-6, 1e-7));
    }
}
