//! Native Rust mirrors of the Layer-1 optimizer kernels.
//!
//! These exactly mirror `python/compile/kernels/ref.py` and serve three
//! purposes:
//! 1. golden-vector verification that the Rust and JAX stacks agree
//!    (`rust/tests/golden.rs` checks against `artifacts/golden.json`);
//! 2. a native execution engine (`runtime::Engine::Native`) so every
//!    algorithm can also run without PJRT — used heavily by unit tests and
//!    as the perf baseline the PJRT path is compared to;
//! 3. the in-place hot-path variants the coordinator uses for mixing.
//!
//! All functions are allocation-free in-place updates over `&mut [f32]`.

pub mod kernels;

/// Fused Nesterov-momentum SGD step (paper Alg. 2/4 inner step).
///
/// `h <- beta0*h + (g + wd*x)`; `x <- x - gamma*(beta0*h + g + wd*x)`.
pub fn nesterov_step(
    x: &mut [f32],
    h: &mut [f32],
    g: &[f32],
    gamma: f32,
    beta0: f32,
    wd: f32,
) {
    assert_eq!(x.len(), h.len());
    assert_eq!(x.len(), g.len());
    for i in 0..x.len() {
        let gi = g[i] + wd * x[i];
        let hn = beta0 * h[i] + gi;
        h[i] = hn;
        x[i] -= gamma * (beta0 * hn + gi);
    }
}

/// Momentum-free Nesterov step — the `beta0 = 0` special case of
/// [`nesterov_step`] with the `h` buffer elided entirely:
/// `x <- x - gamma*(g + wd*x)`.
///
/// With `beta0 = 0` the fused kernel computes `hn = gi` and then
/// `x -= gamma*(0*hn + gi)`, so `h` is written but never read and the `x`
/// trajectory here is bitwise-identical to [`nesterov_step`] for any `wd`
/// (asserted in tests below). The shared-state trainer mode uses this to
/// drop the per-worker momentum replica at scale.
pub fn nesterov_step_nomom(
    x: &mut [f32],
    g: &[f32],
    gamma: f32,
    wd: f32,
) {
    assert_eq!(x.len(), g.len());
    for i in 0..x.len() {
        let gi = g[i] + wd * x[i];
        x[i] -= gamma * gi;
    }
}

/// Fused Adam step with bias correction (paper Table C.1). `step` is the
/// 1-based global counter `l`.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    x: &mut [f32],
    h: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    gamma: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    step: f32,
) {
    assert_eq!(x.len(), h.len());
    assert_eq!(x.len(), v.len());
    assert_eq!(x.len(), g.len());
    let bc1 = 1.0 - beta1.powf(step);
    let bc2 = 1.0 - beta2.powf(step);
    for i in 0..x.len() {
        let gi = g[i];
        let hn = beta1 * h[i] + (1.0 - beta1) * gi;
        let vn = beta2 * v[i] + (1.0 - beta2) * gi * gi;
        h[i] = hn;
        v[i] = vn;
        let h_hat = hn / bc1;
        let v_hat = vn / bc2;
        x[i] -= gamma * h_hat / (v_hat.sqrt() + eps);
    }
}

/// Fused SlowMo outer update (paper Eq. 2–3), in place:
/// `u <- beta*u + (x0 - xt)/gamma`; returns the new outer iterate in `x0`.
pub fn slowmo_update(
    x0: &mut [f32],
    xt: &[f32],
    u: &mut [f32],
    gamma: f32,
    alpha: f32,
    beta: f32,
) {
    assert_eq!(x0.len(), xt.len());
    assert_eq!(x0.len(), u.len());
    for i in 0..x0.len() {
        let un = beta * u[i] + (x0[i] - xt[i]) / gamma;
        u[i] = un;
        x0[i] -= alpha * gamma * un;
    }
}

/// Fused outer-Nesterov update on the displacement pseudo-gradient
/// `g = (x0 - xt)/gamma` (DeMo-style decoupled momentum), in place:
/// `u <- beta*u + g`; `x0 <- x0 - gamma*(beta*u + g)`. Same math as
/// [`nesterov_step`] with wd=0 and `g` never materialized.
pub fn outer_nesterov_step(
    x0: &mut [f32],
    xt: &[f32],
    u: &mut [f32],
    gamma: f32,
    beta: f32,
) {
    assert_eq!(x0.len(), xt.len());
    assert_eq!(x0.len(), u.len());
    for i in 0..x0.len() {
        let gi = (x0[i] - xt[i]) / gamma;
        let un = beta * u[i] + gi;
        u[i] = un;
        x0[i] -= gamma * (beta * un + gi);
    }
}

/// Fused outer-Adam update on the displacement pseudo-gradient, in place.
/// Same math as [`adam_step`] with `g = (x0 - xt)/gamma` never
/// materialized; `step` is the 1-based outer iteration count driving the
/// bias correction.
#[allow(clippy::too_many_arguments)]
pub fn outer_adam_step(
    x0: &mut [f32],
    xt: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    gamma: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    step: f32,
) {
    assert_eq!(x0.len(), xt.len());
    assert_eq!(x0.len(), m.len());
    assert_eq!(x0.len(), v.len());
    let bc1 = 1.0 - beta1.powf(step);
    let bc2 = 1.0 - beta2.powf(step);
    for i in 0..x0.len() {
        let gi = (x0[i] - xt[i]) / gamma;
        let hn = beta1 * m[i] + (1.0 - beta1) * gi;
        let vn = beta2 * v[i] + (1.0 - beta2) * gi * gi;
        m[i] = hn;
        v[i] = vn;
        let h_hat = hn / bc1;
        let v_hat = vn / bc2;
        x0[i] -= gamma * h_hat / (v_hat.sqrt() + eps);
    }
}

/// `x <- a*x + b*y` (gossip mixing / push-sum combine).
pub fn axpy_mix_inplace(x: &mut [f32], y: &[f32], a: f32, b: f32) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        x[i] = a * x[i] + b * y[i];
    }
}

/// `out <- a*x + b*y` into a separate buffer.
pub fn axpy_mix(out: &mut [f32], x: &[f32], y: &[f32], a: f32, b: f32) {
    assert_eq!(out.len(), x.len());
    assert_eq!(out.len(), y.len());
    for i in 0..out.len() {
        out[i] = a * x[i] + b * y[i];
    }
}

/// `acc += x` (reduction building block).
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for i in 0..acc.len() {
        acc[i] += x[i];
    }
}

/// `x *= s`.
pub fn scale(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Mean of `m` equal-length vectors into `out`.
pub fn mean_into(out: &mut [f32], vecs: &[&[f32]]) {
    assert!(!vecs.is_empty());
    out.copy_from_slice(vecs[0]);
    for v in &vecs[1..] {
        add_assign(out, v);
    }
    scale(out, 1.0 / vecs.len() as f32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::allclose;

    #[test]
    fn nesterov_zero_momentum_is_sgd() {
        let mut x = vec![1.0, 2.0];
        let mut h = vec![0.0, 0.0];
        nesterov_step(&mut x, &mut h, &[0.5, -0.5], 0.1, 0.0, 0.0);
        assert!(allclose(&x, &[0.95, 2.05], 1e-6, 1e-7));
        assert!(allclose(&h, &[0.5, -0.5], 1e-6, 1e-7));
    }

    #[test]
    fn nesterov_momentum_accumulates() {
        let mut x = vec![0.0];
        let mut h = vec![0.0];
        // Two steps with the same gradient: direction grows with momentum.
        nesterov_step(&mut x, &mut h, &[1.0], 1.0, 0.9, 0.0);
        let first = -x[0]; // = 0.9*1 + 1 = 1.9
        assert!((first - 1.9).abs() < 1e-6);
        nesterov_step(&mut x, &mut h, &[1.0], 1.0, 0.9, 0.0);
        // h = 0.9*1 + 1 = 1.9; update = 0.9*1.9 + 1 = 2.71
        assert!((-x[0] - (1.9 + 2.71)).abs() < 1e-5);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut x = vec![10.0];
        let mut h = vec![0.0];
        nesterov_step(&mut x, &mut h, &[0.0], 0.1, 0.0, 0.1);
        assert!(x[0] < 10.0);
    }

    #[test]
    fn nomom_is_bitwise_identical_to_beta0_zero() {
        // x trajectory must match the fused kernel with beta0=0 bit for
        // bit, including with weight decay, across several steps.
        let d = 64;
        for &wd in &[0.0f32, 1e-4, 0.1] {
            let mut xa: Vec<f32> =
                (0..d).map(|i| 1.0 + 0.37 * (i as f32).sin()).collect();
            let mut xb = xa.clone();
            let mut h = vec![0.0f32; d];
            for s in 0..5 {
                let g: Vec<f32> = (0..d)
                    .map(|i| ((i + s) as f32 * 0.13).cos() * 0.7)
                    .collect();
                nesterov_step(&mut xa, &mut h, &g, 0.05, 0.0, wd);
                nesterov_step_nomom(&mut xb, &g, 0.05, wd);
            }
            assert_eq!(xa, xb, "wd={wd}");
        }
    }

    #[test]
    fn adam_first_step_sign_like() {
        let mut x = vec![0.0, 0.0];
        let mut h = vec![0.0, 0.0];
        let mut v = vec![0.0, 0.0];
        adam_step(&mut x, &mut h, &mut v, &[3.0, -0.01], 1e-3, 0.9, 0.98,
                  1e-12, 1.0);
        assert!((x[0] + 1e-3).abs() < 1e-6, "{}", x[0]);
        assert!((x[1] - 1e-3).abs() < 1e-6, "{}", x[1]);
    }

    #[test]
    fn slowmo_beta0_alpha1_adopts_average() {
        let mut x0 = vec![1.0, 2.0, 3.0];
        let xt = vec![0.5, 1.5, 2.5];
        let mut u = vec![0.0; 3];
        slowmo_update(&mut x0, &xt, &mut u, 0.05, 1.0, 0.0);
        assert!(allclose(&x0, &xt, 1e-6, 1e-7));
    }

    #[test]
    fn slowmo_buffer_lr_invariance() {
        // u update divides by gamma, so u after one update is independent
        // of gamma given the same displacement.
        let x0 = vec![1.0f32; 4];
        let xt = vec![0.0f32; 4];
        for &gamma in &[0.1, 0.01] {
            let mut x = x0.clone();
            let mut u = vec![0.0; 4];
            slowmo_update(&mut x, &xt, &mut u, gamma, 1.0, 0.7);
            assert!(allclose(&u, &[1.0 / gamma; 4], 1e-5, 1e-6));
        }
    }

    #[test]
    fn slowmo_momentum_carries_over() {
        let mut x0 = vec![0.0f32];
        let xt = vec![0.0f32];
        let mut u = vec![2.0f32];
        // No displacement: u' = beta*u; x' = -alpha*gamma*beta*u.
        slowmo_update(&mut x0, &xt, &mut u, 0.1, 1.0, 0.5);
        assert!((u[0] - 1.0).abs() < 1e-6);
        assert!((x0[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn outer_nesterov_matches_inner_nesterov_on_pseudo_gradient() {
        // The fused kernel must equal nesterov_step(wd=0) fed the
        // materialized pseudo-gradient, bit for bit.
        let d = 16;
        let gamma = 0.3f32;
        let beta = 0.7f32;
        let x0: Vec<f32> = (0..d).map(|i| 1.0 + 0.21 * i as f32).collect();
        let xt: Vec<f32> =
            (0..d).map(|i| 0.8 + 0.17 * (i as f32).cos()).collect();
        let u0: Vec<f32> = (0..d).map(|i| 0.05 * i as f32).collect();
        let mut xa = x0.clone();
        let mut ua = u0.clone();
        outer_nesterov_step(&mut xa, &xt, &mut ua, gamma, beta);
        let g: Vec<f32> =
            x0.iter().zip(&xt).map(|(a, b)| (a - b) / gamma).collect();
        let mut xb = x0;
        let mut ub = u0;
        nesterov_step(&mut xb, &mut ub, &g, gamma, beta, 0.0);
        assert_eq!(xa, xb);
        assert_eq!(ua, ub);
    }

    #[test]
    fn outer_adam_matches_inner_adam_on_pseudo_gradient() {
        let d = 16;
        let gamma = 0.2f32;
        let x0: Vec<f32> = (0..d).map(|i| 1.0 + 0.13 * i as f32).collect();
        let xt: Vec<f32> =
            (0..d).map(|i| 0.9 + 0.11 * (i as f32).sin()).collect();
        let m0: Vec<f32> = (0..d).map(|i| 0.01 * i as f32).collect();
        let v0: Vec<f32> = (0..d).map(|i| 0.02 * i as f32).collect();
        let mut xa = x0.clone();
        let mut ma = m0.clone();
        let mut va = v0.clone();
        outer_adam_step(&mut xa, &xt, &mut ma, &mut va, gamma, 0.9, 0.95,
                        1e-8, 3.0);
        let g: Vec<f32> =
            x0.iter().zip(&xt).map(|(a, b)| (a - b) / gamma).collect();
        let mut xb = x0;
        let mut mb = m0;
        let mut vb = v0;
        adam_step(&mut xb, &mut mb, &mut vb, &g, gamma, 0.9, 0.95, 1e-8,
                  3.0);
        assert_eq!(xa, xb);
        assert_eq!(ma, mb);
        assert_eq!(va, vb);
    }

    #[test]
    fn axpy_variants_agree() {
        let x = vec![1.0, 2.0];
        let y = vec![3.0, 4.0];
        let mut out = vec![0.0; 2];
        axpy_mix(&mut out, &x, &y, 0.25, 0.75);
        let mut xin = x.clone();
        axpy_mix_inplace(&mut xin, &y, 0.25, 0.75);
        assert_eq!(out, xin);
        assert!(allclose(&out, &[2.5, 3.5], 1e-6, 1e-7));
    }

    #[test]
    fn mean_into_matches_manual() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 6.0];
        let mut out = vec![0.0; 2];
        mean_into(&mut out, &[&a, &b]);
        assert!(allclose(&out, &[2.0, 4.0], 1e-6, 1e-7));
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut x = vec![0.0; 2];
        let mut h = vec![0.0; 3];
        nesterov_step(&mut x, &mut h, &[0.0; 2], 0.1, 0.9, 0.0);
    }
}
