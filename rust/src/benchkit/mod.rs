//! Micro-benchmark harness (criterion replacement).
//!
//! `cargo bench` targets in `benches/` use [`Bench`] for hot-path timing
//! (warmup, calibrated iteration counts, median/p10/p90 over samples) and
//! plain table printing for the experiment harnesses. Results can also be
//! appended as JSONL for EXPERIMENTS.md bookkeeping.

use crate::jsonx::Json;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Stats {
    fn percentile(&self, q: f64) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return 0.0;
        }
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }

    pub fn p10(&self) -> f64 {
        self.percentile(0.1)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.9)
    }

    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.samples)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("median_s", Json::num(self.median())),
            ("p10_s", Json::num(self.p10())),
            ("p90_s", Json::num(self.p90())),
            ("mean_s", Json::num(self.mean())),
            ("samples", Json::num(self.samples.len() as f64)),
        ])
    }
}

/// Timing harness with warmup + automatic iteration calibration.
pub struct Bench {
    pub warmup_iters: usize,
    pub samples: usize,
    pub min_sample_time: f64, // seconds per sample
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            samples: 15,
            min_sample_time: 0.05,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn fast() -> Self {
        Self {
            warmup_iters: 1,
            samples: 5,
            min_sample_time: 0.01,
            results: Vec::new(),
        }
    }

    /// Time `f`, reporting seconds per call.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        // Calibrate: how many iterations per sample to exceed
        // min_sample_time.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = (self.min_sample_time / once).ceil().max(1.0) as usize;
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        self.results.push(Stats {
            name: name.to_string(),
            samples,
        });
        self.results.last().unwrap()
    }

    pub fn report(&self) {
        println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "median",
                 "p10", "p90");
        for s in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12}",
                s.name,
                crate::util::fmt_secs(s.median()),
                crate::util::fmt_secs(s.p10()),
                crate::util::fmt_secs(s.p90()),
            );
        }
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Append results to a JSONL file (one object per line).
    pub fn write_jsonl(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        for s in &self.results {
            writeln!(f, "{}", crate::jsonx::to_string(&s.to_json()))?;
        }
        Ok(())
    }
}

/// Fixed-width ASCII table printer for experiment harnesses (paper tables).
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::Arr(r.iter().map(|c| Json::str(c)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the table as JSON under results/.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, crate::jsonx::to_string(&self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_scale() {
        let mut b = Bench {
            warmup_iters: 0,
            samples: 3,
            min_sample_time: 0.001,
            results: vec![],
        };
        let s = b.run("spin", || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(s.median() > 100e-6, "median {}", s.median());
        assert!(s.median() < 10e-3);
    }

    #[test]
    fn stats_percentiles() {
        let s = Stats {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.p10(), 1.0);
        assert_eq!(s.p90(), 5.0);
        assert_eq!(s.mean(), 3.0);
    }

    #[test]
    fn stats_json_shape() {
        let s = Stats { name: "x".into(), samples: vec![1.0] };
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("samples").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn table_json() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".to_string(), "2".to_string()]);
        let j = t.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
