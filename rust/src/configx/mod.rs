//! Experiment configuration: a TOML-subset parser + the typed experiment
//! config the trainer consumes.
//!
//! The grammar covers what experiment files need: `[section]` headers,
//! `key = value` with string/float/int/bool/array values, `#` comments.
//! (No nested tables-in-arrays / datetimes — flagged as parse errors.)

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed config: section -> key -> value. Top-level keys live in "".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(|v| v.as_f64())
            .unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.f64_or(section, key, default as f64) as usize
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> = inner
            .split(',')
            .map(|x| parse_value(x.trim()))
            .collect();
        return Ok(Value::Arr(items?));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment file
seed = 42
name = "table1-cell"   # inline comment

[train]
steps = 1200
lr = 0.05
warmup = true
taus = [12, 24, 48]

[slowmo]
alpha = 1.0
beta = 0.7
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.f64_or("", "seed", 0.0), 42.0);
        assert_eq!(c.str_or("", "name", ""), "table1-cell");
        assert_eq!(c.usize_or("train", "steps", 0), 1200);
        assert_eq!(c.f64_or("train", "lr", 0.0), 0.05);
        assert!(c.bool_or("train", "warmup", false));
        assert_eq!(c.f64_or("slowmo", "beta", 0.0), 0.7);
        let taus = c.get("train", "taus").unwrap();
        match taus {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        assert_eq!(taus.as_arr().map(|v| v.len()), Some(3));
        assert!(c.get("train", "steps").unwrap().as_arr().is_none());
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("train", "steps", 7), 7);
        assert_eq!(c.str_or("x", "y", "z"), "z");
        assert!(!c.bool_or("a", "b", false));
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(c.str_or("", "s", ""), "a # b");
    }

    #[test]
    fn errors() {
        assert!(Config::parse("[oops").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = ").is_err());
        assert!(Config::parse("x = \"unterminated").is_err());
        assert!(Config::parse("x = [1, 2").is_err());
        assert!(Config::parse("x = what").is_err());
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("x = []").unwrap();
        assert_eq!(c.get("", "x"), Some(&Value::Arr(vec![])));
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let c = Config::parse("a = -1.5\nb = 1e-4").unwrap();
        assert_eq!(c.f64_or("", "a", 0.0), -1.5);
        assert_eq!(c.f64_or("", "b", 0.0), 1e-4);
    }
}
