//! `slowmo` — CLI launcher for the SlowMo reproduction.
//!
//! Commands:
//!   train  — run one training job (preset × algorithm × SlowMo config)
//!   exp    — regenerate a paper table/figure (see DESIGN.md §4)
//!   micro  — hot-path micro-benchmarks
//!   info   — show manifest / artifacts / algorithm-registry status
//!
//! All training runs go through the session/builder API
//! ([`slowmo::session::Session`]); the `--algo` spec strings resolve
//! against the [`slowmo::algorithms::AlgoRegistry`].
//!
//! Examples:
//!   slowmo train --preset cifar-mlp --algo sgp --slowmo --tau 12 --beta 0.7
//!   slowmo train --config experiments/cifar.toml --progress 20
//!   slowmo exp table1 --scale quick
//!   slowmo exp fig3 --scale standard

use slowmo::bench::{experiments, micro, Env, Scale};
use slowmo::clix::{App, Command, Flag};
use slowmo::configx::Config;
use slowmo::net::ChaosCfg;
use slowmo::runtime::{artifacts_dir, Manifest};
use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::{ProgressPrinter, Schedule};

fn app() -> App {
    App::new("slowmo", "SlowMo (ICLR 2020) reproduction — rust/JAX/Pallas")
        .command(
            Command::new("train", "run one training job")
                .flag(Flag::opt("preset", "cifar-mlp", "model preset (see `slowmo info`)"))
                .flag(Flag::opt("algo", "sgp",
                                "algorithm registry spec: \
                                 local|sgp|osgp|dpsgd|ar|doubleavg[:tau], \
                                 add -adam for Adam (see `slowmo info`)"))
                .flag(Flag::opt("m", "4", "number of workers"))
                .flag(Flag::opt("steps", "240", "inner steps per worker"))
                .flag(Flag::opt("seed", "0", "RNG seed"))
                .flag(Flag::switch("slowmo", "wrap the base algorithm in SlowMo"))
                .flag(Flag::opt("outer", "",
                                "outer-optimizer registry spec: \
                                 slowmo[:beta,alpha]|avg|\
                                 lookahead[:alpha]|nesterov[:beta]|\
                                 adam[:b1,b2] — enables the outer wrapper \
                                 and overrides --alpha/--beta (see \
                                 `slowmo info`)"))
                .flag(Flag::opt("tau", "12", "SlowMo inner-loop length"))
                .flag(Flag::opt("alpha", "1.0", "slow learning rate"))
                .flag(Flag::opt("beta", "0.7", "slow momentum"))
                .flag(Flag::opt("buffers", "reset",
                                "reset|maintain|average buffer strategy"))
                .flag(Flag::switch("no-average", "skip the exact average (§6)"))
                .flag(Flag::opt("lr", "0.1", "base/peak fast learning rate"))
                .flag(Flag::opt("sched", "auto",
                                "auto|const:<g>|image:<base>@<total>|\
                                 lm:<peak>@<total>"))
                .flag(Flag::opt("het", "0.5", "data heterogeneity (0..1)"))
                .flag(Flag::opt("eval-every", "0", "eval period (0 = end only)"))
                .flag(Flag::opt("eval-batches", "8", "batches per eval"))
                .flag(Flag::switch("pjrt-kernels",
                                   "run optimizer kernels via the PJRT \
                                    artifacts instead of the native \
                                    mirrors (slower on CPU; see §Perf)"))
                .flag(Flag::opt("groups", "",
                                "hierarchical two-level topology: a group \
                                 count (\"2\") or explicit ranges \
                                 (\"0-3|4-7\") — groups run the base \
                                 algorithm locally and the SlowMo \
                                 boundary becomes a two-level reduce \
                                 (empty = flat)"))
                .flag(Flag::opt("tau-inner", "",
                                "fast intra-group average every N inner \
                                 steps (0 = off, overriding any [groups] \
                                 tau_inner from --config; empty = leave \
                                 the config's value; needs --groups)"))
                .flag(Flag::opt("compress", "",
                                "communication compression registry spec: \
                                 none|fp16|bf16|topk[:frac]|randk[:frac]|\
                                 signsgd[:chunk]|demo[:k,chunk]|\
                                 ef:<codec> (empty = none, or whatever \
                                 --config sets; see `slowmo info`)"))
                .flag(Flag::opt("chaos", "",
                                "deterministic network degradation spec: \
                                 seed=N,delay=2ms,delay-max=20ms,\
                                 drop=0.05,rto=1ms,retries=3,reorder=4,\
                                 straggle=W:F,fault=W@T..R (empty = off)"))
                .flag(Flag::opt("quorum", "",
                                "semi-synchronous outer boundary: the \
                                 outer average proceeds once Q of M \
                                 workers arrive; late workers miss the \
                                 round and resync at the next boundary \
                                 (Q = M or empty = blocking; sim-only, \
                                 needs --slowmo/--outer and a comm-free \
                                 base like local)"))
                .flag(Flag::opt("staleness", "",
                                "bounded staleness for --quorum: fold a \
                                 late worker's contribution into the \
                                 next boundary's average, down-weighted \
                                 (0 or empty = drop late contributions)"))
                .flag(Flag::opt("exec", "",
                                "execution backend: sim (default; \
                                 simulated clock) | threaded (one OS \
                                 thread per worker, real concurrent \
                                 transfers; identical math, real wall \
                                 clock; empty = leave config's value)"))
                .flag(Flag::opt("state", "",
                                "worker-state layout: dense (default) | \
                                 shared (one read-only init Arc + \
                                 copy-on-write buffers for large-m sim \
                                 runs; sim-only, native kernels; empty = \
                                 leave config's value)"))
                .flag(Flag::opt("progress", "0",
                                "stream a progress line every N steps \
                                 (0 = off)"))
                .flag(Flag::opt("config", "",
                                "TOML experiment file; replaces the \
                                 flag-based run configuration (--out and \
                                 --progress still apply)"))
                .flag(Flag::opt("out", "results/runs.jsonl",
                                "append JSONL result here")),
        )
        .command(
            Command::new("exp", "regenerate a paper table/figure")
                .flag(Flag::opt("scale", "quick", "quick|standard|full"))
                .flag(Flag::opt("task", "", "restrict to one task (cifar|imagenet|wmt)")),
        )
        .command(Command::new("micro", "hot-path micro-benchmarks"))
        .command(Command::new("info", "artifacts / manifest status"))
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let (cmd, args) = match app.dispatch(&raw) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if raw.is_empty() { 0 } else { 2 });
        }
    };
    let result = match cmd.name {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "micro" => cmd_micro(&args),
        "info" => cmd_info(),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &slowmo::clix::Args) -> anyhow::Result<()> {
    let session = Session::open()?;
    let config_path = args.string("config");
    let builder = if !config_path.is_empty() {
        let text = std::fs::read_to_string(&config_path)?;
        let conf = Config::parse(&text)
            .map_err(|e| anyhow::anyhow!("{config_path}: {e}"))?;
        session.train(&args.string("preset")).config(&conf)?
    } else {
        let mut b = session
            .train(&args.string("preset"))
            .algo(&args.string("algo"))
            .workers(args.usize("m"))
            .steps(args.u64("steps"))
            .seed(args.u64("seed"))
            .lr(args.f32("lr"))
            .heterogeneity(args.f64("het"))
            .eval_every(args.u64("eval-every"))
            .eval_batches(args.u64("eval-batches"))
            .native_kernels(!args.get_bool("pjrt-kernels"));
        if args.string("sched") != "auto" {
            b = b.schedule(
                args.get_parsed::<Schedule>("sched")
                    .map_err(anyhow::Error::msg)?,
            );
        }
        let outer_spec = args.string("outer");
        if args.get_bool("slowmo") || !outer_spec.is_empty() {
            b = b
                .slowmo_cfg(SlowMoCfg::new(
                    args.f32("alpha"),
                    args.f32("beta"),
                    args.u64("tau"),
                ))
                .buffers(
                    args.get_parsed::<BufferStrategy>("buffers")
                        .map_err(anyhow::Error::msg)?,
                );
            if !outer_spec.is_empty() {
                // Replaces the slow-momentum rule, keeps --tau/--buffers.
                b = b.outer(&outer_spec);
            }
            if args.get_bool("no-average") {
                b = b.no_average();
            }
        }
        b
    };
    // Like --compress/--chaos, the hierarchy flags also apply on top of a
    // --config file (the flag wins over the [groups] table).
    let groups_spec = args.string("groups");
    let builder = if groups_spec.is_empty() {
        builder
    } else {
        builder.groups(&groups_spec)
    };
    // An explicit `--tau-inner 0` must override a [groups] tau_inner
    // coming from --config (like `--compress none`), so only an *empty*
    // flag leaves the config's value alone.
    let tau_inner = args.string("tau-inner");
    let builder = if tau_inner.is_empty() {
        builder
    } else {
        builder.tau_inner(
            args.get_parsed::<u64>("tau-inner")
                .map_err(anyhow::Error::msg)?,
        )
    };
    // "none" passes through too: `--compress none` must override a
    // `[compress]` table coming from --config, not silently no-op.
    let compress_spec = args.string("compress");
    let builder = if compress_spec.is_empty() {
        builder
    } else {
        builder.compress(&compress_spec)
    };
    let chaos_spec = args.string("chaos");
    let builder = if chaos_spec.is_empty() {
        builder
    } else {
        builder.chaos(
            chaos_spec
                .parse::<ChaosCfg>()
                .map_err(anyhow::Error::msg)?,
        )
    };
    // Semi-sync boundary knobs stack on --config too (flag wins over the
    // [outer] table, like the other surfaces).
    let quorum_spec = args.string("quorum");
    let builder = if quorum_spec.is_empty() {
        builder
    } else {
        builder.quorum(
            args.get_parsed::<usize>("quorum")
                .map_err(anyhow::Error::msg)?,
        )
    };
    let staleness_spec = args.string("staleness");
    let builder = if staleness_spec.is_empty() {
        builder
    } else {
        builder.staleness(
            args.get_parsed::<u64>("staleness")
                .map_err(anyhow::Error::msg)?,
        )
    };
    let exec_spec = args.string("exec");
    let builder = if exec_spec.is_empty() {
        builder
    } else {
        builder.exec(
            exec_spec
                .parse::<slowmo::exec::ExecMode>()
                .map_err(anyhow::Error::msg)?,
        )
    };
    let state_spec = args.string("state");
    let builder = if state_spec.is_empty() {
        builder
    } else {
        builder.state(
            state_spec
                .parse::<slowmo::trainer::StateMode>()
                .map_err(anyhow::Error::msg)?,
        )
    };
    let cfg = builder.build_cfg()?;
    println!("training {} / {} ...", cfg.preset, cfg.algo.spec());
    let r = match args.u64("progress") {
        0 => session.run(&cfg)?,
        every => {
            let mut obs = ProgressPrinter { every };
            session.run_observed(&cfg, Some(&mut obs))?
        }
    };
    println!("algo                {}", r.algo);
    println!("best train loss     {:.4}", r.best_train_loss);
    println!("best val metric     {:.4}", r.best_eval_metric);
    println!("final val loss      {:.4}", r.final_eval_loss);
    println!("simulated time/iter {}",
             slowmo::util::fmt_secs(r.sim_time_per_iter()));
    println!("fabric bytes sent   {}", slowmo::util::fmt_bytes(r.bytes_sent));
    if r.groups.is_some() {
        println!("inter-group bytes   {}",
                 slowmo::util::fmt_bytes(r.bytes_inter));
    }
    if r.bytes_saved > 0 {
        println!("compression saved   {}",
                 slowmo::util::fmt_bytes(r.bytes_saved));
    }
    if r.retransmits > 0 {
        println!("chaos retransmits   {}", r.retransmits);
    }
    if r.quorum_misses > 0 || r.stale_folds > 0 {
        println!("quorum misses       {}", r.quorum_misses);
        println!("stale folds         {}", r.stale_folds);
    }
    println!("wall time           {}", slowmo::util::fmt_secs(r.wall_time));
    r.append_jsonl(&args.string("out"))?;
    Ok(())
}

fn cmd_exp(args: &slowmo::clix::Args) -> anyhow::Result<()> {
    let scale: Scale =
        args.get_parsed("scale").map_err(anyhow::Error::msg)?;
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let env = Env::load(scale)?;
    let tasks = {
        let filter = args.string("task");
        let all = vec![
            experiments::TaskSpec::cifar(),
            experiments::TaskSpec::imagenet(),
            experiments::TaskSpec::wmt(scale),
        ];
        if filter.is_empty() {
            all
        } else {
            all.into_iter()
                .filter(|t| {
                    t.paper_name.to_lowercase().contains(&filter)
                        || t.preset.contains(&filter)
                })
                .collect()
        }
    };
    let t0 = std::time::Instant::now();
    match which {
        "table1" => {
            experiments::table1(&env, &tasks)?;
        }
        "table2" => {
            experiments::table2(&env)?;
        }
        "fig2" => experiments::fig2(&env, &tasks)?,
        "fig3" => {
            experiments::fig3(&env, &tasks[0])?;
        }
        "figb2" => {
            experiments::figb2(
                &env,
                &tasks[0],
                &[0.5, 1.0],
                &[0.0, 0.2, 0.4, 0.6, 0.8],
            )?;
        }
        "tableb23" => {
            experiments::tableb23(&env, &tasks[0])?;
        }
        "tableb4" => {
            experiments::tableb4(&env, &tasks[0])?;
        }
        "doubleavg" => {
            experiments::doubleavg(&env, &tasks[0])?;
        }
        "noaverage" => {
            experiments::noaverage(&env, &tasks[0])?;
        }
        "outers" => {
            experiments::outers(&env, &tasks[0])?;
        }
        "compress" => {
            experiments::compress(&env, &tasks[0])?;
        }
        "hier" => {
            experiments::hier(&env, &tasks[0])?;
        }
        "semisync" => {
            experiments::semisync(&env, &tasks[0])?;
        }
        "theory" => {
            experiments::theory(&env)?;
        }
        "throughput" => {
            experiments::throughput(&env)?;
        }
        "scale" => {
            experiments::scale(&env)?;
        }
        "all" => {
            experiments::table2(&env)?;
            experiments::theory(&env)?;
            experiments::table1(&env, &tasks)?;
            experiments::fig2(&env, &tasks)?;
            experiments::fig3(&env, &tasks[0])?;
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (table1|table2|fig2|fig3|figb2|\
             tableb23|tableb4|doubleavg|noaverage|outers|compress|hier|\
             semisync|theory|throughput|scale|all)"
        ),
    }
    println!("\n[exp {which} done in {}]",
             slowmo::util::fmt_secs(t0.elapsed().as_secs_f64()));
    Ok(())
}

fn cmd_micro(_args: &slowmo::clix::Args) -> anyhow::Result<()> {
    let env = Env::load(Scale::Quick)?;
    micro::run(&env)?;
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    println!("artifacts dir: {dir}");
    let manifest = Manifest::load(&dir)?;
    println!("presets:");
    for (name, p) in &manifest.presets {
        println!(
            "  {:<16} family={:<5} d={:>9} ({} raw params)",
            name, p.family, p.flat_len, p.raw_len
        );
    }
    println!("optimizer graph dims: {:?}",
             manifest.optim.keys().collect::<Vec<_>>());
    println!("algorithms (--algo):");
    print!("{}", slowmo::algorithms::AlgoRegistry::builtin().help_text());
    println!("outer optimizers (--outer):");
    print!("{}", slowmo::slowmo::OuterRegistry::builtin().help_text());
    println!("compressors (--compress):");
    print!("{}", slowmo::compress::CompressRegistry::builtin().help_text());
    Ok(())
}
