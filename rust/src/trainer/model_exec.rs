//! Model execution: the per-step fwd/bwd (train) and metric (eval) calls.
//!
//! Two backends:
//! - [`ModelExec::Pjrt`] — the AOT artifacts (Layer-2 JAX graphs with the
//!   Layer-1 kernels lowered in) via the PJRT CPU client. The production
//!   path; Python never runs here.
//! - [`ModelExec::NativeQuad`] — the quadratic theory workload in closed
//!   form (the L2 `quad` graphs are trivial, and the theory benches sweep
//!   thousands of cells, so a native fast path keeps them cheap). Verified
//!   against the PJRT quad artifacts in `rust/tests/`.

use crate::data::Batch;
use crate::runtime::engine::{Arg, ExecHandle};
use crate::runtime::{DataDesc, Engine, Manifest, PresetInfo};
use anyhow::{bail, Result};

pub enum ModelExec {
    Pjrt {
        train: ExecHandle,
        eval: ExecHandle,
        desc: DataDesc,
        d: usize,
    },
    NativeQuad {
        dim: usize,
        cond: f64,
        d: usize,
    },
}

impl ModelExec {
    /// Load the PJRT graphs for a preset.
    pub fn pjrt(engine: &Engine, preset: &PresetInfo) -> Result<Self> {
        Ok(ModelExec::Pjrt {
            train: engine.load(&preset.train)?,
            eval: engine.load(&preset.eval)?,
            desc: preset.data.clone(),
            d: preset.flat_len,
        })
    }

    /// Closed-form quad executor matching the `quad` preset semantics.
    pub fn native_quad(preset: &PresetInfo) -> Result<Self> {
        match preset.data {
            DataDesc::Quad { dim, cond } => Ok(ModelExec::NativeQuad {
                dim,
                cond,
                d: preset.flat_len,
            }),
            _ => bail!("native executor only supports the quad family"),
        }
    }

    pub fn d(&self) -> usize {
        match self {
            ModelExec::Pjrt { d, .. } => *d,
            ModelExec::NativeQuad { d, .. } => *d,
        }
    }

    fn quad_lambda(dim: usize, cond: f64, i: usize) -> f64 {
        if dim <= 1 {
            return 1.0;
        }
        10f64.powf(cond.log10() * i as f64 / (dim - 1) as f64)
    }

    /// One fwd/bwd: returns (loss, grads).
    pub fn train_step(
        &self,
        params: &[f32],
        batch: &Batch,
    ) -> Result<(f32, Vec<f32>)> {
        match self {
            ModelExec::Pjrt { train, desc, d, .. } => {
                let out = exec_graph(train, params, *d, desc, batch)?;
                let mut it = out.into_iter();
                let loss = it.next().unwrap()[0];
                let grads = it.next().unwrap();
                Ok((loss, grads))
            }
            ModelExec::NativeQuad { dim, cond, d } => {
                let (center, noise) = match batch {
                    Batch::Quad { center, noise } => (center, noise),
                    _ => bail!("quad executor needs quad batches"),
                };
                let mut grads = vec![0.0f32; *d];
                let mut loss = 0.0f64;
                let inv = 1.0 / *dim as f64;
                for i in 0..*dim {
                    let lam = Self::quad_lambda(*dim, *cond, i);
                    let diff = (params[i] - center[i]) as f64;
                    loss += 0.5 * lam * diff * diff * inv;
                    grads[i] = (lam * diff * inv) as f32 + noise[i];
                }
                Ok((loss as f32, grads))
            }
        }
    }

    /// Eval: returns (loss, metric) where metric is `ncorrect` for
    /// classifiers/LM and grad-norm² for quad.
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        match self {
            ModelExec::Pjrt { eval, desc, d, .. } => {
                let out = exec_graph(eval, params, *d, desc, batch)?;
                Ok((out[0][0], out[1][0]))
            }
            ModelExec::NativeQuad { dim, cond, d: _ } => {
                let center = match batch {
                    Batch::Quad { center, .. } => center,
                    _ => bail!("quad executor needs quad batches"),
                };
                let mut loss = 0.0f64;
                let mut gsq = 0.0f64;
                let inv = 1.0 / *dim as f64;
                for i in 0..*dim {
                    let lam = Self::quad_lambda(*dim, *cond, i);
                    let diff = (params[i] - center[i]) as f64;
                    loss += 0.5 * lam * diff * diff * inv;
                    let g = lam * diff * inv;
                    gsq += g * g;
                }
                Ok((loss as f32, gsq as f32))
            }
        }
    }

    /// Fraction denominator for accuracy metrics (examples per eval batch).
    pub fn metric_denom(&self) -> f64 {
        match self {
            ModelExec::Pjrt { desc, .. } => desc.examples_per_step() as f64,
            ModelExec::NativeQuad { .. } => 1.0,
        }
    }
}

fn exec_graph(
    exe: &ExecHandle,
    params: &[f32],
    d: usize,
    desc: &DataDesc,
    batch: &Batch,
) -> Result<Vec<Vec<f32>>> {
    match (desc, batch) {
        (DataDesc::Lm { seq_len, batch: b, .. }, Batch::Lm { tokens, targets }) => {
            let shape = [*b, *seq_len];
            exe.exec(&[
                Arg::F32(params, &[d]),
                Arg::I32(tokens, &shape),
                Arg::I32(targets, &shape),
            ])
        }
        (DataDesc::Class { in_dim, batch: b, .. }, Batch::Class { x, y }) => {
            exe.exec(&[
                Arg::F32(params, &[d]),
                Arg::F32(x, &[*b, *in_dim]),
                Arg::I32(y, &[*b]),
            ])
        }
        (
            DataDesc::Image { hw, in_ch, batch: b, .. },
            Batch::Class { x, y },
        ) => exe.exec(&[
            Arg::F32(params, &[d]),
            Arg::F32(x, &[*b, *hw, *hw, *in_ch]),
            Arg::I32(y, &[*b]),
        ]),
        (DataDesc::Quad { dim, .. }, Batch::Quad { center, noise }) => exe
            .exec(&[
                Arg::F32(params, &[d]),
                Arg::F32(center, &[*dim]),
                Arg::F32(noise, &[*dim]),
            ]),
        _ => bail!("batch kind does not match data descriptor"),
    }
}

/// Build a model executor for `preset`, choosing native fast paths where
/// available unless `force_pjrt`.
pub fn build(
    engine: Option<&Engine>,
    manifest: &Manifest,
    preset: &str,
    force_pjrt: bool,
) -> Result<ModelExec> {
    let info = manifest.preset(preset)?;
    if !force_pjrt && matches!(info.data, DataDesc::Quad { .. }) {
        return ModelExec::native_quad(info);
    }
    match engine {
        Some(e) => ModelExec::pjrt(e, info),
        None => bail!("preset {preset} requires the PJRT engine"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_exec(dim: usize) -> ModelExec {
        ModelExec::NativeQuad { dim, cond: 100.0, d: dim }
    }

    #[test]
    fn native_quad_loss_and_grads() {
        let e = quad_exec(4);
        let params = vec![1.0f32; 4];
        let batch = Batch::Quad { center: vec![0.0; 4], noise: vec![0.0; 4] };
        let (loss, grads) = e.train_step(&params, &batch).unwrap();
        // lam = 10^{2i/3}: [1, 4.64, 21.5, 100]; loss = 0.5*sum(lam)/4.
        let lam: Vec<f64> =
            (0..4).map(|i| 10f64.powf(2.0 * i as f64 / 3.0)).collect();
        let want = 0.5 * lam.iter().sum::<f64>() / 4.0;
        assert!((loss as f64 - want).abs() < 1e-4);
        for i in 0..4 {
            assert!((grads[i] as f64 - lam[i] / 4.0).abs() < 1e-4);
        }
    }

    #[test]
    fn native_quad_noise_enters_grads_not_loss() {
        let e = quad_exec(4);
        let params = vec![1.0f32; 4];
        let b0 = Batch::Quad { center: vec![0.0; 4], noise: vec![0.0; 4] };
        let b1 = Batch::Quad { center: vec![0.0; 4], noise: vec![1.0; 4] };
        let (l0, g0) = e.train_step(&params, &b0).unwrap();
        let (l1, g1) = e.train_step(&params, &b1).unwrap();
        assert_eq!(l0, l1);
        for i in 0..4 {
            assert!((g1[i] - g0[i] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn native_quad_eval_at_center_is_zero() {
        let e = quad_exec(8);
        let params = vec![2.0f32; 8];
        let batch =
            Batch::Quad { center: vec![2.0; 8], noise: vec![0.0; 8] };
        let (loss, gsq) = e.eval_step(&params, &batch).unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(gsq, 0.0);
    }

    #[test]
    fn mismatched_batch_kind_errors() {
        let e = quad_exec(4);
        let bad = Batch::Class { x: vec![0.0; 4], y: vec![0] };
        assert!(e.train_step(&[0.0; 4], &bad).is_err());
    }
}
