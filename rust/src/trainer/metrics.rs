//! Training metrics: curves, summaries, JSONL sinks.

use crate::jsonx::Json;
use crate::util::{mean, stddev};

/// One evaluation point (paper Fig. 2 / B.1 curves).
#[derive(Clone, Debug)]
pub struct EvalPoint {
    /// Global inner step at which the eval ran.
    pub step: u64,
    /// Mean / min / max across workers (Fig. 2's shaded min-max band).
    pub loss_mean: f64,
    pub loss_min: f64,
    pub loss_max: f64,
    /// Task metric: accuracy for classifiers, token accuracy for LM,
    /// grad-norm for quad. Mean across workers.
    pub metric_mean: f64,
    /// Simulated wall-clock when the eval ran (max across workers).
    pub sim_time: f64,
}

impl EvalPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss_mean", Json::num(self.loss_mean)),
            ("loss_min", Json::num(self.loss_min)),
            ("loss_max", Json::num(self.loss_max)),
            ("metric_mean", Json::num(self.metric_mean)),
            ("sim_time", Json::num(self.sim_time)),
        ])
    }
}

/// Result of one training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub algo: String,
    /// Outer-optimizer spec string ("slowmo:0.7", "adam:0.9,0.95") when
    /// the run wrapped its base algorithm; `None` for bare runs.
    pub outer: Option<String>,
    /// Canonical tier-tree spec when the run was tiered (two-level or
    /// flat-on-tiers): the leaf partition ("0-3|4-7") for depth-1 runs,
    /// `';'`-joined tiers leaves-first ("0-3|4-7;0-7") for deeper
    /// trees; `None` for flat runs.
    pub groups: Option<String>,
    /// Communication-compression spec string ("topk:0.1", "ef:signsgd")
    /// when a codec was configured; `None` for raw-f32 runs.
    pub compress: Option<String>,
    pub preset: String,
    pub m: usize,
    pub steps: u64,
    /// Steps actually executed per worker (< `steps` when a
    /// [`super::RunObserver`] stopped the run early).
    pub steps_run: u64,
    pub seed: u64,
    /// Per-outer-iteration mean training loss (averaged over workers).
    pub train_curve: Vec<(u64, f64)>,
    pub eval_curve: Vec<EvalPoint>,
    /// Best (minimum) smoothed training loss.
    pub best_train_loss: f64,
    /// Best validation metric (max for accuracy-like, caller interprets).
    pub best_eval_metric: f64,
    /// Final validation loss (for NLL tables).
    pub final_eval_loss: f64,
    /// Simulated seconds for the whole run (max across workers).
    pub sim_time: f64,
    /// Real wall-clock seconds spent training.
    pub wall_time: f64,
    /// Execution backend the run used ("sim" | "threaded"). Backends
    /// share every simulated-time and byte computation; only the
    /// wall-clock fields mean different transports.
    pub exec: String,
    /// Real seconds spent inside model `train_step` calls (mean across
    /// workers) — the compute half of the wall-clock phase breakdown.
    pub compute_wall_time: f64,
    /// Real seconds spent blocked in fabric receives (mean across
    /// workers) — the communication half of the breakdown.
    pub comm_wall_time: f64,
    /// Total bytes on the wire (compressed sizes when a codec is active).
    pub bytes_sent: u64,
    /// Bytes compression kept off the wire (raw 4 B/elem total minus
    /// `bytes_sent`; 0 for raw-f32 runs).
    pub bytes_saved: u64,
    /// Wire bytes that crossed slow inter-group links (0 for untiered
    /// runs — the two-tier cost model's headline accounting).
    pub bytes_inter: u64,
    /// Chaos-layer retransmitted messages (0 without a chaos plan).
    pub retransmits: u64,
    /// Semi-synchronous boundaries: total (worker × boundary) quorum
    /// misses across the run (0 for blocking runs).
    pub quorum_misses: u64,
    /// Semi-synchronous boundaries: stale contributions folded into a
    /// later boundary's average (0 for blocking or `staleness = 0` runs).
    pub stale_folds: u64,
    /// Worker-state layout the run used ("dense" | "shared").
    pub state: String,
    /// Process peak resident set (bytes, Linux `VmHWM`) sampled after the
    /// run finished; `None` where the kernel doesn't expose it. Whole-
    /// process, so only comparable across runs in the same process after
    /// a [`crate::util::reset_peak_rss`].
    pub peak_rss_bytes: Option<u64>,
    /// Mean grad-norm^2 trajectory per outer iteration (theory bench).
    pub gradnorm_curve: Vec<(u64, f64)>,
    /// Worker 0's final (de-biased) parameters — recorded only when
    /// `TrainCfg::record_final_params` is set; never serialized to JSONL.
    pub final_params: Option<Vec<f32>>,
}

impl TrainResult {
    /// Simulated seconds per inner iteration.
    pub fn sim_time_per_iter(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sim_time / self.steps as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("algo", Json::str(&self.algo)),
            ("preset", Json::str(&self.preset)),
            ("m", Json::num(self.m as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("steps_run", Json::num(self.steps_run as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("best_train_loss", Json::num(self.best_train_loss)),
            ("best_eval_metric", Json::num(self.best_eval_metric)),
            ("final_eval_loss", Json::num(self.final_eval_loss)),
            ("sim_time", Json::num(self.sim_time)),
            ("wall_time", Json::num(self.wall_time)),
            ("exec", Json::str(&self.exec)),
            ("compute_wall_time", Json::num(self.compute_wall_time)),
            ("comm_wall_time", Json::num(self.comm_wall_time)),
            ("bytes_sent", Json::num(self.bytes_sent as f64)),
            ("bytes_saved", Json::num(self.bytes_saved as f64)),
            ("bytes_inter", Json::num(self.bytes_inter as f64)),
            ("retransmits", Json::num(self.retransmits as f64)),
            ("quorum_misses", Json::num(self.quorum_misses as f64)),
            ("stale_folds", Json::num(self.stale_folds as f64)),
            ("state", Json::str(&self.state)),
            (
                "train_curve",
                Json::Arr(
                    self.train_curve
                        .iter()
                        .map(|&(s, l)| {
                            Json::Arr(vec![
                                Json::num(s as f64),
                                Json::num(l),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "eval_curve",
                Json::Arr(
                    self.eval_curve.iter().map(|p| p.to_json()).collect(),
                ),
            ),
        ];
        if let Some(outer) = &self.outer {
            pairs.push(("outer", Json::str(outer)));
        }
        if let Some(groups) = &self.groups {
            pairs.push(("groups", Json::str(groups)));
        }
        if let Some(compress) = &self.compress {
            pairs.push(("compress", Json::str(compress)));
        }
        if let Some(rss) = self.peak_rss_bytes {
            pairs.push(("peak_rss_bytes", Json::num(rss as f64)));
        }
        Json::obj(pairs)
    }

    /// Append to a JSONL results file.
    pub fn append_jsonl(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        writeln!(f, "{}", crate::jsonx::to_string(&self.to_json()))
    }
}

/// Aggregate of several seeds of the same cell (paper Table B.4).
#[derive(Clone, Debug)]
pub struct SeedAggregate {
    pub best_train_loss_mean: f64,
    pub best_eval_metric_mean: f64,
    pub best_eval_metric_std: f64,
    pub n: usize,
}

impl SeedAggregate {
    pub fn from_runs(runs: &[TrainResult]) -> Self {
        let losses: Vec<f64> =
            runs.iter().map(|r| r.best_train_loss).collect();
        let metrics: Vec<f64> =
            runs.iter().map(|r| r.best_eval_metric).collect();
        Self {
            best_train_loss_mean: mean(&losses),
            best_eval_metric_mean: mean(&metrics),
            best_eval_metric_std: stddev(&metrics),
            n: runs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(seed: u64, loss: f64, metric: f64) -> TrainResult {
        TrainResult {
            algo: "x".into(),
            outer: Some("slowmo:0.7".into()),
            groups: Some("0-0|1-1".into()),
            compress: Some("topk:0.1".into()),
            preset: "p".into(),
            m: 2,
            steps: 100,
            steps_run: 100,
            seed,
            train_curve: vec![(10, 1.0), (20, loss)],
            eval_curve: vec![],
            best_train_loss: loss,
            best_eval_metric: metric,
            final_eval_loss: loss,
            sim_time: 50.0,
            wall_time: 1.0,
            exec: "sim".into(),
            compute_wall_time: 0.6,
            comm_wall_time: 0.3,
            bytes_sent: 42,
            bytes_saved: 7,
            bytes_inter: 13,
            retransmits: 0,
            quorum_misses: 3,
            stale_folds: 2,
            state: "dense".into(),
            peak_rss_bytes: Some(1 << 20),
            gradnorm_curve: vec![],
            final_params: None,
        }
    }

    #[test]
    fn per_iter_time() {
        let r = dummy(0, 0.5, 0.9);
        assert!((r.sim_time_per_iter() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_round_trips() {
        let r = dummy(0, 0.5, 0.9);
        let j = r.to_json();
        assert_eq!(j.get("algo").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("outer").unwrap().as_str(), Some("slowmo:0.7"));
        assert_eq!(j.get("compress").unwrap().as_str(), Some("topk:0.1"));
        assert_eq!(j.get("groups").unwrap().as_str(), Some("0-0|1-1"));
        assert_eq!(j.get("bytes_saved").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("bytes_inter").unwrap().as_f64(), Some(13.0));
        assert_eq!(j.get("exec").unwrap().as_str(), Some("sim"));
        assert_eq!(
            j.get("compute_wall_time").unwrap().as_f64(),
            Some(0.6)
        );
        assert_eq!(j.get("comm_wall_time").unwrap().as_f64(), Some(0.3));
        assert_eq!(j.get("quorum_misses").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("stale_folds").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("state").unwrap().as_str(), Some("dense"));
        assert_eq!(
            j.get("peak_rss_bytes").unwrap().as_f64(),
            Some((1u64 << 20) as f64)
        );
        let parsed =
            crate::jsonx::parse(&crate::jsonx::to_string(&j)).unwrap();
        assert_eq!(parsed.get("best_train_loss").unwrap().as_f64(),
                   Some(0.5));
        assert_eq!(
            parsed.get("train_curve").unwrap().as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    fn seed_aggregate() {
        let runs =
            vec![dummy(0, 0.5, 0.90), dummy(1, 0.3, 0.92), dummy(2, 0.4, 0.94)];
        let agg = SeedAggregate::from_runs(&runs);
        assert!((agg.best_eval_metric_mean - 0.92).abs() < 1e-12);
        assert!((agg.best_train_loss_mean - 0.4).abs() < 1e-12);
        assert!(agg.best_eval_metric_std > 0.0);
        assert_eq!(agg.n, 3);
    }
}
