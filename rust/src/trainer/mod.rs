//! The multi-worker training driver.
//!
//! Spawns one thread per worker, wires the data tasks, the base algorithm,
//! the optional SlowMo controller, the fabric, and the model executor
//! together, and records the metrics every experiment harness consumes.

pub mod metrics;
pub mod model_exec;
pub mod schedule;

pub use metrics::{EvalPoint, SeedAggregate, TrainResult};
pub use model_exec::ModelExec;
pub use schedule::Schedule;

use crate::algorithms::{
    AllReduce, BaseAlgorithm, Ctx, DoubleAvg, Dpsgd, Local, Sgp, WorkerState,
};
use crate::data::{task_for, Task};
use crate::net::{CostModel, Fabric};
use crate::optim::kernels::{InnerOpt, Kernels};
use crate::runtime::{DataDesc, Engine, Manifest};
use crate::slowmo::{OuterState, SlowMoCfg};
use crate::topology::ExponentialGraph;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Which base algorithm to construct (flat spec, CLI/config friendly).
#[derive(Clone, Debug)]
pub enum AlgoSpec {
    Local(InnerOpt),
    Sgp(InnerOpt),
    Osgp(InnerOpt),
    Dpsgd(InnerOpt),
    AllReduce(InnerOpt),
    DoubleAvg(InnerOpt, u64),
}

impl AlgoSpec {
    pub fn build(&self, m: usize) -> Arc<dyn BaseAlgorithm> {
        match self {
            AlgoSpec::Local(i) => Arc::new(Local::new(*i)),
            AlgoSpec::Sgp(i) => {
                Arc::new(Sgp::new(*i, Arc::new(ExponentialGraph::new(m))))
            }
            AlgoSpec::Osgp(i) => {
                Arc::new(Sgp::overlap(*i, Arc::new(ExponentialGraph::new(m))))
            }
            AlgoSpec::Dpsgd(i) => Arc::new(Dpsgd::new(*i, m)),
            AlgoSpec::AllReduce(i) => Arc::new(AllReduce::new(*i)),
            AlgoSpec::DoubleAvg(i, tau) => Arc::new(DoubleAvg::new(*i, *tau)),
        }
    }

    /// Parse e.g. "sgp", "local-adam", "doubleavg:12".
    pub fn parse(s: &str) -> Option<Self> {
        let (name, rest) = match s.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (s, None),
        };
        let adam = name.ends_with("-adam");
        let base = name.trim_end_matches("-adam");
        let inner = if adam {
            InnerOpt::adam_default()
        } else {
            InnerOpt::nesterov_default()
        };
        match base {
            "local" => Some(AlgoSpec::Local(inner)),
            "sgp" => Some(AlgoSpec::Sgp(inner)),
            "osgp" => Some(AlgoSpec::Osgp(inner)),
            "dpsgd" => Some(AlgoSpec::Dpsgd(inner)),
            "ar" | "allreduce" => Some(AlgoSpec::AllReduce(inner)),
            "doubleavg" => {
                let tau = rest.and_then(|r| r.parse().ok()).unwrap_or(12);
                Some(AlgoSpec::DoubleAvg(inner, tau))
            }
            _ => None,
        }
    }
}

/// Full training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub preset: String,
    pub m: usize,
    /// Total inner steps per worker.
    pub steps: u64,
    pub seed: u64,
    pub algo: AlgoSpec,
    /// `None` = run the base algorithm bare (e.g. plain SGP baseline).
    pub slowmo: Option<SlowMoCfg>,
    pub sched: Schedule,
    /// Data heterogeneity knob (0 = iid shards .. 1 = strongly non-iid).
    pub heterogeneity: f64,
    /// Evaluate every this many steps (0 = only at the end).
    pub eval_every: u64,
    pub eval_batches: u64,
    /// Force model graphs through PJRT even when a native path exists.
    pub force_pjrt: bool,
    /// Run the optimizer kernels natively instead of via the AOT
    /// artifacts (perf ablation; math is identical).
    pub native_kernels: bool,
    pub cost: CostModel,
    /// Simulated compute charge per inner step; 0.0 = use measured wall
    /// time of the train_step call.
    pub compute_time_s: f64,
    /// Record grad-norm² trajectories (theory benches).
    pub record_gradnorm: bool,
}

impl TrainCfg {
    pub fn quick(preset: &str, algo: AlgoSpec, steps: u64) -> Self {
        Self {
            preset: preset.to_string(),
            m: 4,
            steps,
            seed: 0,
            algo,
            slowmo: None,
            sched: Schedule::Const(0.05),
            heterogeneity: 0.5,
            eval_every: 0,
            eval_batches: 4,
            force_pjrt: false,
            native_kernels: false,
            cost: CostModel::free(),
            compute_time_s: 0.0,
            record_gradnorm: false,
        }
    }

    pub fn with_slowmo(mut self, s: SlowMoCfg) -> Self {
        self.slowmo = Some(s);
        self
    }

    /// Display name: "sgp+slowmo(t48,b0.6)" etc.
    pub fn algo_name(&self) -> String {
        let base = self.algo.build(self.m).name();
        match &self.slowmo {
            None => base,
            Some(s) => format!(
                "{base}+slowmo(t{},a{},b{}{}{})",
                s.tau,
                s.alpha,
                s.beta,
                if s.exact_average { "" } else { ",noavg" },
                format_args!(",{}", s.buffers.name()),
            ),
        }
    }
}

struct WorkerOut {
    losses: Vec<f32>,
    gradnorms: Vec<f64>,
    evals: Vec<(u64, f32, f32, f64)>, // (step, loss, metric, clock)
    clock: f64,
}

/// Run one training job. `engine` may be `None` only for presets with a
/// native model path (quad).
pub fn train(
    cfg: &TrainCfg,
    manifest: &Manifest,
    engine: Option<&Engine>,
) -> Result<TrainResult> {
    let t_wall = Instant::now();
    let info = manifest.preset(&cfg.preset)?;
    let init = manifest.load_init(info)?;
    let d = info.flat_len;
    let task: Box<dyn Task> =
        task_for(&info.data, cfg.m, cfg.seed, cfg.heterogeneity);
    let model =
        model_exec::build(engine, manifest, &cfg.preset, cfg.force_pjrt)?;
    let kernels = if cfg.native_kernels || engine.is_none() {
        Kernels::Native
    } else {
        Kernels::pjrt(engine.unwrap(), manifest, d)?
    };
    let algo = cfg.algo.build(cfg.m);
    let fabric = Fabric::new(cfg.m, cfg.cost.clone());

    let eval_points: Vec<u64> = {
        let mut pts = Vec::new();
        if cfg.eval_every > 0 {
            let mut s = cfg.eval_every;
            while s < cfg.steps {
                pts.push(s);
                s += cfg.eval_every;
            }
        }
        pts.push(cfg.steps); // always evaluate at the end
        pts
    };

    let outs: Vec<Result<WorkerOut>> = crate::exec::run_workers(cfg.m, |w| {
        let mut state = WorkerState::new(&init, algo.inner());
        let mut outer = cfg.slowmo.as_ref().map(|_| OuterState::new(&init));
        let mut ctx = Ctx {
            worker: w,
            m: cfg.m,
            fabric: &fabric,
            kernels: &kernels,
            clock: 0.0,
        };
        let mut out = WorkerOut {
            losses: Vec::with_capacity(cfg.steps as usize),
            gradnorms: Vec::new(),
            evals: Vec::new(),
            clock: 0.0,
        };
        let mut eval_idx = 0;
        let mut gamma_outer = cfg.sched.gamma(0);
        for k in 0..cfg.steps {
            let gamma = cfg.sched.gamma(k);
            if let Some(s) = &cfg.slowmo {
                if k % s.tau == 0 {
                    // γ_t for Eq. 2: the rate in effect at the start of
                    // this outer iteration.
                    gamma_outer = gamma;
                }
            }
            let batch = task.train_batch(w, k);
            let t0 = Instant::now();
            let (loss, grads) =
                model.train_step(algo.eval_params(&state), &batch)?;
            ctx.clock += if cfg.compute_time_s > 0.0 {
                cfg.compute_time_s
            } else {
                t0.elapsed().as_secs_f64()
            };
            out.losses.push(loss);
            if cfg.record_gradnorm {
                out.gradnorms.push(crate::util::sqnorm(&grads));
            }
            algo.step(&mut ctx, &mut state, &grads, gamma, k)?;
            if let (Some(scfg), Some(outer)) = (&cfg.slowmo, outer.as_mut())
            {
                if scfg.is_boundary(k) {
                    ctx.clock = crate::slowmo::outer_update(
                        scfg, algo.as_ref(), &fabric, &kernels, w,
                        &mut state, outer, gamma_outer, ctx.clock,
                    )?;
                }
            }
            // Evaluation checkpoints.
            while eval_idx < eval_points.len()
                && k + 1 == eval_points[eval_idx]
            {
                let (l, mtr) =
                    run_eval(&model, &*task, algo.eval_params(&state),
                             cfg.eval_batches)?;
                out.evals.push((k + 1, l, mtr, ctx.clock));
                eval_idx += 1;
            }
        }
        out.clock = ctx.clock;
        Ok(out)
    });
    let mut workers = Vec::with_capacity(cfg.m);
    for o in outs {
        workers.push(o?);
    }

    Ok(assemble(cfg, info.data.clone(), workers, &fabric,
                t_wall.elapsed().as_secs_f64()))
}

fn run_eval(
    model: &ModelExec,
    task: &dyn Task,
    params: &[f32],
    batches: u64,
) -> Result<(f32, f32)> {
    let mut loss = 0.0f64;
    let mut metric = 0.0f64;
    for b in 0..batches.max(1) {
        let batch = task.eval_batch(b);
        let (l, c) = model.eval_step(params, &batch)?;
        loss += l as f64;
        metric += c as f64;
    }
    let n = batches.max(1) as f64;
    Ok((
        (loss / n) as f32,
        (metric / (n * model.metric_denom())) as f32,
    ))
}

fn assemble(
    cfg: &TrainCfg,
    desc: DataDesc,
    workers: Vec<WorkerOut>,
    fabric: &Fabric,
    wall: f64,
) -> TrainResult {
    let window = cfg
        .slowmo
        .as_ref()
        .map(|s| s.tau)
        .unwrap_or(16)
        .max(1) as usize;
    // Train curve: per-window mean over steps and workers.
    let steps = cfg.steps as usize;
    let mut train_curve = Vec::new();
    let mut best_train = f64::INFINITY;
    let mut i = 0;
    while i < steps {
        let j = (i + window).min(steps);
        let mut acc = 0.0f64;
        let mut n = 0usize;
        for w in &workers {
            for &l in &w.losses[i..j] {
                acc += l as f64;
                n += 1;
            }
        }
        let mean = acc / n.max(1) as f64;
        train_curve.push((j as u64, mean));
        best_train = best_train.min(mean);
        i = j;
    }
    // Grad-norm curve (same windows).
    let mut gradnorm_curve = Vec::new();
    if cfg.record_gradnorm {
        let mut i = 0;
        while i < steps {
            let j = (i + window).min(steps);
            let mut acc = 0.0f64;
            let mut n = 0usize;
            for w in &workers {
                for &g in &w.gradnorms[i..j] {
                    acc += g;
                    n += 1;
                }
            }
            gradnorm_curve.push((j as u64, acc / n.max(1) as f64));
            i = j;
        }
    }
    // Eval curve: combine workers per step.
    let mut eval_curve = Vec::new();
    if let Some(first) = workers.first() {
        for (idx, &(step, ..)) in first.evals.iter().enumerate() {
            let losses: Vec<f64> = workers
                .iter()
                .map(|w| w.evals[idx].1 as f64)
                .collect();
            let metrics: Vec<f64> = workers
                .iter()
                .map(|w| w.evals[idx].2 as f64)
                .collect();
            let clock = workers
                .iter()
                .map(|w| w.evals[idx].3)
                .fold(0.0f64, f64::max);
            eval_curve.push(EvalPoint {
                step,
                loss_mean: crate::util::mean(&losses),
                loss_min: losses.iter().cloned().fold(f64::INFINITY, f64::min),
                loss_max: losses.iter().cloned().fold(f64::NEG_INFINITY,
                                                      f64::max),
                metric_mean: crate::util::mean(&metrics),
                sim_time: clock,
            });
        }
    }
    // Higher-is-better for classifier/LM accuracy; lower for quad gsq.
    let metric_better_high = !matches!(desc, DataDesc::Quad { .. });
    let best_eval_metric = eval_curve
        .iter()
        .map(|p| p.metric_mean)
        .fold(
            if metric_better_high {
                f64::NEG_INFINITY
            } else {
                f64::INFINITY
            },
            if metric_better_high { f64::max } else { f64::min },
        );
    let final_eval_loss =
        eval_curve.last().map(|p| p.loss_mean).unwrap_or(f64::NAN);
    let sim_time = workers.iter().map(|w| w.clock).fold(0.0f64, f64::max);
    TrainResult {
        algo: cfg.algo_name(),
        preset: cfg.preset.clone(),
        m: cfg.m,
        steps: cfg.steps,
        seed: cfg.seed,
        train_curve,
        eval_curve,
        best_train_loss: best_train,
        best_eval_metric,
        final_eval_loss,
        sim_time,
        wall_time: wall,
        bytes_sent: fabric.bytes_sent(),
        gradnorm_curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_spec_parse() {
        assert!(matches!(AlgoSpec::parse("local"),
                         Some(AlgoSpec::Local(_))));
        assert!(matches!(AlgoSpec::parse("sgp"), Some(AlgoSpec::Sgp(_))));
        assert!(matches!(AlgoSpec::parse("osgp"), Some(AlgoSpec::Osgp(_))));
        assert!(matches!(AlgoSpec::parse("dpsgd"),
                         Some(AlgoSpec::Dpsgd(_))));
        assert!(matches!(AlgoSpec::parse("ar"),
                         Some(AlgoSpec::AllReduce(_))));
        match AlgoSpec::parse("doubleavg:24") {
            Some(AlgoSpec::DoubleAvg(_, 24)) => {}
            other => panic!("{other:?}"),
        }
        match AlgoSpec::parse("local-adam") {
            Some(AlgoSpec::Local(InnerOpt::Adam { .. })) => {}
            other => panic!("{other:?}"),
        }
        assert!(AlgoSpec::parse("bogus").is_none());
    }

    #[test]
    fn algo_name_formats() {
        let cfg = TrainCfg::quick("quad", AlgoSpec::parse("sgp").unwrap(), 10)
            .with_slowmo(crate::slowmo::SlowMoCfg::new(1.0, 0.6, 48));
        let n = cfg.algo_name();
        assert!(n.contains("sgp"), "{n}");
        assert!(n.contains("t48"), "{n}");
        assert!(n.contains("b0.6"), "{n}");
        let bare =
            TrainCfg::quick("quad", AlgoSpec::parse("local").unwrap(), 10);
        assert_eq!(bare.algo_name(), "local-nesterov-sgd");
    }
}
