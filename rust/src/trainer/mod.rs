//! The multi-worker training driver.
//!
//! Spawns one thread per worker, wires the data tasks, the base algorithm,
//! the optional SlowMo controller, the fabric, and the model executor
//! together, and records the metrics every experiment harness consumes.
//!
//! Runs are configured and launched through the [`crate::session`] API
//! (`Session::train(..) -> TrainBuilder -> run()`); [`TrainCfg`] is the
//! resolved configuration the builder produces. Attach a [`RunObserver`]
//! to stream per-step/per-eval events and to stop a run early.

pub mod metrics;
pub mod model_exec;
pub mod observer;
pub mod schedule;

pub use metrics::{EvalPoint, SeedAggregate, TrainResult};
pub use model_exec::ModelExec;
pub use observer::{
    EvalEarlyStop, EvalEvent, OuterEvent, ProgressPrinter, Recorder,
    RunControl, RunObserver, StepEvent,
};
pub use schedule::Schedule;

use crate::algorithms::{
    AlgoSel, BaseAlgorithm, Ctx, StateLayout, WorkerState,
};
use crate::compress::{CompressSel, CompressState, Compressor};
use crate::data::{task_for, Task};
use crate::exec::ExecMode;
use crate::net::{ChaosCfg, ChaosPlan, CostModel, Fabric};
use crate::optim::kernels::{InnerOpt, Kernels};
use crate::runtime::DataDesc;
use crate::slowmo::{
    hier, outer_update_g, BufferStrategy, HierCfg, OuterOpt, OuterState,
    SlowMoCfg,
};
use crate::topology::{Groups, TierTree};
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Worker-state layout for the simulator's scale runs.
///
/// `Dense` gives every worker the full private buffer set (`x`, `h`,
/// `z`, `x0`, rule state) — the default, and the only layout the PJRT
/// kernels or the threaded backend accept. `Shared` initializes every
/// worker from one read-only `Arc` of the init vector
/// ([`crate::slowmo::OuterState::new_shared`]) and elides the buffers
/// the run provably never reads — the momentum buffer `h` when the
/// inner optimizer is momentum-free and the de-bias mirror `z` when the
/// base algorithm reports [`BaseAlgorithm::needs_debias`] `false` — so
/// memory per worker drops from 5 to 3 `d`-vectors and m = 4096 quad
/// cells fit in one process. Math is bitwise-identical where both
/// layouts run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateMode {
    Dense,
    Shared,
}

impl StateMode {
    pub fn name(&self) -> &'static str {
        match self {
            StateMode::Dense => "dense",
            StateMode::Shared => "shared",
        }
    }
}

impl std::str::FromStr for StateMode {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "dense" => Ok(StateMode::Dense),
            "shared" => Ok(StateMode::Shared),
            other => Err(format!(
                "unknown state mode {other:?} (use \"dense\" or \
                 \"shared\")"
            )),
        }
    }
}

/// Full training configuration for one run. Construct through
/// [`crate::session::TrainBuilder`] — the builder owns the defaults and
/// resolves the algorithm key against the session's
/// [`crate::algorithms::AlgoRegistry`].
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub preset: String,
    pub m: usize,
    /// Total inner steps per worker.
    pub steps: u64,
    pub seed: u64,
    /// Registry key + inner optimizer + optional argument.
    pub algo: AlgoSel,
    /// `None` = run the base algorithm bare (e.g. plain SGP baseline).
    pub slowmo: Option<SlowMoCfg>,
    /// Hierarchical topology: worker groups with fast intra-group and
    /// slow inter-group links. With `two_level` the base algorithm runs
    /// group-locally and the SlowMo boundary is the two-level reduce;
    /// without it the flat algorithm runs on the tiered cluster
    /// (per-link costs + inter-byte accounting only). `None` = flat.
    pub hier: Option<HierCfg>,
    pub sched: Schedule,
    /// Data heterogeneity knob (0 = iid shards .. 1 = strongly non-iid).
    pub heterogeneity: f64,
    /// Evaluate every this many steps (0 = only at the end).
    pub eval_every: u64,
    pub eval_batches: u64,
    /// Force model graphs through PJRT even when a native path exists.
    pub force_pjrt: bool,
    /// Run the optimizer kernels natively instead of via the AOT
    /// artifacts (perf ablation; math is identical).
    pub native_kernels: bool,
    pub cost: CostModel,
    /// Simulated compute charge per inner step; 0.0 = use measured wall
    /// time of the train_step call.
    pub compute_time_s: f64,
    /// Record grad-norm² trajectories (theory benches).
    pub record_gradnorm: bool,
    /// Observer early-stop granularity in steps; `None` = the SlowMo τ,
    /// or 16 without SlowMo. Stops only take effect at multiples of this.
    pub stop_check_every: Option<u64>,
    /// Execution backend: `Sim` (default) runs the simulated fabric,
    /// `Threaded` the real-parallel spin-channel transport. Identical
    /// math; only `wall_time`/`comm_wall_time` change meaning.
    pub exec: ExecMode,
    /// Deterministic network degradation (delays, drops, stragglers,
    /// fault windows). `None` = the perfect network. Sim-only: a run
    /// with both `exec = threaded` and chaos is rejected.
    pub chaos: Option<ChaosCfg>,
    /// Communication compression (registry selection; `none` = raw f32
    /// everywhere, bit-identical to the pre-compression path). Resolved
    /// against the session's [`crate::compress::CompressRegistry`] when
    /// the run starts.
    pub compress: CompressSel,
    /// Record worker 0's final (de-biased) parameters into the result —
    /// used by the chaos equivalence tests; off by default (costs one
    /// `d`-sized copy).
    pub record_final_params: bool,
    /// Worker-state layout (see [`StateMode`]); `Dense` by default.
    /// `Shared` is sim-only and requires native kernels; chaos, the
    /// `Average` buffer strategy and semi-synchronous quorums are
    /// rejected (they overwrite or average buffers the layout elides).
    pub state: StateMode,
}

impl TrainCfg {
    /// The builder's starting point (see `TrainBuilder` for the knobs).
    pub(crate) fn defaults(preset: &str) -> Self {
        Self {
            preset: preset.to_string(),
            m: 4,
            steps: 240,
            seed: 0,
            algo: AlgoSel::new("sgp"),
            slowmo: None,
            hier: None,
            sched: Schedule::Const(0.1),
            heterogeneity: 0.5,
            eval_every: 0,
            eval_batches: 8,
            force_pjrt: false,
            native_kernels: true,
            cost: CostModel::ethernet_10g(),
            compute_time_s: 0.0,
            record_gradnorm: false,
            stop_check_every: None,
            exec: ExecMode::Sim,
            chaos: None,
            compress: CompressSel::none(),
            record_final_params: false,
            state: StateMode::Dense,
        }
    }
}

/// Display name for a run: the base algorithm plus the outer rule's key
/// and hyperparameters, e.g. "sgp-nesterov-sgd+slowmo(t48,a1,b0.6,reset)"
/// or "local-nesterov-sgd+adam(t48,b1=0.9,b2=0.95,reset)".
pub fn display_name(
    base: &str,
    slowmo: &Option<SlowMoCfg>,
    rule: Option<&dyn OuterOpt>,
) -> String {
    match (slowmo, rule) {
        (Some(s), Some(r)) => {
            let params = r.params();
            format!(
                "{base}+{}(t{}{}{}{})",
                r.key(),
                s.tau,
                if params.is_empty() {
                    String::new()
                } else {
                    format!(",{params}")
                },
                if s.exact_average { "" } else { ",noavg" },
                format_args!(",{}", s.buffers.name()),
            )
        }
        _ => base.to_string(),
    }
}

struct WorkerOut {
    losses: Vec<f32>,
    gradnorms: Vec<f64>,
    evals: Vec<(u64, f32, f32, f64)>, // (step, loss, metric, clock)
    clock: f64,
    /// Real seconds this worker spent inside `train_step` calls (the
    /// compute half of the wall-clock phase breakdown; the comm half
    /// lives in the fabric's per-worker wait counters).
    compute_wall: f64,
    steps_run: u64,
    /// Outer boundaries this worker missed the quorum at (semi-sync).
    quorum_misses: u64,
    /// Stale contributions this worker folded into a later boundary.
    stale_folds: u64,
    final_params: Option<Vec<f32>>,
}

/// Checkpoint rendezvous for observed runs: like a cyclic barrier, but a
/// worker that exits with an error calls [`CheckpointGate::depart`] so the
/// remaining workers are released instead of deadlocking (the error then
/// propagates when the results are joined).
struct CheckpointGate {
    m: usize,
    state: std::sync::Mutex<GateState>,
    cv: std::sync::Condvar,
}

#[derive(Default)]
struct GateState {
    arrived: usize,
    departed: usize,
    generation: u64,
}

impl CheckpointGate {
    fn new(m: usize) -> Self {
        Self {
            m,
            state: std::sync::Mutex::new(GateState::default()),
            cv: std::sync::Condvar::new(),
        }
    }

    /// Block until every still-active worker arrives.
    fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        st.arrived += 1;
        if st.arrived + st.departed >= self.m {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Permanently leave the gate (worker errored out); releases the
    /// current generation if this departure completes it.
    fn depart(&self) {
        let mut st = self.state.lock().unwrap();
        st.departed += 1;
        if st.arrived > 0 && st.arrived + st.departed >= self.m {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
        }
    }
}

/// Run one training job whose resources (model executor, kernels, built
/// algorithm, init vector) have already been prepared by the
/// [`crate::session::Session`]. Observer callbacks fire on worker 0; see
/// [`observer`] for the early-stop synchronization contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_prepared(
    cfg: &TrainCfg,
    algos: Vec<Arc<dyn BaseAlgorithm>>,
    tiers: Option<Arc<TierTree>>,
    outer_rule: Option<Arc<dyn OuterOpt>>,
    compressor: Option<Arc<dyn Compressor>>,
    init: &Arc<Vec<f32>>,
    desc: &DataDesc,
    model: &ModelExec,
    kernels: &Kernels,
    observer: Option<&mut dyn RunObserver>,
) -> Result<TrainResult> {
    let t_wall = Instant::now();
    // Leaf partition: the group-local algorithm machinery (scopes,
    // intra-group averages, rejoin shipping) always works on tier 0.
    let groups: Option<Arc<Groups>> =
        tiers.as_ref().map(|t| Arc::clone(t.leaf()));
    let tree_depth = tiers.as_ref().map(|t| t.depth()).unwrap_or(0);
    if let Some(s) = &cfg.slowmo {
        s.validate()?;
        ensure!(
            outer_rule.is_some(),
            "slowmo configured without a built outer rule (run through \
             Session, which resolves cfg.slowmo.outer via its \
             OuterRegistry)"
        );
    }
    ensure!(
        cfg.compress.is_none() || compressor.is_some(),
        "compression configured without a built codec (run through \
         Session, which resolves cfg.compress via its CompressRegistry)"
    );
    // Hierarchical topology: the session resolves the partition and
    // builds one group-local algorithm per group (two-level mode).
    let two_level = cfg.hier.as_ref().map(|h| h.two_level).unwrap_or(false);
    if let Some(h) = &cfg.hier {
        h.validate()?;
        let gr = groups.as_deref().ok_or_else(|| {
            anyhow::anyhow!(
                "hierarchy configured without a resolved tier tree (run \
                 through Session, which parses [groups] spec against m)"
            )
        })?;
        ensure!(
            gr.m() == cfg.m,
            "groups partition covers {} workers but m={}",
            gr.m(),
            cfg.m
        );
        ensure!(
            !h.two_level || cfg.slowmo.is_some(),
            "hierarchical groups need a SlowMo outer wrapper (the \
             two-level reduce happens at outer boundaries); use \
             two_level = false for tier accounting alone"
        );
        ensure!(
            algos.len() == if h.two_level { gr.g() } else { 1 },
            "expected one built algorithm per group"
        );
    } else {
        ensure!(algos.len() == 1, "flat runs build exactly one algorithm");
    }
    // Chaos charges simulated time for its delays/stragglers; the
    // threaded backend measures real time, which would silently ignore
    // every injected degradation. Refuse the combination outright.
    ensure!(
        cfg.exec == ExecMode::Sim || cfg.chaos.is_none(),
        "chaos injection is sim-only: simulated delay/straggler charges \
         have no effect on the threaded backend's wall clock (drop \
         [chaos] or use exec = \"sim\")"
    );
    // The identity codec takes the exact pre-compression code path.
    let codec: Option<&dyn Compressor> =
        compressor.as_deref().filter(|c| !c.is_identity());
    let task: Box<dyn Task> =
        task_for(desc, cfg.m, cfg.seed, cfg.heterogeneity);
    let chaos_plan: Option<Arc<ChaosPlan>> = match &cfg.chaos {
        Some(c) => {
            let plan = ChaosPlan::new(c.clone(), cfg.m, &cfg.cost)?;
            if plan.has_faults() {
                ensure!(
                    cfg.slowmo.is_some(),
                    "chaos fault injection needs SlowMo outer boundaries \
                     (elastic membership happens at the outer allreduce)"
                );
                // Probe with a large d: amortized accountings like
                // doubleavg's `2*buffers*d/tau` round down to 0 for d=1.
                ensure!(
                    algos[0].comm_elems_per_step(1 << 20) == 0,
                    "chaos fault injection requires a communication-free \
                     base algorithm (use `local`; got {})",
                    algos[0].name()
                );
                ensure!(
                    cfg.hier.as_ref().map(|h| h.tau_inner).unwrap_or(0)
                        == 0,
                    "chaos fault injection cannot combine with \
                     tau_inner intra-group averages (membership is only \
                     defined at outer boundaries)"
                );
                ensure!(
                    tree_depth <= 1,
                    "chaos fault injection supports flat or two-level \
                     topologies only (rejoin shipping is leaf-group \
                     based; got a depth-{tree_depth} tier tree)"
                );
            }
            Some(Arc::new(plan))
        }
        None => None,
    };
    // Semi-synchronous quorum boundaries (q < m) share the elastic
    // machinery's constraints — a quorum-late worker freezes a full
    // outer round — plus their own: membership would be decided twice
    // if fault windows ran alongside, and arrival stamps are simulated
    // clocks.
    if let Some(s) = &cfg.slowmo {
        if let Some(q) = s.quorum {
            ensure!(
                q <= cfg.m,
                "slowmo quorum {q} exceeds the worker count m={}",
                cfg.m
            );
            if q < cfg.m {
                ensure!(
                    cfg.exec == ExecMode::Sim,
                    "semi-synchronous quorum boundaries are sim-only \
                     (quorum selection reads simulated arrival stamps); \
                     use exec = \"sim\" or quorum = m"
                );
                ensure!(
                    algos[0].comm_elems_per_step(1 << 20) == 0,
                    "semi-synchronous quorum boundaries require a \
                     communication-free base algorithm (a gossiping base \
                     would deadlock on quorum-late workers; use `local`, \
                     got {})",
                    algos[0].name()
                );
                if let Some(c) = &cfg.chaos {
                    ensure!(
                        c.faults.is_empty(),
                        "semi-synchronous quorum boundaries cannot \
                         combine with chaos fault windows (two membership \
                         authorities at one boundary); model the \
                         adversary with stragglers/delays instead"
                    );
                }
                if let Some(h) = &cfg.hier {
                    ensure!(
                        h.tau_inner == 0,
                        "semi-synchronous quorum boundaries cannot \
                         combine with tau_inner intra-group averages \
                         (they would deadlock on quorum-late workers)"
                    );
                }
                ensure!(
                    tree_depth <= 1,
                    "semi-synchronous quorum boundaries support flat or \
                     two-level topologies only (got a \
                     depth-{tree_depth} tier tree)"
                );
            }
        }
    }
    // Shared worker state: the seams it relies on (elided buffers, one
    // read-only init Arc) hold only on the native sim path without
    // machinery that overwrites or averages the elided buffers.
    if cfg.state == StateMode::Shared {
        ensure!(
            cfg.native_kernels,
            "shared worker state requires native kernels (the AOT PJRT \
             optimizer kernels take full-size momentum buffers and \
             cannot elide them); set native_kernels = true"
        );
        ensure!(
            cfg.exec == ExecMode::Sim,
            "shared worker state is sim-only (the scale harness \
             measures one process's peak RSS under the simulated \
             fabric); use exec = \"sim\" or state = \"dense\""
        );
        ensure!(
            cfg.chaos.is_none(),
            "shared worker state cannot combine with chaos injection \
             (rejoin transfers overwrite buffers the layout elides); \
             drop [chaos] or use state = \"dense\""
        );
        if let Some(s) = &cfg.slowmo {
            ensure!(
                s.buffers != BufferStrategy::Average,
                "shared worker state cannot use the Average buffer \
                 strategy (it averages momentum buffers the layout may \
                 elide); use reset/maintain or state = \"dense\""
            );
            ensure!(
                !s.quorum.is_some_and(|q| q < cfg.m),
                "shared worker state cannot combine with \
                 semi-synchronous quorum boundaries (resync transfers \
                 overwrite buffers the layout elides); use quorum = m \
                 or state = \"dense\""
            );
        }
    }
    let mut fabric = match &chaos_plan {
        Some(plan) => {
            Fabric::with_chaos(cfg.m, cfg.cost.clone(), Arc::clone(plan))
        }
        None => Fabric::with_mode(cfg.m, cfg.cost.clone(), cfg.exec),
    };
    if let (Some(h), Some(tree)) = (&cfg.hier, &tiers) {
        fabric.set_tier_tree(
            Arc::clone(tree),
            h.tier_costs(&cfg.cost, tree.depth()),
        );
    }
    let fabric = fabric;
    let mut algo_name =
        display_name(&algos[0].name(), &cfg.slowmo, outer_rule.as_deref());
    if let (Some(h), Some(gr)) = (&cfg.hier, &groups) {
        // Depth-1 trees keep the historical two-level display names.
        let depth_suffix = if tree_depth >= 2 {
            format!(",d{tree_depth}")
        } else {
            String::new()
        };
        if h.two_level {
            algo_name.push_str(&format!(
                "+hier(g{}{}{})",
                gr.g(),
                depth_suffix,
                if h.tau_inner > 0 {
                    format!(",ti{}", h.tau_inner)
                } else {
                    String::new()
                }
            ));
        } else {
            algo_name.push_str(&format!(
                "+tiered(g{}{})",
                gr.g(),
                depth_suffix
            ));
        }
    }
    if codec.is_some() {
        algo_name.push_str(&format!("+{}", cfg.compress.spec()));
    }
    if cfg.chaos.is_some() {
        algo_name.push_str("+chaos");
    }

    let eval_points: Vec<u64> = {
        let mut pts = Vec::new();
        if cfg.eval_every > 0 {
            let mut s = cfg.eval_every;
            while s < cfg.steps {
                pts.push(s);
                s += cfg.eval_every;
            }
        }
        pts.push(cfg.steps); // always evaluate at the end
        pts
    };

    // Early-stop plumbing (active only when an observer is attached).
    // Stops take effect at checkpoint steps where all workers rendezvous
    // and read the same decision, keeping lockstep collectives aligned.
    let check = cfg
        .stop_check_every
        .unwrap_or_else(|| cfg.slowmo.as_ref().map(|s| s.tau).unwrap_or(16))
        .max(1);
    let stop_at = AtomicU64::new(u64::MAX);
    let observing = observer.is_some();
    let observer = observer.map(Mutex::new);
    let gate = CheckpointGate::new(cfg.m);

    let outs: Vec<Result<WorkerOut>> = crate::exec::run_workers(cfg.m, |w| {
        let body = || -> Result<WorkerOut> {
        // Group-local view (two-level mode): this worker's base algorithm
        // instance is sized to its group and communicates only inside it.
        let (algo, scope): (&Arc<dyn BaseAlgorithm>, Option<&[usize]>) =
            match (&groups, two_level) {
                (Some(gr), true) => {
                    let gi = gr.group_of(w);
                    (&algos[gi], Some(gr.members(gi)))
                }
                _ => (&algos[0], None),
            };
        let mut state = if cfg.state == StateMode::Shared {
            // Elide what this run provably never reads: `h` when the
            // inner optimizer carries no momentum, `z` when the base
            // algorithm needs no de-bias mirror.
            let layout = StateLayout {
                lean_h: matches!(
                    algo.inner(),
                    InnerOpt::Nesterov { beta0, .. } if *beta0 == 0.0
                ),
                lean_z: !algo.needs_debias(),
            };
            WorkerState::with_layout(init, algo.inner(), layout)
        } else {
            WorkerState::new(init, algo.inner())
        };
        // Key the compression streams/residuals by (run seed, rank) so
        // randomized codecs are deterministic per worker.
        state.comp = CompressState::new(cfg.seed, w as u64);
        let mut outer = outer_rule.as_deref().map(|r| {
            if cfg.state == StateMode::Shared {
                // All m workers reference one init allocation; x0
                // copies on its first write (the first outer step).
                OuterState::new_shared(Arc::clone(init), r)
            } else {
                OuterState::new(init, r)
            }
        });
        let mut ctx = Ctx {
            worker: w,
            m: cfg.m,
            fabric: &fabric,
            kernels,
            compress: codec,
            scope,
            clock: 0.0,
            scratch: crate::util::Scratch::new(),
        };
        let mut out = WorkerOut {
            losses: Vec::with_capacity(cfg.steps as usize),
            gradnorms: Vec::new(),
            evals: Vec::new(),
            clock: 0.0,
            compute_wall: 0.0,
            steps_run: 0,
            quorum_misses: 0,
            stale_folds: 0,
            final_params: None,
        };
        // Straggler slowdown: a chaos-designated slow worker charges more
        // simulated time per inner compute step.
        let slowdown = chaos_plan
            .as_ref()
            .map(|p| p.compute_factor(w))
            .unwrap_or(1.0);
        let mut eval_idx = 0;
        let mut gamma_outer = cfg.sched.gamma(0);
        for k in 0..cfg.steps {
            if observing && k > 0 && k % check == 0 {
                gate.wait();
                if k >= stop_at.load(Ordering::SeqCst) {
                    break;
                }
            }
            let gamma = cfg.sched.gamma(k);
            if let Some(s) = &cfg.slowmo {
                if k % s.tau == 0 {
                    // γ_t for Eq. 2: the rate in effect at the start of
                    // this outer iteration.
                    gamma_outer = gamma;
                }
            }
            let batch = task.train_batch(w, k);
            let t0 = Instant::now();
            let (loss, grads) =
                model.train_step(algo.eval_params(&state), &batch)?;
            let step_wall = t0.elapsed().as_secs_f64();
            out.compute_wall += step_wall;
            let compute = if cfg.compute_time_s > 0.0 {
                cfg.compute_time_s
            } else {
                step_wall
            };
            ctx.clock += compute * slowdown;
            out.losses.push(loss);
            if cfg.record_gradnorm {
                out.gradnorms.push(crate::util::sqnorm(&grads));
            }
            algo.step(&mut ctx, &mut state, &grads, gamma, k)?;
            out.steps_run += 1;
            // Hierarchical fast path: exact-average the group every
            // tau_inner steps (outer boundaries subsume their own — the
            // two-level reduce already synchronizes everyone).
            if let (Some(h), Some(gr)) = (&cfg.hier, &groups) {
                let at_boundary = cfg
                    .slowmo
                    .as_ref()
                    .map(|s| s.is_boundary(k))
                    .unwrap_or(false);
                if h.two_level
                    && h.tau_inner > 0
                    && (k + 1) % h.tau_inner == 0
                    && !at_boundary
                {
                    {
                        let WorkerState { x, comp, .. } = &mut state;
                        ctx.clock = hier::intra_average(
                            &fabric, gr, w, x, comp, ctx.clock, k, codec,
                        );
                    }
                    algo.on_exact_average(&mut state);
                }
            }
            let mut stop_req = false;
            if w == 0 {
                if let Some(obs) = &observer {
                    let ev = StepEvent {
                        step: k,
                        loss,
                        gamma,
                        clock: ctx.clock,
                    };
                    stop_req |= obs.lock().unwrap().on_step(&ev)
                        == RunControl::Stop;
                }
            }
            if let (Some(scfg), Some(rule), Some(outer)) =
                (&cfg.slowmo, outer_rule.as_deref(), outer.as_mut())
            {
                if scfg.is_boundary(k) {
                    let hier_tree = if two_level {
                        tiers.as_deref()
                    } else {
                        None
                    };
                    ctx.clock = outer_update_g(
                        scfg, rule, algo.as_ref(), &fabric, kernels, w,
                        &mut state, outer, gamma_outer, ctx.clock,
                        chaos_plan.as_deref(), hier_tree, codec,
                    )?;
                    if w == 0 {
                        if let Some(obs) = &observer {
                            let ev = OuterEvent {
                                step: k,
                                outer_t: outer.t,
                                clock: ctx.clock,
                            };
                            stop_req |= obs
                                .lock()
                                .unwrap()
                                .on_outer_boundary(&ev)
                                == RunControl::Stop;
                        }
                    }
                }
            }
            // Evaluation checkpoints.
            while eval_idx < eval_points.len()
                && k + 1 == eval_points[eval_idx]
            {
                let (l, mtr) =
                    run_eval(model, &*task, algo.eval_params(&state),
                             cfg.eval_batches)?;
                out.evals.push((k + 1, l, mtr, ctx.clock));
                if w == 0 {
                    if let Some(obs) = &observer {
                        let ev = EvalEvent {
                            step: k + 1,
                            loss: l,
                            metric: mtr,
                            clock: ctx.clock,
                        };
                        stop_req |= obs.lock().unwrap().on_eval(&ev)
                            == RunControl::Stop;
                    }
                }
                eval_idx += 1;
            }
            if stop_req {
                // Effective at the next checkpoint after k; every worker
                // reads it behind the checkpoint barrier.
                stop_at.fetch_min((k / check + 1) * check,
                                  Ordering::SeqCst);
            }
        }
        out.clock = ctx.clock;
        if let Some(o) = &outer {
            out.quorum_misses = o.quorum_misses;
            out.stale_folds = o.stale_folds;
        }
        if cfg.record_final_params {
            out.final_params = Some(algo.eval_params(&state).to_vec());
        }
        Ok(out)
        };
        let res = body();
        if res.is_err() {
            // Release peers blocked at a checkpoint so the error can
            // propagate instead of deadlocking the join below.
            gate.depart();
        }
        res
    });
    let mut workers = Vec::with_capacity(cfg.m);
    for o in outs {
        workers.push(o?);
    }

    let retransmits = chaos_plan
        .as_ref()
        .map(|p| p.retransmits())
        .unwrap_or(0);
    Ok(assemble(cfg, algo_name, desc.clone(), workers, &fabric,
                t_wall.elapsed().as_secs_f64(), retransmits))
}

fn run_eval(
    model: &ModelExec,
    task: &dyn Task,
    params: &[f32],
    batches: u64,
) -> Result<(f32, f32)> {
    let mut loss = 0.0f64;
    let mut metric = 0.0f64;
    for b in 0..batches.max(1) {
        let batch = task.eval_batch(b);
        let (l, c) = model.eval_step(params, &batch)?;
        loss += l as f64;
        metric += c as f64;
    }
    let n = batches.max(1) as f64;
    Ok((
        (loss / n) as f32,
        (metric / (n * model.metric_denom())) as f32,
    ))
}

fn assemble(
    cfg: &TrainCfg,
    algo_name: String,
    desc: DataDesc,
    mut workers: Vec<WorkerOut>,
    fabric: &Fabric,
    wall: f64,
    retransmits: u64,
) -> TrainResult {
    let final_params =
        workers.first_mut().and_then(|w| w.final_params.take());
    let window = cfg
        .slowmo
        .as_ref()
        .map(|s| s.tau)
        .unwrap_or(16)
        .max(1) as usize;
    // Steps every worker completed (== cfg.steps unless an observer
    // stopped the run early).
    let steps = workers
        .iter()
        .map(|w| w.losses.len())
        .min()
        .unwrap_or(0);
    let steps_run = workers
        .iter()
        .map(|w| w.steps_run)
        .min()
        .unwrap_or(0);
    // Train curve: per-window mean over steps and workers.
    let mut train_curve = Vec::new();
    let mut best_train = f64::INFINITY;
    let mut i = 0;
    while i < steps {
        let j = (i + window).min(steps);
        let mut acc = 0.0f64;
        let mut n = 0usize;
        for w in &workers {
            for &l in &w.losses[i..j] {
                acc += l as f64;
                n += 1;
            }
        }
        let mean = acc / n.max(1) as f64;
        train_curve.push((j as u64, mean));
        best_train = best_train.min(mean);
        i = j;
    }
    // Grad-norm curve (same windows).
    let mut gradnorm_curve = Vec::new();
    if cfg.record_gradnorm {
        let mut i = 0;
        while i < steps {
            let j = (i + window).min(steps);
            let mut acc = 0.0f64;
            let mut n = 0usize;
            for w in &workers {
                for &g in &w.gradnorms[i..j] {
                    acc += g;
                    n += 1;
                }
            }
            gradnorm_curve.push((j as u64, acc / n.max(1) as f64));
            i = j;
        }
    }
    // Eval curve: combine workers per step.
    let mut eval_curve = Vec::new();
    let n_evals = workers
        .iter()
        .map(|w| w.evals.len())
        .min()
        .unwrap_or(0);
    for idx in 0..n_evals {
        let step = workers[0].evals[idx].0;
        let losses: Vec<f64> = workers
            .iter()
            .map(|w| w.evals[idx].1 as f64)
            .collect();
        let metrics: Vec<f64> = workers
            .iter()
            .map(|w| w.evals[idx].2 as f64)
            .collect();
        let clock = workers
            .iter()
            .map(|w| w.evals[idx].3)
            .fold(0.0f64, f64::max);
        eval_curve.push(EvalPoint {
            step,
            loss_mean: crate::util::mean(&losses),
            loss_min: losses.iter().cloned().fold(f64::INFINITY, f64::min),
            loss_max: losses.iter().cloned().fold(f64::NEG_INFINITY,
                                                  f64::max),
            metric_mean: crate::util::mean(&metrics),
            sim_time: clock,
        });
    }
    // Higher-is-better for classifier/LM accuracy; lower for quad gsq.
    let metric_better_high = !matches!(desc, DataDesc::Quad { .. });
    let best_eval_metric = eval_curve
        .iter()
        .map(|p| p.metric_mean)
        .fold(
            if metric_better_high {
                f64::NEG_INFINITY
            } else {
                f64::INFINITY
            },
            if metric_better_high { f64::max } else { f64::min },
        );
    let final_eval_loss =
        eval_curve.last().map(|p| p.loss_mean).unwrap_or(f64::NAN);
    let sim_time = workers.iter().map(|w| w.clock).fold(0.0f64, f64::max);
    let compute_wall_time = crate::util::mean(
        &workers.iter().map(|w| w.compute_wall).collect::<Vec<_>>(),
    );
    let comm_wall_time = crate::util::mean(
        &(0..cfg.m).map(|w| fabric.comm_wait_s(w)).collect::<Vec<_>>(),
    );
    let quorum_misses =
        workers.iter().map(|w| w.quorum_misses).sum::<u64>();
    let stale_folds = workers.iter().map(|w| w.stale_folds).sum::<u64>();
    TrainResult {
        algo: algo_name,
        outer: cfg.slowmo.as_ref().map(|s| s.outer.spec()),
        // The full tier-tree spec; identical to the leaf partition's
        // spec for depth-1 (historical two-level) runs.
        groups: fabric.tier_tree().map(|t| t.spec()),
        compress: if cfg.compress.is_none() {
            None
        } else {
            Some(cfg.compress.spec())
        },
        preset: cfg.preset.clone(),
        m: cfg.m,
        steps: cfg.steps,
        steps_run,
        seed: cfg.seed,
        train_curve,
        eval_curve,
        best_train_loss: best_train,
        best_eval_metric,
        final_eval_loss,
        sim_time,
        wall_time: wall,
        exec: fabric.mode().name().to_string(),
        compute_wall_time,
        comm_wall_time,
        bytes_sent: fabric.bytes_sent(),
        bytes_saved: fabric.bytes_saved(),
        bytes_inter: fabric.bytes_inter(),
        retransmits,
        quorum_misses,
        stale_folds,
        state: cfg.state.name().to_string(),
        peak_rss_bytes: crate::util::peak_rss_bytes(),
        gradnorm_curve,
        final_params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slowmo::{
        BufferStrategy, OuterOptState, OuterRegistry, OuterSel,
    };
    use std::sync::Arc as StdArc;

    fn built(cfg: &SlowMoCfg) -> StdArc<dyn OuterOpt> {
        OuterRegistry::builtin().build(&cfg.outer).unwrap()
    }

    #[test]
    fn display_name_formats() {
        let cfg = crate::slowmo::SlowMoCfg::new(1.0, 0.6, 48);
        let rule = built(&cfg);
        let n = display_name("sgp-nesterov-sgd", &Some(cfg), Some(&*rule));
        // Exact legacy format: the slowmo rule's name is bit-compatible
        // with pre-registry display names.
        assert_eq!(n, "sgp-nesterov-sgd+slowmo(t48,a1,b0.6,reset)");
        assert_eq!(display_name("local-nesterov-sgd", &None, None),
                   "local-nesterov-sgd");
        let noavg = crate::slowmo::SlowMoCfg::new(1.0, 0.5, 8)
            .with_buffers(BufferStrategy::Maintain)
            .no_average();
        let rule = built(&noavg);
        let n = display_name("sgp", &Some(noavg), Some(&*rule));
        assert_eq!(n, "sgp+slowmo(t8,a1,b0.5,noavg,maintain)");
    }

    #[test]
    fn display_name_covers_every_registered_outer_key() {
        let reg = OuterRegistry::builtin();
        for key in reg.keys() {
            let sel = OuterSel::new(key);
            let rule = reg.build(&sel).unwrap();
            let s = Some(SlowMoCfg::with_outer(sel, 48));
            let n = display_name("local-nesterov-sgd", &s, Some(&*rule));
            assert!(
                n.starts_with(&format!("local-nesterov-sgd+{key}(t48")),
                "{n}"
            );
            assert!(n.ends_with(",reset)"), "{n}");
        }
        // The avg fast path carries no hyperparameters.
        let avg = reg.build(&OuterSel::new("avg")).unwrap();
        let s = Some(SlowMoCfg::with_outer(OuterSel::new("avg"), 8));
        assert_eq!(
            display_name("local-nesterov-sgd", &s, Some(&*avg)),
            "local-nesterov-sgd+avg(t8,reset)"
        );
        // Outer Adam renders both betas by name.
        let sel = reg.parse("adam:0.9,0.95").unwrap();
        let adam = reg.build(&sel).unwrap();
        let s = Some(SlowMoCfg::with_outer(sel, 48));
        assert_eq!(
            display_name("local-nesterov-sgd", &s, Some(&*adam)),
            "local-nesterov-sgd+adam(t48,b1=0.9,b2=0.95,reset)"
        );
    }

    #[test]
    fn display_name_reports_custom_registered_rule() {
        struct Whirl;
        impl OuterOpt for Whirl {
            fn key(&self) -> String {
                "whirl".into()
            }
            fn params(&self) -> String {
                "k=3".into()
            }
            fn n_bufs(&self) -> usize {
                0
            }
            fn step(
                &self,
                _x0: &mut Vec<f32>,
                _xt: &[f32],
                _state: &mut OuterOptState,
                _gamma: f32,
                _t: u64,
                _kernels: &Kernels,
            ) -> Result<()> {
                Ok(())
            }
        }
        let s = Some(SlowMoCfg::with_outer(OuterSel::new("whirl"), 4));
        assert_eq!(
            display_name("local-nesterov-sgd", &s, Some(&Whirl)),
            "local-nesterov-sgd+whirl(t4,k=3,reset)"
        );
    }

    #[test]
    fn checkpoint_gate_departure_releases_waiters() {
        // Two of three workers rendezvous repeatedly; the third departs
        // (as an erroring worker would) — the others must not deadlock.
        let gate = CheckpointGate::new(3);
        let out = crate::exec::run_workers(3, |w| {
            if w == 2 {
                gate.depart();
                return 0u32;
            }
            for _ in 0..5 {
                gate.wait();
            }
            1u32
        });
        assert_eq!(out, vec![1, 1, 0]);
    }

    #[test]
    fn checkpoint_gate_single_worker_never_blocks() {
        let gate = CheckpointGate::new(1);
        gate.wait();
        gate.wait();
    }

    #[test]
    fn cfg_defaults_are_sane() {
        let cfg = TrainCfg::defaults("quad");
        assert_eq!(cfg.preset, "quad");
        assert_eq!(cfg.m, 4);
        assert_eq!(cfg.algo.key, "sgp");
        assert!(cfg.slowmo.is_none());
        assert!(cfg.native_kernels);
        assert!(!cfg.force_pjrt);
        assert_eq!(cfg.stop_check_every, None);
        assert_eq!(cfg.exec, ExecMode::Sim);
        assert!(cfg.chaos.is_none());
        assert!(cfg.compress.is_none());
        assert!(cfg.hier.is_none());
        assert!(!cfg.record_final_params);
        assert_eq!(cfg.state, StateMode::Dense);
    }

    #[test]
    fn state_mode_parses_and_names_round_trip() {
        for mode in [StateMode::Dense, StateMode::Shared] {
            assert_eq!(mode.name().parse::<StateMode>().unwrap(), mode);
        }
        let e = "sparse".parse::<StateMode>().unwrap_err();
        assert!(e.contains("sparse"), "{e}");
        assert!(e.contains("dense"), "{e}");
    }
}
