//! Fast-learning-rate schedules γ_t.
//!
//! The paper uses linear warmup + step decay for the image tasks (Goyal et
//! al. 2017) and linear warmup + inverse-sqrt decay for WMT (Ott et al.
//! 2018). SlowMo's Eq. 2 divides the displacement by γ_t precisely so the
//! slow buffer is invariant to these schedules.

/// γ as a function of the global inner step k.
#[derive(Clone, Debug)]
pub enum Schedule {
    Const(f32),
    /// Linear warmup to `base` over `warmup` steps, then multiply by
    /// `factor` at each step in `decays` (absolute step indices).
    WarmupStepDecay {
        base: f32,
        warmup: u64,
        decays: Vec<u64>,
        factor: f32,
    },
    /// Linear warmup to `peak` over `warmup` steps, then
    /// peak * sqrt(warmup / k).
    WarmupInvSqrt { peak: f32, warmup: u64 },
}

impl Schedule {
    pub fn gamma(&self, k: u64) -> f32 {
        match self {
            Schedule::Const(g) => *g,
            Schedule::WarmupStepDecay { base, warmup, decays, factor } => {
                let mut g = if *warmup > 0 && k < *warmup {
                    base * (k + 1) as f32 / *warmup as f32
                } else {
                    *base
                };
                for &d in decays {
                    if k >= d {
                        g *= factor;
                    }
                }
                g
            }
            Schedule::WarmupInvSqrt { peak, warmup } => {
                if *warmup > 0 && k < *warmup {
                    peak * (k + 1) as f32 / *warmup as f32
                } else {
                    peak * (*warmup.max(&1) as f32 / (k + 1) as f32).sqrt()
                }
            }
        }
    }

    /// The paper's image-task schedule scaled to `total` steps: warmup for
    /// the first 2.5%, decay ×0.1 at 50%, 75%, 87.5% (CIFAR shape).
    pub fn image_default(base: f32, total: u64) -> Self {
        Schedule::WarmupStepDecay {
            base,
            warmup: total / 40,
            decays: vec![total / 2, total * 3 / 4, total * 7 / 8],
            factor: 0.1,
        }
    }

    /// The WMT-style Adam schedule scaled to `total` steps.
    pub fn lm_default(peak: f32, total: u64) -> Self {
        Schedule::WarmupInvSqrt { peak, warmup: (total / 10).max(1) }
    }
}

/// Spec-string form, used by `--sched` and TOML configs:
/// `const:<g>`, `image:<base>@<total>` (warmup + step decay), or
/// `lm:<peak>@<total>` (warmup + inverse-sqrt).
impl std::str::FromStr for Schedule {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let bad = || {
            format!(
                "bad schedule {s:?}: expected const:<g>, \
                 image:<base>@<total>, or lm:<peak>@<total>"
            )
        };
        let (kind, rest) = s.split_once(':').ok_or_else(bad)?;
        match kind {
            "const" => rest.parse::<f32>().map(Schedule::Const).map_err(|_| bad()),
            "image" | "lm" => {
                let (lr, total) = rest.split_once('@').ok_or_else(bad)?;
                let lr: f32 = lr.parse().map_err(|_| bad())?;
                let total: u64 = total.parse().map_err(|_| bad())?;
                Ok(if kind == "image" {
                    Schedule::image_default(lr, total)
                } else {
                    Schedule::lm_default(lr, total)
                })
            }
            _ => Err(bad()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = Schedule::Const(0.1);
        assert_eq!(s.gamma(0), 0.1);
        assert_eq!(s.gamma(1_000_000), 0.1);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::WarmupStepDecay {
            base: 1.0,
            warmup: 10,
            decays: vec![],
            factor: 0.1,
        };
        assert!((s.gamma(0) - 0.1).abs() < 1e-6);
        assert!((s.gamma(4) - 0.5).abs() < 1e-6);
        assert!((s.gamma(9) - 1.0).abs() < 1e-6);
        assert!((s.gamma(100) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn decays_compound() {
        let s = Schedule::WarmupStepDecay {
            base: 1.0,
            warmup: 0,
            decays: vec![10, 20],
            factor: 0.1,
        };
        assert!((s.gamma(5) - 1.0).abs() < 1e-7);
        assert!((s.gamma(10) - 0.1).abs() < 1e-7);
        assert!((s.gamma(25) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn inv_sqrt_decays() {
        let s = Schedule::WarmupInvSqrt { peak: 1e-3, warmup: 100 };
        assert!(s.gamma(0) < 1e-4);
        let at_warmup = s.gamma(99);
        assert!((at_warmup - 1e-3).abs() < 1e-5);
        let later = s.gamma(399);
        assert!((later - 5e-4).abs() < 1e-5, "{later}"); // sqrt(100/400)
        assert!(s.gamma(1000) < later);
    }

    #[test]
    fn from_str_parses_every_form() {
        let c: Schedule = "const:0.05".parse().unwrap();
        assert_eq!(c.gamma(0), 0.05);
        let img: Schedule = "image:0.1@4000".parse().unwrap();
        assert!((img.gamma(1000) - 0.1).abs() < 1e-6);
        assert!(img.gamma(2000) < 0.05);
        let lm: Schedule = "lm:2e-3@1000".parse().unwrap();
        assert!(lm.gamma(999) < 2e-3);
    }

    #[test]
    fn from_str_rejects_malformed() {
        for bad in ["", "const", "const:x", "image:0.1", "image:0.1@x",
                    "step:1@2", "lm:@100"] {
            let e = bad.parse::<Schedule>().unwrap_err();
            assert!(e.contains("expected"), "{bad}: {e}");
        }
    }

    #[test]
    fn presets_are_sane() {
        let img = Schedule::image_default(0.1, 4000);
        assert!(img.gamma(0) < 0.1);
        assert!((img.gamma(1000) - 0.1).abs() < 1e-6);
        assert!((img.gamma(2000) - 0.01).abs() < 1e-6);
        assert!(img.gamma(3999) < 1e-3);
        let lm = Schedule::lm_default(1e-3, 1000);
        assert!(lm.gamma(999) < 1e-3);
    }
}
