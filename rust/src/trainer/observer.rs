//! Streaming run observation: progress reporting, early stopping and
//! mid-run metric streaming without post-hoc `TrainResult` surgery.
//!
//! A [`RunObserver`] is attached to a run through
//! `TrainBuilder::run_observed` (or `Session::run_observed`). The trainer
//! invokes it **on worker 0 only**, synchronously inside the training
//! loop:
//!
//! - [`RunObserver::on_step`] after every inner step,
//! - [`RunObserver::on_outer_boundary`] after every SlowMo outer update,
//! - [`RunObserver::on_eval`] after every evaluation checkpoint (with
//!   worker 0's eval values).
//!
//! Returning [`RunControl::Stop`] from any callback requests early
//! termination. The stop takes effect at the next *checkpoint step* (a
//! multiple of the run's `stop_check_every`, default = the SlowMo τ, or
//! 16 without SlowMo), where all workers rendezvous on a barrier and read
//! the same decision — this keeps lockstep collectives (gossip, ring
//! allreduce, the SlowMo exact average) aligned, so no worker can block
//! on a peer that already stopped.

/// What an observer callback tells the trainer to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunControl {
    Continue,
    /// Halt the run at the next checkpoint step.
    Stop,
}

/// Emitted after every inner step (worker 0's view).
#[derive(Clone, Copy, Debug)]
pub struct StepEvent {
    /// Global inner step index k (0-based).
    pub step: u64,
    /// Worker 0's training loss at this step.
    pub loss: f32,
    /// Fast learning rate γ_k in effect.
    pub gamma: f32,
    /// Worker 0's simulated clock.
    pub clock: f64,
}

/// Emitted after every SlowMo outer update.
#[derive(Clone, Copy, Debug)]
pub struct OuterEvent {
    /// Inner step k at which the boundary fired.
    pub step: u64,
    /// Outer iterations completed (1-based after the first update).
    pub outer_t: u64,
    pub clock: f64,
}

/// Emitted after every evaluation checkpoint (worker 0's values).
#[derive(Clone, Copy, Debug)]
pub struct EvalEvent {
    /// 1-based step count at which the eval ran.
    pub step: u64,
    pub loss: f32,
    pub metric: f32,
    pub clock: f64,
}

/// Observer of a live training run. All methods default to
/// [`RunControl::Continue`], so implementors override only what they need.
pub trait RunObserver: Send {
    fn on_step(&mut self, _ev: &StepEvent) -> RunControl {
        RunControl::Continue
    }

    fn on_outer_boundary(&mut self, _ev: &OuterEvent) -> RunControl {
        RunControl::Continue
    }

    fn on_eval(&mut self, _ev: &EvalEvent) -> RunControl {
        RunControl::Continue
    }
}

/// Prints a progress line every `every` steps and at every eval point.
pub struct ProgressPrinter {
    pub every: u64,
}

impl RunObserver for ProgressPrinter {
    fn on_step(&mut self, ev: &StepEvent) -> RunControl {
        if self.every > 0 && (ev.step + 1) % self.every == 0 {
            println!(
                "[step {:>6}] loss {:.4}  gamma {:.4}  t_sim {:.2}s",
                ev.step + 1,
                ev.loss,
                ev.gamma,
                ev.clock
            );
        }
        RunControl::Continue
    }

    fn on_eval(&mut self, ev: &EvalEvent) -> RunControl {
        println!(
            "[eval {:>6}] loss {:.4}  metric {:.4}",
            ev.step, ev.loss, ev.metric
        );
        RunControl::Continue
    }
}

/// Stops the run after `patience` consecutive evals without the eval loss
/// improving by at least `min_delta`.
pub struct EvalEarlyStop {
    pub patience: usize,
    pub min_delta: f64,
    best: f64,
    bad: usize,
}

impl EvalEarlyStop {
    pub fn new(patience: usize, min_delta: f64) -> Self {
        Self {
            patience,
            min_delta,
            best: f64::INFINITY,
            bad: 0,
        }
    }

    /// Evals seen since the last improvement.
    pub fn evals_since_best(&self) -> usize {
        self.bad
    }
}

impl RunObserver for EvalEarlyStop {
    fn on_eval(&mut self, ev: &EvalEvent) -> RunControl {
        if (ev.loss as f64) < self.best - self.min_delta {
            self.best = ev.loss as f64;
            self.bad = 0;
        } else {
            self.bad += 1;
        }
        if self.bad > self.patience {
            RunControl::Stop
        } else {
            RunControl::Continue
        }
    }
}

/// Records every event (metric streaming / testing).
#[derive(Default)]
pub struct Recorder {
    pub steps: Vec<StepEvent>,
    pub outers: Vec<OuterEvent>,
    pub evals: Vec<EvalEvent>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RunObserver for Recorder {
    fn on_step(&mut self, ev: &StepEvent) -> RunControl {
        self.steps.push(*ev);
        RunControl::Continue
    }

    fn on_outer_boundary(&mut self, ev: &OuterEvent) -> RunControl {
        self.outers.push(*ev);
        RunControl::Continue
    }

    fn on_eval(&mut self, ev: &EvalEvent) -> RunControl {
        self.evals.push(*ev);
        RunControl::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(step: u64, loss: f32) -> EvalEvent {
        EvalEvent {
            step,
            loss,
            metric: 0.0,
            clock: 0.0,
        }
    }

    #[test]
    fn early_stop_fires_after_patience_exhausted() {
        let mut es = EvalEarlyStop::new(2, 0.0);
        assert_eq!(es.on_eval(&eval(10, 1.0)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(20, 0.5)), RunControl::Continue);
        // Three non-improving evals > patience of 2.
        assert_eq!(es.on_eval(&eval(30, 0.6)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(40, 0.6)), RunControl::Continue);
        assert_eq!(es.evals_since_best(), 2);
        assert_eq!(es.on_eval(&eval(50, 0.6)), RunControl::Stop);
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut es = EvalEarlyStop::new(1, 0.0);
        assert_eq!(es.on_eval(&eval(1, 1.0)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(2, 1.0)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(3, 0.9)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(4, 0.95)), RunControl::Continue);
        assert_eq!(es.on_eval(&eval(5, 0.95)), RunControl::Stop);
    }

    #[test]
    fn recorder_accumulates_all_event_kinds() {
        let mut r = Recorder::new();
        r.on_step(&StepEvent {
            step: 0,
            loss: 1.0,
            gamma: 0.1,
            clock: 0.0,
        });
        r.on_outer_boundary(&OuterEvent {
            step: 11,
            outer_t: 1,
            clock: 0.0,
        });
        r.on_eval(&eval(12, 0.5));
        assert_eq!(r.steps.len(), 1);
        assert_eq!(r.outers.len(), 1);
        assert_eq!(r.evals.len(), 1);
    }

    #[test]
    fn default_impls_continue() {
        struct Nop;
        impl RunObserver for Nop {}
        let mut n = Nop;
        assert_eq!(
            n.on_step(&StepEvent {
                step: 0,
                loss: 0.0,
                gamma: 0.0,
                clock: 0.0
            }),
            RunControl::Continue
        );
    }
}
