//! Deterministic random number generation (no external crates).
//!
//! Two generators:
//! - [`SplitMix64`] — seeding / stream derivation (it is the standard seeder
//!   for the xoshiro family and is itself a fine 64-bit mixer).
//! - [`Xoshiro256`] (xoshiro256++) — the workhorse generator for data
//!   synthesis and stochastic-gradient noise.
//!
//! Determinism discipline (DESIGN.md §6): every stochastic choice in the
//! trainer derives its stream from `(seed, purpose, worker, t, k)` via
//! [`stream`], so any run is bit-reproducible and two algorithms fed the
//! same seed see the same data order.

/// SplitMix64: one-at-a-time 64-bit mixer (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 of any seed cannot produce
        // four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; modulo bias is < 2^-32 for n << 2^32, but we use the
    /// widening-multiply trick anyway).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (pair cached is omitted for
    /// reproducibility simplicity: one draw consumes two u64s).
    pub fn normal_f32(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            return (r * theta.cos()) as f32;
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Derive an independent stream for `(seed, purpose, a, b, c)`.
///
/// `purpose` namespaces usages ("data", "noise", "init", ...) so adding a
/// new consumer never perturbs existing streams.
pub fn stream(seed: u64, purpose: &str, a: u64, b: u64, c: u64) -> Xoshiro256 {
    let mut h = SplitMix64::new(seed ^ 0xA076_1D64_78BD_642F);
    let mut key = h.next_u64();
    for &byte in purpose.as_bytes() {
        key = key.wrapping_mul(0x100_0000_01B3) ^ byte as u64;
    }
    let mut sm = SplitMix64::new(key);
    let k1 = sm.next_u64() ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut sm = SplitMix64::new(k1);
    let k2 = sm.next_u64() ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    let mut sm = SplitMix64::new(k2);
    let k3 = sm.next_u64() ^ c.wrapping_mul(0x1656_67B1_9E37_79F9);
    Xoshiro256::seed_from(k3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 from the public-domain C impl.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::seed_from(42);
        let mut b = Xoshiro256::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(8);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from(9);
        let n = 10u64;
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            let x = r.below(n);
            assert!(x < n);
            counts[x as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from(10);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal_f32() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn streams_are_independent_of_purpose() {
        let mut a = stream(5, "data", 0, 0, 0);
        let mut b = stream(5, "noise", 0, 0, 0);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_keyed_by_indices() {
        let mut seen = std::collections::HashSet::new();
        for w in 0..4 {
            for t in 0..4 {
                for k in 0..4 {
                    let mut s = stream(1, "noise", w, t, k);
                    assert!(seen.insert(s.next_u64()), "collision {w} {t} {k}");
                }
            }
        }
    }

    #[test]
    fn streams_reproducible() {
        let mut a = stream(99, "x", 1, 2, 3);
        let mut b = stream(99, "x", 1, 2, 3);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
