//! Declarative CLI argument parser (clap replacement).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! repeated flags, defaults, required flags, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_switch: bool,
    pub required: bool,
    pub repeatable: bool,
}

impl Flag {
    pub fn opt(name: &'static str, default: &str, help: &'static str) -> Self {
        Self {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
            required: false,
            repeatable: false,
        }
    }

    pub fn required(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            default: None,
            is_switch: false,
            required: true,
            repeatable: false,
        }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            default: None,
            is_switch: true,
            required: false,
            repeatable: false,
        }
    }

    pub fn repeated(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            default: None,
            is_switch: false,
            required: false,
            repeatable: true,
        }
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true"))
    }

    /// Parse a flag value with any [`std::str::FromStr`] type; the error
    /// names the flag and carries the parser's own message, so domain
    /// types (Schedule, BufferStrategy, Scale, ...) surface their valid
    /// forms uniformly.
    pub fn get_parsed<T>(&self, name: &str) -> Result<T, String>
    where
        T: std::str::FromStr,
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?;
        raw.parse()
            .map_err(|e: T::Err| format!("--{name}: cannot parse {raw:?}: {e}"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get_parsed(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get_parsed(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.get_parsed(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.get_parsed(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn string(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("missing --{name}"))
            .to_string()
    }
}

#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, f: Flag) -> Self {
        self.flags.push(f);
        self
    }

    fn find(&self, name: &str) -> Option<&Flag> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Parse raw args (after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name.to_string(), vec![d.clone()]);
            }
            if f.is_switch {
                args.values
                    .insert(f.name.to_string(), vec!["false".to_string()]);
            }
        }
        let mut i = 0;
        let mut seen: Vec<&str> = Vec::new();
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let flag = self
                    .find(name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                let value = if flag.is_switch {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    raw.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{name} needs a value"))?
                };
                let slot = args.values.entry(name.to_string()).or_default();
                if flag.repeatable && seen.contains(&flag.name) {
                    slot.push(value);
                } else {
                    *slot = vec![value];
                }
                seen.push(flag.name);
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !args.values.contains_key(f.name) {
                return Err(format!("missing required flag --{}", f.name));
            }
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_switch {
                "".to_string()
            } else if let Some(d) = &f.default {
                format!(" <value> (default {d})")
            } else if f.required {
                " <value> (required)".to_string()
            } else {
                " <value>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }
}

/// Top-level multi-command application.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nCommands:\n", self.name, self.about);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `<command> --help` for per-command flags.\n");
        s
    }

    /// Dispatch: returns (command name, parsed args) or a help/error text.
    pub fn dispatch(&self, raw: &[String]) -> Result<(&Command, Args), String> {
        let Some(cmd_name) = raw.first() else {
            return Err(self.help());
        };
        if cmd_name == "--help" || cmd_name == "help" || cmd_name == "-h" {
            return Err(self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command {cmd_name:?}\n\n{}", self.help()))?;
        if raw[1..].iter().any(|a| a == "--help" || a == "-h") {
            return Err(cmd.help());
        }
        let args = cmd.parse(&raw[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .flag(Flag::opt("steps", "100", "number of steps"))
            .flag(Flag::required("preset", "model preset"))
            .flag(Flag::switch("verbose", "chatty output"))
            .flag(Flag::repeated("tag", "experiment tags"))
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = cmd().parse(&s(&["--preset", "lm-tiny"])).unwrap();
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.usize("steps"), 100);
        assert_eq!(a.string("preset"), "lm-tiny");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&s(&[])).is_err());
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cmd()
            .parse(&s(&["--preset=quad", "--steps=5", "--verbose"]))
            .unwrap();
        assert_eq!(a.usize("steps"), 5);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = cmd()
            .parse(&s(&["--preset", "q", "--tag", "a", "--tag", "b"]))
            .unwrap();
        assert_eq!(a.get_all("tag"), vec!["a", "b"]);
    }

    #[test]
    fn non_repeated_last_wins() {
        let a = cmd()
            .parse(&s(&["--preset", "a", "--preset", "b"]))
            .unwrap();
        assert_eq!(a.string("preset"), "b");
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(cmd().parse(&s(&["--nope", "1"])).is_err());
    }

    #[test]
    fn switch_with_value_is_error() {
        assert!(cmd().parse(&s(&["--preset", "p", "--verbose=yes"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = cmd().parse(&s(&["--preset", "p", "extra1", "extra2"])).unwrap();
        assert_eq!(a.positionals, vec!["extra1", "extra2"]);
    }

    #[test]
    fn app_dispatch() {
        let app = App::new("slowmo", "repro").command(cmd());
        let (c, a) = app
            .dispatch(&s(&["train", "--preset", "p"]))
            .unwrap();
        assert_eq!(c.name, "train");
        assert_eq!(a.string("preset"), "p");
        assert!(app.dispatch(&s(&["bogus"])).is_err());
        assert!(app.dispatch(&s(&[])).is_err());
        let help = app.dispatch(&s(&["train", "--help"])).unwrap_err();
        assert!(help.contains("--steps"));
    }

    #[test]
    fn parse_numeric_error_message() {
        let a = cmd().parse(&s(&["--preset", "p", "--steps", "abc"])).unwrap();
        let e = a.get_parsed::<usize>("steps").unwrap_err();
        assert!(e.contains("steps"));
    }
}
