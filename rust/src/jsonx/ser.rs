//! Compact JSON serializer.

use super::Json;

pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{parse, Json};
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(to_string(&Json::Null), "null");
        assert_eq!(to_string(&Json::Bool(true)), "true");
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(0.5)), "0.5");
        assert_eq!(to_string(&Json::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Json::Str("a\"b\n".into())), r#""a\"b\n""#);
    }

    #[test]
    fn containers() {
        let j = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("s", Json::str("hi")),
        ]);
        // BTreeMap orders keys: "s" before "xs".
        assert_eq!(to_string(&j), r#"{"s":"hi","xs":[1,2]}"#);
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(to_string(&Json::Str("\u{0001}".into())), "\"\\u0001\"");
    }

    #[test]
    fn round_trip_preserves() {
        let j = Json::obj(vec![
            ("a", Json::Num(-1.25e-7)),
            ("b", Json::Arr(vec![Json::Null, Json::Bool(false)])),
        ]);
        assert_eq!(parse(&to_string(&j)).unwrap(), j);
    }
}
