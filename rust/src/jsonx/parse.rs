//! Recursive-descent JSON parser.

use super::Json;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(s: &str) -> Result<Json, ParseError> {
    let mut p = P {
        b: s.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad surrogate"));
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::super::Json;
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("0.25").unwrap(), Json::Num(0.25));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\bA""#).unwrap(),
            Json::Str("a\n\t\"\\bA".into())
        );
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn nested() {
        let j = parse(r#" { "a" : [ 1 , { "b" : [] } ] , "c" : {} } "#).unwrap();
        match j {
            Json::Obj(m) => {
                assert_eq!(m.len(), 2);
                assert!(matches!(m["c"], Json::Obj(_)));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }
}
