//! Minimal JSON parser/serializer (serde replacement for this image).
//!
//! Consumes `artifacts/manifest.json` + `golden.json` and emits metrics
//! JSONL / result tables. Supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bool, null); numbers are stored as f64
//! (adequate: the manifest's largest integers are parameter offsets < 2^53).

mod parse;
mod ser;

pub use parse::{parse, ParseError};
pub use ser::to_string;

use std::collections::BTreeMap;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Follow a `.`-separated path of object keys.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of f32 (for golden vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Self {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let j = parse(r#"{"a": {"b": [1, 2.5, "x", true, null]}}"#).unwrap();
        assert_eq!(j.path("a.b").unwrap().as_arr().unwrap().len(), 5);
        assert_eq!(j.path("a.b").unwrap().as_arr().unwrap()[1].as_f64(),
                   Some(2.5));
        assert_eq!(j.path("a.missing"), None);
        assert_eq!(j.get("a").unwrap().get("b").unwrap().as_arr().unwrap()[2]
            .as_str(), Some("x"));
    }

    #[test]
    fn f32_vec() {
        let j = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(parse("[1, \"x\"]").unwrap().as_f32_vec(), None);
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m": {"x": [1,2,3], "y": "hi\n", "z": -1.5e-3}, "n": null}"#;
        let j = parse(src).unwrap();
        let s = to_string(&j);
        assert_eq!(parse(&s).unwrap(), j);
    }
}
