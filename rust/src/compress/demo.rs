//! DeMo-style frequency-domain compression (Peng et al., arXiv
//! 2411.19870): transform each chunk of the message into an orthonormal
//! DCT basis, transmit only the top-k coefficients per chunk, and
//! accumulate every untransmitted coefficient in a persistent per-link
//! *frequency residual* so the slow-moving part of the signal is
//! eventually delivered instead of dropped.
//!
//! The codec is the frequency-domain sibling of
//! [`super::ErrorFeedback`]`(`[`super::TopK`]`)`: where `ef:topk` keeps
//! its residual in the spatial domain, `demo` keeps it in the DCT domain,
//! where SlowMo's outer displacement concentrates energy into few
//! coefficients — the same byte budget reconstructs more of the signal.
//! Because the DCT is linear, the elastic-membership residual rescale
//! (multiply by the live-worker ratio) is exactly as valid on frequency
//! residuals as on spatial ones, so the codec rides the existing
//! [`super::CompressState`] machinery unchanged: residuals at
//! [`super::site::OUTER`] rescale on membership changes and ship in the
//! rejoin state transfer via the [`super::Compressor::ef_bufs`] lane.
//!
//! Wire format: the standard sparse index+value layout of
//! [`super::TopK`] — indices address *DCT coefficients*, the decoder
//! scatters them into the frequency scratch and inverse-transforms. Byte
//! accounting stays honest: `8` bytes per kept coefficient summed over
//! chunks, capped at the raw `4·d`.

use super::{
    decode_len_check, k_of, site, sparse_pack_into, sparse_unpack,
    CompressState, Compressor, Wire,
};
use crate::optim::kernels::{dct2_chunked, dct3_chunked, DctPlans};
use crate::util::Scratch;

/// `demo[:k,chunk]` — per-chunk DCT top-k with a persistent frequency
/// residual. `frac` is the kept fraction per chunk (`ceil(frac·n)`
/// coefficients of every `n`-length chunk); `chunk` is the transform
/// length (the trailing partial chunk gets its own shorter plan).
///
/// With `frac = 1.0` every coefficient is transmitted, the residual is
/// identically zero and the transcode equals `dct3(dct2(x))` — value-
/// equal to `none` within the DCT round-trip ulp bound pinned by the
/// property suite (not bitwise: the transform rounds through f32 twice).
pub struct Demo {
    pub frac: f32,
    pub chunk: usize,
    /// Per-length DCT plan cache (interior mutability: `encode` takes
    /// `&self`). At most two plans live here — `chunk` and the tail.
    plans: DctPlans,
}

impl Demo {
    pub fn new(frac: f32, chunk: usize) -> Self {
        assert!(chunk >= 1, "demo chunk must be >= 1");
        Demo { frac, chunk, plans: DctPlans::new() }
    }

    /// Kept-coefficient count summed over the chunks of a `d`-length
    /// message (per-chunk `ceil`, so it can exceed `ceil(frac·d)`).
    fn total_k(&self, d: usize) -> usize {
        let full = d / self.chunk;
        let tail = d % self.chunk;
        full * k_of(self.frac, self.chunk) + k_of(self.frac, tail)
    }

    /// Shared body of the fresh and pooled encodes: with `Some(sc)` the
    /// spectrum scratch, the per-chunk order buffer, the kept-index list
    /// and the wire data all come from (and return to) the pools;
    /// bitwise-identical either way.
    fn encode_impl(
        &self,
        x: &[f32],
        st: &mut CompressState,
        s: u64,
        mut sc: Option<&mut Scratch>,
    ) -> Wire {
        let d = x.len();
        if d == 0 {
            return Wire { data: Vec::new(), d: 0, wire_bytes: 0 };
        }
        // Forward transform, then fold in the carried frequency residual
        // (the codec's analogue of `ef`'s `x + r`).
        let mut f = match sc.as_deref_mut() {
            Some(sc) => sc.f32s.take_filled(d),
            None => vec![0.0f32; d],
        };
        dct2_chunked(&self.plans, x, &mut f, self.chunk);
        {
            let r = st.residual(s, d);
            for (fv, rv) in f.iter_mut().zip(r.iter()) {
                *fv += *rv;
            }
        }
        // Per-chunk top-|coefficient| selection with the same total
        // order as `topk` (index tie-break), kept as global indices.
        let (mut kept, mut order) = match sc.as_deref_mut() {
            Some(sc) => (sc.idx.take(), sc.idx.take()),
            None => (Vec::new(), Vec::new()),
        };
        kept.clear();
        kept.reserve(self.total_k(d));
        let mut lo = 0;
        while lo < d {
            let n = (d - lo).min(self.chunk);
            let k = k_of(self.frac, n);
            order.clear();
            order.extend(lo..lo + n);
            if k < n {
                order.select_nth_unstable_by(k - 1, |&a, &b| {
                    f[b].abs()
                        .total_cmp(&f[a].abs())
                        .then_with(|| a.cmp(&b))
                });
                order.truncate(k);
            }
            kept.extend_from_slice(&order);
            lo += n;
        }
        kept.sort_unstable();
        // The new residual is exactly the untransmitted coefficients:
        // residual + decoded-coefficients is a bitwise partition of `f`
        // (pinned by the property suite's residual-accounting test).
        {
            let r = st.residual(s, d);
            r.copy_from_slice(&f);
            for &i in &kept {
                r[i] = 0.0;
            }
        }
        let data = match sc.as_deref_mut() {
            Some(sc) => sc.f32s.take(),
            None => Vec::new(),
        };
        let wire = sparse_pack_into(&kept, &f, self.wire_bytes(d), data);
        if let Some(sc) = sc {
            sc.f32s.put(f);
            sc.idx.put(kept);
            sc.idx.put(order);
        }
        wire
    }
}

impl Compressor for Demo {
    fn key(&self) -> String {
        "demo".into()
    }

    fn params(&self) -> String {
        format!("{},{}", self.frac, self.chunk)
    }

    fn encode(&self, x: &[f32], st: &mut CompressState, s: u64) -> Wire {
        self.encode_impl(x, st, s, None)
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        st: &mut CompressState,
        s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        self.encode_impl(x, st, s, Some(sc))
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        let d = wire.d;
        decode_len_check("demo", wire, out.len(), 2 * self.total_k(d));
        if d == 0 {
            return;
        }
        // Scatter kept coefficients into the frequency scratch, then
        // inverse-transform chunk by chunk.
        let mut f = vec![0.0f32; d];
        sparse_unpack("demo", wire, &mut f, 1.0);
        dct3_chunked(&self.plans, &f, out, self.chunk);
    }

    fn decode_pooled(&self, wire: &Wire, out: &mut [f32], sc: &mut Scratch) {
        let d = wire.d;
        decode_len_check("demo", wire, out.len(), 2 * self.total_k(d));
        if d == 0 {
            return;
        }
        let mut f = sc.f32s.take_filled(d);
        sparse_unpack("demo", wire, &mut f, 1.0);
        dct3_chunked(&self.plans, &f, out, self.chunk);
        sc.f32s.put(f);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        if d == 0 {
            return 0;
        }
        (self.total_k(d) as u64 * 8).min(d as u64 * 4)
    }

    fn ef_bufs(&self) -> usize {
        1
    }

    fn rejoin_state(&self, st: &CompressState, d: usize) -> Vec<Vec<f32>> {
        vec![match st.residual_opt(site::OUTER) {
            Some(r) if r.len() == d => r.clone(),
            _ => vec![0.0; d],
        }]
    }

    fn install_rejoin_state(&self, st: &mut CompressState, bufs: &[&[f32]]) {
        if let Some(buf) = bufs.first() {
            st.set_residual(site::OUTER, buf.to_vec());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st() -> CompressState {
        CompressState::new(7, 0)
    }

    fn signal(d: usize) -> Vec<f32> {
        // Smooth-ish deterministic signal with a rough component, so the
        // DCT spectrum has both large and small coefficients.
        (0..d)
            .map(|i| {
                let t = i as f32 / d.max(1) as f32;
                (6.3 * t).sin() + 0.25 * (41.0 * t).cos()
                    + 0.05 * ((i * 2654435761usize) as f32 / 4e9)
            })
            .collect()
    }

    #[test]
    fn keep_all_round_trips_with_zero_residual() {
        let c = Demo::new(1.0, 16);
        let mut s = st();
        let x = signal(50);
        let wire = c.encode(&x, &mut s, site::OUTER);
        assert_eq!(wire.wire_bytes, 50 * 4); // dense cap
        let r = s.residual_opt(site::OUTER).unwrap();
        assert!(r.iter().all(|&v| v == 0.0), "residual must be zero");
        let mut y = vec![0.0f32; 50];
        c.decode(&wire, &mut y);
        let mag = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= 1e-6 * mag, "{a} vs {b}");
        }
    }

    #[test]
    fn residual_is_exact_bitwise_partition_of_spectrum() {
        let c = Demo::new(0.1, 16);
        let mut s = st();
        let x = signal(80);
        // Fresh state: residual starts zero, so wire ∪ residual must be
        // exactly dct2(x), bitwise.
        let wire = c.encode(&x, &mut s, site::GRAD);
        let plans = DctPlans::new();
        let mut f = vec![0.0f32; 80];
        dct2_chunked(&plans, &x, &mut f, 16);
        let r = s.residual_opt(site::GRAD).unwrap().clone();
        let k = wire.data.len() / 2;
        let mut seen = vec![false; 80];
        for j in 0..k {
            let i = wire.data[j].to_bits() as usize;
            assert_eq!(wire.data[k + j].to_bits(), f[i].to_bits());
            assert_eq!(r[i], 0.0, "kept coefficient must leave residual");
            seen[i] = true;
        }
        for (i, kept) in seen.iter().enumerate() {
            if !kept {
                assert_eq!(r[i].to_bits(), f[i].to_bits());
            }
        }
    }

    #[test]
    fn residual_feeds_next_message() {
        let c = Demo::new(0.05, 32);
        let mut s = st();
        let x = signal(64);
        c.encode(&x, &mut s, site::OUTER);
        let r1 = s.residual_opt(site::OUTER).unwrap().clone();
        assert!(r1.iter().any(|&v| v != 0.0), "lossy keep must leave mass");
        // Encoding a zero vector next still transmits: the carried
        // residual alone ranks the coefficients.
        let wire = c.encode(&[0.0; 64], &mut s, site::OUTER);
        let mut y = vec![0.0f32; 64];
        c.decode(&wire, &mut y);
        assert!(y.iter().any(|&v| v != 0.0), "residual must drain");
    }

    #[test]
    fn wire_bytes_per_chunk_ceil_and_dense_cap() {
        let c = Demo::new(0.1, 64);
        // 2 full chunks (k = ceil(6.4) = 7 each) + tail 22 (k = 3).
        assert_eq!(c.wire_bytes(150), (7 + 7 + 3) * 8);
        assert_eq!(c.wire_bytes(0), 0);
        // keep-all caps at the raw size.
        assert_eq!(Demo::new(1.0, 8).wire_bytes(100), 400);
        // Reported bytes match the encode path.
        let mut s = st();
        let wire = c.encode(&signal(150), &mut s, site::GRAD);
        assert_eq!(wire.wire_bytes, c.wire_bytes(150));
    }

    #[test]
    fn encode_is_deterministic() {
        let x = signal(96);
        let run = || {
            let c = Demo::new(0.1, 32);
            let mut s = st();
            c.encode(&x, &mut s, site::OUTER);
            let w = c.encode(&x, &mut s, site::OUTER);
            (w.data, s.residual_opt(site::OUTER).unwrap().clone())
        };
        let (w1, r1) = run();
        let (w2, r2) = run();
        assert_eq!(w1, w2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn rejoin_state_ships_and_installs_outer_residual() {
        let c = Demo::new(0.1, 16);
        let mut s = st();
        c.encode(&signal(48), &mut s, site::OUTER);
        let shipped = c.rejoin_state(&s, 48);
        assert_eq!(shipped.len(), 1);
        assert_eq!(&shipped[0], s.residual_opt(site::OUTER).unwrap());
        let mut s2 = st();
        c.install_rejoin_state(&mut s2, &[&shipped[0]]);
        assert_eq!(s2.residual_opt(site::OUTER).unwrap(), &shipped[0]);
        // No residual yet (or wrong length) ships zeros.
        assert_eq!(c.rejoin_state(&st(), 5), vec![vec![0.0; 5]]);
        assert_eq!(c.ef_bufs(), 1);
    }
}
