//! Pluggable communication compression: quantization, sparsification and
//! error-feedback, applied to every message lane the reproduction owns
//! (inner gossip, ring collectives, the SlowMo outer average) with
//! *honest byte accounting* — the fabric, the α-β [`crate::net::CostModel`]
//! and the chaos retransmit charges all see the compressed wire size,
//! while the data lane keeps carrying the decoded f32 values so the
//! simulated math is exactly what a real compressed transport delivers.
//!
//! A [`Compressor`] lossily encodes an f32 slice into a [`Wire`] message
//! (decoded back on the receive side); [`CompressState`] carries the
//! per-worker, per-link residual buffers for error-feedback and the
//! deterministic [`crate::rng::stream`] counters for randomized codecs,
//! so two runs with the same seed are bit-identical. Compressors are
//! selected through the string-keyed [`CompressRegistry`] — the same
//! `key[:args]` spec grammar and hard-parse-error contract as
//! [`crate::algorithms::AlgoRegistry`] and
//! [`crate::slowmo::OuterRegistry`] — backing `--compress` on the CLI,
//! the `[compress]` TOML table, `TrainBuilder::compress` and the
//! `slowmo exp compress` sweep.
//!
//! Built-ins:
//! - `none`            — identity (the default; bit-identical to the
//!   pre-subsystem path, asserted in `rust/tests/equivalences.rs`);
//! - `fp16` / `bf16`   — 2-byte quantization (round-to-nearest-even);
//! - `topk[:frac]`     — keep the `ceil(frac·d)` largest-magnitude
//!   coordinates (index+value wire format, dense fallback when sparse
//!   encoding would exceed the raw size);
//! - `randk[:frac]`    — keep `ceil(frac·d)` uniformly random coordinates
//!   (unbiased `d/k` rescale; indices drawn from a seeded
//!   [`crate::rng::stream`], so runs stay deterministic);
//! - `signsgd[:chunk]` — 1 bit per coordinate plus one f32 scale
//!   (mean |x|) per `chunk` coordinates; the mean of the decoded
//!   ±scale vectors acts as the soft majority vote of SIGNSGD-style
//!   reduces;
//! - `demo[:k,chunk]`  — DeMo-style frequency-domain top-k ([`Demo`]):
//!   DCT-transform each `chunk` of the message, transmit the
//!   `ceil(k·chunk)` largest coefficients per chunk and carry every
//!   untransmitted coefficient in a persistent per-link *frequency*
//!   residual (a state-aware codec — see `compress/demo.rs`);
//! - `ef:<inner>`      — error feedback around any other compressor:
//!   the residual `e = (x + r) - decode(encode(x + r))` is carried per
//!   link and re-injected into the next message. Residuals at the SlowMo
//!   outer boundary register with the elastic-membership machinery: they
//!   rescale with the live-worker ratio and ride the rejoin state
//!   transfer exactly like [`crate::slowmo::OuterOpt`] buffers.
//!   `ef:demo` is a hard parse error: `demo` already carries its own
//!   per-link residual, and stacking a second spatial-domain residual on
//!   top double-counts dropped mass.

use crate::rng::{stream, Xoshiro256};
use crate::util::Scratch;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

mod demo;
pub use demo::Demo;

/// Well-known residual/stream site keys. A *site* identifies one logical
/// send location on one worker (a gossip out-link, a collective input, the
/// outer-boundary average), so error-feedback residuals never mix across
/// lanes and randomized codecs draw from independent deterministic
/// streams.
pub mod site {
    /// The SlowMo outer-boundary exact average (paper Alg. 1 line 6).
    /// Residuals at this site are rescaled on elastic-membership changes
    /// and shipped in the rejoin state transfer.
    pub const OUTER: u64 = 1 << 40;
    /// Outer-boundary momentum-buffer average (`BufferStrategy::Average`).
    pub const OUTER_H: u64 = (1 << 40) + 1;
    /// Outer-boundary second-moment average (`BufferStrategy::Average`).
    pub const OUTER_V: u64 = (1 << 40) + 2;
    /// Per-step gradient allreduce (the `ar` base algorithm).
    pub const GRAD: u64 = 2 << 40;
    /// Double-averaging periodic parameter / h / v averages.
    pub const DAVG_X: u64 = 3 << 40;
    pub const DAVG_H: u64 = (3 << 40) + 1;
    pub const DAVG_V: u64 = (3 << 40) + 2;
    /// Hierarchical outer boundary: the inter-group *leader* collective
    /// re-transcodes the intra-group means before they cross the slow
    /// links (distinct EF residuals from the intra-stage [`OUTER`] site).
    pub const OUTER_L: u64 = (1 << 40) + 3;
    /// Leader-stage momentum-buffer average (`BufferStrategy::Average`).
    pub const OUTER_LH: u64 = (1 << 40) + 4;
    /// Leader-stage second-moment average (`BufferStrategy::Average`).
    pub const OUTER_LV: u64 = (1 << 40) + 5;
    /// The fast intra-group parameter average every `tau_inner` inner
    /// steps (hierarchical SlowMo).
    pub const INTRA: u64 = 5 << 40;
    /// Gossip out-link to `peer` (SGP / OSGP / D-PSGD).
    pub fn gossip(peer: usize) -> u64 {
        (4u64 << 40) | peer as u64
    }
}

/// One encoded message: the wire representation (still carried as f32
/// slots through the in-process fabric) plus the honest byte count a real
/// transport would move for it.
#[derive(Clone, Debug)]
pub struct Wire {
    /// Codec-specific representation (values, packed index bits, packed
    /// sign words, per-chunk scales, ...).
    pub data: Vec<f32>,
    /// Original (decoded) length.
    pub d: usize,
    /// Bytes a real transport would move for this message.
    pub wire_bytes: u64,
}

/// Per-worker compression state: error-feedback residuals and stream
/// counters, keyed by [`site`]. Owned by
/// [`crate::algorithms::WorkerState`] so it follows the worker through
/// elastic membership (rescale + rejoin transfer).
#[derive(Clone, Debug, Default)]
pub struct CompressState {
    /// Base seed (the run seed) for deterministic randomized codecs.
    pub seed: u64,
    /// This worker's rank (stream namespace).
    pub worker: u64,
    residuals: BTreeMap<u64, Vec<f32>>,
    counters: BTreeMap<u64, u64>,
}

impl CompressState {
    pub fn new(seed: u64, worker: u64) -> Self {
        Self {
            seed,
            worker,
            residuals: BTreeMap::new(),
            counters: BTreeMap::new(),
        }
    }

    /// The residual buffer for `site`, created zeroed (and reset when the
    /// message length changed, e.g. after an elastic ring rebuild).
    pub fn residual(&mut self, site: u64, d: usize) -> &mut Vec<f32> {
        let r = self.residuals.entry(site).or_default();
        if r.len() != d {
            *r = vec![0.0; d];
        }
        r
    }

    /// Read-only view of the residual at `site`, if one exists.
    pub fn residual_opt(&self, site: u64) -> Option<&Vec<f32>> {
        self.residuals.get(&site)
    }

    /// Overwrite the residual at `site` (rejoin transfer install path).
    pub fn set_residual(&mut self, site: u64, buf: Vec<f32>) {
        self.residuals.insert(site, buf);
    }

    /// Rescale every residual buffer by `factor` — called by the elastic
    /// membership machinery when the live worker count changes (residuals
    /// aggregate displacement mass exactly like outer-optimizer state).
    pub fn scale_residuals(&mut self, factor: f32) {
        for buf in self.residuals.values_mut() {
            for v in buf.iter_mut() {
                *v *= factor;
            }
        }
    }

    /// Drop every residual buffer. Called for a rejoining worker before
    /// the leader's state is installed: residuals from before the outage
    /// are stale (they missed every membership rescale while the worker
    /// was down) — exactly like base-optimizer buffers, they reset.
    pub fn clear_residuals(&mut self) {
        self.residuals.clear();
    }

    /// A fresh deterministic RNG for the next message at `site`: streams
    /// derive from `(seed, worker, site, per-site counter)`, so encode
    /// results never depend on thread interleaving.
    pub fn next_stream(&mut self, s: u64) -> Xoshiro256 {
        let c = self.counters.entry(s).or_insert(0);
        let idx = *c;
        *c += 1;
        stream(self.seed, "compress", self.worker, s, idx)
    }
}

/// One communication compressor. Implementations are stateless
/// hyperparameter descriptors (like [`crate::slowmo::OuterOpt`]); all
/// mutable per-run state lives in [`CompressState`] so the framework can
/// rescale and ship it without knowing the codec.
pub trait Compressor: Send + Sync {
    /// Registry key this codec answers to ("topk", "fp16", ...).
    fn key(&self) -> String;

    /// Hyperparameter fragment for display names; empty when none.
    fn params(&self) -> String;

    /// Lossily encode `x`. `site` keys the error-feedback residual and
    /// the deterministic stream for randomized codecs.
    fn encode(&self, x: &[f32], st: &mut CompressState, site: u64) -> Wire;

    /// Decode into `out` (length `wire.d`); overwrites every slot.
    fn decode(&self, wire: &Wire, out: &mut [f32]);

    /// Bytes a real transport moves for a `d`-element message under this
    /// codec. Used by the α-β cost model and the collective byte
    /// accounting; must match what [`Compressor::encode`] reports and
    /// never exceed the raw `4·d` (codecs fall back to dense encoding
    /// when the sparse form would be larger).
    fn wire_bytes(&self, d: usize) -> u64;

    /// `true` only for the `none` codec: callers skip the encode/decode
    /// round-trip entirely so the path stays bit-identical to the
    /// pre-subsystem code.
    fn is_identity(&self) -> bool {
        false
    }

    /// Number of `d`-length buffers this codec contributes to the SlowMo
    /// rejoin state transfer (error-feedback residuals at [`site::OUTER`];
    /// 0 for stateless codecs). The rejoin wire format is derived from
    /// this count, the same state-shape-agnostic way it is from
    /// [`crate::slowmo::OuterOpt::n_bufs`].
    fn ef_bufs(&self) -> usize {
        0
    }

    /// The buffers to ship in a rejoin transfer (exactly
    /// [`Compressor::ef_bufs`] buffers of length `d`, zero-filled when the
    /// site has no residual yet).
    fn rejoin_state(&self, st: &CompressState, d: usize) -> Vec<Vec<f32>> {
        let _ = (st, d);
        Vec::new()
    }

    /// Install buffers received in a rejoin transfer (same order as
    /// [`Compressor::rejoin_state`]).
    fn install_rejoin_state(&self, st: &mut CompressState, bufs: &[&[f32]]) {
        let _ = (st, bufs);
    }

    /// Encode+decode `x` in place (what every send site calls) and return
    /// the honest wire byte count.
    fn transcode(&self, x: &mut [f32], st: &mut CompressState, s: u64) -> u64 {
        if self.is_identity() {
            return x.len() as u64 * 4;
        }
        let wire = self.encode(x, st, s);
        self.decode(&wire, x);
        wire.wire_bytes
    }

    /// Buffer-reusing variant of [`Compressor::encode`]: internal scratch
    /// and the returned [`Wire::data`] vec are drawn from `sc` where the
    /// codec supports it, so a warm pool makes the encode allocation-free.
    /// Bitwise-identical to `encode` — pools change where bytes live,
    /// never their values (pinned in tests). The default ignores the pool.
    fn encode_pooled(
        &self,
        x: &[f32],
        st: &mut CompressState,
        site: u64,
        sc: &mut Scratch,
    ) -> Wire {
        let _ = sc;
        self.encode(x, st, site)
    }

    /// Buffer-reusing variant of [`Compressor::decode`], for codecs that
    /// need an intermediate buffer (demo's spectrum). Bitwise-identical
    /// to `decode`; the default ignores the pool.
    fn decode_pooled(&self, wire: &Wire, out: &mut [f32], sc: &mut Scratch) {
        let _ = sc;
        self.decode(wire, out);
    }

    /// Buffer-reusing [`Compressor::transcode`]: encode and decode draw
    /// from `sc`, and the wire's data buffer is recycled into the pool
    /// after decode — a warm pool makes the whole round-trip
    /// allocation-free (pinned by the `alloc_gate` integration test).
    fn transcode_pooled(
        &self,
        x: &mut [f32],
        st: &mut CompressState,
        s: u64,
        sc: &mut Scratch,
    ) -> u64 {
        if self.is_identity() {
            return x.len() as u64 * 4;
        }
        let wire = self.encode_pooled(x, st, s, sc);
        self.decode_pooled(&wire, x, sc);
        let bytes = wire.wire_bytes;
        sc.f32s.put(wire.data);
        bytes
    }
}

/// Named hard error for decode length mismatches (satellite contract:
/// a wire that does not match `out` must fail with the codec key and the
/// offending lengths, never an opaque slice-index panic).
#[track_caller]
fn decode_len_check(
    codec: &str,
    wire: &Wire,
    out_len: usize,
    want_slots: usize,
) {
    assert!(
        wire.d == out_len && wire.data.len() == want_slots,
        "[compress] {codec} decode length mismatch: wire.d={} vs \
         out.len()={}; wire.data carries {} f32 slot(s), codec expects {}",
        wire.d,
        out_len,
        wire.data.len(),
        want_slots,
    );
}

/// Human-readable "key" or "key(params)" fragment for display names.
pub fn describe(c: &dyn Compressor) -> String {
    let p = c.params();
    if p.is_empty() {
        c.key()
    } else {
        format!("{}({p})", c.key())
    }
}

// ------------------------------------------------------- f16/bf16 helpers

/// f32 -> IEEE binary16 bit pattern, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (preserve NaN-ness with a quiet payload bit).
        return sign | 0x7c00 | u16::from(mant != 0) << 9;
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal half (or underflow to zero).
        if e < -10 {
            return sign;
        }
        let mant = mant | 0x0080_0000; // make the implicit bit explicit
        let shift = (14 - e) as u32; // 14..=24
        let half = mant >> shift;
        let rem = mant & ((1u32 << shift) - 1);
        let midpoint = 1u32 << (shift - 1);
        let rounded = if rem > midpoint
            || (rem == midpoint && (half & 1) == 1)
        {
            half + 1
        } else {
            half
        };
        return sign | rounded as u16;
    }
    let half = mant >> 13;
    let rem = mant & 0x1fff;
    let mut out = ((e as u32) << 10) | half;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out += 1; // carry may bump the exponent — that is correct
    }
    sign | out as u16
}

/// IEEE binary16 bit pattern -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = u32::from((h >> 10) & 0x1f);
    let mant = u32::from(h & 0x03ff);
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal half: renormalize into f32.
            let mut e: u32 = 113; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            sign | (e << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round `x` through bfloat16 (round-to-nearest-even on the top 16 bits).
pub fn round_bf16(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let rem = bits & 0xffff;
    let mut hi = bits >> 16;
    if rem > 0x8000 || (rem == 0x8000 && (hi & 1) == 1) {
        hi += 1; // may round up to inf — correct
    }
    f32::from_bits(hi << 16)
}

/// Round `x` through IEEE binary16.
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

// -------------------------------------------------------------- built-ins

/// Identity codec: the default. Callers short-circuit on
/// [`Compressor::is_identity`], so this path is bit-identical to the
/// pre-compression code (equivalence-tested).
#[derive(Clone, Copy, Debug)]
pub struct NoneCompressor;

impl Compressor for NoneCompressor {
    fn key(&self) -> String {
        "none".into()
    }

    fn params(&self) -> String {
        String::new()
    }

    fn encode(&self, x: &[f32], _st: &mut CompressState, _s: u64) -> Wire {
        Wire {
            data: x.to_vec(),
            d: x.len(),
            wire_bytes: x.len() as u64 * 4,
        }
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        _st: &mut CompressState,
        _s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        let mut data = sc.f32s.take();
        data.extend_from_slice(x);
        Wire {
            data,
            d: x.len(),
            wire_bytes: x.len() as u64 * 4,
        }
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        decode_len_check("none", wire, out.len(), wire.d);
        out.copy_from_slice(&wire.data);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        d as u64 * 4
    }

    fn is_identity(&self) -> bool {
        true
    }
}

/// 2-byte quantization: fp16 (IEEE binary16) or bf16 (truncated f32),
/// both round-to-nearest-even. Wire: 2 bytes per coordinate.
#[derive(Clone, Copy, Debug)]
pub struct HalfQuant {
    /// `true` = bfloat16, `false` = IEEE binary16.
    pub bf: bool,
}

impl Compressor for HalfQuant {
    fn key(&self) -> String {
        if self.bf { "bf16".into() } else { "fp16".into() }
    }

    fn params(&self) -> String {
        String::new()
    }

    fn encode(&self, x: &[f32], _st: &mut CompressState, _s: u64) -> Wire {
        self.encode_into(x, Vec::new())
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        _st: &mut CompressState,
        _s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        self.encode_into(x, sc.f32s.take())
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        decode_len_check(&self.key(), wire, out.len(), wire.d);
        out.copy_from_slice(&wire.data);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        d as u64 * 2
    }
}

impl HalfQuant {
    fn encode_into(&self, x: &[f32], mut data: Vec<f32>) -> Wire {
        data.clear();
        data.reserve(x.len());
        data.extend(
            x.iter()
                .map(|&v| if self.bf { round_bf16(v) } else { round_f16(v) }),
        );
        Wire {
            data,
            d: x.len(),
            wire_bytes: self.wire_bytes(x.len()),
        }
    }
}

fn k_of(frac: f32, d: usize) -> usize {
    if d == 0 {
        return 0;
    }
    ((frac as f64 * d as f64).ceil() as usize).clamp(1, d)
}

/// Sparse index+value wire size with dense fallback: `8·k` bytes (u32
/// index + f32 value per kept coordinate) capped at the raw `4·d`.
fn sparse_wire_bytes(k: usize, d: usize) -> u64 {
    (k as u64 * 8).min(d as u64 * 4)
}

/// Pack kept (index, value) pairs into a [`Wire`]: first `k` slots carry
/// the index bit patterns, the next `k` the values.
fn sparse_pack(idx: &[usize], x: &[f32], wire_bytes: u64) -> Wire {
    sparse_pack_into(idx, x, wire_bytes, Vec::new())
}

/// [`sparse_pack`] writing into a recycled buffer (cleared first), so a
/// warm pool makes the pack allocation-free.
fn sparse_pack_into(
    idx: &[usize],
    x: &[f32],
    wire_bytes: u64,
    mut data: Vec<f32>,
) -> Wire {
    data.clear();
    data.reserve(idx.len() * 2);
    data.extend(idx.iter().map(|&i| f32::from_bits(i as u32)));
    data.extend(idx.iter().map(|&i| x[i]));
    Wire {
        data,
        d: x.len(),
        wire_bytes,
    }
}

fn sparse_unpack(codec: &str, wire: &Wire, out: &mut [f32], scale: f32) {
    decode_len_check(codec, wire, out.len(), wire.data.len());
    let k = wire.data.len() / 2;
    assert!(
        wire.data.len() % 2 == 0 && k <= wire.d,
        "[compress] {codec} decode length mismatch: {} wire slot(s) is \
         not an (index, value) pairing for d={}",
        wire.data.len(),
        wire.d,
    );
    out.fill(0.0);
    for j in 0..k {
        let i = wire.data[j].to_bits() as usize;
        assert!(
            i < out.len(),
            "[compress] {codec} decode length mismatch: sparse index {i} \
             out of range for out.len()={}",
            out.len(),
        );
        out[i] = wire.data[k + j] * scale;
    }
}

/// Top-k magnitude sparsification: keep the `ceil(frac·d)` coordinates
/// with the largest |x| (ties broken toward the lower index, so encodes
/// are deterministic).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub frac: f32,
}

impl Compressor for TopK {
    fn key(&self) -> String {
        "topk".into()
    }

    fn params(&self) -> String {
        self.frac.to_string()
    }

    fn encode(&self, x: &[f32], _st: &mut CompressState, _s: u64) -> Wire {
        let mut order = Vec::new();
        self.select(x, &mut order);
        sparse_pack(&order, x, self.wire_bytes(x.len()))
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        _st: &mut CompressState,
        _s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        let mut order = sc.idx.take();
        self.select(x, &mut order);
        let wire =
            sparse_pack_into(&order, x, self.wire_bytes(x.len()),
                             sc.f32s.take());
        sc.idx.put(order);
        wire
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        decode_len_check("topk", wire, out.len(),
                         2 * k_of(self.frac, wire.d));
        sparse_unpack("topk", wire, out, 1.0);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        sparse_wire_bytes(k_of(self.frac, d), d)
    }
}

impl TopK {
    /// The kept index set (the `k` largest-|x| coordinates), ascending,
    /// written into `order` — shared by the fresh and pooled encodes.
    fn select(&self, x: &[f32], order: &mut Vec<usize>) {
        let d = x.len();
        let k = k_of(self.frac, d);
        order.clear();
        order.extend(0..d);
        // O(d) selection of the k largest-|x| indices (total order with
        // the index tie-break, so the kept set is deterministic), then
        // sort just those k for the wire layout.
        if k > 0 && k < d {
            order.select_nth_unstable_by(k - 1, |&a, &b| {
                x[b].abs()
                    .total_cmp(&x[a].abs())
                    .then_with(|| a.cmp(&b))
            });
            order.truncate(k);
        }
        order.sort_unstable();
    }
}

/// Random-k sparsification with the unbiased `d/k` rescale. Indices come
/// from the per-site deterministic stream, so two runs with the same seed
/// pick the same coordinates.
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub frac: f32,
}

impl Compressor for RandK {
    fn key(&self) -> String {
        "randk".into()
    }

    fn params(&self) -> String {
        self.frac.to_string()
    }

    fn encode(&self, x: &[f32], st: &mut CompressState, s: u64) -> Wire {
        let mut kept = Vec::new();
        self.draw(x.len(), st, s, &mut kept);
        // The d/k rescale is applied at decode so the wire carries the raw
        // values (exact) and EF residuals see the decoded estimate.
        sparse_pack(&kept, x, self.wire_bytes(x.len()))
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        st: &mut CompressState,
        s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        let mut kept = sc.idx.take();
        self.draw(x.len(), st, s, &mut kept);
        let wire = sparse_pack_into(&kept, x, self.wire_bytes(x.len()),
                                    sc.f32s.take());
        sc.idx.put(kept);
        wire
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        decode_len_check("randk", wire, out.len(),
                         2 * k_of(self.frac, wire.d));
        let k = wire.data.len() / 2;
        let scale = if k == 0 { 0.0 } else { wire.d as f32 / k as f32 };
        sparse_unpack("randk", wire, out, scale);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        sparse_wire_bytes(k_of(self.frac, d), d)
    }
}

impl RandK {
    /// Draw the kept index set (k distinct, ascending) into `pool` via a
    /// partial Fisher-Yates over the site's deterministic stream — shared
    /// by the fresh and pooled encodes.
    fn draw(
        &self,
        d: usize,
        st: &mut CompressState,
        s: u64,
        pool: &mut Vec<usize>,
    ) {
        let k = k_of(self.frac, d);
        let mut rng = st.next_stream(s);
        pool.clear();
        pool.extend(0..d);
        for j in 0..k {
            let pick = j + rng.below((d - j) as u64) as usize;
            pool.swap(j, pick);
        }
        pool.truncate(k);
        pool.sort_unstable();
    }
}

/// 1-bit SIGNSGD-style quantization: per `chunk` coordinates, one f32
/// scale (mean |x| over the chunk) plus one sign bit per coordinate
/// (zero encodes as +). Averaging the decoded ±scale vectors across
/// workers is the soft majority vote of majority-vote SIGNSGD reduces.
#[derive(Clone, Copy, Debug)]
pub struct SignSgd {
    pub chunk: usize,
}

impl SignSgd {
    fn n_chunks(&self, d: usize) -> usize {
        d.div_ceil(self.chunk)
    }

    /// `true` when the sparse layout (per-chunk scales + packed sign
    /// words) would not beat raw f32, i.e. [`Compressor::wire_bytes`]
    /// clamps to `4·d` (pathologically small `chunk`). In that regime the
    /// wire carries `x` verbatim — `d` slots matching the charged bytes —
    /// so layout and accounting agree. Deterministic in `(chunk, d)`;
    /// encode and decode need no wire flag to agree.
    fn dense_fallback(&self, d: usize) -> bool {
        d > 0
            && self.n_chunks(d) as u64 * 4 + d.div_ceil(8) as u64
                >= d as u64 * 4
    }

    fn encode_into(&self, x: &[f32], mut data: Vec<f32>) -> Wire {
        let d = x.len();
        data.clear();
        if self.dense_fallback(d) {
            data.extend_from_slice(x);
            return Wire {
                data,
                d,
                wire_bytes: self.wire_bytes(d),
            };
        }
        let n_chunks = self.n_chunks(d);
        let n_words = d.div_ceil(32);
        data.reserve(n_chunks + n_words);
        for c in 0..n_chunks {
            let lo = c * self.chunk;
            let hi = (lo + self.chunk).min(d);
            let mean_abs: f32 = x[lo..hi]
                .iter()
                .map(|v| v.abs())
                .sum::<f32>()
                / (hi - lo) as f32;
            data.push(mean_abs);
        }
        for w in 0..n_words {
            let mut word: u32 = 0;
            for b in 0..32 {
                let i = w * 32 + b;
                if i < d && x[i].is_sign_negative() && x[i] != 0.0 {
                    word |= 1 << b;
                }
            }
            data.push(f32::from_bits(word));
        }
        Wire {
            data,
            d,
            wire_bytes: self.wire_bytes(d),
        }
    }
}

impl Compressor for SignSgd {
    fn key(&self) -> String {
        "signsgd".into()
    }

    fn params(&self) -> String {
        self.chunk.to_string()
    }

    fn encode(&self, x: &[f32], _st: &mut CompressState, _s: u64) -> Wire {
        self.encode_into(x, Vec::new())
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        _st: &mut CompressState,
        _s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        self.encode_into(x, sc.f32s.take())
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        let d = wire.d;
        if self.dense_fallback(d) {
            decode_len_check("signsgd", wire, out.len(), d);
            out.copy_from_slice(&wire.data);
            return;
        }
        let n_chunks = self.n_chunks(d);
        decode_len_check("signsgd", wire, out.len(),
                         n_chunks + d.div_ceil(32));
        for (i, o) in out.iter_mut().enumerate() {
            let scale = wire.data[i / self.chunk];
            let word = wire.data[n_chunks + i / 32].to_bits();
            let neg = (word >> (i % 32)) & 1 == 1;
            *o = if neg { -scale } else { scale };
        }
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        if d == 0 {
            return 0;
        }
        // One f32 scale per chunk + one sign bit per coordinate.
        (self.n_chunks(d) as u64 * 4 + d.div_ceil(8) as u64)
            .min(d as u64 * 4)
    }
}

/// Error feedback (Seide et al. 2014; Karimireddy et al. 2019) around any
/// inner codec: each message sends `compress(x + r)` and keeps the new
/// residual `r ← (x + r) - decode(compress(x + r))` for this site. With
/// `topk:1.0` inside (keep everything) the residual is identically zero
/// and the transcode is value-exact, which the equivalence tests pin.
pub struct ErrorFeedback {
    pub inner: Arc<dyn Compressor>,
}

impl ErrorFeedback {
    /// One residual-map walk per message: take the residual buffer out of
    /// the map (leaving an empty vec on the existing key), fold `x` in,
    /// subtract the decode, and re-insert — the old path walked the map
    /// twice and allocated `e`/`dec` fresh every call. Bitwise-identical:
    /// `r + x` equals the old `x + r` (IEEE f32 addition commutes) and
    /// the in-place `e -= dec` computes the same `(x + r) - dec`
    /// (equivalence-tested against a reference of the old path).
    fn encode_impl(
        &self,
        x: &[f32],
        st: &mut CompressState,
        s: u64,
        sc: Option<&mut Scratch>,
    ) -> Wire {
        let d = x.len();
        let mut e = std::mem::take(st.residual(s, d));
        for (ev, xv) in e.iter_mut().zip(x) {
            *ev += *xv;
        }
        let wire;
        match sc {
            Some(sc) => {
                wire = self.inner.encode_pooled(&e, st, s, sc);
                let mut dec = sc.f32s.take_filled(d);
                self.inner.decode_pooled(&wire, &mut dec, sc);
                for (ev, dv) in e.iter_mut().zip(&dec) {
                    *ev -= *dv;
                }
                sc.f32s.put(dec);
            }
            None => {
                wire = self.inner.encode(&e, st, s);
                let mut dec = vec![0.0f32; d];
                self.inner.decode(&wire, &mut dec);
                for (ev, dv) in e.iter_mut().zip(&dec) {
                    *ev -= *dv;
                }
            }
        }
        st.set_residual(s, e);
        wire
    }
}

impl Compressor for ErrorFeedback {
    fn key(&self) -> String {
        "ef".into()
    }

    fn params(&self) -> String {
        describe(self.inner.as_ref())
    }

    fn encode(&self, x: &[f32], st: &mut CompressState, s: u64) -> Wire {
        self.encode_impl(x, st, s, None)
    }

    fn encode_pooled(
        &self,
        x: &[f32],
        st: &mut CompressState,
        s: u64,
        sc: &mut Scratch,
    ) -> Wire {
        self.encode_impl(x, st, s, Some(sc))
    }

    fn decode(&self, wire: &Wire, out: &mut [f32]) {
        self.inner.decode(wire, out);
    }

    fn decode_pooled(&self, wire: &Wire, out: &mut [f32], sc: &mut Scratch) {
        self.inner.decode_pooled(wire, out, sc);
    }

    fn wire_bytes(&self, d: usize) -> u64 {
        self.inner.wire_bytes(d)
    }

    fn ef_bufs(&self) -> usize {
        1
    }

    fn rejoin_state(&self, st: &CompressState, d: usize) -> Vec<Vec<f32>> {
        vec![match st.residual_opt(site::OUTER) {
            Some(r) if r.len() == d => r.clone(),
            _ => vec![0.0; d],
        }]
    }

    fn install_rejoin_state(&self, st: &mut CompressState, bufs: &[&[f32]]) {
        if let Some(buf) = bufs.first() {
            st.set_residual(site::OUTER, buf.to_vec());
        }
    }
}

// -------------------------------------------------------------- selection

/// A parsed compressor selection: canonical key + numeric args + the
/// nested inner selection for wrapper codecs (`ef:<inner>`). Round-trips
/// through [`CompressSel::spec`].
#[derive(Clone, Debug, PartialEq)]
pub struct CompressSel {
    pub key: String,
    pub args: Vec<f32>,
    pub inner: Option<Box<CompressSel>>,
}

impl CompressSel {
    pub fn none() -> Self {
        Self::new("none")
    }

    pub fn new(key: &str) -> Self {
        Self {
            key: key.to_string(),
            args: Vec::new(),
            inner: None,
        }
    }

    pub fn with_args(key: &str, args: &[f32]) -> Self {
        Self {
            key: key.to_string(),
            args: args.to_vec(),
            inner: None,
        }
    }

    pub fn wrapping(key: &str, inner: CompressSel) -> Self {
        Self {
            key: key.to_string(),
            args: Vec::new(),
            inner: Some(Box::new(inner)),
        }
    }

    /// `true` for the identity selection (no compression configured).
    pub fn is_none(&self) -> bool {
        self.key == "none"
    }

    /// The spec-string form ("topk:0.1", "ef:topk:0.1", "none").
    pub fn spec(&self) -> String {
        let mut s = self.key.clone();
        if let Some(inner) = &self.inner {
            s.push(':');
            s.push_str(&inner.spec());
        }
        if !self.args.is_empty() {
            s.push(':');
            let args: Vec<String> =
                self.args.iter().map(|a| a.to_string()).collect();
            s.push_str(&args.join(","));
        }
        s
    }
}

// --------------------------------------------------------------- registry

type CompressFactory = Box<
    dyn Fn(&[f32], Option<Arc<dyn Compressor>>) -> Result<Arc<dyn Compressor>>
        + Send
        + Sync,
>;

struct CompressEntry {
    factory: CompressFactory,
    help: String,
    /// Positional numeric spec arguments (name, default); an argument
    /// without a default is required.
    args: Vec<(String, Option<f32>)>,
    /// Wrapper codecs (`ef`) take a nested inner spec instead of numbers.
    takes_inner: bool,
}

/// String-keyed registry of [`Compressor`] factories with the same
/// spec-grammar / hard-parse-error contract as
/// [`crate::algorithms::AlgoRegistry`] and
/// [`crate::slowmo::OuterRegistry`].
pub struct CompressRegistry {
    entries: BTreeMap<String, CompressEntry>,
    aliases: BTreeMap<String, String>,
}

impl Default for CompressRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl CompressRegistry {
    /// An empty registry (no codecs).
    pub fn empty() -> Self {
        Self {
            entries: BTreeMap::new(),
            aliases: BTreeMap::new(),
        }
    }

    /// The built-in codecs, pre-registered.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register("none", "no compression (raw f32; the default)", &[],
                   false, |_, _| {
            Ok(Arc::new(NoneCompressor) as Arc<dyn Compressor>)
        });
        r.register("fp16", "IEEE binary16 quantization (2 B/coord)", &[],
                   false, |_, _| {
            Ok(Arc::new(HalfQuant { bf: false }) as Arc<dyn Compressor>)
        });
        r.register("bf16", "bfloat16 quantization (2 B/coord)", &[],
                   false, |_, _| {
            Ok(Arc::new(HalfQuant { bf: true }) as Arc<dyn Compressor>)
        });
        r.register(
            "topk",
            "keep the ceil(frac*d) largest-|x| coords (index+value wire)",
            &[("frac", Some(0.1))],
            false,
            |a, _| {
                ensure!(
                    a[0] > 0.0 && a[0] <= 1.0,
                    "topk frac must be in (0,1] (got {})",
                    a[0]
                );
                Ok(Arc::new(TopK { frac: a[0] }) as Arc<dyn Compressor>)
            },
        );
        r.register(
            "randk",
            "keep ceil(frac*d) random coords (seeded stream, d/k rescale)",
            &[("frac", Some(0.1))],
            false,
            |a, _| {
                ensure!(
                    a[0] > 0.0 && a[0] <= 1.0,
                    "randk frac must be in (0,1] (got {})",
                    a[0]
                );
                Ok(Arc::new(RandK { frac: a[0] }) as Arc<dyn Compressor>)
            },
        );
        r.register(
            "signsgd",
            "1 bit/coord + one f32 scale per chunk (soft majority vote)",
            &[("chunk", Some(64.0))],
            false,
            |a, _| {
                ensure!(
                    a[0] >= 1.0 && a[0].fract() == 0.0,
                    "signsgd chunk must be an integer >= 1 (got {})",
                    a[0]
                );
                Ok(Arc::new(SignSgd { chunk: a[0] as usize })
                    as Arc<dyn Compressor>)
            },
        );
        r.register(
            "demo",
            "DCT top-k per chunk + persistent frequency residual (DeMo)",
            &[("k", Some(0.1)), ("chunk", Some(64.0))],
            false,
            |a, _| {
                ensure!(
                    a[0] > 0.0 && a[0] <= 1.0,
                    "demo k must be in (0,1] (got {})",
                    a[0]
                );
                ensure!(
                    a[1] >= 1.0 && a[1].fract() == 0.0,
                    "demo chunk must be an integer >= 1 (got {})",
                    a[1]
                );
                Ok(Arc::new(Demo::new(a[0], a[1] as usize))
                    as Arc<dyn Compressor>)
            },
        );
        r.register(
            "ef",
            "error feedback around any inner codec (ef:topk:0.1, ...)",
            &[],
            true,
            |_, inner| {
                let inner = inner.ok_or_else(|| {
                    anyhow!("ef needs an inner codec (e.g. ef:topk:0.1)")
                })?;
                ensure!(
                    inner.key() != "ef",
                    "ef cannot wrap another ef (residuals would share a \
                     site)"
                );
                ensure!(
                    inner.key() != "demo",
                    "ef cannot wrap demo: both codecs (\"ef\" and \
                     \"demo\") keep a per-link residual, and stacking \
                     ef's spatial-domain residual on demo's frequency-\
                     domain residual double-counts dropped mass — demo \
                     already carries its own error feedback"
                );
                ensure!(
                    !inner.is_identity(),
                    "ef around the identity codec is a no-op; drop the \
                     ef: prefix or pick a lossy inner codec"
                );
                Ok(Arc::new(ErrorFeedback { inner })
                    as Arc<dyn Compressor>)
            },
        );
        r
    }

    /// Register a factory under `key`. `args` declares the positional
    /// numeric spec arguments (name, default); `takes_inner` marks
    /// wrapper codecs whose `:`-suffix is a nested codec spec instead.
    /// Re-registering a key replaces the previous factory.
    pub fn register(
        &mut self,
        key: &str,
        help: &str,
        args: &[(&str, Option<f32>)],
        takes_inner: bool,
        factory: impl Fn(
                &[f32],
                Option<Arc<dyn Compressor>>,
            ) -> Result<Arc<dyn Compressor>>
            + Send
            + Sync
            + 'static,
    ) {
        self.entries.insert(
            key.to_string(),
            CompressEntry {
                factory: Box::new(factory),
                help: help.to_string(),
                args: args
                    .iter()
                    .map(|(n, d)| (n.to_string(), *d))
                    .collect(),
                takes_inner,
            },
        );
    }

    /// Register `alias` as another name for the existing `key`.
    pub fn alias(&mut self, alias: &str, key: &str) {
        assert!(
            self.entries.contains_key(key),
            "alias target {key:?} not registered"
        );
        self.aliases.insert(alias.to_string(), key.to_string());
    }

    /// Canonical keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.entries.keys().map(|k| k.as_str()).collect()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.canonical(key).is_some()
    }

    fn canonical(&self, key: &str) -> Option<&str> {
        if let Some((k, _)) = self.entries.get_key_value(key) {
            return Some(k.as_str());
        }
        self.aliases.get(key).map(|k| k.as_str())
    }

    /// Human-readable list of valid spec forms, for error messages and
    /// CLI help.
    pub fn valid_forms(&self) -> String {
        let forms: Vec<String> = self
            .entries
            .iter()
            .map(|(k, e)| {
                if e.takes_inner {
                    format!("{k}:<codec>")
                } else if e.args.is_empty() {
                    k.clone()
                } else {
                    let names: Vec<&str> =
                        e.args.iter().map(|(n, _)| n.as_str()).collect();
                    format!("{k}[:{}]", names.join(","))
                }
            })
            .collect();
        forms.join("|")
    }

    /// One line per codec, for `--help`-style output.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        for (k, e) in &self.entries {
            s.push_str(&format!("  {:<12} {}\n", k, e.help));
        }
        s
    }

    /// Parse a spec string such as "topk:0.1", "ef:topk:0.1", "fp16" or
    /// "none". Every malformed input is a hard error: unknown keys,
    /// non-numeric / non-finite arguments, extra arguments, and a missing
    /// inner codec for wrappers all fail with a message listing the valid
    /// forms.
    pub fn parse(&self, spec: &str) -> Result<CompressSel> {
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (spec, None),
        };
        let Some(key) = self.canonical(name) else {
            bail!(
                "unknown compressor {spec:?}; valid forms: {}",
                self.valid_forms()
            );
        };
        let entry = &self.entries[key];
        if entry.takes_inner {
            let Some(rest) = rest else {
                bail!(
                    "compressor {name:?} needs an inner codec (e.g. \
                     {name}:topk:0.1); valid forms: {}",
                    self.valid_forms()
                );
            };
            let inner = self.parse(rest)?;
            return Ok(CompressSel::wrapping(key, inner));
        }
        let mut args = Vec::new();
        if let Some(rest) = rest {
            if entry.args.is_empty() {
                bail!(
                    "compressor {name:?} takes no ':' argument (got \
                     {spec:?}); valid forms: {}",
                    self.valid_forms()
                );
            }
            for raw in rest.split(',') {
                let v = raw.parse::<f32>().map_err(|_| {
                    anyhow!(
                        "malformed argument {raw:?} in compress spec \
                         {spec:?}: expected a number; valid forms: {}",
                        self.valid_forms()
                    )
                })?;
                ensure!(
                    v.is_finite(),
                    "non-finite argument {raw:?} in compress spec {spec:?}"
                );
                args.push(v);
            }
            if args.len() > entry.args.len() {
                bail!(
                    "too many arguments in compress spec {spec:?}: \
                     {name:?} takes at most {} ({}); valid forms: {}",
                    entry.args.len(),
                    entry
                        .args
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                        .join(","),
                    self.valid_forms()
                );
            }
        }
        Ok(CompressSel {
            key: key.to_string(),
            args,
            inner: None,
        })
    }

    /// Instantiate the codec `sel` names, filling in defaults for
    /// arguments the spec omitted and building nested inner codecs.
    pub fn build(&self, sel: &CompressSel) -> Result<Arc<dyn Compressor>> {
        let key = self.canonical(&sel.key).ok_or_else(|| {
            anyhow!(
                "unknown compressor key {:?}; registered: {}",
                sel.key,
                self.keys().join(", ")
            )
        })?;
        let entry = &self.entries[key];
        let inner = match (&sel.inner, entry.takes_inner) {
            (Some(i), true) => Some(self.build(i)?),
            (None, _) => None,
            (Some(i), false) => bail!(
                "compressor {key:?} does not wrap an inner codec (got \
                 inner {:?})",
                i.spec()
            ),
        };
        ensure!(
            sel.args.len() <= entry.args.len(),
            "compressor {key:?} takes at most {} argument(s), got {}",
            entry.args.len(),
            sel.args.len()
        );
        let mut args = sel.args.clone();
        for (name, default) in entry.args.iter().skip(args.len()) {
            match default {
                Some(d) => args.push(*d),
                None => bail!(
                    "compressor {key:?} needs argument {name:?} (no \
                     default); valid forms: {}",
                    self.valid_forms()
                ),
            }
        }
        (entry.factory)(&args, inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st() -> CompressState {
        CompressState::new(7, 0)
    }

    fn demo(d: usize) -> Vec<f32> {
        (0..d)
            .map(|i| ((i as f32 * 0.7).sin() - 0.3) * (1.0 + i as f32 * 0.1))
            .collect()
    }

    fn transcoded(c: &dyn Compressor, x: &[f32]) -> (Vec<f32>, u64) {
        let mut y = x.to_vec();
        let wire = c.transcode(&mut y, &mut st(), site::GRAD);
        (y, wire)
    }

    #[test]
    fn none_is_identity_bitwise() {
        let c = NoneCompressor;
        assert!(c.is_identity());
        let x = demo(17);
        let (y, wire) = transcoded(&c, &x);
        assert_eq!(y, x);
        assert_eq!(wire, 17 * 4);
        assert_eq!(c.wire_bytes(17), 68);
    }

    #[test]
    fn f16_round_trip_known_values() {
        for &(x, want) in &[
            (0.0f32, 0.0f32),
            (1.0, 1.0),
            (-2.0, -2.0),
            (0.5, 0.5),
            (65504.0, 65504.0), // f16 max
            (1e-8, 0.0),        // below subnormal range -> flush
        ] {
            assert_eq!(round_f16(x), want, "x={x}");
        }
        // Overflow saturates to inf.
        assert!(round_f16(1e6).is_infinite());
        // Rounding error bounded by 2^-11 relative for normals.
        for &x in &[0.1f32, 3.14159, -271.8, 0.000061] {
            let r = round_f16(x);
            assert!(
                (r - x).abs() <= x.abs() * 4.9e-4 + 6e-8,
                "x={x} r={r}"
            );
        }
        // Subnormal halves round-trip through the decoder exactly.
        let sub = f16_bits_to_f32(0x0001);
        assert!(sub > 0.0);
        assert_eq!(round_f16(sub), sub);
    }

    #[test]
    fn bf16_round_trip_bounds() {
        for &x in &[0.1f32, 1.0, -3.5, 1234.5, 1e-20] {
            let r = round_bf16(x);
            assert!((r - x).abs() <= x.abs() * 4e-3, "x={x} r={r}");
        }
        assert!(round_bf16(f32::NAN).is_nan());
        let c = HalfQuant { bf: true };
        assert_eq!(c.key(), "bf16");
        assert_eq!(c.wire_bytes(10), 20);
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let c = TopK { frac: 0.25 };
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -2.0];
        let (y, wire) = transcoded(&c, &x);
        // k = 2: keeps -5.0 and 3.0, exactly, zeros elsewhere.
        assert_eq!(y, vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(wire, 16);
    }

    #[test]
    fn topk_full_keep_is_value_exact() {
        let c = TopK { frac: 1.0 };
        let x = demo(33);
        let (y, wire) = transcoded(&c, &x);
        assert_eq!(y, x);
        // Dense fallback: never charged more than raw f32.
        assert_eq!(wire, 33 * 4);
    }

    #[test]
    fn topk_tie_break_is_deterministic() {
        let c = TopK { frac: 0.5 };
        let x = vec![1.0f32, -1.0, 1.0, -1.0];
        let (y, _) = transcoded(&c, &x);
        // Ties broken toward lower indices: keeps 0 and 1.
        assert_eq!(y, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn randk_is_deterministic_per_site_counter() {
        let c = RandK { frac: 0.5 };
        let x = demo(20);
        let mut s1 = CompressState::new(42, 3);
        let mut s2 = CompressState::new(42, 3);
        let w1 = c.encode(&x, &mut s1, site::OUTER);
        let w2 = c.encode(&x, &mut s2, site::OUTER);
        assert_eq!(w1.data, w2.data);
        // The next message at the same site draws a fresh stream.
        let w3 = c.encode(&x, &mut s1, site::OUTER);
        assert_ne!(w1.data, w3.data);
        // Different workers pick different coordinates.
        let mut s4 = CompressState::new(42, 4);
        let w4 = c.encode(&x, &mut s4, site::OUTER);
        assert_ne!(w1.data, w4.data);
    }

    #[test]
    fn randk_rescales_unbiased() {
        let c = RandK { frac: 0.5 };
        let x = demo(16);
        let mut state = st();
        let wire = c.encode(&x, &mut state, site::GRAD);
        let mut y = vec![0.0; 16];
        c.decode(&wire, &mut y);
        let k = 8;
        let mut nonzero = 0;
        for i in 0..16 {
            if y[i] != 0.0 {
                nonzero += 1;
                assert_eq!(y[i], x[i] * (16.0 / k as f32), "coord {i}");
            }
        }
        assert!(nonzero <= k);
        // frac=1.0 keeps everything with scale 1 (value-exact).
        let c1 = RandK { frac: 1.0 };
        let (y1, _) = transcoded(&c1, &x);
        assert_eq!(y1, x);
    }

    #[test]
    fn signsgd_signs_and_scales() {
        let c = SignSgd { chunk: 4 };
        let x = vec![1.0f32, -2.0, 3.0, -4.0, 0.5, 0.5, -0.5, 0.0];
        let (y, wire) = transcoded(&c, &x);
        // Chunk 0 scale = mean(|1,-2,3,-4|) = 2.5; chunk 1 = 0.375.
        assert_eq!(&y[..4], &[2.5, -2.5, 2.5, -2.5]);
        assert_eq!(&y[4..], &[0.375, 0.375, -0.375, 0.375]); // 0 -> +
        // 2 chunk scales (8 B) + 8 sign bits (1 B).
        assert_eq!(wire, 9);
        assert_eq!(c.wire_bytes(8), 9);
    }

    #[test]
    fn signsgd_wire_bytes_never_exceed_raw() {
        for d in [0usize, 1, 2, 7, 64, 65, 1000] {
            let c = SignSgd { chunk: 64 };
            assert!(c.wire_bytes(d) <= d as u64 * 4, "d={d}");
        }
        // Tiny messages fall back to the raw cap.
        let c = SignSgd { chunk: 64 };
        assert_eq!(c.wire_bytes(1), 4);
    }

    #[test]
    fn ef_residual_carries_the_error() {
        let inner = Arc::new(TopK { frac: 0.5 }) as Arc<dyn Compressor>;
        let ef = ErrorFeedback { inner };
        let mut state = st();
        let x = vec![1.0f32, 0.1, -2.0, 0.2];
        let mut y = x.clone();
        ef.transcode(&mut y, &mut state, site::OUTER);
        // k=2 keeps 1.0 and -2.0; residual = the dropped mass.
        assert_eq!(y, vec![1.0, 0.0, -2.0, 0.0]);
        let r = state.residual_opt(site::OUTER).unwrap();
        assert_eq!(r, &vec![0.0, 0.1, 0.0, 0.2]);
        // Next message re-injects the residual: 0.1/0.2 grow until sent.
        let mut y2 = x.clone();
        ef.transcode(&mut y2, &mut state, site::OUTER);
        let r2 = state.residual_opt(site::OUTER).unwrap().clone();
        // e = x + r = [1.0, 0.2, -2.0, 0.4]; still keeps the big two.
        assert_eq!(y2, vec![1.0, 0.0, -2.0, 0.0]);
        assert_eq!(r2, vec![0.0, 0.2, 0.0, 0.4]);
    }

    #[test]
    fn ef_topk_full_keep_is_identity_with_zero_residual() {
        let inner = Arc::new(TopK { frac: 1.0 }) as Arc<dyn Compressor>;
        let ef = ErrorFeedback { inner };
        let mut state = st();
        let x = demo(29);
        let mut y = x.clone();
        for _ in 0..3 {
            ef.transcode(&mut y, &mut state, site::OUTER);
            assert_eq!(y, x);
        }
        let r = state.residual_opt(site::OUTER).unwrap();
        assert!(r.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ef_rejoin_state_round_trips() {
        let ef = ErrorFeedback {
            inner: Arc::new(TopK { frac: 0.5 }),
        };
        assert_eq!(ef.ef_bufs(), 1);
        let mut a = st();
        let mut x = vec![1.0f32, 0.25, -3.0, 0.5];
        ef.transcode(&mut x, &mut a, site::OUTER);
        let shipped = ef.rejoin_state(&a, 4);
        assert_eq!(shipped.len(), 1);
        let mut b = st();
        let views: Vec<&[f32]> =
            shipped.iter().map(|v| v.as_slice()).collect();
        ef.install_rejoin_state(&mut b, &views);
        assert_eq!(
            b.residual_opt(site::OUTER),
            a.residual_opt(site::OUTER)
        );
        // A site with no residual yet ships zeros.
        let fresh = st();
        assert_eq!(ef.rejoin_state(&fresh, 3), vec![vec![0.0; 3]]);
    }

    #[test]
    fn residual_rescale_and_length_reset() {
        let mut s = st();
        s.set_residual(site::OUTER, vec![2.0; 4]);
        s.scale_residuals(0.5);
        assert_eq!(s.residual_opt(site::OUTER).unwrap(), &vec![1.0; 4]);
        // Length change (elastic rebuild) resets to zeros.
        assert_eq!(s.residual(site::OUTER, 6), &vec![0.0; 6]);
    }

    #[test]
    fn registry_round_trips_every_builtin() {
        let r = CompressRegistry::builtin();
        assert_eq!(
            r.keys(),
            vec!["bf16", "demo", "ef", "fp16", "none", "randk", "signsgd",
                 "topk"]
        );
        for spec in ["none", "fp16", "bf16", "topk:0.1", "randk:0.25",
                     "signsgd:128", "demo:0.1,64", "demo:0.25,32",
                     "ef:topk:0.1", "ef:signsgd"] {
            let sel = r.parse(spec).unwrap();
            assert_eq!(sel.spec(), spec, "spec round-trip");
            let c = r.build(&sel).unwrap();
            assert_eq!(c.key(), sel.key);
        }
        // Defaults fill in.
        let c = r.build(&r.parse("topk").unwrap()).unwrap();
        assert_eq!(c.params(), "0.1");
        let c = r.build(&r.parse("signsgd").unwrap()).unwrap();
        assert_eq!(c.params(), "64");
        let c = r.build(&r.parse("demo").unwrap()).unwrap();
        assert_eq!(c.params(), "0.1,64");
        let c = r.build(&r.parse("demo:0.25").unwrap()).unwrap();
        assert_eq!(c.params(), "0.25,64");
    }

    #[test]
    fn malformed_specs_are_hard_errors() {
        let r = CompressRegistry::builtin();
        for bad in ["bogus", "topk:", "topk:abc", "topk:0", "topk:1.5",
                    "topk:0.1,0.2", "randk:-1", "fp16:2", "signsgd:0",
                    "signsgd:1.5", "ef", "ef:none", "ef:ef:topk",
                    "ef:bogus", "topk:inf", "demo:0", "demo:1.5",
                    "demo:0.1,0", "demo:0.1,1.5", "demo:0.1,64,3",
                    "ef:demo:0.1"] {
            let failed = match r.parse(bad) {
                Err(_) => true,
                Ok(sel) => r.build(&sel).is_err(),
            };
            assert!(failed, "{bad} must be rejected");
        }
        let e = r.parse("bogus").unwrap_err().to_string();
        assert!(e.contains("valid forms"), "{e}");
        assert!(e.contains("topk"), "{e}");
        // The ef:demo rejection names both codecs (satellite contract:
        // two stacked per-link residuals is a semantic trap).
        let sel = r.parse("ef:demo:0.1").unwrap();
        let e = match r.build(&sel) {
            Ok(_) => panic!("ef:demo must be rejected"),
            Err(e) => e.to_string(),
        };
        assert!(e.contains("\"ef\"") && e.contains("\"demo\""), "{e}");
    }

    #[test]
    fn custom_registration_and_aliases() {
        let mut r = CompressRegistry::builtin();
        r.register("quarter", "test-only topk 0.25", &[], false, |_, _| {
            Ok(Arc::new(TopK { frac: 0.25 }) as Arc<dyn Compressor>)
        });
        r.alias("half16", "fp16");
        assert_eq!(r.build(&r.parse("quarter").unwrap()).unwrap().key(),
                   "topk");
        assert_eq!(r.parse("half16").unwrap().key, "fp16");
        assert!(r.contains("quarter") && r.contains("half16"));
        assert!(r.valid_forms().contains("quarter"));
        assert!(r.help_text().contains("test-only"));
    }

    #[test]
    fn wire_bytes_bounded_by_raw_for_all_builtins() {
        let r = CompressRegistry::builtin();
        for spec in ["none", "fp16", "bf16", "topk", "topk:1.0", "randk",
                     "signsgd", "ef:topk:0.9", "demo", "demo:1.0,8"] {
            let c = r.build(&r.parse(spec).unwrap()).unwrap();
            for d in [0usize, 1, 3, 64, 1000] {
                assert!(
                    c.wire_bytes(d) <= d as u64 * 4,
                    "{spec} d={d}: {} > {}",
                    c.wire_bytes(d),
                    d * 4
                );
            }
        }
    }

    #[test]
    fn ef_restructured_path_matches_old_reference() {
        // Reference implementation of the pre-refactor EF encode: two
        // residual-map walks plus fresh `e`/`dec` buffers. The
        // restructured single-walk path must be bitwise-identical to it
        // (wire, decoded values via the wire, and the stored residual).
        fn reference(
            inner: &dyn Compressor,
            x: &[f32],
            st: &mut CompressState,
            s: u64,
        ) -> Wire {
            let d = x.len();
            let r = st.residual(s, d).clone();
            let mut e = x.to_vec();
            for (ev, rv) in e.iter_mut().zip(&r) {
                *ev += *rv;
            }
            let wire = inner.encode(&e, st, s);
            let mut dec = vec![0.0f32; d];
            inner.decode(&wire, &mut dec);
            let newr: Vec<f32> =
                e.iter().zip(&dec).map(|(a, b)| a - b).collect();
            st.set_residual(s, newr);
            wire
        }
        let inners = [
            Arc::new(TopK { frac: 0.5 }) as Arc<dyn Compressor>,
            Arc::new(SignSgd { chunk: 4 }) as Arc<dyn Compressor>,
            Arc::new(RandK { frac: 0.5 }) as Arc<dyn Compressor>,
        ];
        for inner in inners {
            let ef = ErrorFeedback { inner: inner.clone() };
            let mut sa = CompressState::new(9, 1);
            let mut sb = CompressState::new(9, 1);
            for round in 0..4 {
                let x: Vec<f32> = demo(21)
                    .iter()
                    .map(|v| v * (round as f32 + 1.0))
                    .collect();
                let wa = ef.encode(&x, &mut sa, site::OUTER);
                let wb = reference(inner.as_ref(), &x, &mut sb,
                                   site::OUTER);
                assert_eq!(wa.d, wb.d);
                assert_eq!(wa.wire_bytes, wb.wire_bytes);
                assert_eq!(wa.data.len(), wb.data.len());
                for (a, b) in wa.data.iter().zip(&wb.data) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{} wire, round {round}", ef.params());
                }
                let ra = sa.residual_opt(site::OUTER).unwrap();
                let rb = sb.residual_opt(site::OUTER).unwrap();
                for (a, b) in ra.iter().zip(rb) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{} residual, round {round}", ef.params());
                }
            }
        }
    }

    #[test]
    fn signsgd_dense_fallback_layout_matches_accounting() {
        // Clamp regime: when per-chunk scales + sign words cannot beat
        // raw f32 (chunk == 1, or d == 1), wire_bytes clamps to 4·d.
        // The wire must then actually carry d slots — layout and
        // accounting agree — and the round-trip is exact (the charged
        // bytes buy a verbatim copy, including -0.0).
        for (chunk, d) in [(1usize, 5usize), (1, 32), (2, 1), (64, 1)] {
            let c = SignSgd { chunk };
            assert!(c.dense_fallback(d), "chunk={chunk} d={d}");
            assert_eq!(c.wire_bytes(d), d as u64 * 4);
            let mut x = demo(d);
            x[0] = -0.0;
            let wire = c.encode(&x, &mut st(), site::GRAD);
            assert_eq!(wire.data.len(), d,
                       "dense wire carries d slots (chunk={chunk} d={d})");
            assert_eq!(wire.wire_bytes, d as u64 * 4);
            let mut y = vec![0.0f32; d];
            c.decode(&wire, &mut y);
            for (a, b) in x.iter().zip(&y) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "chunk={chunk} d={d}");
            }
        }
        // Outside the clamp the 1-bit layout is still in force.
        let c = SignSgd { chunk: 4 };
        assert!(!c.dense_fallback(8));
        assert_eq!(c.encode(&demo(8), &mut st(), site::GRAD).data.len(),
                   2 + 1); // 2 chunk scales + 1 sign word
    }

    #[test]
    #[should_panic(expected = "decode length mismatch")]
    fn decode_rejects_wrong_out_length() {
        let c = NoneCompressor;
        let wire = c.encode(&demo(8), &mut st(), site::GRAD);
        let mut out = vec![0.0f32; 7];
        c.decode(&wire, &mut out);
    }

    #[test]
    #[should_panic(expected = "decode length mismatch")]
    fn topk_decode_rejects_truncated_wire() {
        let c = TopK { frac: 0.5 };
        let mut wire = c.encode(&demo(8), &mut st(), site::GRAD);
        wire.data.pop();
        let mut out = vec![0.0f32; 8];
        c.decode(&wire, &mut out);
    }

    #[test]
    #[should_panic(expected = "decode length mismatch")]
    fn signsgd_decode_rejects_truncated_wire() {
        let c = SignSgd { chunk: 4 };
        let mut wire = c.encode(&demo(8), &mut st(), site::GRAD);
        wire.data.pop();
        let mut out = vec![0.0f32; 8];
        c.decode(&wire, &mut out);
    }

    #[test]
    fn pooled_transcode_bitwise_matches_fresh_for_all_builtins() {
        let r = CompressRegistry::builtin();
        for spec in ["none", "fp16", "bf16", "topk:0.25", "randk:0.25",
                     "signsgd:8", "signsgd:1", "demo:0.25,16",
                     "ef:topk:0.5", "ef:signsgd:8"] {
            let c = r.build(&r.parse(spec).unwrap()).unwrap();
            let mut sf = CompressState::new(11, 2);
            let mut sp = CompressState::new(11, 2);
            let mut sc = Scratch::new();
            let x = demo(37);
            for round in 0..3 {
                let mut yf = x.clone();
                let mut yp = x.clone();
                let bf = c.transcode(&mut yf, &mut sf, site::OUTER);
                let bp = c.transcode_pooled(&mut yp, &mut sp, site::OUTER,
                                            &mut sc);
                assert_eq!(bf, bp, "{spec} round {round}: wire bytes");
                for (a, b) in yf.iter().zip(&yp) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{spec} round {round}");
                }
            }
            // Residual state (EF / demo) stayed bitwise in lockstep too.
            match (sf.residual_opt(site::OUTER),
                   sp.residual_opt(site::OUTER)) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.len(), b.len(), "{spec}");
                    for (u, v) in a.iter().zip(b) {
                        assert_eq!(u.to_bits(), v.to_bits(),
                                   "{spec} residual");
                    }
                }
                (None, None) => {}
                _ => panic!("{spec}: residual presence diverged"),
            }
            // And the pool is genuinely being fed and drained.
            if !c.is_identity() {
                assert!(sc.f32s.idle() > 0, "{spec}: pool never recycled");
            }
        }
    }

    #[test]
    fn describe_formats() {
        assert_eq!(describe(&NoneCompressor), "none");
        assert_eq!(describe(&TopK { frac: 0.1 }), "topk(0.1)");
        let ef = ErrorFeedback {
            inner: Arc::new(SignSgd { chunk: 64 }),
        };
        assert_eq!(describe(&ef), "ef(signsgd(64))");
    }
}
