//! Hierarchical two-level SlowMo: groups of workers with fast intra-group
//! links and slow inter-group links (the BMUF cluster shape; Gao & Huang
//! 2020's periodic two-level momentum structure).
//!
//! The `m` workers are partitioned by a [`Groups`] spec (`"g"` or explicit
//! `"0-3|4-7"` ranges — see [`crate::topology::Groups`]). Inside a group
//! the base algorithm runs over a *group-local fabric view* (topologies
//! and collectives sized to the group, peers addressed by local rank —
//! [`crate::algorithms::Ctx::scope`]), optionally exact-averaging the
//! group every [`HierCfg::tau_inner`] inner steps. The SlowMo outer
//! boundary becomes a **two-level reduce**:
//!
//! 1. each group ring-averages its live members (fast links, the same
//!    `3t + lane` collective ids as the flat path — `g = 1` is therefore
//!    *bitwise identical* to flat SlowMo);
//! 2. group leaders (lowest live rank per group) scale their group means
//!    by `|G|·g / m` and ring-average over leaders only (slow links; the
//!    weighting makes the leader mean the exact global mean for unequal
//!    groups);
//! 3. leaders broadcast the global mean back down their group (with the
//!    leader clock packed into the payload, same causality trick as the
//!    elastic rejoin transfer), and every worker applies the registered
//!    [`super::OuterOpt`] rule locally — deterministic fp on identical
//!    inputs keeps all workers bit-synchronized.
//!
//! Costs are honest end to end: the fabric's two-tier link context
//! ([`crate::net::Tiers`]) charges intra rings at the fast model and the
//! leader ring at the slow model (a synchronous ring is gated by its
//! slowest link), tallies inter-group wire bytes separately, and composes
//! with compression (per-stage EF sites) and the chaos layer (collective
//! ids key the delay streams; elastic membership works per group).

use crate::compress::{site, CompressState, Compressor};
use crate::net::{ring_allreduce_mean_group_c, CostModel, Fabric};
use crate::topology::{Groups, TierTree};
use anyhow::{ensure, Result};

/// Collective-id bit for the inter-group leader ring at an outer
/// boundary: distinct from the flat/intra lane ids `3t + L` so the chaos
/// delay streams and chunk tags never collide across the two stages.
pub(crate) const LEADER_COLL_BIT: u64 = 1 << 29;

/// Collective-id bit for the fast intra-group average every `tau_inner`
/// inner steps (`coll_id = INNER_COLL_BIT | k`). Keeps the inner-step
/// lane disjoint from boundary lanes and from base-algorithm collectives
/// for any realistic step count (`k < 2^29`).
pub(crate) const INNER_COLL_BIT: u64 = 1 << 30;

/// Chunk tag for the leader→members broadcast of lane `lane` (bit 63 is
/// the rejoin flag; collective tags use `coll_id << 32 | round`, and this
/// id sets both stage bits so it can never be a ring id).
fn bcast_tag(lane: u64) -> u64 {
    (LEADER_COLL_BIT | INNER_COLL_BIT | lane) << 32
}

/// Collective id of the level-`lvl` leader ring (N-level reduce). Level 1
/// — the ring over leaf-group leaders — keeps exactly the two-level id
/// `LEADER_COLL_BIT | lane`, so the depth-1 special case shares lanes
/// with the historical path; deeper levels stamp the level into bits
/// 24.. (lanes are `3t + L`, so `t < 2^22` boundaries never collide).
fn ring_lane_lvl(lane: u64, lvl: usize) -> u64 {
    debug_assert!(lvl >= 1);
    if lvl == 1 {
        LEADER_COLL_BIT | lane
    } else {
        LEADER_COLL_BIT | ((lvl as u64) << 24) | lane
    }
}

/// Chunk tag of the downward final-mean broadcast feeding level `lvl`
/// (level 0 = leaf members, matching [`bcast_tag`]; level `l >= 1` = the
/// non-leader participants of ring `l`). Both stage bits are set, so the
/// tags can never collide with ring ids at any level.
fn bcast_tag_lvl(lane: u64, lvl: usize) -> u64 {
    (LEADER_COLL_BIT | INNER_COLL_BIT | ((lvl as u64) << 24) | lane) << 32
}

/// The chunk lane carries `Vec<f32>`, but broadcast and rejoin transfers
/// must also convey the sender's f64 clock (simulated time stays causal:
/// state cannot arrive before the sender computed it). Split the f64 bit
/// pattern across two f32 payload slots — exact round-trip, no rounding.
pub(crate) fn clock_to_f32s(clock: f64) -> [f32; 2] {
    let bits = clock.to_bits();
    [
        f32::from_bits((bits >> 32) as u32),
        f32::from_bits(bits as u32),
    ]
}

pub(crate) fn clock_from_f32s(hi: f32, lo: f32) -> f64 {
    f64::from_bits(((hi.to_bits() as u64) << 32) | lo.to_bits() as u64)
}

/// Hierarchical-topology configuration for one run.
#[derive(Clone, Debug, PartialEq)]
pub struct HierCfg {
    /// Tier spec string, resolved against the run's worker count when the
    /// run starts (hard parse error naming the offending token). A plain
    /// [`Groups`] spec (`"g"`, `"0-3|4-7"`) is the two-level hierarchy;
    /// `';'`-separated partitions, leaves first (`"0-1|2-3|4-5|6-7;0-3|4-7"`),
    /// build an N-level [`TierTree`] (rack → pod → datacenter → ...).
    pub spec: String,
    /// Fast intra-group exact average every this many inner steps
    /// (0 = off; boundary steps are skipped — the outer reduce subsumes
    /// them). Requires `two_level`.
    pub tau_inner: u64,
    /// `true` (the default) = the hierarchical algorithm: group-local
    /// base algorithm + two-level outer reduce. `false` = *flat SlowMo on
    /// the tiered cluster*: the classic global algorithm, but with
    /// per-link two-tier costs and inter-group byte accounting — the
    /// honest baseline `slowmo exp hier` compares against.
    pub two_level: bool,
    /// Inter-group link latency override (seconds); `None` = the run's
    /// cost model (both tiers equally fast).
    pub inter_latency_s: Option<f64>,
    /// Inter-group link bandwidth override (bytes/s); `None` = the run's
    /// cost model.
    pub inter_bandwidth_bps: Option<f64>,
    /// `(latency_s, bandwidth_bps)` per tier *above* the first crossing:
    /// entry `i` governs transfers first joined at tier `i + 2` of an
    /// N-level tree (tier 1 uses the `inter_*` overrides). Missing
    /// entries inherit the next-faster link, so setting only the
    /// inter-group link makes every upper tier equally slow.
    pub tier_links: Vec<(f64, f64)>,
}

impl HierCfg {
    /// Hierarchical two-level SlowMo over `spec` groups.
    pub fn new(spec: &str) -> Self {
        Self {
            spec: spec.to_string(),
            tau_inner: 0,
            two_level: true,
            inter_latency_s: None,
            inter_bandwidth_bps: None,
            tier_links: Vec::new(),
        }
    }

    /// Flat SlowMo on the tiered cluster (accounting/cost baseline).
    pub fn flat(spec: &str) -> Self {
        Self {
            two_level: false,
            ..Self::new(spec)
        }
    }

    pub fn with_tau_inner(mut self, tau_inner: u64) -> Self {
        self.tau_inner = tau_inner;
        self
    }

    /// Override the slow inter-group link parameters.
    pub fn with_inter_link(
        mut self,
        latency_s: f64,
        bandwidth_bps: f64,
    ) -> Self {
        self.inter_latency_s = Some(latency_s);
        self.inter_bandwidth_bps = Some(bandwidth_bps);
        self
    }

    /// Append one upper-tier link model (first call = tier 2, next =
    /// tier 3, ...). Only meaningful with an N-level `';'` spec.
    pub fn with_tier_link(
        mut self,
        latency_s: f64,
        bandwidth_bps: f64,
    ) -> Self {
        self.tier_links.push((latency_s, bandwidth_bps));
        self
    }

    /// Structural validation (spec grammar is checked by [`Self::resolve`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.tau_inner == 0 || self.two_level,
            "[groups] tau_inner needs the two-level reduce \
             (two_level = false is the flat-on-tiered-cluster baseline)"
        );
        if let Some(l) = self.inter_latency_s {
            ensure!(
                l.is_finite() && l >= 0.0,
                "[groups] inter latency must be finite and >= 0 (got {l})"
            );
        }
        if let Some(b) = self.inter_bandwidth_bps {
            ensure!(
                b > 0.0,
                "[groups] inter bandwidth must be > 0 (got {b})"
            );
        }
        for (i, &(l, b)) in self.tier_links.iter().enumerate() {
            ensure!(
                l.is_finite() && l >= 0.0,
                "[groups] tier-{} latency must be finite and >= 0 (got {l})",
                i + 2
            );
            ensure!(
                b > 0.0,
                "[groups] tier-{} bandwidth must be > 0 (got {b})",
                i + 2
            );
        }
        Ok(())
    }

    /// Parse the spec against `m` workers as a single partition (hard
    /// error naming the token). Two-level callers; N-level `';'` specs
    /// must go through [`Self::resolve_tree`].
    pub fn resolve(&self, m: usize) -> Result<Groups> {
        self.validate()?;
        Groups::parse(&self.spec, m).map_err(anyhow::Error::msg)
    }

    /// Parse the spec against `m` workers as an N-level [`TierTree`]
    /// (depth 1 for a plain [`Groups`] spec — identical to
    /// [`Self::resolve`] wrapped in a tree). Hard error naming the
    /// offending token, and a depth check against `tier_links`.
    pub fn resolve_tree(&self, m: usize) -> Result<TierTree> {
        self.validate()?;
        let tree =
            TierTree::parse(&self.spec, m).map_err(anyhow::Error::msg)?;
        ensure!(
            self.tier_links.len() <= tree.depth().saturating_sub(1),
            "[groups] {} tier link override(s) but the tier spec {:?} has \
             only {} tier(s) above the leaves",
            self.tier_links.len(),
            self.spec,
            tree.depth() - 1
        );
        Ok(tree)
    }

    /// Per-tier slow-link ladder for an N-level run: entry `l - 1`
    /// governs transfers first joined at tier `l` ([`crate::net::Tiers`]
    /// invariant: one model per tier). Tier 1 is [`Self::inter_cost`];
    /// deeper tiers take their `tier_links` override or inherit the
    /// next-faster link.
    pub fn tier_costs(&self, intra: &CostModel, depth: usize) -> Vec<CostModel> {
        let mut links = vec![self.inter_cost(intra)];
        for l in 1..depth {
            links.push(match self.tier_links.get(l - 1) {
                Some(&(latency_s, bandwidth_bps)) => {
                    CostModel { latency_s, bandwidth_bps }
                }
                None => links[l - 1].clone(),
            });
        }
        links
    }

    /// The slow inter-group cost model: the run's `intra` model with any
    /// configured overrides applied.
    pub fn inter_cost(&self, intra: &CostModel) -> CostModel {
        CostModel {
            latency_s: self.inter_latency_s.unwrap_or(intra.latency_s),
            bandwidth_bps: self
                .inter_bandwidth_bps
                .unwrap_or(intra.bandwidth_bps),
        }
    }
}

/// Live members of `worker`'s group: intersection of the group with the
/// (sorted) live contributor set.
fn group_live(groups: &Groups, live: &[usize], gi: usize) -> Vec<usize> {
    groups
        .members(gi)
        .iter()
        .copied()
        .filter(|w| live.binary_search(w).is_ok())
        .collect()
}

/// One boundary-average lane (parameters, or an h/v buffer under
/// `BufferStrategy::Average`): the flat exact average when `hier` is
/// `None`, the two-level reduce otherwise. `lane` is the flat-compatible
/// collective id (`3t + L`) — with a single group the two-level path
/// performs the *identical* operations (same transcode, same ring, same
/// id), so `g = 1` is bitwise flat SlowMo by construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn boundary_average(
    fabric: &Fabric,
    hier: Option<&Groups>,
    worker: usize,
    live: &[usize],
    x: &mut Vec<f32>,
    comp: &mut CompressState,
    mut clock: f64,
    lane: u64,
    codec: Option<&dyn Compressor>,
    site_intra: u64,
    site_leader: u64,
) -> Result<f64> {
    let d = x.len();
    let Some(groups) = hier else {
        // Flat path, operation for operation the pre-hierarchy code: a
        // lone survivor's "average" moves no bytes, so its contribution
        // is not lossily transcoded either.
        if live.len() > 1 {
            if let Some(c) = codec {
                c.transcode(x, comp, site_intra);
            }
        }
        return Ok(ring_allreduce_mean_group_c(
            fabric, worker, live, x, clock, lane, codec,
        ));
    };

    // Stage 1: fast intra-group average over the group's live members
    // (flat-compatible collective id; disjoint groups sharing the id is
    // fine — chunks only travel within a group, per-recipient mailboxes).
    let gi = groups.group_of(worker);
    let gl = group_live(groups, live, gi);
    debug_assert!(gl.binary_search(&worker).is_ok());
    if gl.len() > 1 {
        if let Some(c) = codec {
            c.transcode(x, comp, site_intra);
        }
    }
    clock = ring_allreduce_mean_group_c(
        fabric, worker, &gl, x, clock, lane, codec,
    );

    // Stage 2: inter-group leader reduce. Leaders are the lowest live
    // rank of each group with at least one live member, in group order
    // (ascending — the canonicalized partition keeps leaders sorted).
    let live_groups: Vec<(usize, usize, usize)> = groups
        .all()
        .iter()
        .enumerate()
        .filter_map(|(g, members)| {
            let mut it = members
                .iter()
                .filter(|&&w| live.binary_search(&w).is_ok());
            it.next().map(|&leader| (g, 1 + it.count(), leader))
        })
        .collect();
    let n_lg = live_groups.len();
    if n_lg <= 1 {
        return Ok(clock);
    }
    let total: usize = live_groups.iter().map(|&(_, c, _)| c).sum();
    debug_assert_eq!(total, live.len());
    let my_leader = live_groups
        .iter()
        .find(|&&(g, ..)| g == gi)
        .expect("a live worker's own group is live")
        .2;

    if worker == my_leader {
        // Weight the group mean by |G_live|·g_live / m_live so the leader
        // mean is the exact global mean for unequal (or degraded) groups.
        // Equal live counts give factor == 1.0 exactly — skipped, so the
        // equal-group fast path stays bit-clean.
        let factor = (gl.len() * n_lg) as f32 / total as f32;
        if factor != 1.0 {
            for v in x.iter_mut() {
                *v *= factor;
            }
        }
        // More than one live group (checked above), so the leader ring
        // moves bytes — re-transcode the weighted group mean before it
        // crosses the slow links.
        if let Some(c) = codec {
            c.transcode(x, comp, site_leader);
        }
        let leader_ids: Vec<usize> =
            live_groups.iter().map(|&(.., l)| l).collect();
        clock = ring_allreduce_mean_group_c(
            fabric,
            worker,
            &leader_ids,
            x,
            clock,
            LEADER_COLL_BIT | lane,
            codec,
        );
        // Stage 3: broadcast the global mean (plus the leader clock) back
        // down the fast links. Raw f32 like the rejoin transfer.
        let members: Vec<usize> =
            gl.iter().copied().filter(|&w| w != worker).collect();
        if !members.is_empty() {
            let mut msg = Vec::with_capacity(d + 2);
            msg.extend_from_slice(x);
            msg.extend_from_slice(&clock_to_f32s(clock));
            for &r in &members {
                fabric.chunk_send(worker, r, bcast_tag(lane), msg.clone());
                clock += fabric.cost_for_link(worker, r).xfer_time(d + 2);
            }
        }
    } else {
        let mut payload = fabric.chunk_recv_tag(worker, bcast_tag(lane));
        // A misshaped payload would silently zero-fill the clock and
        // corrupt the parameters — hard error naming worker and lane.
        ensure!(
            payload.len() == d + 2,
            "hierarchical broadcast corrupt at worker {worker}, \
             collective lane {lane}: got {} elems, want {}",
            payload.len(),
            d + 2
        );
        let lo = payload.pop().expect("payload length checked");
        let hi = payload.pop().expect("payload length checked");
        let leader_clock = clock_from_f32s(hi, lo);
        clock = clock.max(leader_clock)
            + fabric.cost_for_link(my_leader, worker).xfer_time(d + 2);
        x.copy_from_slice(&payload);
    }
    Ok(clock)
}

/// One boundary-average lane over an N-level [`TierTree`]: rack rings,
/// then a ladder of leader rings (pod, datacenter, ...), then cascading
/// broadcasts back down. `tree = None` is the flat exact average and a
/// depth-1 tree delegates to [`boundary_average`] outright — both are
/// therefore *bitwise identical* to the historical paths, operation for
/// operation (asserted in tests and `rust/tests/equivalences.rs`).
///
/// Depth `D >= 2` generalizes the two-level schedule recursively:
///
/// 1. level-0 ring: each leaf group ring-averages its live members on the
///    flat-compatible lane id;
/// 2. level-`l` rings (`l = 1..=D`): the leaders (lowest live rank) of
///    the live tier-`l-1` subtrees sharing a tier-`l` group (all of them
///    at `l = D`) scale their subtree means by `c·n/T` (subtree live
///    count × ring size / scope live count — the exact-mean weighting for
///    unequal or degraded subtrees; `1.0` exactly, hence skipped, for
///    equal ones) and ring-average on [`ring_lane_lvl`], gated by that
///    tier's links via [`Fabric::cost_for_span`];
/// 3. every top-ring member holds the global mean; each ring leader then
///    broadcasts it down to its ring's non-ascending participants
///    ([`bcast_tag_lvl`], packed-clock causality), level by level, until
///    leaf leaders broadcast to their members.
///
/// With a codec, contributions transcode at `site_intra` before the leaf
/// ring and at `site_leader` before each leader ring a worker enters
/// (sequential per-worker EF residual reuse across levels — deterministic
/// because ascent order is).
#[allow(clippy::too_many_arguments)]
pub(crate) fn boundary_average_tree(
    fabric: &Fabric,
    tree: Option<&TierTree>,
    worker: usize,
    live: &[usize],
    x: &mut Vec<f32>,
    comp: &mut CompressState,
    mut clock: f64,
    lane: u64,
    codec: Option<&dyn Compressor>,
    site_intra: u64,
    site_leader: u64,
) -> Result<f64> {
    let hier = tree.filter(|t| t.depth() >= 2);
    let Some(tree) = hier else {
        return boundary_average(
            fabric,
            tree.map(|t| t.leaf().as_ref()),
            worker,
            live,
            x,
            comp,
            clock,
            lane,
            codec,
            site_intra,
            site_leader,
        );
    };
    let d = x.len();
    let depth = tree.depth();

    // Level-0 ring: live members of my leaf group (flat-compatible lane).
    let leaf = tree.leaf();
    let gl = group_live(leaf, live, leaf.group_of(worker));
    debug_assert!(gl.binary_search(&worker).is_ok());
    if gl.len() > 1 {
        if let Some(c) = codec {
            c.transcode(x, comp, site_intra);
        }
    }
    clock = ring_allreduce_mean_group_c(
        fabric, worker, &gl, x, clock, lane, codec,
    );

    // Ascend while I am the leader of my tier-(lvl-1) subtree. rings[l-1]
    // records the level-l ring I joined (sorted ascending — leaders of
    // the canonicalized partitions — so ring[0] is its leader; empty when
    // the level was a single-subtree no-op).
    let mut rings: Vec<Vec<usize>> = Vec::new();
    for lvl in 1..=depth {
        let sub = tree.tier(lvl - 1);
        let my_sub = group_live(sub, live, sub.group_of(worker));
        if my_sub.first() != Some(&worker) {
            break; // not my subtree's leader: wait for the broadcast
        }
        // Participants: leaders of every live tier-(lvl-1) subtree in my
        // level-lvl scope (my tier-lvl group; the whole run at lvl == D),
        // with their subtree live counts.
        let in_scope = |w: usize| {
            lvl == depth || !tree.tier(lvl).is_inter(w, worker)
        };
        let parts: Vec<(usize, usize)> = sub
            .all()
            .iter()
            .filter_map(|members| {
                let mut it = members
                    .iter()
                    .filter(|&&w| live.binary_search(&w).is_ok());
                match it.next() {
                    Some(&l) if in_scope(l) => Some((l, 1 + it.count())),
                    _ => None,
                }
            })
            .collect();
        let n = parts.len();
        if n <= 1 {
            // Sole live subtree in scope: my value already is the scope
            // mean; keep ascending (at the top it is the global mean).
            rings.push(Vec::new());
            continue;
        }
        let total: usize = parts.iter().map(|&(_, c)| c).sum();
        let factor = (my_sub.len() * n) as f32 / total as f32;
        if factor != 1.0 {
            for v in x.iter_mut() {
                *v *= factor;
            }
        }
        if let Some(c) = codec {
            c.transcode(x, comp, site_leader);
        }
        let ring: Vec<usize> = parts.iter().map(|&(l, _)| l).collect();
        clock = ring_allreduce_mean_group_c(
            fabric,
            worker,
            &ring,
            x,
            clock,
            ring_lane_lvl(lane, lvl),
            codec,
        );
        rings.push(ring);
    }
    let ascent = rings.len();

    // Obtain the final global mean: top-ring members already hold it;
    // everyone else receives the level-`ascent` broadcast from the leader
    // of the ring they stopped at (the leaf leader for ordinary members).
    if ascent < depth {
        let sender = match rings.last() {
            Some(ring) if !ring.is_empty() => ring[0],
            // ascent == 0 (leaf member), or my last joined level was a
            // single-subtree no-op — in the latter case I *am* that
            // level's leader and would have ascended, so this is leaf.
            _ => gl[0],
        };
        debug_assert_ne!(sender, worker);
        let mut payload =
            fabric.chunk_recv_tag(worker, bcast_tag_lvl(lane, ascent));
        ensure!(
            payload.len() == d + 2,
            "tier broadcast corrupt at worker {worker}, collective lane \
             {lane}, level {ascent}: got {} elems, want {}",
            payload.len(),
            d + 2
        );
        let lo = payload.pop().expect("payload length checked");
        let hi = payload.pop().expect("payload length checked");
        let leader_clock = clock_from_f32s(hi, lo);
        clock = clock.max(leader_clock)
            + fabric.cost_for_link(sender, worker).xfer_time(d + 2);
        x.copy_from_slice(&payload);
    }

    // Cascade the final mean down every ring I *led* (I am ring[0] of
    // every joined ring except possibly the one I stopped at), then to my
    // leaf members. Top-ring members already share the mean via the
    // allreduce, so level `depth` never broadcasts.
    let led_to = ascent.min(depth.saturating_sub(1));
    for lvl in (1..=led_to).rev() {
        if rings[lvl - 1].first() != Some(&worker) {
            continue; // the ring I received from (or a no-op level)
        }
        let others: Vec<usize> = rings[lvl - 1]
            .iter()
            .copied()
            .filter(|&w| w != worker)
            .collect();
        if others.is_empty() {
            continue;
        }
        let mut msg = Vec::with_capacity(d + 2);
        msg.extend_from_slice(x);
        msg.extend_from_slice(&clock_to_f32s(clock));
        for &r in &others {
            fabric.chunk_send(worker, r, bcast_tag_lvl(lane, lvl), msg.clone());
            clock += fabric.cost_for_link(worker, r).xfer_time(d + 2);
        }
    }
    if ascent >= 1 && gl.len() > 1 {
        let mut msg = Vec::with_capacity(d + 2);
        msg.extend_from_slice(x);
        msg.extend_from_slice(&clock_to_f32s(clock));
        for &r in gl.iter().filter(|&&w| w != worker) {
            fabric.chunk_send(worker, r, bcast_tag_lvl(lane, 0), msg.clone());
            clock += fabric.cost_for_link(worker, r).xfer_time(d + 2);
        }
    }
    Ok(clock)
}

/// The fast intra-group exact average every `tau_inner` inner steps
/// (full group membership — fault windows only change membership at
/// outer boundaries, and the trainer rejects `tau_inner` + faults).
/// Returns the updated clock.
#[allow(clippy::too_many_arguments)]
pub(crate) fn intra_average(
    fabric: &Fabric,
    groups: &Groups,
    worker: usize,
    x: &mut Vec<f32>,
    comp: &mut CompressState,
    clock: f64,
    k: u64,
    codec: Option<&dyn Compressor>,
) -> f64 {
    let members = groups.members(groups.group_of(worker));
    if members.len() > 1 {
        if let Some(c) = codec {
            c.transcode(x, comp, site::INTRA);
        }
    }
    ring_allreduce_mean_group_c(
        fabric,
        worker,
        members,
        x,
        clock,
        INNER_COLL_BIT | k,
        codec,
    )
}

/// Test hook: run one raw two-level reduce lane over `live` (free of the
/// outer-update framing) so the integration property suite can compare
/// the distributed schedule against [`Groups::weighted_mean`].
#[doc(hidden)]
pub fn test_two_level_average(
    fabric: &Fabric,
    groups: &Groups,
    worker: usize,
    live: &[usize],
    x: &mut Vec<f32>,
    comp: &mut CompressState,
) -> Result<f64> {
    boundary_average(
        fabric,
        Some(groups),
        worker,
        live,
        x,
        comp,
        0.0,
        0,
        None,
        site::OUTER,
        site::OUTER_L,
    )
}

/// Which live contributor ships the rejoin `(x0, state)` transfer to
/// `rejoiner`: the lowest live rank in the rejoiner's own group (state is
/// bit-identical everywhere after a boundary, so prefer the fast link),
/// falling back to the globally lowest survivor when the whole group was
/// down. Deterministic — both endpoints compute it independently.
///
/// The semi-sync quorum boundary reuses this with `live` = the quorum
/// ring (sorted worker ids), so a quorum-late worker resyncs from the
/// same shipper a fault-window rejoiner would — the `live`-subset
/// machinery here is agnostic to *which* authority shrank the group.
pub(crate) fn rejoin_shipper(
    hier: Option<&Groups>,
    live: &[usize],
    rejoiner: usize,
) -> usize {
    if let Some(groups) = hier {
        let members = groups.members(groups.group_of(rejoiner));
        if let Some(&s) = members
            .iter()
            .find(|&&w| live.binary_search(&w).is_ok())
        {
            return s;
        }
    }
    live[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_workers;
    use crate::util::allclose;

    #[test]
    fn clock_encoding_round_trips_exactly() {
        for clock in [0.0, 1.5e-3, 123.456789, 9.87654321e7] {
            let [hi, lo] = clock_to_f32s(clock);
            assert_eq!(clock_from_f32s(hi, lo), clock);
        }
    }

    #[test]
    fn hier_cfg_validation_and_inter_cost() {
        assert!(HierCfg::new("2").validate().is_ok());
        assert!(HierCfg::new("2").with_tau_inner(4).validate().is_ok());
        let e = HierCfg::flat("2")
            .with_tau_inner(4)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("tau_inner"), "{e}");
        assert!(HierCfg::new("2")
            .with_inter_link(-1.0, 1e9)
            .validate()
            .is_err());
        assert!(HierCfg::new("2")
            .with_inter_link(1e-3, 0.0)
            .validate()
            .is_err());
        // Spec errors surface through resolve.
        assert!(HierCfg::new("0-3|3-7").resolve(8).is_err());
        assert_eq!(HierCfg::new("2").resolve(8).unwrap().g(), 2);
        // inter_cost defaults to the intra model, overrides apply.
        let intra = CostModel::ethernet_10g();
        let same = HierCfg::new("2").inter_cost(&intra);
        assert_eq!(same.latency_s, intra.latency_s);
        assert_eq!(same.bandwidth_bps, intra.bandwidth_bps);
        let slow =
            HierCfg::new("2").with_inter_link(1e-3, 1e8).inter_cost(&intra);
        assert_eq!(slow.latency_s, 1e-3);
        assert_eq!(slow.bandwidth_bps, 1e8);
    }

    fn run_two_level(
        groups: &Groups,
        live: Vec<usize>,
        xs: Vec<Vec<f32>>,
    ) -> Vec<(Vec<f32>, f64)> {
        let m = groups.m();
        let fabric = Fabric::new(m, CostModel::free());
        run_workers(m, |w| {
            let mut x = xs[w].clone();
            let mut comp = CompressState::default();
            let mut clock = 0.0;
            if live.binary_search(&w).is_ok() {
                clock = boundary_average(
                    &fabric,
                    Some(groups),
                    w,
                    &live,
                    &mut x,
                    &mut comp,
                    0.0,
                    0,
                    None,
                    site::OUTER,
                    site::OUTER_L,
                )
                .unwrap();
            }
            (x, clock)
        })
    }

    #[test]
    fn two_level_reduce_recovers_global_mean() {
        // Unequal groups: every live worker ends with the weighted global
        // mean, bit-identical across workers.
        let m = 7;
        let groups = Groups::parse("0|1-3|4-6", m).unwrap();
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..9).map(|i| (w * 9 + i) as f32 * 0.01).collect())
            .collect();
        let want = groups.weighted_mean(&xs);
        let live: Vec<usize> = (0..m).collect();
        let out = run_two_level(&groups, live, xs.clone());
        for (w, (x, _)) in out.iter().enumerate() {
            assert!(allclose(x, &want, 1e-5, 1e-6), "worker {w}");
            assert_eq!(*x, out[0].0, "workers must agree bitwise");
        }
        // And it is the true global mean up to f32 rounding.
        for i in 0..9 {
            let g: f64 = (0..m).map(|w| f64::from(xs[w][i])).sum::<f64>()
                / m as f64;
            assert!((f64::from(want[i]) - g).abs() < 1e-5);
        }
    }

    #[test]
    fn two_level_reduce_survivor_weighting() {
        // Worker 3 of group {2,3} is dead: the global mean is over the
        // three survivors, weighted 2:1 across groups.
        let m = 4;
        let groups = Groups::parse("0-1|2-3", m).unwrap();
        let xs: Vec<Vec<f32>> =
            (0..m).map(|w| vec![w as f32; 5]).collect();
        let live = vec![0usize, 1, 2];
        let out = run_two_level(&groups, live, xs);
        let want = (0.0 + 1.0 + 2.0) / 3.0;
        for &w in &[0usize, 1, 2] {
            for &v in &out[w].0 {
                assert!((v - want).abs() < 1e-6, "worker {w}: {v}");
            }
        }
        // The dead worker's parameters are untouched.
        assert_eq!(out[3].0, vec![3.0; 5]);
    }

    #[test]
    fn single_group_is_the_flat_path_bitwise() {
        // g=1: stage 1 covers everyone with the flat collective id and
        // the leader stage is a no-op — identical bits and identical
        // clock to the hier=None path.
        let m = 4;
        let groups = Groups::flat(m);
        let cost = CostModel { latency_s: 1e-4, bandwidth_bps: 1e7 };
        let live: Vec<usize> = (0..m).collect();
        let mk = |hier: Option<&Groups>| {
            let fabric = Fabric::new(m, cost.clone());
            run_workers(m, |w| {
                let mut x: Vec<f32> =
                    (0..13).map(|i| (w * 13 + i) as f32 * 0.1).collect();
                let mut comp = CompressState::default();
                let clock = boundary_average(
                    &fabric, hier, w, &live, &mut x, &mut comp, 0.0, 3,
                    None, site::OUTER, site::OUTER_L,
                )
                .unwrap();
                (x, clock)
            })
        };
        assert_eq!(mk(Some(&groups)), mk(None));
    }

    #[test]
    fn broadcast_carries_leader_clock_causality() {
        // Non-free network: a member whose own clock is stale must land
        // after the leader's post-reduce clock plus the broadcast hop.
        let m = 4;
        let groups = Groups::parse("0-1|2-3", m).unwrap();
        let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let fabric = Fabric::new(m, cost.clone());
        let live: Vec<usize> = (0..m).collect();
        let out = run_workers(m, |w| {
            let mut x = vec![w as f32; 8];
            let mut comp = CompressState::default();
            // Leaders (0, 2) enter late; members (1, 3) at 0.
            let start = if w % 2 == 0 { 5.0 } else { 0.0 };
            boundary_average(
                &fabric, Some(&groups), w, &live, &mut x, &mut comp,
                start, 0, None, site::OUTER, site::OUTER_L,
            )
            .unwrap()
        });
        for &member in &[1usize, 3] {
            assert!(
                out[member] > 5.0,
                "member {member} clock {} ignores leader causality",
                out[member]
            );
            assert!(out[member] >= out[member - 1]);
        }
    }

    fn run_tree(
        tree: &TierTree,
        live: Vec<usize>,
        xs: Vec<Vec<f32>>,
    ) -> Vec<(Vec<f32>, f64)> {
        let m = tree.m();
        let fabric = Fabric::new(m, CostModel::free());
        run_workers(m, |w| {
            let mut x = xs[w].clone();
            let mut comp = CompressState::default();
            let mut clock = 0.0;
            if live.binary_search(&w).is_ok() {
                clock = boundary_average_tree(
                    &fabric,
                    Some(tree),
                    w,
                    &live,
                    &mut x,
                    &mut comp,
                    0.0,
                    0,
                    None,
                    site::OUTER,
                    site::OUTER_L,
                )
                .unwrap();
            }
            (x, clock)
        })
    }

    #[test]
    fn depth_one_tree_is_the_two_level_path_bitwise() {
        // A plain Groups spec wrapped as a depth-1 tree must perform the
        // identical operations (values AND clocks) as boundary_average.
        let m = 6;
        let groups = Groups::parse("0-2|3-5", m).unwrap();
        let tree = TierTree::parse("0-2|3-5", m).unwrap();
        assert_eq!(tree.depth(), 1);
        let cost = CostModel { latency_s: 1e-4, bandwidth_bps: 1e7 };
        let live: Vec<usize> = (0..m).collect();
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..11).map(|i| (w * 11 + i) as f32 * 0.1).collect())
            .collect();
        let via_groups = {
            let fabric = Fabric::new(m, cost.clone());
            run_workers(m, |w| {
                let mut x = xs[w].clone();
                let mut comp = CompressState::default();
                let clock = boundary_average(
                    &fabric, Some(&groups), w, &live, &mut x, &mut comp,
                    0.0, 3, None, site::OUTER, site::OUTER_L,
                )
                .unwrap();
                (x, clock)
            })
        };
        let via_tree = {
            let fabric = Fabric::new(m, cost.clone());
            run_workers(m, |w| {
                let mut x = xs[w].clone();
                let mut comp = CompressState::default();
                let clock = boundary_average_tree(
                    &fabric, Some(&tree), w, &live, &mut x, &mut comp,
                    0.0, 3, None, site::OUTER, site::OUTER_L,
                )
                .unwrap();
                (x, clock)
            })
        };
        assert_eq!(via_tree, via_groups);
    }

    #[test]
    fn depth_two_tree_recovers_global_mean() {
        // Unequal racks under unequal pods (m=7, 3-level hierarchy): the
        // per-level c·n/T weighting must still land every worker on the
        // uniform global mean, bit-identical across workers.
        let m = 7;
        let tree = TierTree::parse("0|1-3|4-6;0-3|4-6", m).unwrap();
        assert_eq!(tree.depth(), 2);
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..9).map(|i| (w * 9 + i) as f32 * 0.01).collect())
            .collect();
        let live: Vec<usize> = (0..m).collect();
        let out = run_tree(&tree, live, xs.clone());
        for (w, (x, _)) in out.iter().enumerate() {
            assert_eq!(*x, out[0].0, "worker {w} must agree bitwise");
        }
        for i in 0..9 {
            let g: f64 = (0..m).map(|w| f64::from(xs[w][i])).sum::<f64>()
                / m as f64;
            assert!(
                (f64::from(out[0].0[i]) - g).abs() < 1e-5,
                "elem {i}: {} want {g}",
                out[0].0[i]
            );
        }
    }

    #[test]
    fn depth_two_tree_survivor_weighting() {
        // Kill the global leader (0) and one worker per right-hand rack:
        // survivors must agree on the mean over the live set only, and
        // dead parameters stay untouched.
        let m = 8;
        let tree =
            TierTree::parse("0-1|2-3|4-5|6-7;0-3|4-7", m).unwrap();
        let xs: Vec<Vec<f32>> =
            (0..m).map(|w| vec![w as f32; 5]).collect();
        let live = vec![1usize, 2, 3, 5, 6];
        let out = run_tree(&tree, live.clone(), xs);
        let want =
            live.iter().map(|&w| w as f64).sum::<f64>() / live.len() as f64;
        for &w in &live {
            assert_eq!(out[w].0, out[live[0]].0, "worker {w} disagrees");
            for &v in &out[w].0 {
                assert!(
                    (f64::from(v) - want).abs() < 1e-5,
                    "worker {w}: {v} want {want}"
                );
            }
        }
        for &w in &[0usize, 4, 7] {
            assert_eq!(out[w].0, vec![w as f32; 5], "dead worker {w} moved");
        }
    }

    #[test]
    fn depth_three_tree_recovers_global_mean() {
        // Explicit trivial top tier: the extra level only adds no-op
        // rings and one more broadcast hop — the mean is unchanged.
        let m = 8;
        let tree =
            TierTree::parse("0-1|2-3|4-5|6-7;0-3|4-7;0-7", m).unwrap();
        assert_eq!(tree.depth(), 3);
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..6).map(|i| (w * 6 + i) as f32 * 0.1).collect())
            .collect();
        let live: Vec<usize> = (0..m).collect();
        let out = run_tree(&tree, live, xs.clone());
        for (w, (x, _)) in out.iter().enumerate() {
            assert_eq!(*x, out[0].0, "worker {w} must agree bitwise");
        }
        for i in 0..6 {
            let g: f64 = (0..m).map(|w| f64::from(xs[w][i])).sum::<f64>()
                / m as f64;
            assert!((f64::from(out[0].0[i]) - g).abs() < 1e-5);
        }
    }

    #[test]
    fn tree_broadcast_cascades_leader_clock() {
        // Slow network, stale members: every non-top worker's clock must
        // exceed the global leader's entry time (5.0) — the cascade
        // carries causality down all levels.
        let m = 8;
        let tree =
            TierTree::parse("0-1|2-3|4-5|6-7;0-3|4-7", m).unwrap();
        let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let fabric = Fabric::new(m, cost);
        let live: Vec<usize> = (0..m).collect();
        let out = run_workers(m, |w| {
            let mut x = vec![w as f32; 8];
            let mut comp = CompressState::default();
            let start = if w == 0 { 5.0 } else { 0.0 };
            boundary_average_tree(
                &fabric, Some(&tree), w, &live, &mut x, &mut comp, start,
                0, None, site::OUTER, site::OUTER_L,
            )
            .unwrap()
        });
        for (w, &clock) in out.iter().enumerate() {
            assert!(
                clock > 5.0,
                "worker {w} clock {clock} ignores the slow leader"
            );
        }
    }

    #[test]
    fn hier_cfg_resolves_trees_and_tier_costs() {
        // Plain spec -> depth-1 tree, same partition as resolve().
        let cfg = HierCfg::new("0-3|4-7");
        let tree = cfg.resolve_tree(8).unwrap();
        assert_eq!(tree.depth(), 1);
        assert_eq!(**tree.leaf(), cfg.resolve(8).unwrap());
        // ';' spec -> depth-2 tree; malformed tiers are hard errors that
        // name the offending token.
        let deep = HierCfg::new("0-1|2-3;0-3");
        assert_eq!(deep.resolve_tree(4).unwrap().depth(), 2);
        let e = HierCfg::new("0-1|2-3;0-2|3")
            .resolve_tree(4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("not nested"), "{e}");
        let e = HierCfg::new("0-1|2-3;;0-3")
            .resolve_tree(4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("tier 1 is empty"), "{e}");
        // More tier links than upper tiers is rejected.
        let e = HierCfg::new("0-1|2-3;0-3")
            .with_tier_link(1e-3, 1e8)
            .with_tier_link(1e-2, 1e7)
            .resolve_tree(4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("tier link"), "{e}");
        // Cost ladder: tier 1 from inter_*, tier 2 explicit, tier 3
        // inherits tier 2.
        let intra = CostModel::ethernet_10g();
        let cfg = HierCfg::new("ignored")
            .with_inter_link(1e-4, 1e9)
            .with_tier_link(1e-2, 1e7);
        let links = cfg.tier_costs(&intra, 3);
        assert_eq!(links.len(), 3);
        assert_eq!(links[0].latency_s, 1e-4);
        assert_eq!(links[1].latency_s, 1e-2);
        assert_eq!(links[2].latency_s, links[1].latency_s);
        assert_eq!(links[2].bandwidth_bps, links[1].bandwidth_bps);
        // No overrides at all: every tier inherits the intra model.
        let flat = HierCfg::new("2").tier_costs(&intra, 2);
        for l in &flat {
            assert_eq!(l.latency_s, intra.latency_s);
            assert_eq!(l.bandwidth_bps, intra.bandwidth_bps);
        }
        // Bad tier link parameters fail validation.
        assert!(HierCfg::new("2")
            .with_tier_link(-1.0, 1e9)
            .validate()
            .is_err());
    }

    #[test]
    fn rejoin_shipper_prefers_own_group() {
        let groups = Groups::parse("0-1|2-3", 4).unwrap();
        // Worker 3 rejoins; its group-mate 2 is live -> 2 ships.
        assert_eq!(rejoin_shipper(Some(&groups), &[0, 1, 2], 3), 2);
        // Whole group down -> global lowest survivor ships.
        assert_eq!(rejoin_shipper(Some(&groups), &[0, 1], 3), 0);
        // Flat: always the lowest survivor.
        assert_eq!(rejoin_shipper(None, &[1, 2], 3), 1);
    }
}
