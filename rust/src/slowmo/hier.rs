//! Hierarchical two-level SlowMo: groups of workers with fast intra-group
//! links and slow inter-group links (the BMUF cluster shape; Gao & Huang
//! 2020's periodic two-level momentum structure).
//!
//! The `m` workers are partitioned by a [`Groups`] spec (`"g"` or explicit
//! `"0-3|4-7"` ranges — see [`crate::topology::Groups`]). Inside a group
//! the base algorithm runs over a *group-local fabric view* (topologies
//! and collectives sized to the group, peers addressed by local rank —
//! [`crate::algorithms::Ctx::scope`]), optionally exact-averaging the
//! group every [`HierCfg::tau_inner`] inner steps. The SlowMo outer
//! boundary becomes a **two-level reduce**:
//!
//! 1. each group ring-averages its live members (fast links, the same
//!    `3t + lane` collective ids as the flat path — `g = 1` is therefore
//!    *bitwise identical* to flat SlowMo);
//! 2. group leaders (lowest live rank per group) scale their group means
//!    by `|G|·g / m` and ring-average over leaders only (slow links; the
//!    weighting makes the leader mean the exact global mean for unequal
//!    groups);
//! 3. leaders broadcast the global mean back down their group (with the
//!    leader clock packed into the payload, same causality trick as the
//!    elastic rejoin transfer), and every worker applies the registered
//!    [`super::OuterOpt`] rule locally — deterministic fp on identical
//!    inputs keeps all workers bit-synchronized.
//!
//! Costs are honest end to end: the fabric's two-tier link context
//! ([`crate::net::Tiers`]) charges intra rings at the fast model and the
//! leader ring at the slow model (a synchronous ring is gated by its
//! slowest link), tallies inter-group wire bytes separately, and composes
//! with compression (per-stage EF sites) and the chaos layer (collective
//! ids key the delay streams; elastic membership works per group).

use crate::compress::{site, CompressState, Compressor};
use crate::net::{ring_allreduce_mean_group_c, CostModel, Fabric};
use crate::topology::Groups;
use anyhow::{ensure, Result};

/// Collective-id bit for the inter-group leader ring at an outer
/// boundary: distinct from the flat/intra lane ids `3t + L` so the chaos
/// delay streams and chunk tags never collide across the two stages.
pub(crate) const LEADER_COLL_BIT: u64 = 1 << 29;

/// Collective-id bit for the fast intra-group average every `tau_inner`
/// inner steps (`coll_id = INNER_COLL_BIT | k`). Keeps the inner-step
/// lane disjoint from boundary lanes and from base-algorithm collectives
/// for any realistic step count (`k < 2^29`).
pub(crate) const INNER_COLL_BIT: u64 = 1 << 30;

/// Chunk tag for the leader→members broadcast of lane `lane` (bit 63 is
/// the rejoin flag; collective tags use `coll_id << 32 | round`, and this
/// id sets both stage bits so it can never be a ring id).
fn bcast_tag(lane: u64) -> u64 {
    (LEADER_COLL_BIT | INNER_COLL_BIT | lane) << 32
}

/// The chunk lane carries `Vec<f32>`, but broadcast and rejoin transfers
/// must also convey the sender's f64 clock (simulated time stays causal:
/// state cannot arrive before the sender computed it). Split the f64 bit
/// pattern across two f32 payload slots — exact round-trip, no rounding.
pub(crate) fn clock_to_f32s(clock: f64) -> [f32; 2] {
    let bits = clock.to_bits();
    [
        f32::from_bits((bits >> 32) as u32),
        f32::from_bits(bits as u32),
    ]
}

pub(crate) fn clock_from_f32s(hi: f32, lo: f32) -> f64 {
    f64::from_bits(((hi.to_bits() as u64) << 32) | lo.to_bits() as u64)
}

/// Hierarchical-topology configuration for one run.
#[derive(Clone, Debug, PartialEq)]
pub struct HierCfg {
    /// [`Groups`] spec string, resolved against the run's worker count
    /// when the run starts (hard parse error).
    pub spec: String,
    /// Fast intra-group exact average every this many inner steps
    /// (0 = off; boundary steps are skipped — the outer reduce subsumes
    /// them). Requires `two_level`.
    pub tau_inner: u64,
    /// `true` (the default) = the hierarchical algorithm: group-local
    /// base algorithm + two-level outer reduce. `false` = *flat SlowMo on
    /// the tiered cluster*: the classic global algorithm, but with
    /// per-link two-tier costs and inter-group byte accounting — the
    /// honest baseline `slowmo exp hier` compares against.
    pub two_level: bool,
    /// Inter-group link latency override (seconds); `None` = the run's
    /// cost model (both tiers equally fast).
    pub inter_latency_s: Option<f64>,
    /// Inter-group link bandwidth override (bytes/s); `None` = the run's
    /// cost model.
    pub inter_bandwidth_bps: Option<f64>,
}

impl HierCfg {
    /// Hierarchical two-level SlowMo over `spec` groups.
    pub fn new(spec: &str) -> Self {
        Self {
            spec: spec.to_string(),
            tau_inner: 0,
            two_level: true,
            inter_latency_s: None,
            inter_bandwidth_bps: None,
        }
    }

    /// Flat SlowMo on the tiered cluster (accounting/cost baseline).
    pub fn flat(spec: &str) -> Self {
        Self {
            two_level: false,
            ..Self::new(spec)
        }
    }

    pub fn with_tau_inner(mut self, tau_inner: u64) -> Self {
        self.tau_inner = tau_inner;
        self
    }

    /// Override the slow inter-group link parameters.
    pub fn with_inter_link(
        mut self,
        latency_s: f64,
        bandwidth_bps: f64,
    ) -> Self {
        self.inter_latency_s = Some(latency_s);
        self.inter_bandwidth_bps = Some(bandwidth_bps);
        self
    }

    /// Structural validation (spec grammar is checked by [`Self::resolve`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.tau_inner == 0 || self.two_level,
            "[groups] tau_inner needs the two-level reduce \
             (two_level = false is the flat-on-tiered-cluster baseline)"
        );
        if let Some(l) = self.inter_latency_s {
            ensure!(
                l.is_finite() && l >= 0.0,
                "[groups] inter latency must be finite and >= 0 (got {l})"
            );
        }
        if let Some(b) = self.inter_bandwidth_bps {
            ensure!(
                b > 0.0,
                "[groups] inter bandwidth must be > 0 (got {b})"
            );
        }
        Ok(())
    }

    /// Parse the spec against `m` workers (hard error naming the token).
    pub fn resolve(&self, m: usize) -> Result<Groups> {
        self.validate()?;
        Groups::parse(&self.spec, m).map_err(anyhow::Error::msg)
    }

    /// The slow inter-group cost model: the run's `intra` model with any
    /// configured overrides applied.
    pub fn inter_cost(&self, intra: &CostModel) -> CostModel {
        CostModel {
            latency_s: self.inter_latency_s.unwrap_or(intra.latency_s),
            bandwidth_bps: self
                .inter_bandwidth_bps
                .unwrap_or(intra.bandwidth_bps),
        }
    }
}

/// Live members of `worker`'s group: intersection of the group with the
/// (sorted) live contributor set.
fn group_live(groups: &Groups, live: &[usize], gi: usize) -> Vec<usize> {
    groups
        .members(gi)
        .iter()
        .copied()
        .filter(|w| live.binary_search(w).is_ok())
        .collect()
}

/// One boundary-average lane (parameters, or an h/v buffer under
/// `BufferStrategy::Average`): the flat exact average when `hier` is
/// `None`, the two-level reduce otherwise. `lane` is the flat-compatible
/// collective id (`3t + L`) — with a single group the two-level path
/// performs the *identical* operations (same transcode, same ring, same
/// id), so `g = 1` is bitwise flat SlowMo by construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn boundary_average(
    fabric: &Fabric,
    hier: Option<&Groups>,
    worker: usize,
    live: &[usize],
    x: &mut Vec<f32>,
    comp: &mut CompressState,
    mut clock: f64,
    lane: u64,
    codec: Option<&dyn Compressor>,
    site_intra: u64,
    site_leader: u64,
) -> Result<f64> {
    let d = x.len();
    let Some(groups) = hier else {
        // Flat path, operation for operation the pre-hierarchy code: a
        // lone survivor's "average" moves no bytes, so its contribution
        // is not lossily transcoded either.
        if live.len() > 1 {
            if let Some(c) = codec {
                c.transcode(x, comp, site_intra);
            }
        }
        return Ok(ring_allreduce_mean_group_c(
            fabric, worker, live, x, clock, lane, codec,
        ));
    };

    // Stage 1: fast intra-group average over the group's live members
    // (flat-compatible collective id; disjoint groups sharing the id is
    // fine — chunks only travel within a group, per-recipient mailboxes).
    let gi = groups.group_of(worker);
    let gl = group_live(groups, live, gi);
    debug_assert!(gl.binary_search(&worker).is_ok());
    if gl.len() > 1 {
        if let Some(c) = codec {
            c.transcode(x, comp, site_intra);
        }
    }
    clock = ring_allreduce_mean_group_c(
        fabric, worker, &gl, x, clock, lane, codec,
    );

    // Stage 2: inter-group leader reduce. Leaders are the lowest live
    // rank of each group with at least one live member, in group order
    // (ascending — the canonicalized partition keeps leaders sorted).
    let live_groups: Vec<(usize, usize, usize)> = groups
        .all()
        .iter()
        .enumerate()
        .filter_map(|(g, members)| {
            let mut it = members
                .iter()
                .filter(|&&w| live.binary_search(&w).is_ok());
            it.next().map(|&leader| (g, 1 + it.count(), leader))
        })
        .collect();
    let n_lg = live_groups.len();
    if n_lg <= 1 {
        return Ok(clock);
    }
    let total: usize = live_groups.iter().map(|&(_, c, _)| c).sum();
    debug_assert_eq!(total, live.len());
    let my_leader = live_groups
        .iter()
        .find(|&&(g, ..)| g == gi)
        .expect("a live worker's own group is live")
        .2;

    if worker == my_leader {
        // Weight the group mean by |G_live|·g_live / m_live so the leader
        // mean is the exact global mean for unequal (or degraded) groups.
        // Equal live counts give factor == 1.0 exactly — skipped, so the
        // equal-group fast path stays bit-clean.
        let factor = (gl.len() * n_lg) as f32 / total as f32;
        if factor != 1.0 {
            for v in x.iter_mut() {
                *v *= factor;
            }
        }
        // More than one live group (checked above), so the leader ring
        // moves bytes — re-transcode the weighted group mean before it
        // crosses the slow links.
        if let Some(c) = codec {
            c.transcode(x, comp, site_leader);
        }
        let leader_ids: Vec<usize> =
            live_groups.iter().map(|&(.., l)| l).collect();
        clock = ring_allreduce_mean_group_c(
            fabric,
            worker,
            &leader_ids,
            x,
            clock,
            LEADER_COLL_BIT | lane,
            codec,
        );
        // Stage 3: broadcast the global mean (plus the leader clock) back
        // down the fast links. Raw f32 like the rejoin transfer.
        let members: Vec<usize> =
            gl.iter().copied().filter(|&w| w != worker).collect();
        if !members.is_empty() {
            let mut msg = Vec::with_capacity(d + 2);
            msg.extend_from_slice(x);
            msg.extend_from_slice(&clock_to_f32s(clock));
            for &r in &members {
                fabric.chunk_send(worker, r, bcast_tag(lane), msg.clone());
                clock += fabric.cost_for_link(worker, r).xfer_time(d + 2);
            }
        }
    } else {
        let mut payload = fabric.chunk_recv_tag(worker, bcast_tag(lane));
        // A misshaped payload would silently zero-fill the clock and
        // corrupt the parameters — hard error naming worker and lane.
        ensure!(
            payload.len() == d + 2,
            "hierarchical broadcast corrupt at worker {worker}, \
             collective lane {lane}: got {} elems, want {}",
            payload.len(),
            d + 2
        );
        let lo = payload.pop().expect("payload length checked");
        let hi = payload.pop().expect("payload length checked");
        let leader_clock = clock_from_f32s(hi, lo);
        clock = clock.max(leader_clock)
            + fabric.cost_for_link(my_leader, worker).xfer_time(d + 2);
        x.copy_from_slice(&payload);
    }
    Ok(clock)
}

/// The fast intra-group exact average every `tau_inner` inner steps
/// (full group membership — fault windows only change membership at
/// outer boundaries, and the trainer rejects `tau_inner` + faults).
/// Returns the updated clock.
#[allow(clippy::too_many_arguments)]
pub(crate) fn intra_average(
    fabric: &Fabric,
    groups: &Groups,
    worker: usize,
    x: &mut Vec<f32>,
    comp: &mut CompressState,
    clock: f64,
    k: u64,
    codec: Option<&dyn Compressor>,
) -> f64 {
    let members = groups.members(groups.group_of(worker));
    if members.len() > 1 {
        if let Some(c) = codec {
            c.transcode(x, comp, site::INTRA);
        }
    }
    ring_allreduce_mean_group_c(
        fabric,
        worker,
        members,
        x,
        clock,
        INNER_COLL_BIT | k,
        codec,
    )
}

/// Test hook: run one raw two-level reduce lane over `live` (free of the
/// outer-update framing) so the integration property suite can compare
/// the distributed schedule against [`Groups::weighted_mean`].
#[doc(hidden)]
pub fn test_two_level_average(
    fabric: &Fabric,
    groups: &Groups,
    worker: usize,
    live: &[usize],
    x: &mut Vec<f32>,
    comp: &mut CompressState,
) -> Result<f64> {
    boundary_average(
        fabric,
        Some(groups),
        worker,
        live,
        x,
        comp,
        0.0,
        0,
        None,
        site::OUTER,
        site::OUTER_L,
    )
}

/// Which live contributor ships the rejoin `(x0, state)` transfer to
/// `rejoiner`: the lowest live rank in the rejoiner's own group (state is
/// bit-identical everywhere after a boundary, so prefer the fast link),
/// falling back to the globally lowest survivor when the whole group was
/// down. Deterministic — both endpoints compute it independently.
///
/// The semi-sync quorum boundary reuses this with `live` = the quorum
/// ring (sorted worker ids), so a quorum-late worker resyncs from the
/// same shipper a fault-window rejoiner would — the `live`-subset
/// machinery here is agnostic to *which* authority shrank the group.
pub(crate) fn rejoin_shipper(
    hier: Option<&Groups>,
    live: &[usize],
    rejoiner: usize,
) -> usize {
    if let Some(groups) = hier {
        let members = groups.members(groups.group_of(rejoiner));
        if let Some(&s) = members
            .iter()
            .find(|&&w| live.binary_search(&w).is_ok())
        {
            return s;
        }
    }
    live[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_workers;
    use crate::util::allclose;

    #[test]
    fn clock_encoding_round_trips_exactly() {
        for clock in [0.0, 1.5e-3, 123.456789, 9.87654321e7] {
            let [hi, lo] = clock_to_f32s(clock);
            assert_eq!(clock_from_f32s(hi, lo), clock);
        }
    }

    #[test]
    fn hier_cfg_validation_and_inter_cost() {
        assert!(HierCfg::new("2").validate().is_ok());
        assert!(HierCfg::new("2").with_tau_inner(4).validate().is_ok());
        let e = HierCfg::flat("2")
            .with_tau_inner(4)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("tau_inner"), "{e}");
        assert!(HierCfg::new("2")
            .with_inter_link(-1.0, 1e9)
            .validate()
            .is_err());
        assert!(HierCfg::new("2")
            .with_inter_link(1e-3, 0.0)
            .validate()
            .is_err());
        // Spec errors surface through resolve.
        assert!(HierCfg::new("0-3|3-7").resolve(8).is_err());
        assert_eq!(HierCfg::new("2").resolve(8).unwrap().g(), 2);
        // inter_cost defaults to the intra model, overrides apply.
        let intra = CostModel::ethernet_10g();
        let same = HierCfg::new("2").inter_cost(&intra);
        assert_eq!(same.latency_s, intra.latency_s);
        assert_eq!(same.bandwidth_bps, intra.bandwidth_bps);
        let slow =
            HierCfg::new("2").with_inter_link(1e-3, 1e8).inter_cost(&intra);
        assert_eq!(slow.latency_s, 1e-3);
        assert_eq!(slow.bandwidth_bps, 1e8);
    }

    fn run_two_level(
        groups: &Groups,
        live: Vec<usize>,
        xs: Vec<Vec<f32>>,
    ) -> Vec<(Vec<f32>, f64)> {
        let m = groups.m();
        let fabric = Fabric::new(m, CostModel::free());
        run_workers(m, |w| {
            let mut x = xs[w].clone();
            let mut comp = CompressState::default();
            let mut clock = 0.0;
            if live.binary_search(&w).is_ok() {
                clock = boundary_average(
                    &fabric,
                    Some(groups),
                    w,
                    &live,
                    &mut x,
                    &mut comp,
                    0.0,
                    0,
                    None,
                    site::OUTER,
                    site::OUTER_L,
                )
                .unwrap();
            }
            (x, clock)
        })
    }

    #[test]
    fn two_level_reduce_recovers_global_mean() {
        // Unequal groups: every live worker ends with the weighted global
        // mean, bit-identical across workers.
        let m = 7;
        let groups = Groups::parse("0|1-3|4-6", m).unwrap();
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..9).map(|i| (w * 9 + i) as f32 * 0.01).collect())
            .collect();
        let want = groups.weighted_mean(&xs);
        let live: Vec<usize> = (0..m).collect();
        let out = run_two_level(&groups, live, xs.clone());
        for (w, (x, _)) in out.iter().enumerate() {
            assert!(allclose(x, &want, 1e-5, 1e-6), "worker {w}");
            assert_eq!(*x, out[0].0, "workers must agree bitwise");
        }
        // And it is the true global mean up to f32 rounding.
        for i in 0..9 {
            let g: f64 = (0..m).map(|w| f64::from(xs[w][i])).sum::<f64>()
                / m as f64;
            assert!((f64::from(want[i]) - g).abs() < 1e-5);
        }
    }

    #[test]
    fn two_level_reduce_survivor_weighting() {
        // Worker 3 of group {2,3} is dead: the global mean is over the
        // three survivors, weighted 2:1 across groups.
        let m = 4;
        let groups = Groups::parse("0-1|2-3", m).unwrap();
        let xs: Vec<Vec<f32>> =
            (0..m).map(|w| vec![w as f32; 5]).collect();
        let live = vec![0usize, 1, 2];
        let out = run_two_level(&groups, live, xs);
        let want = (0.0 + 1.0 + 2.0) / 3.0;
        for &w in &[0usize, 1, 2] {
            for &v in &out[w].0 {
                assert!((v - want).abs() < 1e-6, "worker {w}: {v}");
            }
        }
        // The dead worker's parameters are untouched.
        assert_eq!(out[3].0, vec![3.0; 5]);
    }

    #[test]
    fn single_group_is_the_flat_path_bitwise() {
        // g=1: stage 1 covers everyone with the flat collective id and
        // the leader stage is a no-op — identical bits and identical
        // clock to the hier=None path.
        let m = 4;
        let groups = Groups::flat(m);
        let cost = CostModel { latency_s: 1e-4, bandwidth_bps: 1e7 };
        let live: Vec<usize> = (0..m).collect();
        let mk = |hier: Option<&Groups>| {
            let fabric = Fabric::new(m, cost.clone());
            run_workers(m, |w| {
                let mut x: Vec<f32> =
                    (0..13).map(|i| (w * 13 + i) as f32 * 0.1).collect();
                let mut comp = CompressState::default();
                let clock = boundary_average(
                    &fabric, hier, w, &live, &mut x, &mut comp, 0.0, 3,
                    None, site::OUTER, site::OUTER_L,
                )
                .unwrap();
                (x, clock)
            })
        };
        assert_eq!(mk(Some(&groups)), mk(None));
    }

    #[test]
    fn broadcast_carries_leader_clock_causality() {
        // Non-free network: a member whose own clock is stale must land
        // after the leader's post-reduce clock plus the broadcast hop.
        let m = 4;
        let groups = Groups::parse("0-1|2-3", m).unwrap();
        let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let fabric = Fabric::new(m, cost.clone());
        let live: Vec<usize> = (0..m).collect();
        let out = run_workers(m, |w| {
            let mut x = vec![w as f32; 8];
            let mut comp = CompressState::default();
            // Leaders (0, 2) enter late; members (1, 3) at 0.
            let start = if w % 2 == 0 { 5.0 } else { 0.0 };
            boundary_average(
                &fabric, Some(&groups), w, &live, &mut x, &mut comp,
                start, 0, None, site::OUTER, site::OUTER_L,
            )
            .unwrap()
        });
        for &member in &[1usize, 3] {
            assert!(
                out[member] > 5.0,
                "member {member} clock {} ignores leader causality",
                out[member]
            );
            assert!(out[member] >= out[member - 1]);
        }
    }

    #[test]
    fn rejoin_shipper_prefers_own_group() {
        let groups = Groups::parse("0-1|2-3", 4).unwrap();
        // Worker 3 rejoins; its group-mate 2 is live -> 2 ships.
        assert_eq!(rejoin_shipper(Some(&groups), &[0, 1, 2], 3), 2);
        // Whole group down -> global lowest survivor ships.
        assert_eq!(rejoin_shipper(Some(&groups), &[0, 1], 3), 0);
        // Flat: always the lowest survivor.
        assert_eq!(rejoin_shipper(None, &[1, 2], 3), 1);
    }
}
