//! The SlowMo outer-loop controller (paper Algorithm 1).
//!
//! Wraps any [`BaseAlgorithm`]: after every τ inner steps it
//! (1) exact-averages worker parameters with the ring allreduce (line 6),
//! (2) applies the configured [`OuterOpt`] update rule (lines 7–8 for the
//! default slow-momentum rule, through the Layer-1 `slowmo_update`
//! kernel), and (3) applies the configured base-optimizer buffer strategy
//! (line 2; App. B.4).
//!
//! [`outer_update`] is the *framework shell*: boundary membership, the
//! exact average, elastic rejoin state transfer and the buffer strategy.
//! The update rule itself — and the state it owns — is pluggable through
//! the [`outer`] module's [`OuterOpt`] trait and string-keyed
//! [`OuterRegistry`] (`slowmo`, `avg`, `lookahead`, `nesterov`, `adam`,
//! plus out-of-crate registrations).
//!
//! Framework special cases (all covered by tests):
//! - α=1, β=0, base=Local  → Local SGD (also the `avg` outer rule)
//! - β>0, base=Local       → BMUF
//! - τ=1, α=1, β=0         → AR-SGD (up to gradient- vs param-averaging)
//! - m=1, β=0, α∈(0,1]     → Lookahead (also the `lookahead` outer rule)
//! - `exact_average=false` → SGP-SlowMo-noaverage (paper §6)

pub mod hier;
pub mod outer;

pub use hier::HierCfg;
pub use outer::{
    AdamRule, AvgRule, LookaheadRule, NesterovRule, OuterOpt, OuterOptState,
    OuterRegistry, OuterSel, SlowMoRule,
};

use crate::algorithms::{BaseAlgorithm, WorkerState};
use crate::compress::{site, Compressor};
use crate::net::{ChaosPlan, Fabric};
use crate::optim::kernels::Kernels;
use crate::topology::TierTree;
use crate::util::CowVec;
use anyhow::{ensure, Result};
use hier::{clock_from_f32s, clock_to_f32s};

/// Chunk-lane tags for the rejoin state transfer at boundary `t`. Bit 63
/// separates them from collective tags (`coll_id << 32 | round`, with
/// coll_id < 2^31), and the boundary index keeps transfers at different
/// boundaries distinct, so [`Fabric::chunk_recv_tag`] routes them
/// correctly even when ring chunks from a fast neighbor's next collective
/// arrive first.
const REJOIN_FLAG: u64 = 1 << 63;

fn rejoin_tags(t: u64) -> (u64, u64) {
    (REJOIN_FLAG | (t << 1), REJOIN_FLAG | (t << 1) | 1)
}

/// Chunk-lane tags for the semi-synchronous boundary machinery: arrival
/// stamps, stale-contribution folds and the folded-mean broadcast at
/// boundary `t`. Bits 63+62 together keep them clear of both collective
/// tags (bit 63 never set) and rejoin tags (bit 63 alone); the sender id
/// keeps same-boundary messages from different peers distinct.
const SEMISYNC_FLAG: u64 = (1 << 63) | (1 << 62);

fn stamp_tag(t: u64, from: usize) -> u64 {
    SEMISYNC_FLAG | (t << 18) | ((from as u64) << 2)
}

fn fold_tag(t: u64, from: usize) -> u64 {
    SEMISYNC_FLAG | (t << 18) | ((from as u64) << 2) | 1
}

fn foldb_tag(t: u64) -> u64 {
    SEMISYNC_FLAG | (t << 18) | 2
}

/// Down-weight λ applied to a stale (one-boundary-old) contribution when
/// it is folded into the next boundary's quorum average:
/// `x' = (|Q|·x̄ + λ·Σ x̃_j) / (|Q| + λ·k)`. Exposed so tests and
/// harnesses can compute the reference fold serially.
pub const STALE_LAMBDA: f32 = 0.5;

/// How base-optimizer buffers are treated at each outer boundary
/// (paper Alg. 1 line 2; App. B.4 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferStrategy {
    /// Zero momentum buffers, restart the Adam counter. Paper default for
    /// Nesterov-SGD bases (CIFAR/ImageNet).
    Reset,
    /// Keep buffers. Paper default for Adam bases (WMT).
    Maintain,
    /// ALLREDUCE-average buffers across workers (extra communication).
    Average,
}

impl std::str::FromStr for BufferStrategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "reset" => Ok(Self::Reset),
            "maintain" => Ok(Self::Maintain),
            "average" => Ok(Self::Average),
            other => Err(format!(
                "unknown buffer strategy {other:?} \
                 (expected reset|maintain|average)"
            )),
        }
    }
}

impl BufferStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Reset => "reset",
            Self::Maintain => "maintain",
            Self::Average => "average",
        }
    }
}

/// Outer-loop configuration: which [`OuterOpt`] rule runs at boundaries
/// (by registry selection), how often, and how the framework shell treats
/// base-optimizer buffers and the exact average.
#[derive(Clone, Debug)]
pub struct SlowMoCfg {
    /// Outer update rule, as a registry selection (key + args). Resolved
    /// against the session's [`OuterRegistry`] when the run starts.
    pub outer: OuterSel,
    /// Inner steps per outer iteration τ.
    pub tau: u64,
    pub buffers: BufferStrategy,
    /// `false` = skip line 6 (SGP-SlowMo-noaverage, §6).
    pub exact_average: bool,
    /// Semi-synchronous boundary quorum: with `Some(q)`, `q < m`, the
    /// outer average proceeds over the `q` earliest boundary arrivals
    /// (by arrival stamp, worker id breaking ties) and later workers are
    /// handled per `staleness`. `None` — or `q >= m` — is the blocking
    /// barrier. Sim-only when effective; validated at run start.
    pub quorum: Option<usize>,
    /// Bounded staleness for quorum-late contributions: `0` drops them
    /// (elastic fault-window semantics — the late worker freezes one
    /// round, then resyncs by pulling the fresh outer state), `>= 1`
    /// additionally folds the stale contribution into the next
    /// boundary's average, down-weighted by [`STALE_LAMBDA`]. The
    /// lockstep boundary schedule never produces an age above 1, so
    /// every `s >= 1` behaves identically; the knob bounds the accepted
    /// age.
    pub staleness: u64,
}

impl SlowMoCfg {
    /// The paper's slow-momentum rule — a thin alias for
    /// `outer = slowmo:<beta>[,<alpha>]` (α=1, the paper's setting, is
    /// omitted from the spec).
    ///
    /// Invalid values (τ=0) are *not* rejected here: validation surfaces
    /// as an `Err` when the run is built (`TrainBuilder::run`/`build_cfg`
    /// and `Session::run`), matching the TOML config path, instead of
    /// aborting the process.
    pub fn new(alpha: f32, beta: f32, tau: u64) -> Self {
        Self::with_outer(OuterSel::slowmo(alpha, beta), tau)
    }

    /// Any registered outer rule.
    pub fn with_outer(outer: OuterSel, tau: u64) -> Self {
        Self {
            outer,
            tau,
            buffers: BufferStrategy::Reset,
            exact_average: true,
            quorum: None,
            staleness: 0,
        }
    }

    pub fn with_buffers(mut self, b: BufferStrategy) -> Self {
        self.buffers = b;
        self
    }

    pub fn no_average(mut self) -> Self {
        self.exact_average = false;
        self
    }

    /// Semi-synchronous boundary quorum (see the `quorum` field).
    pub fn with_quorum(mut self, q: usize) -> Self {
        self.quorum = Some(q);
        self
    }

    /// Bounded staleness for quorum-late contributions (see the
    /// `staleness` field).
    pub fn with_staleness(mut self, s: u64) -> Self {
        self.staleness = s;
        self
    }

    /// Structural validation (run before any boundary arithmetic).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.tau >= 1,
            "slowmo tau must be >= 1 (got {})",
            self.tau
        );
        if let Some(q) = self.quorum {
            ensure!(q >= 1, "slowmo quorum must be >= 1 (got {q})");
            ensure!(
                self.exact_average,
                "slowmo quorum requires the exact average (the quorum \
                 gates the boundary collective; noaverage has no \
                 barrier to relax)"
            );
        } else {
            ensure!(
                self.staleness == 0,
                "slowmo staleness requires a quorum (it bounds the age \
                 of quorum-late contributions; got staleness {} with no \
                 quorum)",
                self.staleness
            );
        }
        Ok(())
    }

    /// Is `k+1` (1-based step count) an outer boundary?
    pub fn is_boundary(&self, k: u64) -> bool {
        (k + 1) % self.tau == 0
    }
}

/// Per-worker outer-loop state: the outer iterate x_{t,0} plus whatever
/// buffers the configured [`OuterOpt`] rule owns (the slow momentum u for
/// the default rule; two moments for outer Adam; nothing for `avg` /
/// `lookahead`). After every exact average these are identical across
/// workers (paper's "always synchronized" invariant — asserted in tests);
/// under the noaverage variant they may drift.
#[derive(Clone, Debug)]
pub struct OuterState {
    /// The outer iterate. Copy-on-write: under the shared-state trainer
    /// mode every worker's x0 starts as a view of one shared init vector
    /// and materializes privately at its first outer step
    /// ([`OuterState::new_shared`]); the dense path owns it outright.
    pub x0: CowVec,
    /// Rule-owned state buffers (shape decided by [`OuterOpt::init`]).
    pub opt: OuterOptState,
    /// Outer iterations completed.
    pub t: u64,
    /// Semi-sync: did this worker miss the previous boundary's quorum?
    /// (It resyncs — pulls the fresh outer state — at the next one.)
    pub late: bool,
    /// Semi-sync: stale contribution snapshot awaiting the next-boundary
    /// fold (`staleness >= 1` only).
    pub pending: Option<Vec<f32>>,
    /// Semi-sync: ring size at the last boundary this worker observed
    /// (0 = none yet, i.e. the rule state is still all-zero) — replaces
    /// the chaos plan's static contributor-count bookkeeping when the
    /// quorum decides membership dynamically.
    pub prev_ring: usize,
    /// Boundaries where this worker missed the quorum.
    pub quorum_misses: u64,
    /// Stale contributions of this worker folded at a later boundary.
    pub stale_folds: u64,
    /// Reusable per-boundary staging buffer (pending snapshots, the
    /// stale-fold accumulator). Pre-sized to `d + 2` at first use so the
    /// packed-clock append never reallocates; always empty between
    /// boundaries — it never holds live data.
    pub staging: Vec<f32>,
}

impl OuterState {
    pub fn new(init: &[f32], rule: &dyn OuterOpt) -> Self {
        Self::with_x0(CowVec::owned(init.to_vec()), init.len(), rule)
    }

    /// Shared-state mode: x0 views `init` (one allocation for all `m`
    /// workers) until the first outer update writes it. Bitwise-identical
    /// to [`Self::new`] in every computation — only the representation
    /// (and therefore peak RSS) differs.
    pub fn new_shared(
        init: std::sync::Arc<Vec<f32>>,
        rule: &dyn OuterOpt,
    ) -> Self {
        let d = init.len();
        Self::with_x0(CowVec::shared(init), d, rule)
    }

    fn with_x0(x0: CowVec, d: usize, rule: &dyn OuterOpt) -> Self {
        Self {
            x0,
            opt: rule.init(d),
            t: 0,
            late: false,
            pending: None,
            prev_ring: 0,
            quorum_misses: 0,
            stale_folds: 0,
            staging: Vec::new(),
        }
    }

    /// The slow-momentum buffer, for rules carrying exactly one state
    /// buffer (test/inspection convenience; panics otherwise).
    pub fn u(&self) -> &Vec<f32> {
        assert_eq!(self.opt.bufs.len(), 1, "rule has no single u buffer");
        &self.opt.bufs[0]
    }
}

/// Execute one outer boundary (paper Alg. 1 lines 6–8 + line 2 for the
/// next iteration) for `worker`: the framework shell around the pluggable
/// [`OuterOpt`] `rule`. Must be called by all workers concurrently when
/// `exact_average` or `buffers == Average` (collectives).
///
/// `gamma` is the fast learning rate γ_t used during the inner loop.
/// Returns the updated simulated clock.
///
/// With a [`ChaosPlan`], membership is elastic: a worker whose fault
/// window covers this boundary is excluded (the ring collective is
/// rebuilt over survivors and the rule's state is rescaled via
/// [`OuterOpt::scale_state`] by the live-count ratio); at its first live
/// boundary after an outage the worker rejoins by pulling the
/// freshly-updated `(x0, state)` from the lowest-ranked survivor — its
/// local progress during the outage is lost, like a real node restart.
#[allow(clippy::too_many_arguments)]
pub fn outer_update(
    cfg: &SlowMoCfg,
    rule: &dyn OuterOpt,
    algo: &dyn BaseAlgorithm,
    fabric: &Fabric,
    kernels: &Kernels,
    worker: usize,
    state: &mut WorkerState,
    outer: &mut OuterState,
    gamma: f32,
    clock: f64,
    chaos: Option<&ChaosPlan>,
) -> Result<f64> {
    outer_update_c(
        cfg, rule, algo, fabric, kernels, worker, state, outer, gamma,
        clock, chaos, None,
    )
}

/// [`outer_update`] with communication compression: the worker's
/// contribution to the exact average is transcoded (error-feedback
/// residual at [`site::OUTER`], kept in `state.comp`) before entering
/// the ring collective, and the collective charges compressed wire
/// bytes. The codec's residual buffers register with the elastic
/// membership machinery exactly like [`OuterOpt`] state: they rescale by
/// the live-count ratio at membership changes and ride the rejoin state
/// transfer ([`Compressor::ef_bufs`] buffers appended after the rule's,
/// same state-shape-agnostic wire format).
#[allow(clippy::too_many_arguments)]
pub fn outer_update_c(
    cfg: &SlowMoCfg,
    rule: &dyn OuterOpt,
    algo: &dyn BaseAlgorithm,
    fabric: &Fabric,
    kernels: &Kernels,
    worker: usize,
    state: &mut WorkerState,
    outer: &mut OuterState,
    gamma: f32,
    clock: f64,
    chaos: Option<&ChaosPlan>,
    codec: Option<&dyn Compressor>,
) -> Result<f64> {
    outer_update_g(
        cfg, rule, algo, fabric, kernels, worker, state, outer, gamma,
        clock, chaos, None, codec,
    )
}

/// [`outer_update_c`] with hierarchical topology: when a [`TierTree`] is
/// given, line 6's exact average becomes the N-level reduce of
/// [`hier::boundary_average_tree`] (leaf-group rings, a ladder of leader
/// rings weighted for unequal subtrees, cascading broadcasts back down),
/// and the rejoin transfer ships from the rejoiner's own leaf group when
/// possible. `hier = None` is bitwise-identical to the flat path, and a
/// depth-1 tree (one [`crate::topology::Groups`] partition — the
/// historical two-level hierarchy) to the two-level reduce. Elastic
/// membership, `scale_state` and the rejoin wire format all work per
/// group — the outer state is bit-synchronized across every live worker
/// after each boundary, exactly as in the flat algorithm.
#[allow(clippy::too_many_arguments)]
pub fn outer_update_g(
    cfg: &SlowMoCfg,
    rule: &dyn OuterOpt,
    algo: &dyn BaseAlgorithm,
    fabric: &Fabric,
    kernels: &Kernels,
    worker: usize,
    state: &mut WorkerState,
    outer: &mut OuterState,
    gamma: f32,
    mut clock: f64,
    chaos: Option<&ChaosPlan>,
    hier: Option<&TierTree>,
    codec: Option<&dyn Compressor>,
) -> Result<f64> {
    // Leaf partition for the per-group helpers (rejoin shipping).
    let leaf = hier.map(|t| t.leaf().as_ref());
    let codec = codec.filter(|c| !c.is_identity());
    let t = outer.t;
    let d = state.x.len();
    let ef_bufs = codec.map(|c| c.ef_bufs()).unwrap_or(0);
    // Rejoin wire format, rule- and codec-agnostic: message 1 is x0 (d
    // elems), message 2 is every rule state buffer, then every codec
    // error-feedback buffer, concatenated, plus the packed leader clock
    // ((n_bufs + ef_bufs)*d + 2 elems).
    let state_msg_len = (rule.n_bufs() + ef_bufs) * d + 2;
    if let Some(plan) = chaos {
        if plan.down(worker, t) {
            // Mid-outage: excluded from the collective; the outer state
            // freezes until the rejoin boundary overwrites it.
            outer.t += 1;
            return Ok(clock);
        }
        if plan.is_rejoiner(worker, t) {
            // Rejoin by pulling the post-update outer state from the
            // shipper (the lowest live rank in this worker's group under
            // hierarchy — post-boundary state is bit-identical everywhere,
            // so prefer the fast link — else the lowest-ranked
            // contributor).
            let shipper =
                hier::rejoin_shipper(leaf, &plan.contributors(t), worker);
            return pull_rejoin_state(
                rule, fabric, worker, shipper, state, outer, clock, codec,
            );
        }
    }
    let group: Vec<usize> = match chaos {
        Some(plan) => plan.contributors(t),
        None => (0..fabric.m()).collect(),
    };

    // Semi-synchronous quorum: with `quorum = Some(q)`, q < m, the
    // boundary proceeds over the q earliest arrivals and everyone else
    // is "late" — dropped-and-rescaled (staleness 0, the elastic
    // fault-window semantics) or folded into the next boundary's average
    // (staleness >= 1). Fault windows and quorum are mutually exclusive
    // (validated at run start), so under semisync `group` is always the
    // full worker set.
    let semisync = cfg.quorum.is_some_and(|q| q < fabric.m());
    // The workers entering this boundary's collectives.
    let mut ring = group.clone();
    // Quorum-late-at-(t-1) workers resyncing now: they pull state like
    // fault-window rejoiners (and, with staleness >= 1, first ship their
    // stale contribution to the collector for the fold).
    let mut resyncers: Vec<usize> = Vec::new();
    let barrier =
        cfg.exact_average || cfg.buffers == BufferStrategy::Average;
    if barrier && group.len() > 1 {
        // Boundary arrival stamps (control plane, uncharged). Everyone
        // needs them: a synchronous collective cannot complete before
        // its last member arrives, so blocking participants charge the
        // max arrival stamp; under semisync the stamps select the quorum
        // deterministically on every participant.
        let stamps =
            exchange_stamps(fabric, worker, &group, t, clock, outer.late)?;
        if semisync {
            resyncers = stamps
                .iter()
                .filter(|s| s.late)
                .map(|s| s.worker)
                .collect();
            let mut cand: Vec<&Stamp> =
                stamps.iter().filter(|s| !s.late).collect();
            cand.sort_by(|a, b| {
                a.clock.total_cmp(&b.clock).then(a.worker.cmp(&b.worker))
            });
            let q = cfg.quorum.unwrap_or(usize::MAX).min(cand.len());
            ring = cand[..q].iter().map(|s| s.worker).collect();
            ring.sort_unstable();
        }
        if ring.contains(&worker) {
            // The collective's entry time is its slowest member's
            // arrival (satellite audit: late arrivals previously charged
            // only their own clock, understating the barrier).
            clock = stamps
                .iter()
                .filter(|s| ring.contains(&s.worker))
                .fold(clock, |c, s| c.max(s.clock));
        }
    }
    if semisync {
        let n_ring = ring.len();
        if outer.late {
            // I missed the previous boundary's quorum. With staleness
            // >= 1 my frozen snapshot still joins this boundary's
            // average (shipped to the collector, charged honestly);
            // either way I resync by pulling the fresh outer state.
            outer.late = false;
            if let Some(snap) = outer.pending.take() {
                let collector = ring[0];
                let link = fabric.cost_for_link(worker, collector);
                let mut msg = snap;
                msg.extend_from_slice(&clock_to_f32s(clock));
                fabric.chunk_send(
                    worker,
                    collector,
                    fold_tag(t, worker),
                    msg,
                );
                clock += link.xfer_time(d + 2);
                outer.stale_folds += 1;
            }
            outer.prev_ring = n_ring;
            let shipper = hier::rejoin_shipper(leaf, &ring, worker);
            return pull_rejoin_state(
                rule, fabric, worker, shipper, state, outer, clock, codec,
            );
        }
        if !ring.contains(&worker) {
            // Late this boundary: the ring proceeds without me; I freeze
            // (keeping my own clock — semisync's whole point) and resync
            // next boundary. staleness >= 1 keeps the contribution for
            // the fold instead of dropping it.
            outer.quorum_misses += 1;
            outer.late = true;
            if cfg.staleness >= 1 {
                // Snapshot into the staging buffer (capacity d + 2 so
                // the resync send can append the packed clock without
                // reallocating) — bitwise-identical to a fresh clone.
                let mut snap = std::mem::take(&mut outer.staging);
                snap.clear();
                snap.reserve(d + 2);
                snap.extend_from_slice(&state.x);
                outer.pending = Some(snap);
            }
            outer.prev_ring = n_ring;
            outer.t += 1;
            return Ok(clock);
        }
    }

    // Line 6: exact average x_{t,tau} over the live group (skip for the
    // noaverage variant) — flat ring, or the hierarchical two-level
    // reduce when a partition is installed. coll_ids 3t..3t+2 key the
    // chaos delay streams (leader-stage rings add their own id bit).
    // With a codec the worker's contribution is lossily transcoded first
    // (EF residual at site::OUTER; leader stages re-transcode at their
    // own sites), and every ring charges compressed bytes.
    // A lone survivor's "average" moves no bytes, so its contribution is
    // not lossily transcoded either (codec itself stays active: the
    // rejoin wire format and residual rescaling are group-size
    // independent).
    if cfg.exact_average {
        {
            let WorkerState { x, comp, .. } = state;
            clock = hier::boundary_average_tree(
                fabric,
                hier,
                worker,
                &ring,
                x,
                comp,
                clock,
                3 * t,
                codec,
                site::OUTER,
                site::OUTER_L,
            )?;
        }
        algo.on_exact_average(state);
    }

    // Bounded-staleness fold: each resyncer shipped its boundary-(t-1)
    // contribution; the collector (lowest ring rank) down-weights those
    // into the fresh ring mean —
    //   x' = (|Q|·x̄ + λ·Σ x̃_j) / (|Q| + λ·k),  λ = STALE_LAMBDA —
    // then re-broadcasts the folded mean (packed-clock payload, the
    // leader-broadcast causality rule) so the ring stays
    // bit-synchronized.
    if cfg.exact_average && cfg.staleness >= 1 && !resyncers.is_empty() {
        let collector = ring[0];
        if worker == collector {
            let qn = ring.len() as f32;
            // Fold accumulator lives in the staging buffer — reused
            // across boundaries, returned below before the broadcast.
            let mut acc = std::mem::take(&mut outer.staging);
            acc.clear();
            acc.reserve(d);
            acc.extend(state.x.iter().map(|&v| v * qn));
            let mut weight = qn;
            for &r in &resyncers {
                let mut payload =
                    fabric.chunk_recv_tag(worker, fold_tag(t, r));
                ensure!(
                    payload.len() == d + 2,
                    "stale fold payload corrupt at worker {worker}, \
                     outer boundary {t}: got {} elems from worker {r}, \
                     want {}",
                    payload.len(),
                    d + 2
                );
                let lo = payload.pop().expect("fold length checked");
                let hi = payload.pop().expect("fold length checked");
                let link = fabric.cost_for_link(r, worker);
                clock = clock.max(clock_from_f32s(hi, lo))
                    + link.xfer_time(d + 2);
                for (a, v) in acc.iter_mut().zip(&payload) {
                    *a += STALE_LAMBDA * v;
                }
                weight += STALE_LAMBDA;
            }
            for (x, a) in state.x.iter_mut().zip(&acc) {
                *x = a / weight;
            }
            outer.staging = acc;
            let mut msg = Vec::with_capacity(d + 2);
            msg.extend_from_slice(&state.x);
            msg.extend_from_slice(&clock_to_f32s(clock));
            for &r in &ring[1..] {
                fabric.chunk_send(worker, r, foldb_tag(t), msg.clone());
                clock +=
                    fabric.cost_for_link(worker, r).xfer_time(d + 2);
            }
        } else {
            let mut msg = fabric.chunk_recv_tag(worker, foldb_tag(t));
            ensure!(
                msg.len() == d + 2,
                "folded-mean broadcast corrupt at worker {worker}, \
                 outer boundary {t}: got {} elems, want {}",
                msg.len(),
                d + 2
            );
            let lo = msg.pop().expect("broadcast length checked");
            let hi = msg.pop().expect("broadcast length checked");
            let link = fabric.cost_for_link(collector, worker);
            clock = clock.max(clock_from_f32s(hi, lo))
                + link.xfer_time(d + 2);
            state.x.copy_from_slice(&msg);
        }
    }

    // Elastic membership: the rule state (and any codec residuals)
    // aggregate displacement mass over the ring; rescale by the
    // live-count ratio when membership changed since the previous
    // boundary. Under semisync the quorum decides membership, so the
    // previous ring size is the per-worker bookkeeping from the stamp
    // exchange (prev_ring == 0 means no boundary observed yet — the
    // rule state is still all-zero, nothing to rescale); otherwise it
    // is the chaos plan's static contributor count.
    if semisync {
        let live = ring.len();
        let prev = outer.prev_ring;
        if prev != 0 && live != prev {
            let factor = live as f32 / prev as f32;
            rule.scale_state(&mut outer.opt, factor);
            if codec.is_some() {
                state.comp.scale_residuals(factor);
            }
        }
        outer.prev_ring = live;
    } else if let Some(plan) = chaos {
        let live = group.len();
        let prev = plan.contributor_count_before(t);
        if live != prev {
            let factor = live as f32 / prev as f32;
            rule.scale_state(&mut outer.opt, factor);
            if codec.is_some() {
                state.comp.scale_residuals(factor);
            }
        }
    }

    // Lines 7-8: the pluggable outer update (fused L1 kernels), in place.
    // First write to a shared x0 materializes the private copy here.
    rule.step(outer.x0.make_mut(), &state.x, &mut outer.opt, gamma, t,
              kernels)?;

    // Adopt the new outer iterate as the inner starting point (the
    // de-bias mirror z is elided under the lean layout: x IS z there).
    state.x.copy_from_slice(&outer.x0);
    state.w = 1.0;
    if !state.z.is_empty() {
        state.z.copy_from_slice(&state.x);
    }

    // Ship the fresh outer state to any workers rejoining right now —
    // static fault-window rejoiners, or quorum-late workers resyncing
    // (under hierarchy, each pulls from its own group's lowest live
    // rank when one exists — the fast link).
    let rejoining: Vec<usize> = if semisync {
        resyncers
    } else if let Some(plan) = chaos {
        plan.rejoiners(t)
    } else {
        Vec::new()
    };
    {
        let mine: Vec<usize> = rejoining
            .into_iter()
            .filter(|&r| hier::rejoin_shipper(leaf, &ring, r) == worker)
            .collect();
        if !mine.is_empty() {
            let (tag_x, tag_u) = rejoin_tags(t);
            let mut msg = Vec::with_capacity(state_msg_len);
            for buf in &outer.opt.bufs {
                msg.extend_from_slice(buf);
            }
            if let Some(c) = codec {
                for buf in c.rejoin_state(&state.comp, d) {
                    msg.extend_from_slice(&buf);
                }
            }
            msg.extend_from_slice(&clock_to_f32s(clock));
            debug_assert_eq!(msg.len(), state_msg_len);
            for &r in &mine {
                fabric.chunk_send(worker, r, tag_x, outer.x0.to_vec());
                fabric.chunk_send(worker, r, tag_u, msg.clone());
            }
            clock += mine
                .iter()
                .map(|&r| {
                    let link = fabric.cost_for_link(worker, r);
                    link.xfer_time(d) + link.xfer_time(state_msg_len)
                })
                .sum::<f64>();
        }
    }

    // Line 2 (for the next outer iteration): buffer strategy.
    match cfg.buffers {
        BufferStrategy::Reset => state.reset_buffers(),
        BufferStrategy::Maintain => {}
        BufferStrategy::Average => {
            {
                let WorkerState { h, comp, .. } = state;
                clock = hier::boundary_average_tree(
                    fabric,
                    hier,
                    worker,
                    &ring,
                    h,
                    comp,
                    clock,
                    3 * t + 1,
                    codec,
                    site::OUTER_H,
                    site::OUTER_LH,
                )?;
            }
            if !state.v.is_empty() {
                let WorkerState { v, comp, .. } = state;
                clock = hier::boundary_average_tree(
                    fabric,
                    hier,
                    worker,
                    &ring,
                    v,
                    comp,
                    clock,
                    3 * t + 2,
                    codec,
                    site::OUTER_V,
                    site::OUTER_LV,
                )?;
            }
        }
    }
    outer.t += 1;
    Ok(clock)
}

/// Rejoin by pulling the post-update `(x0, rule state, codec residuals)`
/// from `shipper` at boundary `outer.t` — the wire format shared by
/// static fault-window rejoiners and quorum-late resyncers (whose
/// previous boundary froze them the same way). The state payload carries
/// the shipper's clock in its last two slots; the state cannot arrive
/// before the shipper finished computing it.
#[allow(clippy::too_many_arguments)]
fn pull_rejoin_state(
    rule: &dyn OuterOpt,
    fabric: &Fabric,
    worker: usize,
    shipper: usize,
    state: &mut WorkerState,
    outer: &mut OuterState,
    mut clock: f64,
    codec: Option<&dyn Compressor>,
) -> Result<f64> {
    let t = outer.t;
    let d = state.x.len();
    let ef_bufs = codec.map(|c| c.ef_bufs()).unwrap_or(0);
    let state_msg_len = (rule.n_bufs() + ef_bufs) * d + 2;
    let (tag_x, tag_u) = rejoin_tags(t);
    let x0 = fabric.chunk_recv_tag(worker, tag_x);
    let mut payload = fabric.chunk_recv_tag(worker, tag_u);
    // A short (or otherwise misshaped) payload would silently
    // zero-fill the clock and corrupt the rule state — hard error
    // instead, naming the worker and boundary.
    ensure!(
        x0.len() == d && payload.len() == state_msg_len,
        "rejoin state transfer corrupt at worker {worker}, outer \
         boundary {t}: got x0 {} / state {} elems, want {d} / {} \
         (outer rule {:?} carries {} buffer(s), compressor {} \
         error-feedback buffer(s))",
        x0.len(),
        payload.len(),
        state_msg_len,
        rule.key(),
        rule.n_bufs(),
        ef_bufs
    );
    let lo = payload.pop().expect("payload length checked");
    let hi = payload.pop().expect("payload length checked");
    let leader_clock = clock_from_f32s(hi, lo);
    let link = fabric.cost_for_link(shipper, worker);
    clock = clock.max(leader_clock)
        + link.xfer_time(d)
        + link.xfer_time(state_msg_len);
    outer.x0 = CowVec::owned(x0);
    for (i, buf) in outer.opt.bufs.iter_mut().enumerate() {
        buf.copy_from_slice(&payload[i * d..(i + 1) * d]);
    }
    if let Some(c) = codec {
        // Residuals from before the outage are stale (they missed
        // every membership rescale) — drop them all, then install
        // what the leader shipped.
        state.comp.clear_residuals();
        let base = rule.n_bufs() * d;
        let views: Vec<&[f32]> = (0..ef_bufs)
            .map(|i| &payload[base + i * d..base + (i + 1) * d])
            .collect();
        c.install_rejoin_state(&mut state.comp, &views);
    }
    state.x.copy_from_slice(&outer.x0);
    state.w = 1.0;
    if !state.z.is_empty() {
        state.z.copy_from_slice(&state.x);
    }
    // Buffers from before the outage are stale — always reset.
    state.reset_buffers();
    outer.t += 1;
    Ok(clock)
}

/// One worker's boundary-arrival stamp (control plane).
struct Stamp {
    worker: usize,
    clock: f64,
    /// Set when the sender missed the previous boundary's quorum and is
    /// resyncing now (excluded from quorum candidacy this round).
    late: bool,
}

/// All-to-all exchange of boundary-arrival stamps among `group`: 12-byte
/// control messages, charged neither bytes nor simulated time — the data
/// transfers that follow already pay for the barrier the stamps
/// establish. Returns one stamp per group member, in group order.
fn exchange_stamps(
    fabric: &Fabric,
    worker: usize,
    group: &[usize],
    t: u64,
    clock: f64,
    late: bool,
) -> Result<Vec<Stamp>> {
    let [hi, lo] = clock_to_f32s(clock);
    let flag = if late { 1.0 } else { 0.0 };
    for &peer in group {
        if peer != worker {
            fabric.chunk_send_ctrl(
                worker,
                peer,
                stamp_tag(t, worker),
                vec![hi, lo, flag],
            );
        }
    }
    group
        .iter()
        .map(|&peer| {
            if peer == worker {
                return Ok(Stamp { worker: peer, clock, late });
            }
            let msg = fabric.chunk_recv_tag(worker, stamp_tag(t, peer));
            ensure!(
                msg.len() == 3,
                "arrival stamp corrupt at worker {worker}, outer \
                 boundary {t}: got {} elems from worker {peer}, want 3",
                msg.len()
            );
            Ok(Stamp {
                worker: peer,
                clock: clock_from_f32s(msg[0], msg[1]),
                late: msg[2] != 0.0,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Local;
    use crate::exec::run_workers;
    use crate::net::CostModel;
    use crate::optim::kernels::InnerOpt;
    use crate::util::allclose;

    /// Build the configured outer rule (registry path, like the session).
    fn rule_of(cfg: &SlowMoCfg) -> std::sync::Arc<dyn OuterOpt> {
        OuterRegistry::builtin().build(&cfg.outer).unwrap()
    }

    fn run_outer(
        cfg: &SlowMoCfg,
        m: usize,
        states: Vec<WorkerState>,
        outers: Vec<OuterState>,
        gamma: f32,
    ) -> Vec<(WorkerState, OuterState)> {
        let fabric = Fabric::new(m, CostModel::free());
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let kernels = Kernels::Native;
        let rule = rule_of(cfg);
        run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            outer_update(cfg, &*rule, &algo, &fabric, &kernels, w, &mut st,
                         &mut ou, gamma, 0.0, None)
                .unwrap();
            (st, ou)
        })
    }

    fn mk_states(m: usize, d: usize) -> (Vec<WorkerState>, Vec<OuterState>) {
        let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 };
        let init = vec![1.0f32; d];
        let slowmo_shape = SlowMoRule { alpha: 1.0, beta: 0.0 };
        let mut states = Vec::new();
        let mut outers = Vec::new();
        for w in 0..m {
            let mut s = WorkerState::new(&init, &inner);
            // Simulate divergent inner trajectories.
            for (i, x) in s.x.iter_mut().enumerate() {
                *x = (w * d + i) as f32 * 0.01;
            }
            s.h = vec![w as f32; d];
            states.push(s);
            outers.push(OuterState::new(&init, &slowmo_shape));
        }
        (states, outers)
    }

    #[test]
    fn beta0_alpha1_adopts_exact_average() {
        // SlowMo(alpha=1, beta=0) must set every worker to the average of
        // the x_{t,tau}'s — the Local SGD equivalence.
        let m = 3;
        let d = 8;
        let (states, outers) = mk_states(m, d);
        let want: Vec<f32> = (0..d)
            .map(|i| {
                (0..m).map(|w| states[w].x[i]).sum::<f32>() / m as f32
            })
            .collect();
        let cfg = SlowMoCfg::new(1.0, 0.0, 4);
        let out = run_outer(&cfg, m, states, outers, 0.1);
        for (st, ou) in &out {
            assert!(allclose(&st.x, &want, 1e-5, 1e-6));
            assert!(allclose(&ou.x0, &want, 1e-5, 1e-6));
        }
    }

    #[test]
    fn workers_synchronized_after_update() {
        let m = 4;
        let (states, outers) = mk_states(m, 16);
        let cfg = SlowMoCfg::new(1.0, 0.7, 4);
        let out = run_outer(&cfg, m, states, outers, 0.05);
        for (st, ou) in &out[1..] {
            assert_eq!(st.x, out[0].0.x, "x must be identical");
            assert_eq!(ou.u(), out[0].1.u(), "u must be identical");
        }
        assert_eq!(out[0].1.t, 1);
    }

    #[test]
    fn reset_strategy_zeroes_buffers_maintain_keeps() {
        let m = 2;
        let (states, outers) = mk_states(m, 4);
        let reset = SlowMoCfg::new(1.0, 0.5, 4);
        let out = run_outer(&reset, m, states.clone(), outers.clone(), 0.1);
        assert!(out[1].0.h.iter().all(|&h| h == 0.0));

        let maintain = SlowMoCfg::new(1.0, 0.5, 4)
            .with_buffers(BufferStrategy::Maintain);
        let out = run_outer(&maintain, m, states, outers, 0.1);
        assert!(out[1].0.h.iter().all(|&h| h == 1.0)); // worker 1's buffer
    }

    #[test]
    fn average_strategy_averages_buffers() {
        let m = 2;
        let (states, outers) = mk_states(m, 4);
        let cfg = SlowMoCfg::new(1.0, 0.5, 4)
            .with_buffers(BufferStrategy::Average);
        let out = run_outer(&cfg, m, states, outers, 0.1);
        // h was w (0 and 1) -> averaged to 0.5 on both workers.
        for (st, _) in &out {
            assert!(st.h.iter().all(|&h| (h - 0.5).abs() < 1e-6));
        }
    }

    #[test]
    fn noaverage_variant_keeps_local_x() {
        let m = 2;
        let (states, outers) = mk_states(m, 4);
        let x_before: Vec<Vec<f32>> =
            states.iter().map(|s| s.x.clone()).collect();
        let cfg = SlowMoCfg::new(1.0, 0.0, 4).no_average();
        let out = run_outer(&cfg, m, states, outers, 0.1);
        // With beta=0, alpha=1 and no averaging, each worker adopts its own
        // x (not the average) — workers stay apart.
        for (w, (st, _)) in out.iter().enumerate() {
            assert!(allclose(&st.x, &x_before[w], 1e-5, 1e-6));
        }
        assert_ne!(out[0].0.x, out[1].0.x);
    }

    #[test]
    fn momentum_accumulates_across_outer_iterations() {
        // Two outer updates with the same displacement: second step moves
        // farther (u compounds).
        let d = 4;
        let cfg = SlowMoCfg::new(1.0, 0.5, 1);
        let rule = rule_of(&cfg);
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let fabric = Fabric::new(1, CostModel::free());
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let mut st = WorkerState::new(&vec![10.0; d], &inner);
        let mut ou = OuterState::new(&vec![10.0; d], &*rule);
        let gamma = 1.0;
        // Inner loop "moved" x down by 1 each outer iteration.
        st.x.iter_mut().for_each(|x| *x -= 1.0);
        outer_update(&cfg, &*rule, &algo, &fabric, &kernels, 0, &mut st,
                     &mut ou, gamma, 0.0, None)
            .unwrap();
        let x1 = ou.x0[0]; // 10 - 1*(1) = 9
        assert!((x1 - 9.0).abs() < 1e-6);
        st.x.iter_mut().for_each(|x| *x -= 1.0);
        outer_update(&cfg, &*rule, &algo, &fabric, &kernels, 0, &mut st,
                     &mut ou, gamma, 0.0, None)
            .unwrap();
        // u = 0.5*1 + 1 = 1.5 -> x = 9 - 1.5 = 7.5
        assert!((ou.x0[0] - 7.5).abs() < 1e-6, "{}", ou.x0[0]);
    }

    #[test]
    fn elastic_membership_excludes_down_worker_and_rejoins() {
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 4;
        let d = 6;
        let cost = CostModel::free();
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 3,
                        fail_at: 0,
                        rejoin_at: 1,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::new(1.0, 0.5, 4);
        let rule = rule_of(&cfg);
        let (states, outers) = mk_states(m, d);
        // Survivors' exact average at boundary 0: mean over workers 0..2.
        let want: Vec<f32> = (0..d)
            .map(|i| (0..3).map(|w| states[w].x[i]).sum::<f32>() / 3.0)
            .collect();
        let out = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            // Boundary 0: worker 3 is down. Boundary 1: it rejoins.
            for _ in 0..2 {
                outer_update(&cfg, &*rule, &algo, &fabric, &kernels, w,
                             &mut st, &mut ou, 0.1, 0.0, Some(&*plan))
                    .unwrap();
            }
            (st, ou)
        });
        // All four workers advanced two boundaries without deadlock.
        for (_, ou) in &out {
            assert_eq!(ou.t, 2);
        }
        // After the rejoin boundary every worker holds the identical
        // outer state, bit for bit.
        for (st, ou) in &out[1..] {
            assert_eq!(st.x, out[0].0.x);
            assert_eq!(ou.x0, out[0].1.x0);
            assert_eq!(ou.u(), out[0].1.u());
        }
        // The boundary-0 average was exact over the three survivors:
        // with alpha=1 the first outer step moves x0 by gamma*u where
        // u = (x0_init - want)/gamma * ... — verify directly instead via a
        // single-boundary run below.
        let cfg0 = SlowMoCfg::new(1.0, 0.0, 4);
        let rule0 = rule_of(&cfg0);
        let single = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            outer_update(&cfg0, &*rule0, &algo, &fabric, &kernels, w,
                         &mut st, &mut ou, 0.1, 0.0, Some(&*plan))
                .unwrap();
            st
        });
        for (w, st) in single.iter().enumerate().take(3) {
            assert!(allclose(&st.x, &want, 1e-5, 1e-6), "worker {w}");
        }
        // The down worker's parameters were untouched at boundary 0.
        assert_eq!(single[3].x, states[3].x);
    }

    #[test]
    fn membership_change_rescales_slow_momentum() {
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 2;
        let d = 3;
        let cost = CostModel::free();
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 1,
                        fail_at: 0,
                        rejoin_at: u64::MAX,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::new(1.0, 0.5, 1);
        let rule = rule_of(&cfg);
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let init = vec![10.0f32; d];
        let mut st = WorkerState::new(&init, &inner);
        let mut ou = OuterState::new(&init, &*rule);
        // Pre-existing momentum mass from m=2 workers.
        ou.opt.bufs[0] = vec![2.0; d];
        st.x.iter_mut().for_each(|x| *x -= 1.0);
        // Worker 0 survives alone: live/prev = 1/2 halves u before the
        // slow update: u = 0.5*(0.5*2) + 1 = 1.5 (gamma=1, alpha=1).
        outer_update(&cfg, &*rule, &algo, &fabric, &kernels, 0, &mut st,
                     &mut ou, 1.0, 0.0, Some(&*plan))
            .unwrap();
        for &u in ou.u() {
            assert!((u - 1.5).abs() < 1e-6, "u={u}");
        }
    }

    #[test]
    fn rejoiner_clock_respects_leader_causality() {
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 2;
        let d = 4;
        // Non-free network so the collective and transfer cost time.
        let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 1,
                        fail_at: 0,
                        rejoin_at: 1,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost.clone(), Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::new(1.0, 0.5, 4);
        let rule = rule_of(&cfg);
        let init = vec![1.0f32; d];
        // Leader enters boundary 1 at t=5s; the rejoiner's own clock is
        // stale at 0 — its rejoin must land after the leader's clock.
        let clocks = run_workers(m, |w| {
            let mut st = WorkerState::new(&init, algo.inner());
            let mut ou = OuterState::new(&init, &*rule);
            let mut clock = 0.0;
            for _ in 0..2 {
                let start = if w == 0 { clock.max(5.0) } else { clock };
                clock = outer_update(&cfg, &*rule, &algo, &fabric,
                                     &kernels, w, &mut st, &mut ou, 0.1,
                                     start, Some(&*plan))
                    .unwrap();
            }
            clock
        });
        let transfer = cost.xfer_time(d) + cost.xfer_time(d + 2);
        assert!(
            clocks[1] >= 5.0 + transfer,
            "rejoiner clock {} must not precede the leader's send",
            clocks[1]
        );
    }

    #[test]
    fn truncated_rejoin_payload_is_a_hard_error() {
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 2;
        let d = 6;
        let cost = CostModel::free();
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 1,
                        fail_at: 0,
                        rejoin_at: 1,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::new(1.0, 0.5, 4);
        let rule = rule_of(&cfg);
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let init = vec![1.0f32; d];
        let mut st = WorkerState::new(&init, &inner);
        let mut ou = OuterState::new(&init, &*rule);
        ou.t = 1; // worker 1's rejoin boundary
        let (tag_x, tag_u) = rejoin_tags(1);
        fabric.chunk_send(0, 1, tag_x, vec![0.0; d]);
        // Truncated state payload: u without the packed clock slots.
        fabric.chunk_send(0, 1, tag_u, vec![0.0; d]);
        let e = outer_update(&cfg, &*rule, &algo, &fabric, &kernels, 1,
                             &mut st, &mut ou, 0.1, 0.0, Some(&*plan))
            .unwrap_err()
            .to_string();
        assert!(e.contains("worker 1"), "{e}");
        assert!(e.contains("boundary 1"), "{e}");
        assert!(e.contains("corrupt"), "{e}");
    }

    #[test]
    fn truncated_rejoin_payload_with_codec_is_a_hard_error() {
        // With an error-feedback codec the rejoin state payload grows to
        // (n_bufs + ef_bufs)*d + 2; a legacy rule-only payload (d + 2)
        // must be rejected — naming the worker, boundary and the codec's
        // buffer count — instead of silently zero-filling the residual.
        use crate::compress::{ErrorFeedback, TopK};
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 2;
        let d = 6;
        let cost = CostModel::free();
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 1,
                        fail_at: 0,
                        rejoin_at: 1,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::new(1.0, 0.5, 4);
        let rule = rule_of(&cfg);
        let codec = ErrorFeedback {
            inner: Arc::new(TopK { frac: 0.5 }),
        };
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let init = vec![1.0f32; d];
        let mut st = WorkerState::new(&init, &inner);
        let mut ou = OuterState::new(&init, &*rule);
        ou.t = 1; // worker 1's rejoin boundary
        let (tag_x, tag_u) = rejoin_tags(1);
        fabric.chunk_send(0, 1, tag_x, vec![0.0; d]);
        // Rule buffer + clock, but no residual buffer.
        fabric.chunk_send(0, 1, tag_u, vec![0.0; d + 2]);
        let e = outer_update_c(&cfg, &*rule, &algo, &fabric, &kernels, 1,
                               &mut st, &mut ou, 0.1, 0.0, Some(&*plan),
                               Some(&codec))
            .unwrap_err()
            .to_string();
        assert!(e.contains("worker 1"), "{e}");
        assert!(e.contains("boundary 1"), "{e}");
        assert!(e.contains("corrupt"), "{e}");
        assert!(e.contains("error-feedback"), "{e}");
    }

    #[test]
    fn rejoin_transfers_multi_buffer_state_bitwise() {
        // Outer Adam carries two moment buffers; a fail-and-rejoin cycle
        // must re-synchronize x0 and both moments, bit for bit.
        use crate::net::{ChaosCfg, ChaosPlan, FaultWindow};
        use std::sync::Arc;
        let m = 3;
        let d = 5;
        let cost = CostModel::free();
        let plan = Arc::new(
            ChaosPlan::new(
                ChaosCfg {
                    faults: vec![FaultWindow {
                        worker: 2,
                        fail_at: 0,
                        rejoin_at: 1,
                    }],
                    ..ChaosCfg::default()
                },
                m,
                &cost,
            )
            .unwrap(),
        );
        let fabric = Fabric::with_chaos(m, cost, Arc::clone(&plan));
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let kernels = Kernels::Native;
        let cfg = SlowMoCfg::with_outer(
            OuterSel::with_args("adam", &[0.9, 0.95]),
            4,
        );
        let rule = rule_of(&cfg);
        assert_eq!(rule.n_bufs(), 2);
        let init = vec![1.0f32; d];
        let out = run_workers(m, |w| {
            let mut st = WorkerState::new(&init, algo.inner());
            let mut ou = OuterState::new(&init, &*rule);
            for t in 0..2u64 {
                // Divergent inner progress before each boundary.
                for (i, x) in st.x.iter_mut().enumerate() {
                    *x -= 0.01 * (w as f32 + 1.0) * (t as f32 + 1.0)
                        + 0.001 * i as f32;
                }
                outer_update(&cfg, &*rule, &algo, &fabric, &kernels, w,
                             &mut st, &mut ou, 0.1, 0.0, Some(&*plan))
                    .unwrap();
            }
            ou
        });
        for ou in &out {
            assert_eq!(ou.t, 2);
        }
        for ou in &out[1..] {
            assert_eq!(ou.x0, out[0].x0);
            assert_eq!(ou.opt, out[0].opt, "moment buffers diverged");
        }
    }

    #[test]
    fn blocking_boundary_charges_max_arrival_stamp() {
        // A synchronous collective cannot complete before its last
        // member arrives: with a free network the only time a boundary
        // can charge is the slowest arrival stamp — and every member
        // must charge exactly that.
        let m = 3;
        let cfg = SlowMoCfg::new(1.0, 0.5, 4);
        let rule = rule_of(&cfg);
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let kernels = Kernels::Native;
        let fabric = Fabric::new(m, CostModel::free());
        let (states, outers) = mk_states(m, 6);
        let clocks = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            outer_update(&cfg, &*rule, &algo, &fabric, &kernels, w,
                         &mut st, &mut ou, 0.1, w as f64, None)
                .unwrap()
        });
        for (w, &c) in clocks.iter().enumerate() {
            assert_eq!(c, 2.0, "worker {w} must leave at the slowest \
                                arrival");
        }
    }

    #[test]
    fn quorum_drops_late_worker_then_resyncs_bitwise() {
        let m = 3;
        let d = 6;
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let kernels = Kernels::Native;
        let fabric = Fabric::new(m, CostModel::free());
        let (states, outers) = mk_states(m, d);
        // Arrival stamps are the worker ids, so with q=2 worker 2 is
        // late and the quorum mean covers workers 0 and 1.
        let want: Vec<f32> = (0..d)
            .map(|i| (0..2).map(|w| states[w].x[i]).sum::<f32>() / 2.0)
            .collect();
        let cfg0 = SlowMoCfg::new(1.0, 0.0, 4).with_quorum(2);
        let rule0 = rule_of(&cfg0);
        let single = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            let clock = outer_update(&cfg0, &*rule0, &algo, &fabric,
                                     &kernels, w, &mut st, &mut ou, 0.1,
                                     w as f64, None)
                .unwrap();
            (st, ou, clock)
        });
        for (w, (st, ou, _)) in single.iter().enumerate().take(2) {
            assert!(allclose(&st.x, &want, 1e-5, 1e-6), "worker {w}");
            assert_eq!(ou.quorum_misses, 0);
        }
        // The late worker froze — parameters untouched, its own clock
        // kept (semisync's whole point), the miss counted.
        let (st2, ou2, clock2) = &single[2];
        assert_eq!(st2.x, states[2].x);
        assert_eq!(*clock2, 2.0);
        assert_eq!(ou2.quorum_misses, 1);
        assert!(ou2.late);
        assert_eq!(ou2.t, 1, "the boundary index still advances");

        // Second boundary: the late worker resyncs by pulling the fresh
        // outer state — everyone bit-identical again afterwards.
        let cfg = SlowMoCfg::new(1.0, 0.5, 4).with_quorum(2);
        let rule = rule_of(&cfg);
        let out = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            let mut clock = w as f64;
            for _ in 0..2 {
                clock = outer_update(&cfg, &*rule, &algo, &fabric,
                                     &kernels, w, &mut st, &mut ou, 0.1,
                                     clock, None)
                    .unwrap();
            }
            (st, ou)
        });
        for (st, ou) in &out {
            assert_eq!(ou.t, 2);
            assert_eq!(st.x, out[0].0.x);
            assert_eq!(ou.x0, out[0].1.x0);
            assert_eq!(ou.u(), out[0].1.u());
        }
        assert_eq!(out[2].1.quorum_misses, 1);
        assert!(!out[2].1.late, "resynced");
    }

    #[test]
    fn staleness_folds_late_contribution_at_next_boundary() {
        // s=1: the late worker's boundary-0 snapshot is down-weighted
        // into boundary 1's quorum mean instead of being dropped.
        let m = 3;
        let d = 4;
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let kernels = Kernels::Native;
        let fabric = Fabric::new(m, CostModel::free());
        let (states, outers) = mk_states(m, d);
        let cfg = SlowMoCfg::new(1.0, 0.0, 4)
            .with_quorum(2)
            .with_staleness(1);
        let rule = rule_of(&cfg);
        let out = run_workers(m, |w| {
            let mut st = states[w].clone();
            let mut ou = outers[w].clone();
            let mut clock = w as f64;
            for _ in 0..2 {
                clock = outer_update(&cfg, &*rule, &algo, &fabric,
                                     &kernels, w, &mut st, &mut ou, 0.1,
                                     clock, None)
                    .unwrap();
            }
            (st, ou)
        });
        // Reference serial fold: the boundary-1 ring mean over workers
        // {0,1} is their shared boundary-0 mean (beta=0, alpha=1 adopts
        // it; Reset zeroes h so the inner loop is a no-op here), and the
        // stale snapshot is worker 2's original x.
        let mean01: Vec<f32> = (0..d)
            .map(|i| (states[0].x[i] + states[1].x[i]) / 2.0)
            .collect();
        let want: Vec<f32> = (0..d)
            .map(|i| {
                (2.0 * mean01[i] + STALE_LAMBDA * states[2].x[i])
                    / (2.0 + STALE_LAMBDA)
            })
            .collect();
        for (st, ou) in &out {
            assert_eq!(ou.t, 2);
            assert_eq!(st.x, out[0].0.x);
            assert!(allclose(&st.x, &want, 1e-6, 1e-7));
        }
        assert_eq!(out[2].1.quorum_misses, 1);
        assert_eq!(out[2].1.stale_folds, 1);
        assert_eq!(out[0].1.stale_folds, 0);
    }

    #[test]
    fn quorum_validation_rejects_degenerate_configs() {
        let e = SlowMoCfg::new(1.0, 0.5, 4)
            .with_quorum(0)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("quorum"), "{e}");
        let e = SlowMoCfg::new(1.0, 0.5, 4)
            .with_staleness(1)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("staleness"), "{e}");
        let e = SlowMoCfg::new(1.0, 0.5, 4)
            .with_quorum(2)
            .no_average()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("exact average"), "{e}");
        assert!(SlowMoCfg::new(1.0, 0.5, 4)
            .with_quorum(2)
            .with_staleness(1)
            .validate()
            .is_ok());
    }

    #[test]
    fn tau_zero_is_an_error_not_a_panic() {
        // The old constructor assert is gone: invalid τ surfaces as a
        // validation Err at run/build time instead of aborting.
        let cfg = SlowMoCfg::new(0.5, 0.0, 0);
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("tau"), "{e}");
        assert!(SlowMoCfg::new(1.0, 0.5, 1).validate().is_ok());
    }

    #[test]
    fn boundary_arithmetic() {
        let cfg = SlowMoCfg::new(1.0, 0.5, 12);
        assert!(!cfg.is_boundary(0));
        assert!(cfg.is_boundary(11));
        assert!(cfg.is_boundary(23));
        assert!(!cfg.is_boundary(12));
        let c1 = SlowMoCfg::new(1.0, 0.0, 1);
        assert!(c1.is_boundary(0));
        assert!(c1.is_boundary(5));
    }

    #[test]
    fn buffer_strategy_from_str() {
        assert_eq!("reset".parse(), Ok(BufferStrategy::Reset));
        assert_eq!("maintain".parse(), Ok(BufferStrategy::Maintain));
        assert_eq!("average".parse(), Ok(BufferStrategy::Average));
        let e = "bogus".parse::<BufferStrategy>().unwrap_err();
        assert!(e.contains("reset|maintain|average"), "{e}");
        assert_eq!(BufferStrategy::Reset.name(), "reset");
    }
}
