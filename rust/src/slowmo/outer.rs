//! Pluggable outer optimizers: the update rule applied at each SlowMo
//! outer boundary (paper Alg. 1 lines 7–8) as a first-class API.
//!
//! The paper frames SlowMo as *base algorithm + periodic outer update*,
//! with BMUF, Lookahead and Local SGD as special cases of the slow-momentum
//! rule. Recent decoupled-momentum work (DeMo-style outer Nesterov, outer
//! Adam) varies exactly this slot, so the rule is factored out of
//! [`super::outer_update`] into the [`OuterOpt`] trait: a rule owns only
//! its math and its state buffers, while the framework shell keeps
//! boundary detection, the exact average, elastic membership and the
//! buffer strategy.
//!
//! Rules are selected through the string-keyed [`OuterRegistry`]
//! (mirroring [`crate::algorithms::AlgoRegistry`]): the same
//! `key[:a,b]` spec grammar works from
//! [`crate::session::TrainBuilder::outer`], `--outer` on the CLI, the
//! `[outer]` TOML table and the bench harness, with hard parse errors for
//! unknown keys and malformed arguments. Out-of-crate rules register via
//! [`crate::session::Session::outer_registry_mut`].
//!
//! Built-ins:
//! - `slowmo[:beta,alpha]` — the paper's slow-momentum rule (the default);
//! - `avg`                 — α=1, β=0 stateless fast path (Local SGD /
//!   post-local SGD), bitwise-identical to `slowmo:0`;
//! - `lookahead[:alpha]`   — Zhang et al. 2019, `x0 ← (1-α)x0 + α x̄`;
//! - `nesterov[:beta]`     — outer Nesterov momentum on the displacement
//!   pseudo-gradient (DeMo-style decoupled momentum);
//! - `adam[:b1,b2]`        — outer Adam on the pseudo-gradient (two moment
//!   buffers, bias correction driven by the outer iteration count).

use crate::optim::kernels::Kernels;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// State owned by an outer rule: zero or more `d`-length f32 buffers
/// (slow momentum `u`, Adam moments, ...). Keeping the shape explicit —
/// rather than hardcoding one `u` vector — lets the elastic-membership
/// rescale and the rejoin wire format stay rule-agnostic.
#[derive(Clone, Debug, PartialEq)]
pub struct OuterOptState {
    pub bufs: Vec<Vec<f32>>,
}

impl OuterOptState {
    pub fn zeros(n_bufs: usize, d: usize) -> Self {
        Self {
            bufs: vec![vec![0.0; d]; n_bufs],
        }
    }

    /// Total f32 elements across all buffers (rejoin payload sizing).
    pub fn flat_len(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }
}

/// One outer-optimizer rule. Implementations are stateless descriptors
/// (hyperparameters only); per-run state lives in [`OuterOptState`] so the
/// framework can ship and rescale it without knowing the rule.
pub trait OuterOpt: Send + Sync {
    /// Registry key this rule answers to ("slowmo", "adam", ...).
    fn key(&self) -> String;

    /// Hyperparameter fragment for display names ("a1,b0.7",
    /// "b1=0.9,b2=0.95"); empty for parameterless rules.
    fn params(&self) -> String;

    /// Number of `d`-length state buffers the rule owns.
    fn n_bufs(&self) -> usize;

    /// Fresh (zeroed) state for flat length `d`.
    fn init(&self, d: usize) -> OuterOptState {
        OuterOptState::zeros(self.n_bufs(), d)
    }

    /// Apply the outer update at boundary `t`: consume the (averaged)
    /// fast weights `xt`, update the outer iterate `x0` and `state` in
    /// place. `gamma` is the fast learning rate in effect for the outer
    /// iteration (paper Eq. 2).
    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        state: &mut OuterOptState,
        gamma: f32,
        t: u64,
        kernels: &Kernels,
    ) -> Result<()>;

    /// Rescale state for an elastic-membership change by the live/prev
    /// worker-count ratio (the state aggregates displacement mass over the
    /// live group). Default: scale every buffer linearly; rules with
    /// quadratic buffers (Adam's second moment) override.
    ///
    /// Called from two membership authorities, never both in one run: the
    /// chaos plan's fault windows (static live counts) and the semi-sync
    /// quorum boundary (dynamic ring sizes tracked per worker in
    /// `OuterState::prev_ring`).
    fn scale_state(&self, state: &mut OuterOptState, factor: f32) {
        for b in &mut state.bufs {
            for v in b.iter_mut() {
                *v *= factor;
            }
        }
    }
}

// ------------------------------------------------------------- built-ins

/// The paper's slow-momentum rule (Alg. 1 lines 7–8):
/// `u ← βu + (x0 - x̄)/γ`; `x0 ← x0 - αγu`. One state buffer.
#[derive(Clone, Copy, Debug)]
pub struct SlowMoRule {
    pub alpha: f32,
    pub beta: f32,
}

impl OuterOpt for SlowMoRule {
    fn key(&self) -> String {
        "slowmo".into()
    }

    fn params(&self) -> String {
        format!("a{},b{}", self.alpha, self.beta)
    }

    fn n_bufs(&self) -> usize {
        1
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        state: &mut OuterOptState,
        gamma: f32,
        _t: u64,
        kernels: &Kernels,
    ) -> Result<()> {
        kernels.slowmo_update(x0, xt, &mut state.bufs[0], gamma, self.alpha,
                              self.beta)
    }
}

/// α=1, β=0 stateless fast path: adopt the exact average (Local SGD /
/// post-local SGD). The arithmetic mirrors the slow-momentum kernel with
/// α=1, β=0 operation for operation on *both* backends (the PJRT arm
/// runs the same AOT `slowmo` graph with a zero scratch buffer), so
/// `avg` is bitwise-identical to `slowmo:0` (asserted in
/// `rust/tests/equivalences.rs`) while carrying no persistent state
/// buffer, no membership rescale and no rejoin payload beyond the clock.
#[derive(Clone, Copy, Debug)]
pub struct AvgRule;

impl OuterOpt for AvgRule {
    fn key(&self) -> String {
        "avg".into()
    }

    fn params(&self) -> String {
        String::new()
    }

    fn n_bufs(&self) -> usize {
        0
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        _state: &mut OuterOptState,
        gamma: f32,
        _t: u64,
        kernels: &Kernels,
    ) -> Result<()> {
        ensure!(x0.len() == xt.len(), "avg: length mismatch");
        match kernels {
            Kernels::Native => {
                // Same fp ops as slowmo_update with u=0, beta=0, alpha=1 —
                // NOT a plain copy: gamma*((x0-xt)/gamma) != (x0-xt) in
                // general, and the bitwise contract with `slowmo:0` wins
                // over the shortcut.
                for i in 0..x0.len() {
                    let un = (x0[i] - xt[i]) / gamma;
                    x0[i] -= gamma * un;
                }
                Ok(())
            }
            pjrt @ Kernels::Pjrt { .. } => {
                let mut scratch = vec![0.0f32; x0.len()];
                pjrt.slowmo_update(x0, xt, &mut scratch, gamma, 1.0, 0.0)
            }
        }
    }
}

/// Lookahead (Zhang et al. 2019): `x0 ← (1-α)x0 + α x̄` — "τ steps
/// forward, one step back". Stateless; equals the slow-momentum rule with
/// β=0 and slow rate α (up to fp association).
#[derive(Clone, Copy, Debug)]
pub struct LookaheadRule {
    pub alpha: f32,
}

impl OuterOpt for LookaheadRule {
    fn key(&self) -> String {
        "lookahead".into()
    }

    fn params(&self) -> String {
        format!("a{}", self.alpha)
    }

    fn n_bufs(&self) -> usize {
        0
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        _state: &mut OuterOptState,
        _gamma: f32,
        _t: u64,
        kernels: &Kernels,
    ) -> Result<()> {
        kernels.axpy(x0, xt, 1.0 - self.alpha, self.alpha)
    }
}

/// Outer Nesterov momentum on the displacement pseudo-gradient
/// `g = (x0 - x̄)/γ` (DeMo-style decoupled momentum):
/// `u ← βu + g`; `x0 ← x0 - γ(βu + g)`. One state buffer.
#[derive(Clone, Copy, Debug)]
pub struct NesterovRule {
    pub beta: f32,
}

impl OuterOpt for NesterovRule {
    fn key(&self) -> String {
        "nesterov".into()
    }

    fn params(&self) -> String {
        format!("b{}", self.beta)
    }

    fn n_bufs(&self) -> usize {
        1
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        state: &mut OuterOptState,
        gamma: f32,
        _t: u64,
        kernels: &Kernels,
    ) -> Result<()> {
        kernels.outer_nesterov(x0, xt, &mut state.bufs[0], gamma, self.beta)
    }
}

/// Outer Adam on the displacement pseudo-gradient, with bias correction
/// driven by the shared outer iteration count. Two state buffers (first
/// and second moment); the second moment is quadratic in the displacement,
/// so membership rescaling squares the factor.
#[derive(Clone, Copy, Debug)]
pub struct AdamRule {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl OuterOpt for AdamRule {
    fn key(&self) -> String {
        "adam".into()
    }

    fn params(&self) -> String {
        format!("b1={},b2={}", self.beta1, self.beta2)
    }

    fn n_bufs(&self) -> usize {
        2
    }

    fn step(
        &self,
        x0: &mut Vec<f32>,
        xt: &[f32],
        state: &mut OuterOptState,
        gamma: f32,
        t: u64,
        kernels: &Kernels,
    ) -> Result<()> {
        let (m, v) = state.bufs.split_at_mut(1);
        kernels.outer_adam(
            x0,
            xt,
            &mut m[0],
            &mut v[0],
            gamma,
            self.beta1,
            self.beta2,
            self.eps,
            (t + 1) as f32,
        )
    }

    fn scale_state(&self, state: &mut OuterOptState, factor: f32) {
        for v in state.bufs[0].iter_mut() {
            *v *= factor;
        }
        let f2 = factor * factor;
        for v in state.bufs[1].iter_mut() {
            *v *= f2;
        }
    }
}

// -------------------------------------------------------------- registry

/// A parsed outer-rule selection: canonical registry key + the numeric
/// arguments given in the spec string (defaults are filled in by
/// [`OuterRegistry::build`], so the selection round-trips to the exact
/// spec the user wrote).
#[derive(Clone, Debug, PartialEq)]
pub struct OuterSel {
    pub key: String,
    pub args: Vec<f32>,
}

impl OuterSel {
    pub fn new(key: &str) -> Self {
        Self {
            key: key.to_string(),
            args: Vec::new(),
        }
    }

    pub fn with_args(key: &str, args: &[f32]) -> Self {
        Self {
            key: key.to_string(),
            args: args.to_vec(),
        }
    }

    /// The paper's slow-momentum rule (`slowmo:<beta>[,<alpha>]`). The
    /// paper-default α=1 is omitted from the args so the stored spec is
    /// the canonical "slowmo:<beta>" — identical to what the spec-string
    /// path produces for the same configuration (keeps
    /// [`crate::trainer::TrainResult`]'s `outer` field groupable).
    pub fn slowmo(alpha: f32, beta: f32) -> Self {
        if alpha == 1.0 {
            Self::with_args("slowmo", &[beta])
        } else {
            Self::with_args("slowmo", &[beta, alpha])
        }
    }

    /// The spec-string form ("slowmo:0.7", "adam:0.9,0.95", "avg").
    pub fn spec(&self) -> String {
        if self.args.is_empty() {
            self.key.clone()
        } else {
            let args: Vec<String> =
                self.args.iter().map(|a| a.to_string()).collect();
            format!("{}:{}", self.key, args.join(","))
        }
    }
}

struct OuterEntry {
    factory: Box<dyn Fn(&[f32]) -> Result<Arc<dyn OuterOpt>> + Send + Sync>,
    help: String,
    /// Positional argument names and defaults; an argument without a
    /// default is required.
    args: Vec<(String, Option<f32>)>,
}

/// String-keyed registry of [`OuterOpt`] factories, with the same
/// spec-string / hard-parse-error contract as
/// [`crate::algorithms::AlgoRegistry`].
pub struct OuterRegistry {
    entries: BTreeMap<String, OuterEntry>,
    aliases: BTreeMap<String, String>,
}

impl Default for OuterRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl OuterRegistry {
    /// An empty registry (no rules).
    pub fn empty() -> Self {
        Self {
            entries: BTreeMap::new(),
            aliases: BTreeMap::new(),
        }
    }

    /// The five built-in rules, pre-registered.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(
            "slowmo",
            "slow momentum u <- b*u + dx/g; x0 -= a*g*u (paper Alg. 1)",
            &[("beta", Some(0.7)), ("alpha", Some(1.0))],
            |a: &[f32]| {
                ensure!(
                    (0.0..1.0).contains(&a[0]),
                    "slowmo beta must be in [0,1) (got {})",
                    a[0]
                );
                ensure!(
                    a[1] > 0.0,
                    "slowmo alpha must be > 0 (got {})",
                    a[1]
                );
                Ok(Arc::new(SlowMoRule { alpha: a[1], beta: a[0] })
                    as Arc<dyn OuterOpt>)
            },
        );
        r.register(
            "avg",
            "adopt the exact average (a=1, b=0 stateless fast path; \
             Local SGD / post-local SGD)",
            &[],
            |_: &[f32]| Ok(Arc::new(AvgRule) as Arc<dyn OuterOpt>),
        );
        r.register(
            "lookahead",
            "x0 <- (1-a)*x0 + a*avg (Zhang et al. 2019); alpha in (0,1]",
            &[("alpha", Some(0.5))],
            |a: &[f32]| {
                ensure!(
                    a[0] > 0.0 && a[0] <= 1.0,
                    "lookahead alpha must be in (0,1] (got {})",
                    a[0]
                );
                Ok(Arc::new(LookaheadRule { alpha: a[0] })
                    as Arc<dyn OuterOpt>)
            },
        );
        r.register(
            "nesterov",
            "outer Nesterov on the displacement pseudo-gradient \
             (DeMo-style decoupled momentum)",
            &[("beta", Some(0.9))],
            |a: &[f32]| {
                ensure!(
                    (0.0..1.0).contains(&a[0]),
                    "nesterov beta must be in [0,1) (got {})",
                    a[0]
                );
                Ok(Arc::new(NesterovRule { beta: a[0] })
                    as Arc<dyn OuterOpt>)
            },
        );
        r.register(
            "adam",
            "outer Adam on the displacement pseudo-gradient (two moments, \
             bias-corrected by the outer iteration count)",
            &[("beta1", Some(0.9)), ("beta2", Some(0.95))],
            |a: &[f32]| {
                // beta=1 would zero the bias correction (0/0 -> NaN
                // parameters); reject degenerate moments up front.
                ensure!(
                    (0.0..1.0).contains(&a[0])
                        && (0.0..1.0).contains(&a[1]),
                    "adam betas must be in [0,1) (got b1={}, b2={})",
                    a[0],
                    a[1]
                );
                Ok(Arc::new(AdamRule {
                    beta1: a[0],
                    beta2: a[1],
                    eps: 1e-8,
                }) as Arc<dyn OuterOpt>)
            },
        );
        r
    }

    /// Register a factory under `key`. `args` declares the positional
    /// `:a,b` spec arguments (name, default); an argument without a
    /// default is required. Re-registering a key replaces the previous
    /// factory.
    pub fn register(
        &mut self,
        key: &str,
        help: &str,
        args: &[(&str, Option<f32>)],
        factory: impl Fn(&[f32]) -> Result<Arc<dyn OuterOpt>>
            + Send
            + Sync
            + 'static,
    ) {
        self.entries.insert(
            key.to_string(),
            OuterEntry {
                factory: Box::new(factory),
                help: help.to_string(),
                args: args
                    .iter()
                    .map(|(n, d)| (n.to_string(), *d))
                    .collect(),
            },
        );
    }

    /// Register `alias` as another name for the existing `key`.
    pub fn alias(&mut self, alias: &str, key: &str) {
        assert!(
            self.entries.contains_key(key),
            "alias target {key:?} not registered"
        );
        self.aliases.insert(alias.to_string(), key.to_string());
    }

    /// Canonical keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.entries.keys().map(|k| k.as_str()).collect()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.canonical(key).is_some()
    }

    fn canonical(&self, key: &str) -> Option<&str> {
        if let Some((k, _)) = self.entries.get_key_value(key) {
            return Some(k.as_str());
        }
        self.aliases.get(key).map(|k| k.as_str())
    }

    /// Human-readable list of valid spec forms, for error messages and
    /// CLI help.
    pub fn valid_forms(&self) -> String {
        let forms: Vec<String> = self
            .entries
            .iter()
            .map(|(k, e)| {
                if e.args.is_empty() {
                    k.clone()
                } else {
                    let names: Vec<&str> =
                        e.args.iter().map(|(n, _)| n.as_str()).collect();
                    format!("{k}[:{}]", names.join(","))
                }
            })
            .collect();
        forms.join("|")
    }

    /// One line per rule, for `--help`-style output.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        for (k, e) in &self.entries {
            s.push_str(&format!("  {:<12} {}\n", k, e.help));
        }
        s
    }

    /// Parse a spec string such as "slowmo:0.7", "adam:0.9,0.95" or
    /// "avg". Every malformed input is a hard error: unknown keys,
    /// non-numeric or non-finite arguments, and more arguments than the
    /// rule declares all fail with a message listing the valid forms.
    pub fn parse(&self, spec: &str) -> Result<OuterSel> {
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (spec, None),
        };
        let Some(key) = self.canonical(name) else {
            bail!(
                "unknown outer optimizer {spec:?}; valid forms: {}",
                self.valid_forms()
            );
        };
        let entry = &self.entries[key];
        let mut args = Vec::new();
        if let Some(rest) = rest {
            for raw in rest.split(',') {
                let v = raw.parse::<f32>().map_err(|_| {
                    anyhow!(
                        "malformed argument {raw:?} in outer spec {spec:?}: \
                         expected a number; valid forms: {}",
                        self.valid_forms()
                    )
                })?;
                ensure!(
                    v.is_finite(),
                    "non-finite argument {raw:?} in outer spec {spec:?}"
                );
                args.push(v);
            }
            if entry.args.is_empty() {
                bail!(
                    "outer optimizer {name:?} takes no ':' argument (got \
                     {spec:?}); valid forms: {}",
                    self.valid_forms()
                );
            }
            if args.len() > entry.args.len() {
                bail!(
                    "too many arguments in outer spec {spec:?}: {name:?} \
                     takes at most {} ({}); valid forms: {}",
                    entry.args.len(),
                    entry
                        .args
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                        .join(","),
                    self.valid_forms()
                );
            }
        }
        Ok(OuterSel {
            key: key.to_string(),
            args,
        })
    }

    /// Instantiate the rule `sel` names, filling in defaults for
    /// arguments the spec omitted.
    pub fn build(&self, sel: &OuterSel) -> Result<Arc<dyn OuterOpt>> {
        let key = self.canonical(&sel.key).ok_or_else(|| {
            anyhow!(
                "unknown outer optimizer key {:?}; registered: {}",
                sel.key,
                self.keys().join(", ")
            )
        })?;
        let entry = &self.entries[key];
        ensure!(
            sel.args.len() <= entry.args.len(),
            "outer optimizer {key:?} takes at most {} argument(s), got {}",
            entry.args.len(),
            sel.args.len()
        );
        let mut args = sel.args.clone();
        for (name, default) in entry.args.iter().skip(args.len()) {
            match default {
                Some(d) => args.push(*d),
                None => bail!(
                    "outer optimizer {key:?} needs argument {name:?} \
                     (no default); valid forms: {}",
                    self.valid_forms()
                ),
            }
        }
        (entry.factory)(&args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native() -> Kernels {
        Kernels::Native
    }

    fn demo_vecs(d: usize) -> (Vec<f32>, Vec<f32>) {
        let x0: Vec<f32> =
            (0..d).map(|i| 1.0 + 0.37 * i as f32).collect();
        let xt: Vec<f32> =
            (0..d).map(|i| 0.9 + 0.31 * (i as f32).sin()).collect();
        (x0, xt)
    }

    #[test]
    fn every_builtin_key_round_trips() {
        let r = OuterRegistry::builtin();
        assert_eq!(r.keys(),
                   vec!["adam", "avg", "lookahead", "nesterov", "slowmo"]);
        for key in r.keys() {
            let sel = r.parse(key).unwrap();
            assert_eq!(sel.key, key);
            assert_eq!(sel.spec(), key);
            let rule = r.build(&sel).unwrap();
            assert_eq!(rule.key(), key);
        }
    }

    #[test]
    fn specs_parse_args_and_fill_defaults() {
        let r = OuterRegistry::builtin();
        let sel = r.parse("adam:0.8,0.99").unwrap();
        assert_eq!(sel.args, vec![0.8, 0.99]);
        assert_eq!(sel.spec(), "adam:0.8,0.99");
        let rule = r.build(&sel).unwrap();
        assert_eq!(rule.params(), "b1=0.8,b2=0.99");
        // Partial args take defaults for the tail.
        let rule = r.build(&r.parse("slowmo:0.6").unwrap()).unwrap();
        assert_eq!(rule.params(), "a1,b0.6");
        // No args at all: full defaults.
        let rule = r.build(&r.parse("nesterov").unwrap()).unwrap();
        assert_eq!(rule.params(), "b0.9");
    }

    #[test]
    fn malformed_specs_are_hard_errors() {
        let r = OuterRegistry::builtin();
        for bad in ["bogus", "slowmo:abc", "slowmo:", "slowmo:1,2,3",
                    "avg:1", "adam:0.9,oops", "lookahead:inf",
                    "lookahead:0", "adam:1,0.95", "adam:0.9,1.5",
                    "nesterov:1", "slowmo:1", "slowmo:0.5,0"] {
            let e = r.parse(bad).map(|sel| r.build(&sel));
            let failed = match e {
                Err(_) => true,
                Ok(built) => built.is_err(),
            };
            assert!(failed, "{bad} must be rejected");
        }
        let e = r.parse("bogus").unwrap_err().to_string();
        assert!(e.contains("valid forms"), "{e}");
        assert!(e.contains("slowmo"), "{e}");
    }

    #[test]
    fn avg_is_bitwise_identical_to_slowmo_beta0() {
        let r = OuterRegistry::builtin();
        let k = native();
        let slow = r.build(&r.parse("slowmo:0").unwrap()).unwrap();
        let avg = r.build(&r.parse("avg").unwrap()).unwrap();
        let d = 33;
        let (x0, xt) = demo_vecs(d);
        let mut xa = x0.clone();
        let mut sa = slow.init(d);
        // Non-zero momentum carried in from a previous boundary: with
        // beta=0 it must not affect the update.
        sa.bufs[0].iter_mut().enumerate().for_each(|(i, u)| {
            *u = (i as f32 - 16.0) * 0.3;
        });
        slow.step(&mut xa, &xt, &mut sa, 0.3, 4, &k).unwrap();
        let mut xb = x0;
        let mut sb = avg.init(d);
        avg.step(&mut xb, &xt, &mut sb, 0.3, 4, &k).unwrap();
        assert_eq!(xa, xb, "avg must match slowmo(beta=0) bitwise");
        assert_eq!(sb.flat_len(), 0);
    }

    #[test]
    fn lookahead_interpolates() {
        let r = OuterRegistry::builtin();
        let rule = r.build(&r.parse("lookahead:0.5").unwrap()).unwrap();
        let mut x0 = vec![2.0f32; 4];
        let xt = vec![0.0f32; 4];
        let mut st = rule.init(4);
        rule.step(&mut x0, &xt, &mut st, 0.1, 0, &native()).unwrap();
        assert!(x0.iter().all(|&x| (x - 1.0).abs() < 1e-6), "{x0:?}");
    }

    #[test]
    fn nesterov_accumulates_and_scales_linearly() {
        let rule = NesterovRule { beta: 0.5 };
        let d = 4;
        let mut x0 = vec![10.0f32; d];
        let xt = vec![9.0f32; d]; // displacement 1, gamma 1 -> g = 1
        let mut st = rule.init(d);
        rule.step(&mut x0, &xt, &mut st, 1.0, 0, &native()).unwrap();
        // u = 0.5*0 + 1 = 1; x0 -= 1*(0.5*1 + 1) = 8.5
        assert!((x0[0] - 8.5).abs() < 1e-6, "{}", x0[0]);
        assert!((st.bufs[0][0] - 1.0).abs() < 1e-6);
        rule.scale_state(&mut st, 0.5);
        assert!((st.bufs[0][0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn adam_scale_state_squares_second_moment() {
        let rule = AdamRule { beta1: 0.9, beta2: 0.95, eps: 1e-8 };
        let mut st = rule.init(3);
        st.bufs[0] = vec![2.0; 3];
        st.bufs[1] = vec![4.0; 3];
        rule.scale_state(&mut st, 0.5);
        assert!(st.bufs[0].iter().all(|&v| (v - 1.0).abs() < 1e-7));
        assert!(st.bufs[1].iter().all(|&v| (v - 1.0).abs() < 1e-7));
    }

    #[test]
    fn adam_moves_against_displacement() {
        let rule = AdamRule { beta1: 0.9, beta2: 0.95, eps: 1e-8 };
        let d = 4;
        let (mut x0, xt) = demo_vecs(d);
        let before = x0.clone();
        let mut st = rule.init(d);
        rule.step(&mut x0, &xt, &mut st, 0.1, 0, &native()).unwrap();
        // Moves toward xt on every coordinate where x0 > xt.
        for i in 0..d {
            if before[i] > xt[i] {
                assert!(x0[i] < before[i], "coord {i}");
            }
        }
        assert_eq!(st.bufs.len(), 2);
        assert!(st.bufs[1].iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn custom_registration_and_aliases() {
        let mut r = OuterRegistry::builtin();
        r.register("half", "test-only: lookahead 0.5", &[], |_| {
            Ok(Arc::new(LookaheadRule { alpha: 0.5 })
                as Arc<dyn OuterOpt>)
        });
        r.alias("mean", "avg");
        let sel = r.parse("half").unwrap();
        assert_eq!(r.build(&sel).unwrap().key(), "lookahead");
        assert_eq!(r.parse("mean").unwrap().key, "avg");
        assert!(r.contains("mean") && r.contains("half"));
        assert!(r.valid_forms().contains("half"));
        assert!(r.help_text().contains("test-only"));
    }

    #[test]
    fn sel_spec_round_trips() {
        let r = OuterRegistry::builtin();
        for spec in ["slowmo:0.7", "avg", "lookahead:0.5",
                     "nesterov:0.9", "adam:0.9,0.95"] {
            let sel = r.parse(spec).unwrap();
            assert_eq!(sel.spec(), spec);
            assert_eq!(r.parse(&sel.spec()).unwrap(), sel);
        }
        // Default alpha is omitted; explicit non-default alpha is kept.
        assert_eq!(OuterSel::slowmo(1.0, 0.7).spec(), "slowmo:0.7");
        assert_eq!(OuterSel::slowmo(0.5, 0.7).spec(), "slowmo:0.7,0.5");
    }
}
