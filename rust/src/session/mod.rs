//! Session/builder experiment API — the canonical way to run training.
//!
//! A [`Session`] loads the artifacts [`Manifest`], brings up the PJRT
//! [`Engine`] once, and caches model executors, optimizer kernels and
//! init vectors across runs, so sweep workloads (the bench harness, α×β
//! grids, multi-seed cells) stop paying per-run rebuild cost. Runs are
//! described with the fluent [`TrainBuilder`]:
//!
//! ```no_run
//! use slowmo::session::Session;
//!
//! let session = Session::open()?;
//! let result = session
//!     .train("cifar-mlp")
//!     .algo("sgp")
//!     .slowmo(0.7, 12)
//!     .workers(8)
//!     .run()?;
//! println!("{}: {:.4}", result.algo, result.best_train_loss);
//! # anyhow::Ok(())
//! ```
//!
//! Algorithms resolve through the session's string-keyed
//! [`AlgoRegistry`], so a new [`crate::algorithms::BaseAlgorithm`]
//! registered with [`Session::registry_mut`] is immediately reachable
//! from the CLI spec syntax, TOML configs and the builder. Outer
//! optimizers (the rule applied at SlowMo boundaries) resolve the same
//! way through the session's [`OuterRegistry`] —
//! [`TrainBuilder::outer`]`("adam:0.9,0.95")`, `--outer` on the CLI, or
//! an `[outer]` TOML table — with [`Session::outer_registry_mut`] for
//! out-of-crate rules. Attach a [`RunObserver`] via
//! [`TrainBuilder::run_observed`] for progress streaming and early
//! stopping.

use crate::algorithms::{AlgoRegistry, AlgoSel};
use crate::compress::CompressRegistry;
use crate::configx::Config;
use crate::exec::ExecMode;
use crate::net::{ChaosCfg, CostModel};
use crate::optim::kernels::{InnerOpt, Kernels};
use crate::runtime::{artifacts_dir, Engine, Manifest};
use crate::slowmo::{BufferStrategy, HierCfg, OuterRegistry, SlowMoCfg};
use crate::trainer::{
    self, model_exec, ModelExec, RunObserver, Schedule, StateMode,
    TrainCfg, TrainResult,
};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// One loaded experiment environment: manifest + engine + caches +
/// algorithm/outer-optimizer registries.
pub struct Session {
    manifest: Manifest,
    engine: Option<Arc<Engine>>,
    registry: AlgoRegistry,
    outers: OuterRegistry,
    compressors: CompressRegistry,
    /// (preset, force_pjrt) -> model executor.
    models: Mutex<BTreeMap<(String, bool), Arc<ModelExec>>>,
    /// Flat length d -> PJRT optimizer kernels.
    pjrt_kernels: Mutex<BTreeMap<usize, Arc<Kernels>>>,
    /// Preset -> initial parameter vector.
    inits: Mutex<BTreeMap<String, Arc<Vec<f32>>>>,
}

impl Session {
    /// Open the default artifacts directory (`SLOWMO_ARTIFACTS` or the
    /// nearest `artifacts/`) and bring up the PJRT CPU engine.
    pub fn open() -> Result<Self> {
        Self::open_at(&artifacts_dir())
    }

    pub fn open_at(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let engine = Engine::cpu(dir)?;
        Ok(Self::from_parts(manifest, Some(engine)))
    }

    /// Open without a PJRT engine: only presets with a native model path
    /// (the quad theory workload) can run. Used by the equivalence tests
    /// and theory benches, which are engine-free by construction.
    pub fn native_only() -> Result<Self> {
        Self::native_only_at(&artifacts_dir())
    }

    pub fn native_only_at(dir: &str) -> Result<Self> {
        Ok(Self::from_parts(Manifest::load(dir)?, None))
    }

    fn from_parts(manifest: Manifest, engine: Option<Arc<Engine>>) -> Self {
        Self {
            manifest,
            engine,
            registry: AlgoRegistry::builtin(),
            outers: OuterRegistry::builtin(),
            compressors: CompressRegistry::builtin(),
            models: Mutex::new(BTreeMap::new()),
            pjrt_kernels: Mutex::new(BTreeMap::new()),
            inits: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn engine(&self) -> Option<&Engine> {
        self.engine.as_deref()
    }

    pub fn registry(&self) -> &AlgoRegistry {
        &self.registry
    }

    /// Mutable registry access, e.g. to register a custom algorithm:
    /// `session.registry_mut().register("demo", ..., factory)`.
    pub fn registry_mut(&mut self) -> &mut AlgoRegistry {
        &mut self.registry
    }

    /// The outer-optimizer registry backing `--outer`, the `[outer]` TOML
    /// table and [`TrainBuilder::outer`].
    pub fn outer_registry(&self) -> &OuterRegistry {
        &self.outers
    }

    /// Mutable outer-registry access, e.g. to register an out-of-crate
    /// rule: `session.outer_registry_mut().register("demo", ..., f)`.
    pub fn outer_registry_mut(&mut self) -> &mut OuterRegistry {
        &mut self.outers
    }

    /// The communication-compression registry backing `--compress`, the
    /// `[compress]` TOML table and [`TrainBuilder::compress`].
    pub fn compress_registry(&self) -> &CompressRegistry {
        &self.compressors
    }

    /// Mutable compress-registry access, e.g. to register an
    /// out-of-crate codec:
    /// `session.compress_registry_mut().register("demo", ..., f)`.
    pub fn compress_registry_mut(&mut self) -> &mut CompressRegistry {
        &mut self.compressors
    }

    /// Start describing a run of `preset`. See [`TrainBuilder`] for the
    /// knobs and their defaults.
    pub fn train(&self, preset: &str) -> TrainBuilder<'_> {
        TrainBuilder::bound(self, preset)
    }

    /// Execute a fully-resolved configuration (normally produced by
    /// [`TrainBuilder::build_cfg`]).
    pub fn run(&self, cfg: &TrainCfg) -> Result<TrainResult> {
        self.run_observed(cfg, None)
    }

    pub fn run_observed(
        &self,
        cfg: &TrainCfg,
        observer: Option<&mut dyn RunObserver>,
    ) -> Result<TrainResult> {
        let info = self.manifest.preset(&cfg.preset)?;
        let d = info.flat_len;
        let desc = info.data.clone();
        let init = self.init(&cfg.preset)?;
        let model = self.model(&cfg.preset, cfg.force_pjrt)?;
        let kernels = self.kernels(d, cfg.native_kernels)?;
        // Hierarchical runs resolve the (possibly N-level) tier tree and
        // build one group-local algorithm per leaf group (topologies and
        // collectives sized to the group); flat and tiers-only runs
        // build the single global instance.
        let (algos, tiers) = match &cfg.hier {
            Some(h) => {
                let tree =
                    Arc::new(h.resolve_tree(cfg.m).with_context(|| {
                        format!("resolving groups {:?}", h.spec)
                    })?);
                let algos = if h.two_level {
                    tree.leaf()
                        .all()
                        .iter()
                        .map(|g| self.registry.build(&cfg.algo, g.len()))
                        .collect::<Result<Vec<_>>>()?
                } else {
                    vec![self.registry.build(&cfg.algo, cfg.m)?]
                };
                (algos, Some(tree))
            }
            None => (vec![self.registry.build(&cfg.algo, cfg.m)?], None),
        };
        let outer_rule = match &cfg.slowmo {
            Some(s) => {
                s.validate()?;
                Some(self.outers.build(&s.outer).with_context(|| {
                    format!("resolving outer {:?}", s.outer.spec())
                })?)
            }
            None => None,
        };
        let compressor = if cfg.compress.is_none() {
            None
        } else {
            Some(self.compressors.build(&cfg.compress).with_context(
                || format!("resolving compress {:?}", cfg.compress.spec()),
            )?)
        };
        trainer::run_prepared(cfg, algos, tiers, outer_rule, compressor,
                              &init, &desc, &model, &kernels, observer)
    }

    /// Cached model executor for `preset` (build-once across runs).
    pub fn model(&self, preset: &str, force_pjrt: bool)
                 -> Result<Arc<ModelExec>> {
        let key = (preset.to_string(), force_pjrt);
        if let Some(m) = self.models.lock().unwrap().get(&key) {
            return Ok(Arc::clone(m));
        }
        let built = Arc::new(model_exec::build(
            self.engine.as_deref(),
            &self.manifest,
            preset,
            force_pjrt,
        )?);
        self.models
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&built));
        Ok(built)
    }

    /// Cached optimizer kernels for flat length `d`. `native` (or an
    /// engine-free session) selects the pure-Rust mirrors.
    pub fn kernels(&self, d: usize, native: bool) -> Result<Arc<Kernels>> {
        let Some(engine) = self.engine.as_deref().filter(|_| !native)
        else {
            return Ok(Arc::new(Kernels::Native));
        };
        if let Some(k) = self.pjrt_kernels.lock().unwrap().get(&d) {
            return Ok(Arc::clone(k));
        }
        let built = Arc::new(Kernels::pjrt(engine, &self.manifest, d)?);
        self.pjrt_kernels
            .lock()
            .unwrap()
            .insert(d, Arc::clone(&built));
        Ok(built)
    }

    /// Cached initial parameter vector for `preset`.
    pub fn init(&self, preset: &str) -> Result<Arc<Vec<f32>>> {
        if let Some(v) = self.inits.lock().unwrap().get(preset) {
            return Ok(Arc::clone(v));
        }
        let info = self.manifest.preset(preset)?;
        let v = Arc::new(self.manifest.load_init(info)?);
        self.inits
            .lock()
            .unwrap()
            .insert(preset.to_string(), Arc::clone(&v));
        Ok(v)
    }
}

/// Fluent description of one training run, with typed defaults:
/// 4 workers, 240 steps, seed 0, SGP base, no SlowMo, auto schedule
/// (image warmup+decay for SGD bases, LM inverse-sqrt for Adam bases),
/// heterogeneity 0.5, eval at the end only, native optimizer kernels,
/// 10G-Ethernet cost model.
#[derive(Clone)]
pub struct TrainBuilder<'s> {
    session: Option<&'s Session>,
    cfg: TrainCfg,
    algo_spec: Option<String>,
    outer_spec: Option<String>,
    outer_tau: Option<u64>,
    quorum: Option<usize>,
    staleness: Option<u64>,
    compress_spec: Option<String>,
    /// (partition spec, two_level) — see [`TrainBuilder::groups`].
    groups_spec: Option<(String, bool)>,
    tau_inner: Option<u64>,
    inter_latency_s: Option<f64>,
    inter_bandwidth_bps: Option<f64>,
    /// Per-tier (α seconds, β bytes/s) overrides for tiers above the
    /// first — see [`TrainBuilder::tier_link`].
    tier_links: Vec<(f64, f64)>,
    state: Option<StateMode>,
    inner: Option<InnerOpt>,
    lr: Option<f32>,
    sched: Option<Schedule>,
    buffers: Option<BufferStrategy>,
    no_average: bool,
}

impl<'s> TrainBuilder<'s> {
    /// A builder not bound to a [`Session`]: `build_cfg` works (against
    /// the built-in registries), `run` does not. Prefer
    /// `session.train(..)`.
    pub fn new(preset: &str) -> Self {
        Self {
            session: None,
            cfg: TrainCfg::defaults(preset),
            algo_spec: None,
            outer_spec: None,
            outer_tau: None,
            quorum: None,
            staleness: None,
            compress_spec: None,
            groups_spec: None,
            tau_inner: None,
            inter_latency_s: None,
            inter_bandwidth_bps: None,
            tier_links: Vec::new(),
            state: None,
            inner: None,
            lr: None,
            sched: None,
            buffers: None,
            no_average: false,
        }
    }

    fn bound(session: &'s Session, preset: &str) -> Self {
        let mut b = Self::new(preset);
        b.session = Some(session);
        b
    }

    /// Select the algorithm by registry spec string, e.g. "sgp",
    /// "local-adam", "doubleavg:24". Parsed (and validated) when the run
    /// is built.
    pub fn algo(mut self, spec: &str) -> Self {
        self.algo_spec = Some(spec.to_string());
        self
    }

    /// Select a pre-parsed algorithm (key + inner optimizer + argument).
    pub fn algo_sel(mut self, sel: AlgoSel) -> Self {
        self.cfg.algo = sel;
        self.algo_spec = None;
        self
    }

    /// Override the inner optimizer independently of the algo spec.
    pub fn inner(mut self, inner: InnerOpt) -> Self {
        self.inner = Some(inner);
        self
    }

    pub fn workers(mut self, m: usize) -> Self {
        self.cfg.m = m;
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.cfg.steps = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Wrap the base algorithm in SlowMo with α=1 (the paper's setting),
    /// slow momentum `beta` and inner-loop length `tau` — a thin alias
    /// for `outer("slowmo:<beta>")` with that `tau`.
    pub fn slowmo(self, beta: f32, tau: u64) -> Self {
        self.slowmo_cfg(SlowMoCfg::new(1.0, beta, tau))
    }

    /// Select the outer-optimizer rule by registry spec string, e.g.
    /// "slowmo:0.7", "avg", "lookahead:0.5", "nesterov:0.9",
    /// "adam:0.9,0.95". Enables the outer wrapper when no SlowMo config
    /// is set yet (default τ=12); otherwise replaces the configured rule
    /// and keeps the structural knobs (τ, buffers, exact average).
    /// Parsed (and validated) against the session's
    /// [`OuterRegistry`] when the run is built.
    pub fn outer(mut self, spec: &str) -> Self {
        self.outer_spec = Some(spec.to_string());
        self
    }

    /// Override the outer-loop length τ. Requires an outer wrapper
    /// (`slowmo()`, `slowmo_cfg()` or `outer()`); an error at build time
    /// otherwise.
    pub fn tau(mut self, tau: u64) -> Self {
        self.outer_tau = Some(tau);
        self
    }

    /// Semi-synchronous outer boundaries: the outer average proceeds as
    /// soon as `q` of the `m` workers reach the boundary; late workers
    /// miss the round (survivor-rescaled mean) and resynchronize at the
    /// next boundary. `q = m` is bitwise-identical to the blocking
    /// path. Requires an outer wrapper with the exact average on, a
    /// communication-free base algorithm, and the sim backend; hard
    /// errors otherwise at build/run time.
    pub fn quorum(mut self, q: usize) -> Self {
        self.quorum = Some(q);
        self
    }

    /// Bounded staleness `s` for semi-synchronous boundaries: a
    /// quorum-late worker's parameters are folded into the *next*
    /// boundary's average, down-weighted by
    /// [`crate::slowmo::STALE_LAMBDA`], instead of dropped. `s = 0`
    /// (the default) drops late contributions. Requires
    /// [`TrainBuilder::quorum`]; an error at build time otherwise.
    pub fn staleness(mut self, s: u64) -> Self {
        self.staleness = Some(s);
        self
    }

    /// Select the communication compressor by registry spec string, e.g.
    /// "topk:0.1", "fp16", "ef:signsgd", "none" (the default). Applies to
    /// every lane the run communicates on — gossip, the base algorithm's
    /// collectives and the SlowMo outer average — with honest wire-byte
    /// accounting ([`crate::trainer::TrainResult`]'s `bytes_sent` /
    /// `bytes_saved`). Parsed (and validated) against the session's
    /// [`CompressRegistry`] when the run is built.
    pub fn compress(mut self, spec: &str) -> Self {
        self.compress_spec = Some(spec.to_string());
        self
    }

    /// Select a pre-parsed compressor selection.
    pub fn compress_sel(mut self, sel: crate::compress::CompressSel) -> Self {
        self.cfg.compress = sel;
        self.compress_spec = None;
        self
    }

    /// Partition the workers into hierarchical groups (fast intra-group,
    /// slow inter-group links) and run hierarchical SlowMo: the base
    /// algorithm goes group-local and the outer boundary becomes the
    /// tiered reduce. `spec` is a [`crate::topology::Groups`] spec —
    /// a count (`"2"`) or explicit ranges (`"0-3|4-7"`) — or an N-level
    /// [`crate::topology::TierTree`] spec with `';'`-separated tiers,
    /// leaves first (`"0-1|2-3|4-5|6-7;0-3|4-7"` = rack → pod); hard
    /// parse errors at build time naming the offending token. Requires
    /// a SlowMo outer wrapper.
    pub fn groups(mut self, spec: &str) -> Self {
        self.groups_spec = Some((spec.to_string(), true));
        self
    }

    /// Flat SlowMo *on the tiered cluster*: keep the classic global
    /// algorithm, but install the partition for per-link two-tier costs
    /// and inter-group byte accounting — the honest baseline
    /// hierarchical runs are compared against (`slowmo exp hier`).
    pub fn groups_flat(mut self, spec: &str) -> Self {
        self.groups_spec = Some((spec.to_string(), false));
        self
    }

    /// Fast intra-group exact average every `n` inner steps (0 = off).
    /// Requires [`TrainBuilder::groups`]; an error at build time
    /// otherwise.
    pub fn tau_inner(mut self, n: u64) -> Self {
        self.tau_inner = Some(n);
        self
    }

    /// Slow inter-group link parameters (α seconds, β bytes/s). Defaults
    /// to the run's cost model (both tiers equally fast). Requires a
    /// groups partition; an error at build time otherwise.
    pub fn inter_link(mut self, latency_s: f64, bandwidth_bps: f64) -> Self {
        self.inter_latency_s = Some(latency_s);
        self.inter_bandwidth_bps = Some(bandwidth_bps);
        self
    }

    /// Append a link model for the next tier above the last configured
    /// one: the first call governs transfers first joined at tier 2,
    /// the second tier 3, and so on (tier 1 uses
    /// [`TrainBuilder::inter_link`]; unconfigured tiers inherit the
    /// next-faster link). Requires an N-level [`TrainBuilder::groups`]
    /// spec deep enough for every entry; an error at build time
    /// otherwise.
    pub fn tier_link(mut self, latency_s: f64, bandwidth_bps: f64) -> Self {
        self.tier_links.push((latency_s, bandwidth_bps));
        self
    }

    /// Worker-state layout: [`StateMode::Shared`] initializes every
    /// worker from one read-only `Arc` and elides provably-unread
    /// buffers so large-m sims fit in memory (sim-only, native kernels;
    /// see [`StateMode`]). Default: [`StateMode::Dense`].
    pub fn state(mut self, mode: StateMode) -> Self {
        self.state = Some(mode);
        self
    }

    pub fn slowmo_cfg(mut self, s: SlowMoCfg) -> Self {
        self.cfg.slowmo = Some(s);
        self
    }

    pub fn slowmo_opt(mut self, s: Option<SlowMoCfg>) -> Self {
        self.cfg.slowmo = s;
        self
    }

    /// Buffer strategy at outer boundaries (applies when SlowMo is on).
    pub fn buffers(mut self, b: BufferStrategy) -> Self {
        self.buffers = Some(b);
        self
    }

    /// Skip the exact average (SGP-SlowMo-noaverage, paper §6).
    pub fn no_average(mut self) -> Self {
        self.no_average = true;
        self
    }

    /// Base/peak fast learning rate for the auto schedule. Ignored when
    /// an explicit [`TrainBuilder::schedule`] is set.
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn schedule(mut self, s: Schedule) -> Self {
        self.sched = Some(s);
        self
    }

    pub fn heterogeneity(mut self, h: f64) -> Self {
        self.cfg.heterogeneity = h;
        self
    }

    pub fn eval_every(mut self, every: u64) -> Self {
        self.cfg.eval_every = every;
        self
    }

    pub fn eval_batches(mut self, batches: u64) -> Self {
        self.cfg.eval_batches = batches;
        self
    }

    pub fn force_pjrt(mut self, on: bool) -> Self {
        self.cfg.force_pjrt = on;
        self
    }

    pub fn native_kernels(mut self, on: bool) -> Self {
        self.cfg.native_kernels = on;
        self
    }

    /// Run the optimizer kernels through the AOT PJRT artifacts instead
    /// of the native mirrors.
    pub fn pjrt_kernels(self) -> Self {
        self.native_kernels(false)
    }

    pub fn cost(mut self, cost: CostModel) -> Self {
        self.cfg.cost = cost;
        self
    }

    pub fn compute_time(mut self, seconds: f64) -> Self {
        self.cfg.compute_time_s = seconds;
        self
    }

    pub fn record_gradnorm(mut self, on: bool) -> Self {
        self.cfg.record_gradnorm = on;
        self
    }

    /// Observer early-stop granularity (see `trainer::observer`).
    pub fn stop_check_every(mut self, steps: u64) -> Self {
        self.cfg.stop_check_every = Some(steps);
        self
    }

    /// Select the execution backend: [`ExecMode::Sim`] (default) runs
    /// the simulated fabric, [`ExecMode::Threaded`] the real-parallel
    /// spin-channel transport. The math is identical across backends —
    /// `sim_time`, byte counts and (for fixed-merge-order algorithms)
    /// parameters are bitwise-equal — while `wall_time` /
    /// `comm_wall_time` measure what the hardware actually did. Chaos
    /// injection is sim-only: `exec(Threaded)` plus `chaos(..)` is a
    /// hard error at run time.
    pub fn exec(mut self, mode: ExecMode) -> Self {
        self.cfg.exec = mode;
        self
    }

    /// Attach a deterministic chaos plan: seeded per-link delays, drops
    /// with retransmit accounting, bounded reordering, stragglers, and
    /// fault windows with elastic membership at outer boundaries (see
    /// [`crate::net::chaos`]).
    pub fn chaos(mut self, c: ChaosCfg) -> Self {
        self.cfg.chaos = Some(c);
        self
    }

    pub fn chaos_opt(mut self, c: Option<ChaosCfg>) -> Self {
        self.cfg.chaos = c;
        self
    }

    /// Record worker 0's final parameters into the [`TrainResult`]
    /// (used to assert chaos moves time, never math).
    pub fn record_params(mut self, on: bool) -> Self {
        self.cfg.record_final_params = on;
        self
    }

    /// Apply a parsed TOML experiment [`Config`] (the configx→builder
    /// bridge). Recognized keys, all optional:
    ///
    /// ```toml
    /// [train]
    /// preset = "cifar-mlp"
    /// algo = "sgp"              # registry spec string
    /// m = 4
    /// steps = 240
    /// seed = 0
    /// lr = 0.1
    /// sched = "const:0.05"      # overrides lr-based auto schedule
    /// heterogeneity = 0.5
    /// eval_every = 60
    /// eval_batches = 8
    /// native_kernels = true
    /// force_pjrt = false
    /// state = "dense"           # "shared" = copy-on-write worker state
    ///
    /// [slowmo]                  # section presence enables SlowMo
    /// alpha = 1.0
    /// beta = 0.7
    /// tau = 12
    /// buffers = "reset"
    /// exact_average = true
    ///
    /// [outer]                   # outer-optimizer registry selection;
    /// rule = "adam:0.9,0.95"    # enables the wrapper on its own, or
    /// tau = 16                  # overrides [slowmo]'s rule when both
    ///                           # sections are present
    /// quorum = 3                # semi-sync boundary: proceed at q-of-m
    /// staleness = 1             # fold late workers in (0 = drop them)
    ///
    /// [compress]                # communication compression
    /// spec = "ef:topk:0.1"      # CompressRegistry spec string
    ///
    /// [exec]                    # execution backend
    /// mode = "threaded"         # "sim" (default) | "threaded"
    ///
    /// [groups]                  # hierarchical tiered topology
    /// spec = "2"                # group count, ranges "0-3|4-7", or an
    ///                           # N-level tree "0-1|2-3;0-3" (tiers
    ///                           # ';'-separated, leaves first)
    /// tau_inner = 4             # fast intra-group average period (0=off)
    /// two_level = true          # false = flat algo on the tiered fabric
    /// inter_latency_ms = 0.5    # slow inter-group link α (default: the
    /// inter_gbps = 1.0          # run's cost model) and bandwidth
    /// tier_latency_ms = [2.0]   # per-tier links above tier 1 (entry i
    /// tier_gbps = [0.25]        # governs tier i+2; set together)
    ///
    /// [chaos]                   # section presence enables chaos
    /// seed = 7
    /// delay_ms = 2.0            # mean per-message extra delay
    /// delay_max_ms = 20.0
    /// drop_prob = 0.05
    /// rto_ms = 1.0              # 0 = derive from the cost model
    /// max_retries = 3
    /// reorder_window = 4
    /// stragglers = ["1:4.0"]    # worker:compute-slowdown-factor
    /// faults = ["2@3..5"]       # worker@fail-boundary..rejoin-boundary
    /// ```
    pub fn config(mut self, c: &Config) -> Result<Self> {
        if let Some(v) = c.get("train", "preset").and_then(|v| v.as_str()) {
            self.cfg.preset = v.to_string();
        }
        if let Some(v) = c.get("train", "algo").and_then(|v| v.as_str()) {
            self.algo_spec = Some(v.to_string());
        }
        if let Some(v) = c.get("train", "m").and_then(|v| v.as_f64()) {
            self.cfg.m = v as usize;
        }
        if let Some(v) = c.get("train", "steps").and_then(|v| v.as_f64()) {
            self.cfg.steps = v as u64;
        }
        if let Some(v) = c.get("train", "seed").and_then(|v| v.as_f64()) {
            self.cfg.seed = v as u64;
        }
        if let Some(v) = c.get("train", "lr").and_then(|v| v.as_f64()) {
            self.lr = Some(v as f32);
        }
        if let Some(v) = c.get("train", "sched").and_then(|v| v.as_str()) {
            self.sched =
                Some(v.parse::<Schedule>().map_err(|e| anyhow!("{e}"))?);
        }
        if let Some(v) =
            c.get("train", "heterogeneity").and_then(|v| v.as_f64())
        {
            self.cfg.heterogeneity = v;
        }
        if let Some(v) =
            c.get("train", "eval_every").and_then(|v| v.as_f64())
        {
            self.cfg.eval_every = v as u64;
        }
        if let Some(v) =
            c.get("train", "eval_batches").and_then(|v| v.as_f64())
        {
            self.cfg.eval_batches = v as u64;
        }
        if let Some(v) =
            c.get("train", "native_kernels").and_then(|v| v.as_bool())
        {
            self.cfg.native_kernels = v;
        }
        if let Some(v) =
            c.get("train", "force_pjrt").and_then(|v| v.as_bool())
        {
            self.cfg.force_pjrt = v;
        }
        if let Some(v) = c.get("train", "state") {
            let s = v.as_str().ok_or_else(|| {
                anyhow!(
                    "[train] state must be a string (\"dense\" or \
                     \"shared\")"
                )
            })?;
            self.state = Some(
                s.parse::<StateMode>()
                    .map_err(|e| anyhow!("[train] state: {e}"))?,
            );
        }
        if c.sections.contains_key("slowmo") {
            let alpha = c.f64_or("slowmo", "alpha", 1.0) as f32;
            let beta = c.f64_or("slowmo", "beta", 0.0) as f32;
            let tau = c.f64_or("slowmo", "tau", 12.0) as u64;
            ensure!(tau >= 1, "[slowmo] tau must be >= 1 (got {tau})");
            let mut s = SlowMoCfg::new(alpha, beta, tau);
            if let Some(b) =
                c.get("slowmo", "buffers").and_then(|v| v.as_str())
            {
                s = s.with_buffers(
                    b.parse::<BufferStrategy>()
                        .map_err(|e| anyhow!("[slowmo] buffers: {e}"))?,
                );
            }
            if !c.bool_or("slowmo", "exact_average", true) {
                s = s.no_average();
            }
            self.cfg.slowmo = Some(s);
        }
        if c.sections.contains_key("outer") {
            let rule = c
                .get("outer", "rule")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "[outer] needs rule = \"<key[:args]>\" (e.g. \
                         rule = \"adam:0.9,0.95\")"
                    )
                })?;
            self.outer_spec = Some(rule.to_string());
            if let Some(v) = c.get("outer", "tau") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[outer] tau must be a number")
                })?;
                ensure!(
                    f >= 1.0 && f.fract() == 0.0,
                    "[outer] tau must be an integer >= 1 (got {f})"
                );
                self.outer_tau = Some(f as u64);
            }
            if let Some(v) = c.get("outer", "quorum") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[outer] quorum must be a number")
                })?;
                ensure!(
                    f >= 1.0 && f.fract() == 0.0,
                    "[outer] quorum must be an integer >= 1 (got {f})"
                );
                self.quorum = Some(f as usize);
            }
            if let Some(v) = c.get("outer", "staleness") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[outer] staleness must be a number")
                })?;
                ensure!(
                    f >= 0.0 && f.fract() == 0.0,
                    "[outer] staleness must be an integer >= 0 (got {f})"
                );
                self.staleness = Some(f as u64);
            }
        }
        if c.sections.contains_key("compress") {
            let spec = c
                .get("compress", "spec")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "[compress] needs spec = \"<key[:args]>\" (e.g. \
                         spec = \"topk:0.1\" or \"ef:signsgd\")"
                    )
                })?;
            self.compress_spec = Some(spec.to_string());
        }
        if c.sections.contains_key("exec") {
            let mode = c
                .get("exec", "mode")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "[exec] needs mode = \"sim\" or mode = \
                         \"threaded\""
                    )
                })?;
            self.cfg.exec = mode
                .parse::<ExecMode>()
                .map_err(|e| anyhow!("[exec] mode: {e}"))?;
        }
        if c.sections.contains_key("groups") {
            let spec = c
                .get("groups", "spec")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "[groups] needs spec = \"<count or ranges>\" \
                         (e.g. spec = \"2\" or spec = \"0-3|4-7\")"
                    )
                })?;
            let two_level = c.bool_or("groups", "two_level", true);
            self.groups_spec = Some((spec.to_string(), two_level));
            if let Some(v) = c.get("groups", "tau_inner") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[groups] tau_inner must be a number")
                })?;
                ensure!(
                    f >= 0.0 && f.fract() == 0.0,
                    "[groups] tau_inner must be an integer >= 0 (got {f})"
                );
                self.tau_inner = Some(f as u64);
            }
            // A present-but-wrong-typed knob is a hard error, not a
            // silent default (same philosophy as [chaos]).
            if let Some(v) = c.get("groups", "inter_latency_ms") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[groups] inter_latency_ms must be a number")
                })?;
                self.inter_latency_s = Some(f * 1e-3);
            }
            if let Some(v) = c.get("groups", "inter_gbps") {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("[groups] inter_gbps must be a number")
                })?;
                // Gigabits/s -> bytes/s.
                self.inter_bandwidth_bps = Some(f * 1.25e8);
            }
            // Per-tier links for N-level trees: two zipped arrays, entry
            // i governing transfers first joined at tier i + 2.
            let tier_arr = |key: &str| -> Result<Option<Vec<f64>>> {
                match c.get("groups", key) {
                    None => Ok(None),
                    Some(v) => {
                        let arr = v.as_arr().ok_or_else(|| {
                            anyhow!(
                                "[groups] {key} must be an array of \
                                 numbers"
                            )
                        })?;
                        arr.iter()
                            .map(|e| {
                                e.as_f64().ok_or_else(|| {
                                    anyhow!(
                                        "[groups] {key} entries must \
                                         be numbers"
                                    )
                                })
                            })
                            .collect::<Result<Vec<f64>>>()
                            .map(Some)
                    }
                }
            };
            match (
                tier_arr("tier_latency_ms")?,
                tier_arr("tier_gbps")?,
            ) {
                (None, None) => {}
                (Some(lat), Some(bw)) => {
                    ensure!(
                        lat.len() == bw.len(),
                        "[groups] tier_latency_ms and tier_gbps must \
                         have the same length (got {} and {})",
                        lat.len(),
                        bw.len()
                    );
                    self.tier_links = lat
                        .iter()
                        .zip(&bw)
                        .map(|(&l, &g)| (l * 1e-3, g * 1.25e8))
                        .collect();
                }
                _ => bail!(
                    "[groups] tier_latency_ms and tier_gbps must be \
                     set together (one α and one β per tier)"
                ),
            }
        }
        if c.sections.contains_key("chaos") {
            // Seeds are full 64-bit values; an f64 TOML number silently
            // loses precision above 2^53, so also accept the exact string
            // form `seed = "18446744073709551557"`.
            let seed = match c.get("chaos", "seed") {
                None => 0,
                Some(v) => {
                    if let Some(s) = v.as_str() {
                        s.parse::<u64>().map_err(|_| {
                            anyhow!("[chaos] seed: bad u64 {s:?}")
                        })?
                    } else {
                        let f = v.as_f64().ok_or_else(|| {
                            anyhow!("[chaos] seed must be an integer or \
                                     a u64 string")
                        })?;
                        ensure!(
                            f >= 0.0 && f.fract() == 0.0 && f <= 2f64.powi(53),
                            "[chaos] seed {f} is not exactly representable; \
                             use the string form, e.g. seed = \"{f:.0}\""
                        );
                        f as u64
                    }
                }
            };
            // A present-but-wrong-typed knob must be a hard error, not a
            // silent default (a chaos run that quietly measures the calm
            // network lies); same philosophy as the seed handling above.
            let num_or = |key: &str, default: f64| -> Result<f64> {
                match c.get("chaos", key) {
                    None => Ok(default),
                    Some(v) => v.as_f64().ok_or_else(|| {
                        anyhow!("[chaos] {key} must be a number")
                    }),
                }
            };
            // `as` casts also silently saturate negatives and truncate
            // fractions — reject those too.
            let uint_or = |key: &str, default: f64| -> Result<f64> {
                let v = num_or(key, default)?;
                ensure!(
                    v >= 0.0 && v.fract() == 0.0 && v <= f64::from(u32::MAX),
                    "[chaos] {key} must be an integer in 0..=u32::MAX \
                     (got {v})"
                );
                Ok(v)
            };
            let mut ch = ChaosCfg {
                seed,
                delay_mean_s: num_or("delay_ms", 0.0)? * 1e-3,
                delay_max_s: num_or("delay_max_ms", 0.0)? * 1e-3,
                drop_prob: num_or("drop_prob", 0.0)?,
                rto_s: num_or("rto_ms", 0.0)? * 1e-3,
                max_retries: uint_or("max_retries", 3.0)? as u32,
                reorder_window: uint_or("reorder_window", 1.0)? as usize,
                stragglers: Vec::new(),
                faults: Vec::new(),
            };
            if let Some(v) = c.get("chaos", "stragglers") {
                let arr = v.as_arr().ok_or_else(|| {
                    anyhow!(
                        "[chaos] stragglers must be an array of \
                         \"worker:factor\" strings"
                    )
                })?;
                for e in arr {
                    let s = e.as_str().ok_or_else(|| {
                        anyhow!("[chaos] stragglers entries must be strings")
                    })?;
                    ch.stragglers.push(
                        ChaosCfg::parse_straggler(s)
                            .map_err(|e| anyhow!("[chaos] stragglers: {e}"))?,
                    );
                }
            }
            if let Some(v) = c.get("chaos", "faults") {
                let arr = v.as_arr().ok_or_else(|| {
                    anyhow!(
                        "[chaos] faults must be an array of \
                         \"worker@fail..rejoin\" strings"
                    )
                })?;
                for e in arr {
                    let s = e.as_str().ok_or_else(|| {
                        anyhow!("[chaos] faults entries must be strings")
                    })?;
                    ch.faults.push(
                        ChaosCfg::parse_fault(s)
                            .map_err(|e| anyhow!("[chaos] faults: {e}"))?,
                    );
                }
            }
            self.cfg.chaos = Some(ch);
        }
        Ok(self)
    }

    fn resolve(
        self,
        registry: &AlgoRegistry,
        outers: &OuterRegistry,
        compressors: &CompressRegistry,
    ) -> Result<TrainCfg> {
        let mut cfg = self.cfg;
        if let Some(spec) = &self.algo_spec {
            cfg.algo = registry
                .parse(spec)
                .with_context(|| format!("resolving algo {spec:?}"))?;
        }
        if let Some(inner) = self.inner {
            cfg.algo.inner = inner;
        }
        if let Some(spec) = &self.compress_spec {
            cfg.compress = compressors
                .parse(spec)
                .with_context(|| format!("resolving compress {spec:?}"))?;
        }
        if !cfg.compress.is_none() {
            // Fail fast on bad codec arguments even when the cfg came in
            // pre-built: a full build runs the factory's own validation,
            // not just the spec grammar.
            compressors.build(&cfg.compress).with_context(|| {
                format!("resolving compress {:?}", cfg.compress.spec())
            })?;
        }
        if let Some(spec) = &self.outer_spec {
            let sel = outers
                .parse(spec)
                .with_context(|| format!("resolving outer {spec:?}"))?;
            match &mut cfg.slowmo {
                Some(s) => s.outer = sel,
                None => cfg.slowmo = Some(SlowMoCfg::with_outer(sel, 12)),
            }
        }
        if let Some(tau) = self.outer_tau {
            match &mut cfg.slowmo {
                Some(s) => s.tau = tau,
                None => bail!(
                    "tau() requires an outer wrapper — set slowmo(..) or \
                     outer(..) first"
                ),
            }
        }
        if let Some(q) = self.quorum {
            match &mut cfg.slowmo {
                Some(s) => s.quorum = Some(q),
                None => bail!(
                    "quorum() requires an outer wrapper — set slowmo(..) \
                     or outer(..) first"
                ),
            }
        }
        if let Some(st) = self.staleness {
            match &mut cfg.slowmo {
                Some(s) => s.staleness = st,
                None => bail!(
                    "staleness() requires an outer wrapper — set \
                     slowmo(..) or outer(..) first"
                ),
            }
        }
        if let Some(st) = self.state {
            cfg.state = st;
        }
        if let Some((spec, two_level)) = &self.groups_spec {
            let mut h = if *two_level {
                HierCfg::new(spec)
            } else {
                HierCfg::flat(spec)
            };
            if let Some(ti) = self.tau_inner {
                h.tau_inner = ti;
            }
            h.inter_latency_s = self.inter_latency_s;
            h.inter_bandwidth_bps = self.inter_bandwidth_bps;
            h.tier_links = self.tier_links.clone();
            cfg.hier = Some(h);
        } else if self.tau_inner.is_some()
            || self.inter_latency_s.is_some()
            || self.inter_bandwidth_bps.is_some()
            || !self.tier_links.is_empty()
        {
            bail!(
                "tau_inner()/inter_link()/tier_link() require a groups \
                 partition — set groups(..) (or a [groups] table) first"
            );
        }
        if let Some(h) = &cfg.hier {
            // Spec grammar (including N-level tier nesting) and
            // structural knobs fail hard at build time.
            h.resolve_tree(cfg.m)
                .with_context(|| format!("resolving groups {:?}", h.spec))?;
            ensure!(
                !h.two_level || cfg.slowmo.is_some(),
                "groups(..) needs a SlowMo outer wrapper (the two-level \
                 reduce runs at outer boundaries) — set slowmo(..) or \
                 outer(..), or use groups_flat(..) for tier accounting \
                 alone"
            );
        }
        if let Some(s) = &mut cfg.slowmo {
            if let Some(b) = self.buffers {
                s.buffers = b;
            }
            if self.no_average {
                s.exact_average = false;
            }
            // Structural validation surfaces here (and again at run) —
            // never as a constructor panic.
            s.validate()?;
            // Fail fast on unknown rules / bad or out-of-range args even
            // when the cfg came in pre-built (slowmo_cfg with a
            // hand-rolled OuterSel): a full build runs the factory's own
            // argument validation, not just the spec grammar.
            outers.build(&s.outer).with_context(|| {
                format!("resolving outer {:?}", s.outer.spec())
            })?;
        }
        cfg.sched = match self.sched {
            Some(s) => s,
            None => {
                if cfg.algo.inner.uses_second_moment() {
                    Schedule::lm_default(self.lr.unwrap_or(2e-3), cfg.steps)
                } else {
                    Schedule::image_default(self.lr.unwrap_or(0.1),
                                            cfg.steps)
                }
            }
        };
        Ok(cfg)
    }

    /// Resolve to a [`TrainCfg`]: parses the algo and outer specs against
    /// the bound session's registries (or the built-in registries when
    /// detached) and materializes the auto schedule.
    pub fn build_cfg(self) -> Result<TrainCfg> {
        match self.session {
            Some(s) => {
                let (algos, outers, comps) = (
                    s.registry(),
                    s.outer_registry(),
                    s.compress_registry(),
                );
                self.resolve(algos, outers, comps)
            }
            None => self.resolve(
                &AlgoRegistry::builtin(),
                &OuterRegistry::builtin(),
                &CompressRegistry::builtin(),
            ),
        }
    }

    /// Resolve against an explicit algorithm registry (detached-builder
    /// use); outer rules and compressors resolve against the built-in
    /// [`OuterRegistry`] / [`CompressRegistry`].
    pub fn build_cfg_with(self, registry: &AlgoRegistry)
                          -> Result<TrainCfg> {
        self.resolve(
            registry,
            &OuterRegistry::builtin(),
            &CompressRegistry::builtin(),
        )
    }

    pub fn run(self) -> Result<TrainResult> {
        self.run_inner(None)
    }

    /// Run with a [`RunObserver`] attached (progress streaming, early
    /// stopping). Callbacks fire on worker 0.
    pub fn run_observed(self, observer: &mut dyn RunObserver)
                        -> Result<TrainResult> {
        self.run_inner(Some(observer))
    }

    fn run_inner(self, observer: Option<&mut dyn RunObserver>)
                 -> Result<TrainResult> {
        let session = self.session.ok_or_else(|| {
            anyhow!(
                "TrainBuilder is not bound to a Session; start from \
                 session.train(preset)"
            )
        })?;
        let cfg = self.resolve(
            session.registry(),
            session.outer_registry(),
            session.compress_registry(),
        )?;
        session.run_observed(&cfg, observer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let cfg = TrainBuilder::new("quad").build_cfg().unwrap();
        assert_eq!(cfg.preset, "quad");
        assert_eq!(cfg.m, 4);
        assert_eq!(cfg.steps, 240);
        assert_eq!(cfg.seed, 0);
        assert_eq!(cfg.algo.key, "sgp");
        assert!(!cfg.algo.inner.uses_second_moment());
        assert!(cfg.slowmo.is_none());
        assert!(cfg.native_kernels);
        assert!(!cfg.force_pjrt);
        assert_eq!(cfg.eval_every, 0);
        // Auto schedule: image warmup+decay shaped around 240 steps.
        assert!(cfg.sched.gamma(0) < 0.1);
        assert!((cfg.sched.gamma(100) - 0.1).abs() < 1e-6);
        assert!(cfg.sched.gamma(239) < 1e-3);
    }

    #[test]
    fn builder_overrides_beat_defaults() {
        let cfg = TrainBuilder::new("quad")
            .algo("doubleavg:24")
            .workers(8)
            .steps(100)
            .seed(7)
            .slowmo(0.6, 12)
            .buffers(BufferStrategy::Maintain)
            .no_average()
            .schedule(Schedule::Const(0.3))
            .heterogeneity(1.0)
            .eval_every(25)
            .eval_batches(2)
            .pjrt_kernels()
            .compute_time(1e-6)
            .record_gradnorm(true)
            .stop_check_every(5)
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.algo.key, "doubleavg");
        assert_eq!(cfg.algo.arg, Some(24));
        assert_eq!(cfg.m, 8);
        assert_eq!(cfg.steps, 100);
        assert_eq!(cfg.seed, 7);
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.tau, 12);
        assert_eq!(s.buffers, BufferStrategy::Maintain);
        assert!(!s.exact_average);
        assert_eq!(cfg.sched.gamma(50), 0.3);
        assert_eq!(cfg.heterogeneity, 1.0);
        assert_eq!(cfg.eval_every, 25);
        assert!(!cfg.native_kernels);
        assert_eq!(cfg.compute_time_s, 1e-6);
        assert!(cfg.record_gradnorm);
        assert_eq!(cfg.stop_check_every, Some(5));
    }

    #[test]
    fn adam_algo_selects_lm_auto_schedule() {
        let cfg = TrainBuilder::new("lm-tiny")
            .algo("local-adam")
            .steps(1000)
            .build_cfg()
            .unwrap();
        assert!(cfg.algo.inner.uses_second_moment());
        // Inverse-sqrt shape: decays past warmup.
        assert!(cfg.sched.gamma(999) < cfg.sched.gamma(99));
    }

    #[test]
    fn explicit_inner_overrides_spec_suffix() {
        let cfg = TrainBuilder::new("quad")
            .algo("sgp")
            .inner(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 })
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.algo.inner,
                   InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
    }

    #[test]
    fn bad_algo_spec_fails_at_build() {
        let e = TrainBuilder::new("quad")
            .algo("doubleavg:abc")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("doubleavg"), "{e}");
        assert!(TrainBuilder::new("quad").algo("nope").build_cfg().is_err());
    }

    #[test]
    fn detached_builder_cannot_run() {
        let e = TrainBuilder::new("quad").run().unwrap_err().to_string();
        assert!(e.contains("not bound"), "{e}");
    }

    #[test]
    fn config_bridge_applies_train_and_slowmo_sections() {
        let toml = r#"
[train]
preset = "cifar-mlp"
algo = "local-adam"
m = 8
steps = 120
seed = 3
sched = "const:0.02"
heterogeneity = 0.9
eval_every = 30
eval_batches = 4
native_kernels = false

[slowmo]
alpha = 1.0
beta = 0.5
tau = 6
buffers = "maintain"
exact_average = false
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.preset, "cifar-mlp");
        assert_eq!(cfg.algo.key, "local");
        assert!(cfg.algo.inner.uses_second_moment());
        assert_eq!(cfg.m, 8);
        assert_eq!(cfg.steps, 120);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.sched.gamma(10), 0.02);
        assert_eq!(cfg.heterogeneity, 0.9);
        assert_eq!(cfg.eval_every, 30);
        assert_eq!(cfg.eval_batches, 4);
        assert!(!cfg.native_kernels);
        let s = cfg.slowmo.unwrap();
        assert_eq!(s.tau, 6);
        assert_eq!(s.outer, crate::slowmo::OuterSel::slowmo(1.0, 0.5));
        assert_eq!(s.buffers, BufferStrategy::Maintain);
        assert!(!s.exact_average);
    }

    #[test]
    fn quorum_and_staleness_flow_through_builder_and_toml() {
        // Builder path.
        let cfg = TrainBuilder::new("quad")
            .slowmo(0.5, 8)
            .quorum(3)
            .staleness(1)
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.quorum, Some(3));
        assert_eq!(s.staleness, 1);
        // Without an outer wrapper both knobs are build-time errors.
        let e = TrainBuilder::new("quad")
            .quorum(2)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("outer wrapper"), "{e}");
        let e = TrainBuilder::new("quad")
            .staleness(1)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("outer wrapper"), "{e}");
        // TOML path, including hard type errors.
        let toml = "[outer]\nrule = \"slowmo:0.5\"\nquorum = 3\n\
                    staleness = 1\n";
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.quorum, Some(3));
        assert_eq!(s.staleness, 1);
        let bad = Config::parse(
            "[outer]\nrule = \"avg\"\nquorum = \"three\"\n",
        )
        .unwrap();
        let e = TrainBuilder::new("quad")
            .config(&bad)
            .unwrap_err()
            .to_string();
        assert!(e.contains("quorum must be a number"), "{e}");
        let bad =
            Config::parse("[outer]\nrule = \"avg\"\nstaleness = 1.5\n")
                .unwrap();
        let e = TrainBuilder::new("quad")
            .config(&bad)
            .unwrap_err()
            .to_string();
        assert!(e.contains("staleness must be an integer"), "{e}");
    }

    #[test]
    fn builder_outer_spec_enables_and_overrides() {
        use crate::slowmo::OuterSel;
        // .outer alone enables the wrapper with default tau.
        let cfg = TrainBuilder::new("quad")
            .outer("adam:0.9,0.95")
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.outer, OuterSel::with_args("adam", &[0.9, 0.95]));
        assert_eq!(s.tau, 12);
        // .tau overrides the default; buffers still apply.
        let cfg = TrainBuilder::new("quad")
            .outer("nesterov:0.9")
            .tau(16)
            .buffers(BufferStrategy::Maintain)
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.outer.key, "nesterov");
        assert_eq!(s.tau, 16);
        assert_eq!(s.buffers, BufferStrategy::Maintain);
        // .outer after .slowmo replaces the rule, keeps tau.
        let cfg = TrainBuilder::new("quad")
            .slowmo(0.7, 8)
            .outer("avg")
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.as_ref().unwrap();
        assert_eq!(s.outer, OuterSel::new("avg"));
        assert_eq!(s.tau, 8);
        // The legacy alias builds outer = slowmo:<beta>.
        let cfg = TrainBuilder::new("quad")
            .slowmo(0.7, 8)
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.slowmo.unwrap().outer,
                   OuterSel::slowmo(1.0, 0.7));
    }

    #[test]
    fn bad_outer_spec_fails_at_build() {
        let e = TrainBuilder::new("quad")
            .outer("bogus")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("bogus"), "{e}");
        assert!(TrainBuilder::new("quad")
            .outer("adam:0.9,nope")
            .build_cfg()
            .is_err());
        // Factory-level argument validation also fires at build_cfg, not
        // only at run: lookahead alpha and adam betas are range-checked.
        assert!(TrainBuilder::new("quad")
            .outer("lookahead:0")
            .build_cfg()
            .is_err());
        assert!(TrainBuilder::new("quad")
            .outer("adam:1,0.95")
            .build_cfg()
            .is_err());
        // tau() without a wrapper is an error, not a silent no-op.
        let e = TrainBuilder::new("quad")
            .tau(8)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("outer"), "{e}");
    }

    #[test]
    fn invalid_tau_is_an_err_not_a_panic() {
        // The satellite contract: TrainBuilder::slowmo(0.5, 0) fails at
        // build/run like the TOML path does, instead of aborting.
        let e = TrainBuilder::new("quad")
            .slowmo(0.5, 0)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("tau"), "{e}");
        assert!(TrainBuilder::new("quad")
            .outer("avg")
            .tau(0)
            .build_cfg()
            .is_err());
    }

    #[test]
    fn config_bridge_applies_outer_section() {
        use crate::slowmo::OuterSel;
        let toml = r#"
[outer]
rule = "nesterov:0.8"
tau = 24
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.unwrap();
        assert_eq!(s.outer, OuterSel::with_args("nesterov", &[0.8]));
        assert_eq!(s.tau, 24);
        // [outer] overrides [slowmo]'s rule but inherits its knobs.
        let toml = r#"
[slowmo]
beta = 0.7
tau = 6
buffers = "maintain"

[outer]
rule = "adam"
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        let s = cfg.slowmo.unwrap();
        assert_eq!(s.outer, OuterSel::new("adam"));
        assert_eq!(s.tau, 6);
        assert_eq!(s.buffers, BufferStrategy::Maintain);
        // Bad sections are hard errors.
        let c = Config::parse("[outer]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c = Config::parse("[outer]\nrule = \"avg\"\ntau = 0").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c =
            Config::parse("[outer]\nrule = \"nope\"").unwrap();
        assert!(TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .is_err());
    }

    #[test]
    fn builder_compress_spec_resolves_and_validates() {
        use crate::compress::CompressSel;
        let cfg = TrainBuilder::new("quad")
            .compress("ef:topk:0.25")
            .build_cfg()
            .unwrap();
        assert_eq!(
            cfg.compress,
            CompressSel::wrapping("ef", CompressSel::with_args(
                "topk",
                &[0.25]
            ))
        );
        assert_eq!(cfg.compress.spec(), "ef:topk:0.25");
        // Default: no compression.
        let cfg = TrainBuilder::new("quad").build_cfg().unwrap();
        assert!(cfg.compress.is_none());
        // Bad specs are hard errors at build time (grammar and factory
        // validation both fire).
        for bad in ["bogus", "topk:0", "ef", "ef:none", "topk:0.1,0.2"] {
            assert!(
                TrainBuilder::new("quad")
                    .compress(bad)
                    .build_cfg()
                    .is_err(),
                "{bad} must be rejected"
            );
        }
        // A hand-rolled pre-built selection is validated too.
        assert!(TrainBuilder::new("quad")
            .compress_sel(CompressSel::with_args("topk", &[7.0]))
            .build_cfg()
            .is_err());
    }

    #[test]
    fn config_bridge_applies_compress_section() {
        let c = Config::parse("[compress]\nspec = \"topk:0.1\"").unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.compress.spec(), "topk:0.1");
        // Section without a spec is a hard error.
        let c = Config::parse("[compress]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        // Unknown codecs fail at build, not silently.
        let c = Config::parse("[compress]\nspec = \"nope\"").unwrap();
        assert!(TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .is_err());
    }

    #[test]
    fn builder_groups_resolves_and_validates() {
        // Two-level hierarchy with an explicit inter link.
        let cfg = TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("2")
            .tau_inner(4)
            .inter_link(5e-4, 1.25e8)
            .build_cfg()
            .unwrap();
        let h = cfg.hier.as_ref().unwrap();
        assert_eq!(h.spec, "2");
        assert!(h.two_level);
        assert_eq!(h.tau_inner, 4);
        assert_eq!(h.inter_latency_s, Some(5e-4));
        assert_eq!(h.inter_bandwidth_bps, Some(1.25e8));
        assert_eq!(h.resolve(8).unwrap().spec(), "0-3|4-7");
        // Flat-on-tiers baseline needs no slowmo wrapper.
        let cfg = TrainBuilder::new("quad")
            .workers(4)
            .groups_flat("0-1|2-3")
            .build_cfg()
            .unwrap();
        assert!(!cfg.hier.as_ref().unwrap().two_level);
        // Two-level without slowmo is a hard error naming the fix.
        let e = TrainBuilder::new("quad")
            .groups("2")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("SlowMo outer wrapper"), "{e}");
        // tau_inner without a partition is an error, not a no-op.
        let e = TrainBuilder::new("quad")
            .tau_inner(4)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("groups"), "{e}");
        // Bad specs fail hard at build time, naming the token.
        let e = TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("0-3|3-7")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("overlap"), "{e}");
        assert!(TrainBuilder::new("quad")
            .workers(4)
            .slowmo(0.7, 8)
            .groups("5")
            .build_cfg()
            .is_err());
        // tau_inner on the flat baseline is rejected.
        assert!(TrainBuilder::new("quad")
            .workers(4)
            .groups_flat("2")
            .tau_inner(2)
            .build_cfg()
            .is_err());
    }

    #[test]
    fn builder_state_and_tier_links_flow_through() {
        // N-level tree spec + per-tier link + shared state.
        let cfg = TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("0-1|2-3|4-5|6-7;0-3|4-7")
            .inter_link(5e-4, 1.25e9)
            .tier_link(2e-3, 1.25e8)
            .state(StateMode::Shared)
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.state, StateMode::Shared);
        let h = cfg.hier.as_ref().unwrap();
        assert_eq!(h.tier_links, vec![(2e-3, 1.25e8)]);
        let tree = h.resolve_tree(8).unwrap();
        assert_eq!(tree.depth(), 2);
        assert_eq!(tree.spec(), "0-1|2-3|4-5|6-7;0-3|4-7");
        // Dense is the default.
        let cfg = TrainBuilder::new("quad").build_cfg().unwrap();
        assert_eq!(cfg.state, StateMode::Dense);
        // Malformed N-level specs are build-time hard errors naming
        // the defect.
        let e = TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("0-1|2-3|4-5|6-7;0-2|3-7")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("not nested"), "{e}");
        let e = TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("0-3|4-7;;0-7")
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("empty"), "{e}");
        // tier_link without a partition is an error, not a no-op.
        let e = TrainBuilder::new("quad")
            .tier_link(1e-3, 1e8)
            .build_cfg()
            .unwrap_err()
            .to_string();
        assert!(e.contains("groups"), "{e}");
        // More tier links than tiers above the leaves is rejected.
        assert!(TrainBuilder::new("quad")
            .workers(8)
            .slowmo(0.7, 8)
            .groups("2")
            .tier_link(1e-3, 1e8)
            .build_cfg()
            .is_err());
    }

    #[test]
    fn config_bridge_applies_state_and_tier_links() {
        let toml = r#"
[train]
state = "shared"

[slowmo]
beta = 0.5
tau = 8

[groups]
spec = "0-1|2-3;0-3"
tier_latency_ms = [2.0]
tier_gbps = [0.5]
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.state, StateMode::Shared);
        let h = cfg.hier.unwrap();
        assert_eq!(h.spec, "0-1|2-3;0-3");
        assert_eq!(h.tier_links, vec![(2e-3, 0.5 * 1.25e8)]);
        // Bad state values are hard errors naming the token.
        let c = Config::parse("[train]\nstate = \"sparse\"").unwrap();
        let e = TrainBuilder::new("quad")
            .config(&c)
            .unwrap_err()
            .to_string();
        assert!(e.contains("sparse"), "{e}");
        let c = Config::parse("[train]\nstate = 3").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        // The tier arrays must be set together, same length, numeric.
        for bad in [
            "tier_latency_ms = [1.0]",
            "tier_gbps = [1.0]",
            "tier_latency_ms = [1.0, 2.0]\ntier_gbps = [1.0]",
            "tier_latency_ms = \"fast\"\ntier_gbps = [1.0]",
            "tier_latency_ms = [\"slow\"]\ntier_gbps = [1.0]",
        ] {
            let c = Config::parse(&format!(
                "[groups]\nspec = \"0-1|2-3;0-3\"\n{bad}"
            ))
            .unwrap();
            assert!(
                TrainBuilder::new("quad").config(&c).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn config_bridge_applies_groups_section() {
        let toml = r#"
[slowmo]
beta = 0.6
tau = 8

[groups]
spec = "0-1|2-3"
tau_inner = 2
inter_latency_ms = 0.5
inter_gbps = 1.0
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        let h = cfg.hier.unwrap();
        assert_eq!(h.spec, "0-1|2-3");
        assert!(h.two_level);
        assert_eq!(h.tau_inner, 2);
        assert_eq!(h.inter_latency_s, Some(0.5e-3));
        assert_eq!(h.inter_bandwidth_bps, Some(1.25e8));
        // two_level = false is the tiered baseline (no slowmo needed).
        let c = Config::parse(
            "[groups]\nspec = \"2\"\ntwo_level = false",
        )
        .unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert!(!cfg.hier.unwrap().two_level);
        // Section without a spec, and wrong-typed knobs, are hard errors.
        let c = Config::parse("[groups]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        for bad in ["tau_inner = 1.5", "tau_inner = -1",
                    "inter_latency_ms = \"fast\"", "inter_gbps = \"big\""]
        {
            let c = Config::parse(&format!(
                "[groups]\nspec = \"2\"\n{bad}"
            ))
            .unwrap();
            assert!(
                TrainBuilder::new("quad").config(&c).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn builder_exec_selects_backend() {
        let cfg = TrainBuilder::new("quad").build_cfg().unwrap();
        assert_eq!(cfg.exec, ExecMode::Sim);
        let cfg = TrainBuilder::new("quad")
            .exec(ExecMode::Threaded)
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.exec, ExecMode::Threaded);
    }

    #[test]
    fn config_bridge_applies_exec_section() {
        let c = Config::parse("[exec]\nmode = \"threaded\"").unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.exec, ExecMode::Threaded);
        let c = Config::parse("[exec]\nmode = \"sim\"").unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.exec, ExecMode::Sim);
        // Section without a mode, or an unknown mode, is a hard error.
        let c = Config::parse("[exec]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c = Config::parse("[exec]\nmode = \"turbo\"").unwrap();
        let e = TrainBuilder::new("quad")
            .config(&c)
            .unwrap_err()
            .to_string();
        assert!(e.contains("turbo"), "{e}");
        let c = Config::parse("[exec]\nmode = 3").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
    }

    #[test]
    fn builder_chaos_and_record_params() {
        use crate::net::FaultWindow;
        let chaos: ChaosCfg =
            "seed=9,delay=1ms,fault=2@2..4".parse().unwrap();
        let cfg = TrainBuilder::new("quad")
            .chaos(chaos)
            .record_params(true)
            .build_cfg()
            .unwrap();
        let ch = cfg.chaos.as_ref().unwrap();
        assert_eq!(ch.seed, 9);
        assert!((ch.delay_mean_s - 1e-3).abs() < 1e-12);
        assert_eq!(
            ch.faults,
            vec![FaultWindow { worker: 2, fail_at: 2, rejoin_at: 4 }]
        );
        assert!(cfg.record_final_params);
        let cfg = TrainBuilder::new("quad")
            .chaos_opt(None)
            .build_cfg()
            .unwrap();
        assert!(cfg.chaos.is_none());
    }

    #[test]
    fn config_bridge_applies_chaos_section() {
        use crate::net::FaultWindow;
        let toml = r#"
[chaos]
seed = 11
delay_ms = 2.0
delay_max_ms = 20.0
drop_prob = 0.05
rto_ms = 1.0
max_retries = 5
reorder_window = 4
stragglers = ["1:4.0", "3:2.5"]
faults = ["2@3..5"]
"#;
        let c = Config::parse(toml).unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        let ch = cfg.chaos.unwrap();
        assert_eq!(ch.seed, 11);
        assert!((ch.delay_mean_s - 2e-3).abs() < 1e-12);
        assert!((ch.delay_max_s - 20e-3).abs() < 1e-12);
        assert!((ch.drop_prob - 0.05).abs() < 1e-12);
        assert!((ch.rto_s - 1e-3).abs() < 1e-12);
        assert_eq!(ch.max_retries, 5);
        assert_eq!(ch.reorder_window, 4);
        assert_eq!(ch.stragglers, vec![(1, 4.0), (3, 2.5)]);
        assert_eq!(
            ch.faults,
            vec![FaultWindow { worker: 2, fail_at: 3, rejoin_at: 5 }]
        );
    }

    #[test]
    fn config_bridge_chaos_seed_exactness() {
        // String form preserves full 64-bit seeds exactly.
        let c = Config::parse(
            "[chaos]\nseed = \"18446744073709551557\"",
        )
        .unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.chaos.unwrap().seed, 18446744073709551557u64);
        // Numeric seeds beyond 2^53 (or negative/fractional) are rejected
        // instead of being silently rounded.
        for bad in
            ["seed = 18446744073709551557", "seed = -1", "seed = 1.5"]
        {
            let c = Config::parse(&format!("[chaos]\n{bad}")).unwrap();
            assert!(
                TrainBuilder::new("quad").config(&c).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn config_bridge_rejects_bad_chaos_entries() {
        let c =
            Config::parse("[chaos]\nstragglers = [\"oops\"]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c = Config::parse("[chaos]\nfaults = [3]").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        // Negative / fractional / wrong-typed values are hard errors,
        // not silent casts or defaults.
        for bad in ["max_retries = -1", "max_retries = 2.7",
                    "reorder_window = -2", "reorder_window = 1.5",
                    "delay_ms = \"2ms\"", "drop_prob = \"high\"",
                    "max_retries = \"5\""]
        {
            let c = Config::parse(&format!("[chaos]\n{bad}")).unwrap();
            assert!(
                TrainBuilder::new("quad").config(&c).is_err(),
                "{bad} must be rejected"
            );
        }
        // Bare section enables a (no-op) plan.
        let c = Config::parse("[chaos]").unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert!(cfg.chaos.is_some());
    }

    #[test]
    fn config_bridge_rejects_bad_values() {
        let c = Config::parse("[slowmo]\ntau = 0").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c = Config::parse("[slowmo]\nbuffers = \"bogus\"").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
        let c = Config::parse("[train]\nsched = \"wat\"").unwrap();
        assert!(TrainBuilder::new("quad").config(&c).is_err());
    }

    #[test]
    fn config_bridge_leaves_unset_fields_at_defaults() {
        let c = Config::parse("[train]\nsteps = 64").unwrap();
        let cfg = TrainBuilder::new("quad")
            .config(&c)
            .unwrap()
            .build_cfg()
            .unwrap();
        assert_eq!(cfg.steps, 64);
        assert_eq!(cfg.preset, "quad");
        assert_eq!(cfg.m, 4);
        assert!(cfg.slowmo.is_none());
    }
}
