//! Communication topologies and mixing weights.
//!
//! SGP/OSGP (paper Alg. 2/3, Assran et al. 2019) gossip over a
//! **time-varying directed exponential graph**: with workers ranked
//! `0..m-1`, at step `k` node `i` sends to the peer `2^(k mod ⌈log2 m⌉)`
//! hops away, so each node sends/receives exactly one message per step and
//! cycles through exponentially-spaced peers. The mixing matrix is
//! **column-stochastic** (each sender splits its mass: 1/2 self, 1/2 peer),
//! which together with push-sum weights de-biases the average.
//!
//! D-PSGD (Lian et al. 2017) uses an undirected graph with a
//! **doubly-stochastic** matrix; we provide the symmetric ring.

/// A directed communication round: who sends to whom with what weight.
#[derive(Clone, Debug, PartialEq)]
pub struct Round {
    /// (peer, weight) pairs for outgoing messages, excluding self.
    pub out: Vec<(usize, f64)>,
    /// Weight kept for self.
    pub self_weight: f64,
}

impl Round {
    /// Column-stochasticity: self weight + outgoing weights must sum to 1.
    pub fn total_mass(&self) -> f64 {
        self.self_weight + self.out.iter().map(|(_, w)| w).sum::<f64>()
    }
}

/// A (possibly time-varying) topology over `m` workers.
pub trait Topology: Send + Sync {
    fn m(&self) -> usize;

    /// Outgoing plan for `worker` at global gossip step `k`.
    fn round(&self, worker: usize, k: u64) -> Round;

    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;

    /// Messages sent per worker per step (for the comm cost model).
    fn sends_per_step(&self) -> usize {
        1
    }
}

/// Time-varying directed exponential graph (SGP/OSGP default).
#[derive(Clone, Debug)]
pub struct ExponentialGraph {
    m: usize,
    n_offsets: u32,
}

impl ExponentialGraph {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        // Offsets 2^0 .. 2^(ceil(log2(m))-1); for m=1 there are none.
        let n_offsets = if m <= 1 {
            0
        } else {
            (usize::BITS - (m - 1).leading_zeros()).max(1)
        };
        Self { m, n_offsets }
    }

    /// The hop distance used at step k.
    pub fn offset_at(&self, k: u64) -> usize {
        if self.n_offsets == 0 {
            0
        } else {
            1usize << (k % self.n_offsets as u64) as u32
        }
    }
}

impl Topology for ExponentialGraph {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, k: u64) -> Round {
        if self.m == 1 {
            return Round { out: vec![], self_weight: 1.0 };
        }
        let peer = (worker + self.offset_at(k)) % self.m;
        if peer == worker {
            // Happens when the offset wraps to a multiple of m (m not a
            // power of two can't produce this since offset < m, but guard).
            return Round { out: vec![], self_weight: 1.0 };
        }
        Round {
            out: vec![(peer, 0.5)],
            self_weight: 0.5,
        }
    }

    fn name(&self) -> &'static str {
        "exponential"
    }
}

/// Directed ring: node i sends to i+1 with weight 1/2.
#[derive(Clone, Debug)]
pub struct DirectedRing {
    m: usize,
}

impl DirectedRing {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for DirectedRing {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        if self.m == 1 {
            return Round { out: vec![], self_weight: 1.0 };
        }
        Round {
            out: vec![((worker + 1) % self.m, 0.5)],
            self_weight: 0.5,
        }
    }

    fn name(&self) -> &'static str {
        "directed-ring"
    }
}

/// Undirected symmetric ring with Metropolis weights 1/3 (D-PSGD): node i
/// exchanges with both neighbors; the induced matrix is doubly stochastic.
#[derive(Clone, Debug)]
pub struct SymmetricRing {
    m: usize,
}

impl SymmetricRing {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for SymmetricRing {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        match self.m {
            1 => Round { out: vec![], self_weight: 1.0 },
            2 => Round {
                out: vec![((worker + 1) % 2, 0.5)],
                self_weight: 0.5,
            },
            m => Round {
                out: vec![
                    ((worker + 1) % m, 1.0 / 3.0),
                    ((worker + m - 1) % m, 1.0 / 3.0),
                ],
                self_weight: 1.0 / 3.0,
            },
        }
    }

    fn name(&self) -> &'static str {
        "symmetric-ring"
    }

    fn sends_per_step(&self) -> usize {
        2
    }
}

/// Complete graph with uniform weights (one-step exact averaging; the
/// degenerate topology that makes gossip equal ALLREDUCE).
#[derive(Clone, Debug)]
pub struct Complete {
    m: usize,
}

impl Complete {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for Complete {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        let w = 1.0 / self.m as f64;
        Round {
            out: (0..self.m)
                .filter(|&p| p != worker)
                .map(|p| (p, w))
                .collect(),
            self_weight: w,
        }
    }

    fn name(&self) -> &'static str {
        "complete"
    }

    fn sends_per_step(&self) -> usize {
        self.m.saturating_sub(1)
    }
}

/// Build the m×m column-stochastic mixing matrix P for step k
/// (`P[dst][src]`): used by tests and the dense-mixing reference path.
pub fn mixing_matrix(topo: &dyn Topology, k: u64) -> Vec<Vec<f64>> {
    let m = topo.m();
    let mut p = vec![vec![0.0; m]; m];
    for src in 0..m {
        let round = topo.round(src, k);
        p[src][src] = round.self_weight;
        for (dst, w) in round.out {
            p[dst][src] += w;
        }
    }
    p
}

/// Column sums of a matrix (stochasticity check helper).
pub fn column_sums(p: &[Vec<f64>]) -> Vec<f64> {
    let m = p.len();
    (0..m).map(|c| (0..m).map(|r| p[r][c]).sum()).collect()
}

/// Row sums of a matrix.
pub fn row_sums(p: &[Vec<f64>]) -> Vec<f64> {
    p.iter().map(|row| row.iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Pair, UsizeIn};

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn exponential_offsets_cycle() {
        let g = ExponentialGraph::new(8); // log2(7)+1 = 3 offsets: 1,2,4
        assert_eq!(g.offset_at(0), 1);
        assert_eq!(g.offset_at(1), 2);
        assert_eq!(g.offset_at(2), 4);
        assert_eq!(g.offset_at(3), 1);
    }

    #[test]
    fn exponential_one_send_per_step() {
        let g = ExponentialGraph::new(32);
        for k in 0..10 {
            for w in 0..32 {
                let r = g.round(w, k);
                assert_eq!(r.out.len(), 1);
                assert!(close(r.total_mass(), 1.0));
            }
        }
    }

    #[test]
    fn exponential_each_node_receives_exactly_one() {
        let g = ExponentialGraph::new(16);
        for k in 0..8 {
            let mut recv_count = vec![0usize; 16];
            for w in 0..16 {
                for (p, _) in g.round(w, k).out {
                    recv_count[p] += 1;
                }
            }
            assert!(recv_count.iter().all(|&c| c == 1), "{recv_count:?}");
        }
    }

    #[test]
    fn single_node_topologies_are_self_loops() {
        for topo in [
            &ExponentialGraph::new(1) as &dyn Topology,
            &DirectedRing::new(1),
            &SymmetricRing::new(1),
            &Complete::new(1),
        ] {
            let r = topo.round(0, 0);
            assert!(r.out.is_empty());
            assert!(close(r.self_weight, 1.0));
        }
    }

    #[test]
    fn mixing_matrices_column_stochastic() {
        // Property: every topology at every step yields a column-stochastic
        // matrix (mass conservation — the push-sum invariant).
        forall(
            "column-stochastic",
            &Pair(UsizeIn(1, 33), UsizeIn(0, 20)),
            |&(m, k)| {
                let topos: Vec<Box<dyn Topology>> = vec![
                    Box::new(ExponentialGraph::new(m)),
                    Box::new(DirectedRing::new(m)),
                    Box::new(SymmetricRing::new(m)),
                    Box::new(Complete::new(m)),
                ];
                topos.iter().all(|t| {
                    column_sums(&mixing_matrix(t.as_ref(), k as u64))
                        .iter()
                        .all(|&s| close(s, 1.0))
                })
            },
        );
    }

    #[test]
    fn symmetric_ring_doubly_stochastic() {
        forall("doubly-stochastic", &UsizeIn(1, 33), |&m| {
            let p = mixing_matrix(&SymmetricRing::new(m), 0);
            column_sums(&p).iter().all(|&s| close(s, 1.0))
                && row_sums(&p).iter().all(|&s| close(s, 1.0))
        });
    }

    #[test]
    fn complete_graph_averages_in_one_step() {
        let m = 5;
        let p = mixing_matrix(&Complete::new(m), 0);
        for row in &p {
            for &v in row {
                assert!(close(v, 1.0 / m as f64));
            }
        }
    }

    #[test]
    fn exponential_info_spreads_to_all_in_log_rounds() {
        // After ceil(log2(m)) rounds every node's value has reached every
        // other node (support of P_k ... P_0 is full).
        let m = 16;
        let g = ExponentialGraph::new(m);
        let mut reach = vec![vec![false; m]; m];
        for (i, row) in reach.iter_mut().enumerate() {
            row[i] = true;
        }
        for k in 0..4 {
            let p = mixing_matrix(&g, k);
            let mut next = reach.clone();
            for dst in 0..m {
                for src in 0..m {
                    if p[dst][src] > 0.0 {
                        for origin in 0..m {
                            if reach[src][origin] {
                                next[dst][origin] = true;
                            }
                        }
                    }
                }
            }
            reach = next;
        }
        assert!(reach.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn mixing_preserves_mean_when_doubly_stochastic() {
        let m = 7;
        let p = mixing_matrix(&SymmetricRing::new(m), 0);
        let xs: Vec<f64> = (0..m).map(|i| i as f64).collect();
        let mean0: f64 = xs.iter().sum::<f64>() / m as f64;
        let mixed: Vec<f64> = (0..m)
            .map(|dst| (0..m).map(|src| p[dst][src] * xs[src]).sum())
            .collect();
        let mean1: f64 = mixed.iter().sum::<f64>() / m as f64;
        assert!(close(mean0, mean1));
    }
}
