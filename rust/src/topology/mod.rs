//! Communication topologies and mixing weights.
//!
//! SGP/OSGP (paper Alg. 2/3, Assran et al. 2019) gossip over a
//! **time-varying directed exponential graph**: with workers ranked
//! `0..m-1`, at step `k` node `i` sends to the peer `2^(k mod ⌈log2 m⌉)`
//! hops away, so each node sends/receives exactly one message per step and
//! cycles through exponentially-spaced peers. The mixing matrix is
//! **column-stochastic** (each sender splits its mass: 1/2 self, 1/2 peer),
//! which together with push-sum weights de-biases the average.
//!
//! D-PSGD (Lian et al. 2017) uses an undirected graph with a
//! **doubly-stochastic** matrix; we provide the symmetric ring.

/// A partition of the `m` workers into `g` disjoint groups — the cluster
/// shape hierarchical SlowMo runs on (fast intra-group links, slow
/// inter-group links; BMUF's node/cluster split, Gao & Huang's periodic
/// two-level structure).
///
/// Spec grammar (hard parse errors name the offending token):
/// - `"g"` — a bare group count: split `0..m` into `g` contiguous,
///   near-equal groups (sizes differ by at most one, larger groups
///   first — the [`crate::net::collectives::chunk_ranges`] convention);
/// - `"0-3|4-7"` — explicit `|`-separated inclusive ranges (a bare index
///   like `"5"` inside a `|` form is the singleton `5-5`). The ranges
///   must partition `0..m` exactly: no overlap, no gap, no out-of-range
///   worker.
///
/// Groups are canonicalized to ascending order of their first member, so
/// group leaders (lowest member rank) are ascending too — the order the
/// inter-group leader collective rings over.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Groups {
    /// Group index -> sorted member worker ids.
    members: Vec<Vec<usize>>,
    /// Worker id -> group index.
    owner: Vec<usize>,
}

impl Groups {
    fn from_members(mut members: Vec<Vec<usize>>, m: usize) -> Self {
        members.sort_by_key(|g| g[0]);
        let mut owner = vec![0usize; m];
        for (gi, grp) in members.iter().enumerate() {
            for &w in grp {
                owner[w] = gi;
            }
        }
        Self { members, owner }
    }

    /// One group holding everyone (the flat topology).
    pub fn flat(m: usize) -> Self {
        Self::even(m, 1).expect("g=1 always partitions")
    }

    /// Split `0..m` into `g` contiguous near-equal groups.
    pub fn even(m: usize, g: usize) -> Result<Self, String> {
        if m == 0 {
            return Err("groups: m must be >= 1".into());
        }
        if g == 0 {
            return Err(format!(
                "groups spec {g:?}: group count must be >= 1"
            ));
        }
        if g > m {
            return Err(format!(
                "groups spec {g:?}: group count {g} exceeds m={m}"
            ));
        }
        let base = m / g;
        let rem = m % g;
        let mut members = Vec::with_capacity(g);
        let mut start = 0;
        for i in 0..g {
            let sz = base + usize::from(i < rem);
            members.push((start..start + sz).collect());
            start += sz;
        }
        Ok(Self::from_members(members, m))
    }

    /// Parse a spec string against `m` workers (see the type docs for the
    /// grammar). Errors are hard and name the offending token.
    pub fn parse(spec: &str, m: usize) -> Result<Self, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(format!(
                "groups spec \"\": expected a group count (e.g. \"2\") or \
                 '|'-separated ranges (e.g. \"0-{}|{}-{}\")",
                m / 2,
                m / 2 + usize::from(m > 1),
                m.saturating_sub(1)
            ));
        }
        if !spec.contains('|') && !spec.contains('-') {
            let g: usize = spec.parse().map_err(|_| {
                format!(
                    "groups spec {spec:?}: expected a group count or \
                     '|'-separated ranges like \"0-3|4-7\""
                )
            })?;
            return Self::even(m, g);
        }
        let mut covered = vec![false; m];
        let mut members: Vec<Vec<usize>> = Vec::new();
        for tok in spec.split('|') {
            let tok = tok.trim();
            let parse_idx = |s: &str| -> Result<usize, String> {
                s.trim().parse::<usize>().map_err(|_| {
                    format!(
                        "groups spec {spec:?}: bad range token {tok:?} \
                         (expected \"a-b\" or a single worker index)"
                    )
                })
            };
            let (lo, hi) = match tok.split_once('-') {
                Some((a, b)) => (parse_idx(a)?, parse_idx(b)?),
                None => {
                    let w = parse_idx(tok)?;
                    (w, w)
                }
            };
            if lo > hi {
                return Err(format!(
                    "groups spec {spec:?}: range {tok:?} is inverted \
                     ({lo} > {hi})"
                ));
            }
            if hi >= m {
                return Err(format!(
                    "groups spec {spec:?}: range {tok:?} names worker {hi} \
                     but m={m}"
                ));
            }
            for w in lo..=hi {
                if covered[w] {
                    return Err(format!(
                        "groups spec {spec:?}: ranges overlap at worker \
                         {w} (token {tok:?})"
                    ));
                }
                covered[w] = true;
            }
            members.push((lo..=hi).collect());
        }
        if let Some(missing) = covered.iter().position(|&c| !c) {
            return Err(format!(
                "groups spec {spec:?}: worker {missing} is not covered \
                 (the ranges must partition 0..{m} exactly)"
            ));
        }
        Ok(Self::from_members(members, m))
    }

    /// Number of groups.
    pub fn g(&self) -> usize {
        self.members.len()
    }

    /// Total workers partitioned.
    pub fn m(&self) -> usize {
        self.owner.len()
    }

    /// Group index of `worker`.
    pub fn group_of(&self, worker: usize) -> usize {
        self.owner[worker]
    }

    /// Sorted member worker ids of group `gi`.
    pub fn members(&self, gi: usize) -> &[usize] {
        &self.members[gi]
    }

    /// All groups (ascending by first member).
    pub fn all(&self) -> &[Vec<usize>] {
        &self.members
    }

    /// Do `a` and `b` sit in different groups (a slow inter-group link)?
    pub fn is_inter(&self, a: usize, b: usize) -> bool {
        self.owner[a] != self.owner[b]
    }

    /// Does a set of workers span more than one group?
    pub fn spans(&self, workers: &[usize]) -> bool {
        match workers.first() {
            None => false,
            Some(&w0) => {
                let g0 = self.owner[w0];
                workers.iter().any(|&w| self.owner[w] != g0)
            }
        }
    }

    /// Canonical spec string ("0-3|4-7").
    pub fn spec(&self) -> String {
        self.members
            .iter()
            .map(|g| format!("{}-{}", g[0], g[g.len() - 1]))
            .collect::<Vec<_>>()
            .join("|")
    }

    /// Serial reference of the two-level weighted mean over full
    /// membership: per-group sequential f32 mean, scaled by
    /// `|G_i|·g / m`, summed across groups and divided by `g`. Equals the
    /// global mean in exact arithmetic for any partition; the distributed
    /// two-level reduce mirrors this operation order (golden-pinned in
    /// `rust/tests/golden.rs`, tolerance-tested in
    /// `rust/tests/properties.rs`).
    pub fn weighted_mean(&self, xs: &[Vec<f32>]) -> Vec<f32> {
        assert_eq!(xs.len(), self.m(), "weighted_mean needs one vec per worker");
        let d = xs.first().map(|v| v.len()).unwrap_or(0);
        let n = self.g();
        let mut acc = vec![0.0f32; d];
        for grp in &self.members {
            let mut gm = vec![0.0f32; d];
            for &w in grp {
                for (a, &v) in gm.iter_mut().zip(&xs[w]) {
                    *a += v;
                }
            }
            let inv = 1.0 / grp.len() as f32;
            for v in gm.iter_mut() {
                *v *= inv;
            }
            let factor = (grp.len() * n) as f32 / self.m() as f32;
            if factor != 1.0 {
                for v in gm.iter_mut() {
                    *v *= factor;
                }
            }
            for (a, &v) in acc.iter_mut().zip(&gm) {
                *a += v;
            }
        }
        let inv_n = 1.0 / n as f32;
        for v in acc.iter_mut() {
            *v *= inv_n;
        }
        acc
    }
}

/// A recursive N-level tier tree over `m` workers — the cluster shape at
/// production scale (rack → pod → datacenter), generalizing the two-level
/// [`Groups`] partition. Each tier is itself a `Groups` partition of
/// `0..m`; tier 0 is the finest (leaf) level and deeper tiers must
/// *nest*: every tier-`l` group is a union of tier-`l-1` groups.
///
/// Spec grammar: `;`-separated tiers, leaves first, each tier in the
/// [`Groups`] grammar — e.g. `"0-1|2-3|4-5|6-7;0-3|4-7"` is four racks in
/// two pods over m=8. Hard parse errors name the offending token: gaps,
/// overlaps and out-of-range workers are rejected by the per-tier
/// [`Groups::parse`], empty tiers and non-nested ranges by the tree
/// validation here.
///
/// A depth-1 tree is exactly one `Groups` partition — the two-level
/// hierarchy every existing path runs on (bitwise-identical, asserted in
/// `rust/src/slowmo/hier.rs` and `rust/tests/equivalences.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TierTree {
    tiers: Vec<std::sync::Arc<Groups>>,
}

impl TierTree {
    /// Wrap a single partition as a depth-1 tree (the two-level case).
    pub fn from_groups(groups: std::sync::Arc<Groups>) -> Self {
        Self { tiers: vec![groups] }
    }

    /// Parse a `;`-separated tier spec against `m` workers (see the type
    /// docs for the grammar). Errors are hard and name the offending
    /// token.
    pub fn parse(spec: &str, m: usize) -> Result<Self, String> {
        let spec_t = spec.trim();
        if spec_t.is_empty() {
            return Err(
                "tiers spec \"\": expected ';'-separated tier partitions \
                 (leaves first), e.g. \"0-1|2-3;0-3\""
                    .into(),
            );
        }
        let mut tiers = Vec::new();
        for (l, tok) in spec_t.split(';').enumerate() {
            if tok.trim().is_empty() {
                return Err(format!(
                    "tiers spec {spec:?}: tier {l} is empty (token \
                     {tok:?}) — every ';'-separated tier needs a partition"
                ));
            }
            let tier = Groups::parse(tok, m)
                .map_err(|e| format!("tiers spec {spec:?}, tier {l}: {e}"))?;
            tiers.push(std::sync::Arc::new(tier));
        }
        let tree = Self { tiers };
        tree.validate_nesting(spec)?;
        Ok(tree)
    }

    /// Check every tier coarsens the one below it: a tier-`l` group may
    /// never split a tier-`l-1` group across two parents.
    fn validate_nesting(&self, spec: &str) -> Result<(), String> {
        for l in 1..self.tiers.len() {
            let (fine, coarse) = (&self.tiers[l - 1], &self.tiers[l]);
            for grp in fine.all() {
                let parent = coarse.group_of(grp[0]);
                if let Some(&w) =
                    grp.iter().find(|&&w| coarse.group_of(w) != parent)
                {
                    return Err(format!(
                        "tiers spec {spec:?}: tier {l} is not nested — \
                         group {}-{} of tier {} is split across tier-{l} \
                         groups (workers {} and {w} have different \
                         parents)",
                        grp[0],
                        grp[grp.len() - 1],
                        l - 1,
                        grp[0],
                    ));
                }
            }
        }
        Ok(())
    }

    /// Number of tiers (1 = the two-level hierarchy).
    pub fn depth(&self) -> usize {
        self.tiers.len()
    }

    /// Total workers.
    pub fn m(&self) -> usize {
        self.tiers[0].m()
    }

    /// The finest (leaf) partition — what two-level code paths consume.
    pub fn leaf(&self) -> &std::sync::Arc<Groups> {
        &self.tiers[0]
    }

    /// Partition at tier `l` (0 = leaves).
    pub fn tier(&self, l: usize) -> &std::sync::Arc<Groups> {
        &self.tiers[l]
    }

    /// All tiers, leaves first.
    pub fn tiers(&self) -> &[std::sync::Arc<Groups>] {
        &self.tiers
    }

    /// The shallowest tier at which `a` and `b` share a group: `Some(0)`
    /// for same leaf group, `Some(l)` when tier `l` is the first to join
    /// them, `None` when they differ at every tier (top-level crossing).
    pub fn join_level(&self, a: usize, b: usize) -> Option<usize> {
        self.tiers.iter().position(|t| !t.is_inter(a, b))
    }

    /// The shallowest tier whose groups contain all of `workers`
    /// (`None` when they span even the top tier).
    pub fn span_level(&self, workers: &[usize]) -> Option<usize> {
        self.tiers.iter().position(|t| !t.spans(workers))
    }

    /// Canonical spec string ("0-1|2-3;0-3").
    pub fn spec(&self) -> String {
        self.tiers
            .iter()
            .map(|t| t.spec())
            .collect::<Vec<_>>()
            .join(";")
    }
}

/// A directed communication round: who sends to whom with what weight.
#[derive(Clone, Debug, PartialEq)]
pub struct Round {
    /// (peer, weight) pairs for outgoing messages, excluding self.
    pub out: Vec<(usize, f64)>,
    /// Weight kept for self.
    pub self_weight: f64,
}

impl Round {
    /// Column-stochasticity: self weight + outgoing weights must sum to 1.
    pub fn total_mass(&self) -> f64 {
        self.self_weight + self.out.iter().map(|(_, w)| w).sum::<f64>()
    }
}

/// A (possibly time-varying) topology over `m` workers.
pub trait Topology: Send + Sync {
    fn m(&self) -> usize;

    /// Outgoing plan for `worker` at global gossip step `k`.
    fn round(&self, worker: usize, k: u64) -> Round;

    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;

    /// Messages sent per worker per step (for the comm cost model).
    fn sends_per_step(&self) -> usize {
        1
    }
}

/// Time-varying directed exponential graph (SGP/OSGP default).
#[derive(Clone, Debug)]
pub struct ExponentialGraph {
    m: usize,
    n_offsets: u32,
}

impl ExponentialGraph {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        // Offsets 2^0 .. 2^(ceil(log2(m))-1); for m=1 there are none.
        let n_offsets = if m <= 1 {
            0
        } else {
            (usize::BITS - (m - 1).leading_zeros()).max(1)
        };
        Self { m, n_offsets }
    }

    /// The hop distance used at step k.
    pub fn offset_at(&self, k: u64) -> usize {
        if self.n_offsets == 0 {
            0
        } else {
            1usize << (k % self.n_offsets as u64) as u32
        }
    }
}

impl Topology for ExponentialGraph {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, k: u64) -> Round {
        if self.m == 1 {
            return Round { out: vec![], self_weight: 1.0 };
        }
        let peer = (worker + self.offset_at(k)) % self.m;
        if peer == worker {
            // Happens when the offset wraps to a multiple of m (m not a
            // power of two can't produce this since offset < m, but guard).
            return Round { out: vec![], self_weight: 1.0 };
        }
        Round {
            out: vec![(peer, 0.5)],
            self_weight: 0.5,
        }
    }

    fn name(&self) -> &'static str {
        "exponential"
    }
}

/// Directed ring: node i sends to i+1 with weight 1/2.
#[derive(Clone, Debug)]
pub struct DirectedRing {
    m: usize,
}

impl DirectedRing {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for DirectedRing {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        if self.m == 1 {
            return Round { out: vec![], self_weight: 1.0 };
        }
        Round {
            out: vec![((worker + 1) % self.m, 0.5)],
            self_weight: 0.5,
        }
    }

    fn name(&self) -> &'static str {
        "directed-ring"
    }
}

/// Undirected symmetric ring with Metropolis weights 1/3 (D-PSGD): node i
/// exchanges with both neighbors; the induced matrix is doubly stochastic.
#[derive(Clone, Debug)]
pub struct SymmetricRing {
    m: usize,
}

impl SymmetricRing {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for SymmetricRing {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        match self.m {
            1 => Round { out: vec![], self_weight: 1.0 },
            2 => Round {
                out: vec![((worker + 1) % 2, 0.5)],
                self_weight: 0.5,
            },
            m => Round {
                out: vec![
                    ((worker + 1) % m, 1.0 / 3.0),
                    ((worker + m - 1) % m, 1.0 / 3.0),
                ],
                self_weight: 1.0 / 3.0,
            },
        }
    }

    fn name(&self) -> &'static str {
        "symmetric-ring"
    }

    fn sends_per_step(&self) -> usize {
        2
    }
}

/// Complete graph with uniform weights (one-step exact averaging; the
/// degenerate topology that makes gossip equal ALLREDUCE).
#[derive(Clone, Debug)]
pub struct Complete {
    m: usize,
}

impl Complete {
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        Self { m }
    }
}

impl Topology for Complete {
    fn m(&self) -> usize {
        self.m
    }

    fn round(&self, worker: usize, _k: u64) -> Round {
        let w = 1.0 / self.m as f64;
        Round {
            out: (0..self.m)
                .filter(|&p| p != worker)
                .map(|p| (p, w))
                .collect(),
            self_weight: w,
        }
    }

    fn name(&self) -> &'static str {
        "complete"
    }

    fn sends_per_step(&self) -> usize {
        self.m.saturating_sub(1)
    }
}

/// Build the m×m column-stochastic mixing matrix P for step k
/// (`P[dst][src]`): used by tests and the dense-mixing reference path.
pub fn mixing_matrix(topo: &dyn Topology, k: u64) -> Vec<Vec<f64>> {
    let m = topo.m();
    let mut p = vec![vec![0.0; m]; m];
    for src in 0..m {
        let round = topo.round(src, k);
        p[src][src] = round.self_weight;
        for (dst, w) in round.out {
            p[dst][src] += w;
        }
    }
    p
}

/// Column sums of a matrix (stochasticity check helper).
pub fn column_sums(p: &[Vec<f64>]) -> Vec<f64> {
    let m = p.len();
    (0..m).map(|c| (0..m).map(|r| p[r][c]).sum()).collect()
}

/// Row sums of a matrix.
pub fn row_sums(p: &[Vec<f64>]) -> Vec<f64> {
    p.iter().map(|row| row.iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Pair, UsizeIn};

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn exponential_offsets_cycle() {
        let g = ExponentialGraph::new(8); // log2(7)+1 = 3 offsets: 1,2,4
        assert_eq!(g.offset_at(0), 1);
        assert_eq!(g.offset_at(1), 2);
        assert_eq!(g.offset_at(2), 4);
        assert_eq!(g.offset_at(3), 1);
    }

    #[test]
    fn exponential_one_send_per_step() {
        let g = ExponentialGraph::new(32);
        for k in 0..10 {
            for w in 0..32 {
                let r = g.round(w, k);
                assert_eq!(r.out.len(), 1);
                assert!(close(r.total_mass(), 1.0));
            }
        }
    }

    #[test]
    fn exponential_each_node_receives_exactly_one() {
        let g = ExponentialGraph::new(16);
        for k in 0..8 {
            let mut recv_count = vec![0usize; 16];
            for w in 0..16 {
                for (p, _) in g.round(w, k).out {
                    recv_count[p] += 1;
                }
            }
            assert!(recv_count.iter().all(|&c| c == 1), "{recv_count:?}");
        }
    }

    #[test]
    fn single_node_topologies_are_self_loops() {
        for topo in [
            &ExponentialGraph::new(1) as &dyn Topology,
            &DirectedRing::new(1),
            &SymmetricRing::new(1),
            &Complete::new(1),
        ] {
            let r = topo.round(0, 0);
            assert!(r.out.is_empty());
            assert!(close(r.self_weight, 1.0));
        }
    }

    #[test]
    fn mixing_matrices_column_stochastic() {
        // Property: every topology at every step yields a column-stochastic
        // matrix (mass conservation — the push-sum invariant).
        forall(
            "column-stochastic",
            &Pair(UsizeIn(1, 33), UsizeIn(0, 20)),
            |&(m, k)| {
                let topos: Vec<Box<dyn Topology>> = vec![
                    Box::new(ExponentialGraph::new(m)),
                    Box::new(DirectedRing::new(m)),
                    Box::new(SymmetricRing::new(m)),
                    Box::new(Complete::new(m)),
                ];
                topos.iter().all(|t| {
                    column_sums(&mixing_matrix(t.as_ref(), k as u64))
                        .iter()
                        .all(|&s| close(s, 1.0))
                })
            },
        );
    }

    #[test]
    fn symmetric_ring_doubly_stochastic() {
        forall("doubly-stochastic", &UsizeIn(1, 33), |&m| {
            let p = mixing_matrix(&SymmetricRing::new(m), 0);
            column_sums(&p).iter().all(|&s| close(s, 1.0))
                && row_sums(&p).iter().all(|&s| close(s, 1.0))
        });
    }

    #[test]
    fn complete_graph_averages_in_one_step() {
        let m = 5;
        let p = mixing_matrix(&Complete::new(m), 0);
        for row in &p {
            for &v in row {
                assert!(close(v, 1.0 / m as f64));
            }
        }
    }

    #[test]
    fn exponential_info_spreads_to_all_in_log_rounds() {
        // After ceil(log2(m)) rounds every node's value has reached every
        // other node (support of P_k ... P_0 is full).
        let m = 16;
        let g = ExponentialGraph::new(m);
        let mut reach = vec![vec![false; m]; m];
        for (i, row) in reach.iter_mut().enumerate() {
            row[i] = true;
        }
        for k in 0..4 {
            let p = mixing_matrix(&g, k);
            let mut next = reach.clone();
            for dst in 0..m {
                for src in 0..m {
                    if p[dst][src] > 0.0 {
                        for origin in 0..m {
                            if reach[src][origin] {
                                next[dst][origin] = true;
                            }
                        }
                    }
                }
            }
            reach = next;
        }
        assert!(reach.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn exponential_in_degree_is_at_most_one_every_step() {
        // Property (scalable-SGP regime): at every step each node sends to
        // exactly one peer and receives from exactly one peer — the
        // one-peer time-varying exponential graph never fans in.
        forall(
            "exp-in-degree-1",
            &Pair(UsizeIn(2, 65), UsizeIn(0, 64)),
            |&(m, k)| {
                let g = ExponentialGraph::new(m);
                let mut recv = vec![0usize; m];
                for w in 0..m {
                    let r = g.round(w, k as u64);
                    if r.out.len() != 1 {
                        return false;
                    }
                    recv[r.out[0].0] += 1;
                }
                recv.iter().all(|&c| c <= 1) && recv.iter().sum::<usize>() == m
            },
        );
    }

    #[test]
    fn exponential_period_and_offset_partition() {
        // Property: the offset schedule has period ceil(log2 m), and any
        // window of one period partitions its steps exactly over the
        // offsets {1, 2, 4, ..., 2^(p-1)} — each offset used once.
        forall(
            "exp-offset-partition",
            &Pair(UsizeIn(2, 65), UsizeIn(0, 64)),
            |&(m, start)| {
                let g = ExponentialGraph::new(m);
                let p = (usize::BITS
                    - (m - 1).leading_zeros())
                    .max(1) as u64;
                let window: Vec<usize> = (start as u64..start as u64 + p)
                    .map(|k| g.offset_at(k))
                    .collect();
                let mut want: Vec<usize> =
                    (0..p).map(|i| 1usize << i).collect();
                let mut got = window.clone();
                got.sort_unstable();
                want.sort_unstable();
                got == want
                    && (0..2 * p).all(|k| {
                        g.offset_at(k) == g.offset_at(k + p)
                    })
            },
        );
    }

    #[test]
    fn exponential_push_sum_conserves_mass() {
        // Push-sum invariant under the time-varying graph: total value
        // mass and total weight are conserved at every step, and weights
        // stay strictly positive (the de-bias divisor never degenerates).
        forall(
            "exp-push-sum-mass",
            &Pair(UsizeIn(2, 33), UsizeIn(1, 16)),
            |&(m, steps)| {
                let g = ExponentialGraph::new(m);
                let mut x: Vec<f64> =
                    (0..m).map(|i| (i as f64) * 0.7 - 1.3).collect();
                let mut w = vec![1.0f64; m];
                let mass0: f64 = x.iter().sum();
                for k in 0..steps as u64 {
                    let p = mixing_matrix(&g, k);
                    let apply = |v: &[f64]| -> Vec<f64> {
                        (0..m)
                            .map(|dst| {
                                (0..m)
                                    .map(|src| p[dst][src] * v[src])
                                    .sum()
                            })
                            .collect()
                    };
                    x = apply(&x);
                    w = apply(&w);
                    if (x.iter().sum::<f64>() - mass0).abs() > 1e-9
                        || (w.iter().sum::<f64>() - m as f64).abs() > 1e-9
                        || w.iter().any(|&wi| wi <= 0.0)
                    {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn exponential_push_sum_exact_average_at_power_of_two() {
        // For m a power of two the de-biased ratios hit the exact average
        // after one period (the hypercube-reduce special case).
        for m in [2usize, 4, 8, 16, 32] {
            let g = ExponentialGraph::new(m);
            let p = (usize::BITS - (m - 1).leading_zeros()).max(1) as u64;
            let mut x: Vec<f64> =
                (0..m).map(|i| (i * i) as f64 * 0.11).collect();
            let mut w = vec![1.0f64; m];
            let mean = x.iter().sum::<f64>() / m as f64;
            for k in 0..p {
                let pk = mixing_matrix(&g, k);
                let apply = |v: &[f64]| -> Vec<f64> {
                    (0..m)
                        .map(|dst| {
                            (0..m).map(|src| pk[dst][src] * v[src]).sum()
                        })
                        .collect()
                };
                x = apply(&x);
                w = apply(&w);
            }
            for i in 0..m {
                assert!(
                    (x[i] / w[i] - mean).abs() < 1e-9,
                    "m={m} node {i}: {} vs {mean}",
                    x[i] / w[i]
                );
            }
        }
    }

    #[test]
    fn tier_tree_parses_and_nests() {
        let t = TierTree::parse("0-1|2-3|4-5|6-7;0-3|4-7", 8).unwrap();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.m(), 8);
        assert_eq!(t.leaf().g(), 4);
        assert_eq!(t.tier(1).g(), 2);
        assert_eq!(t.spec(), "0-1|2-3|4-5|6-7;0-3|4-7");
        assert_eq!(
            TierTree::parse(&t.spec(), 8).unwrap(),
            t,
            "spec must round-trip"
        );
        // join_level: same rack -> 0, same pod -> 1, cross pod -> None.
        assert_eq!(t.join_level(0, 1), Some(0));
        assert_eq!(t.join_level(0, 2), Some(1));
        assert_eq!(t.join_level(0, 4), None);
        assert_eq!(t.span_level(&[0, 1]), Some(0));
        assert_eq!(t.span_level(&[0, 3]), Some(1));
        assert_eq!(t.span_level(&[0, 7]), None);
        // Bare counts work per tier too, and depth-1 equals plain Groups.
        let t = TierTree::parse("4;2", 8).unwrap();
        assert_eq!(t.leaf().spec(), "0-1|2-3|4-5|6-7");
        assert_eq!(t.tier(1).spec(), "0-3|4-7");
        let d1 = TierTree::parse("0-3|4-7", 8).unwrap();
        assert_eq!(d1.depth(), 1);
        assert_eq!(
            d1.leaf().as_ref(),
            &Groups::parse("0-3|4-7", 8).unwrap()
        );
        // Three tiers.
        let t = TierTree::parse("8;4;2", 16).unwrap();
        assert_eq!(t.depth(), 3);
        assert_eq!(t.join_level(0, 2), Some(1));
        assert_eq!(t.join_level(0, 4), Some(2));
        assert_eq!(t.join_level(0, 8), None);
    }

    #[test]
    fn tier_tree_malformed_specs_are_hard_errors_naming_the_token() {
        // Gap inside a tier names the missing worker and the tier.
        let e = TierTree::parse("0-2|4-7;0-7", 8).unwrap_err();
        assert!(e.contains("tier 0"), "{e}");
        assert!(e.contains("worker 3"), "{e}");
        // Overlap inside a tier names the worker and the token.
        let e = TierTree::parse("0-3|3-7;0-7", 8).unwrap_err();
        assert!(e.contains("overlap at worker 3"), "{e}");
        // Empty tier (trailing or doubled ';') names the tier index.
        let e = TierTree::parse("0-3|4-7;", 8).unwrap_err();
        assert!(e.contains("tier 1 is empty"), "{e}");
        let e = TierTree::parse(";0-7", 8).unwrap_err();
        assert!(e.contains("tier 0 is empty"), "{e}");
        // Non-nested ranges name the split group and the worker pair.
        let e = TierTree::parse("0-2|3-5|6-7;0-3|4-7", 8).unwrap_err();
        assert!(e.contains("not nested"), "{e}");
        assert!(e.contains("3-5"), "{e}");
        // Out-of-range and inverted tokens surface the Groups error with
        // tier context.
        let e = TierTree::parse("0-3|4-9;0-7", 8).unwrap_err();
        assert!(e.contains("4-9") && e.contains("tier 0"), "{e}");
        assert!(TierTree::parse("", 8).is_err());
    }

    #[test]
    fn groups_even_split_shapes() {
        let g = Groups::even(8, 3).unwrap();
        assert_eq!(g.g(), 3);
        assert_eq!(g.m(), 8);
        assert_eq!(g.members(0), &[0, 1, 2]);
        assert_eq!(g.members(1), &[3, 4, 5]);
        assert_eq!(g.members(2), &[6, 7]);
        assert_eq!(g.spec(), "0-2|3-5|6-7");
        assert_eq!(g.group_of(4), 1);
        assert!(g.is_inter(2, 3));
        assert!(!g.is_inter(3, 5));
        assert!(g.spans(&[0, 7]));
        assert!(!g.spans(&[3, 4]));
        assert!(!g.spans(&[]));
        assert_eq!(Groups::flat(5).g(), 1);
    }

    #[test]
    fn groups_parse_count_and_ranges() {
        assert_eq!(Groups::parse("2", 8).unwrap(), Groups::even(8, 2).unwrap());
        let g = Groups::parse("4-7|0-3", 8).unwrap();
        // Canonicalized ascending by first member.
        assert_eq!(g.members(0), &[0, 1, 2, 3]);
        assert_eq!(g.members(1), &[4, 5, 6, 7]);
        // Singleton index inside a ranged form.
        let g = Groups::parse("0-1|2|3", 4).unwrap();
        assert_eq!(g.g(), 3);
        assert_eq!(g.members(1), &[2]);
        // Round trip through the canonical spec.
        let g = Groups::parse("0-2|3-7", 8).unwrap();
        assert_eq!(Groups::parse(&g.spec(), 8).unwrap(), g);
    }

    #[test]
    fn groups_malformed_specs_are_hard_errors_naming_the_token() {
        // Zero count / count exceeding m.
        let e = Groups::parse("0", 4).unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        let e = Groups::parse("5", 4).unwrap_err();
        assert!(e.contains("exceeds m=4"), "{e}");
        // Garbage count.
        let e = Groups::parse("two", 4).unwrap_err();
        assert!(e.contains("two"), "{e}");
        // Overlap names the worker and the token.
        let e = Groups::parse("0-3|3-7", 8).unwrap_err();
        assert!(e.contains("overlap at worker 3"), "{e}");
        assert!(e.contains("3-7"), "{e}");
        // Gap names the missing worker.
        let e = Groups::parse("0-2|4-7", 8).unwrap_err();
        assert!(e.contains("worker 3"), "{e}");
        // Out of range names the token and m.
        let e = Groups::parse("0-3|4-9", 8).unwrap_err();
        assert!(e.contains("4-9"), "{e}");
        assert!(e.contains("m=8"), "{e}");
        // Inverted range.
        let e = Groups::parse("3-1|0|2", 4).unwrap_err();
        assert!(e.contains("inverted"), "{e}");
        // Garbage range token / empty spec.
        assert!(Groups::parse("0-x|1-3", 4).is_err());
        assert!(Groups::parse("", 4).is_err());
    }

    #[test]
    fn groups_partition_property_small_domain() {
        // Exhaustive over a small domain: every accepted count spec
        // partitions 0..m exactly once.
        for m in 1..=12 {
            for g in 1..=14 {
                match Groups::even(m, g) {
                    Ok(gr) => {
                        assert!(g <= m);
                        let mut seen = vec![0usize; m];
                        for gi in 0..gr.g() {
                            for &w in gr.members(gi) {
                                seen[w] += 1;
                                assert_eq!(gr.group_of(w), gi);
                            }
                        }
                        assert!(seen.iter().all(|&c| c == 1), "m={m} g={g}");
                        assert_eq!(gr.g(), g);
                    }
                    Err(_) => assert!(g > m, "m={m} g={g} wrongly rejected"),
                }
            }
        }
    }

    #[test]
    fn groups_weighted_mean_equals_global_mean() {
        // Unequal groups: the |G|·g/m weighting recovers the exact global
        // mean (up to f32 rounding).
        let m = 7;
        let gr = Groups::parse("0|1-3|4-6", m).unwrap();
        let xs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..5).map(|i| (w * 5 + i) as f32 * 0.3).collect())
            .collect();
        let got = gr.weighted_mean(&xs);
        for i in 0..5 {
            let want: f64 = (0..m)
                .map(|w| f64::from(xs[w][i]))
                .sum::<f64>()
                / m as f64;
            assert!(
                (f64::from(got[i]) - want).abs() < 1e-5,
                "i={i}: {} vs {want}",
                got[i]
            );
        }
        // Equal groups: every scale factor is exactly 1.0.
        let gr = Groups::even(8, 4).unwrap();
        let xs: Vec<Vec<f32>> = (0..8).map(|w| vec![w as f32; 3]).collect();
        let got = gr.weighted_mean(&xs);
        assert!(got.iter().all(|&v| (v - 3.5).abs() < 1e-6), "{got:?}");
    }

    #[test]
    fn mixing_preserves_mean_when_doubly_stochastic() {
        let m = 7;
        let p = mixing_matrix(&SymmetricRing::new(m), 0);
        let xs: Vec<f64> = (0..m).map(|i| i as f64).collect();
        let mean0: f64 = xs.iter().sum::<f64>() / m as f64;
        let mixed: Vec<f64> = (0..m)
            .map(|dst| (0..m).map(|src| p[dst][src] * xs[src]).sum())
            .collect();
        let mean1: f64 = mixed.iter().sum::<f64>() / m as f64;
        assert!(close(mean0, mean1));
    }
}
