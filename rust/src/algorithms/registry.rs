//! String-keyed registry of [`BaseAlgorithm`] factories.
//!
//! The registry replaces the old closed `AlgoSpec` enum (and its
//! triple-maintained `parse`/`build`/match arms): every algorithm registers
//! one factory under a string key, and the same key is reachable from the
//! CLI (`--algo`), TOML configs, the bench harness, and
//! [`crate::session::TrainBuilder`]. Algorithms defined outside this crate
//! register through [`AlgoRegistry::register`] on a
//! [`crate::session::Session`] and are immediately runnable by key.

use super::{AllReduce, BaseAlgorithm, DoubleAvg, Dpsgd, Local, Sgp};
use crate::optim::kernels::InnerOpt;
use crate::topology::{DirectedRing, ExponentialGraph};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Everything a factory may consult when instantiating an algorithm.
pub struct AlgoCtx {
    pub inner: InnerOpt,
    /// Number of workers in the run (topology sizing).
    pub m: usize,
    /// Optional `:n` argument from the spec string (e.g. double-avg τ).
    pub arg: Option<u64>,
}

/// A parsed algorithm selection: registry key + inner optimizer + optional
/// numeric argument. [`AlgoRegistry::build`] turns it into a live
/// [`BaseAlgorithm`] for a concrete worker count.
#[derive(Clone, Debug, PartialEq)]
pub struct AlgoSel {
    pub key: String,
    pub inner: InnerOpt,
    pub arg: Option<u64>,
}

impl AlgoSel {
    /// Select `key` with the default Nesterov-SGD inner optimizer.
    pub fn new(key: &str) -> Self {
        Self::with_inner(key, InnerOpt::nesterov_default())
    }

    pub fn with_inner(key: &str, inner: InnerOpt) -> Self {
        Self {
            key: key.to_string(),
            inner,
            arg: None,
        }
    }

    pub fn arg(mut self, arg: u64) -> Self {
        self.arg = Some(arg);
        self
    }

    /// The spec-string form ("doubleavg:24", "local-adam").
    pub fn spec(&self) -> String {
        let mut s = self.key.clone();
        if self.inner.uses_second_moment() {
            s.push_str("-adam");
        }
        if let Some(a) = self.arg {
            s.push(':');
            s.push_str(&a.to_string());
        }
        s
    }
}

struct AlgoEntry {
    factory: Box<dyn Fn(&AlgoCtx) -> Arc<dyn BaseAlgorithm> + Send + Sync>,
    help: String,
    takes_arg: bool,
}

/// The registry itself: canonical key -> factory, plus aliases.
pub struct AlgoRegistry {
    entries: BTreeMap<String, AlgoEntry>,
    aliases: BTreeMap<String, String>,
}

impl Default for AlgoRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl AlgoRegistry {
    /// An empty registry (no algorithms).
    pub fn empty() -> Self {
        Self {
            entries: BTreeMap::new(),
            aliases: BTreeMap::new(),
        }
    }

    /// The paper's baselines, pre-registered.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(
            "local",
            "no inner-loop communication (Local SGD / Local Adam)",
            false,
            |c: &AlgoCtx| Arc::new(Local::new(c.inner)) as Arc<dyn BaseAlgorithm>,
        );
        r.register(
            "sgp",
            "stochastic gradient push over the exponential graph (Alg. 2)",
            false,
            |c: &AlgoCtx| {
                Arc::new(Sgp::new(c.inner, Arc::new(ExponentialGraph::new(c.m))))
                    as Arc<dyn BaseAlgorithm>
            },
        );
        r.register(
            "osgp",
            "overlapped SGP: communication hidden behind compute (Alg. 3)",
            false,
            |c: &AlgoCtx| {
                Arc::new(Sgp::overlap(c.inner, Arc::new(ExponentialGraph::new(c.m))))
                    as Arc<dyn BaseAlgorithm>
            },
        );
        r.register(
            "sgp-static",
            "SGP over a fixed directed ring (time-varying gossip off)",
            false,
            |c: &AlgoCtx| {
                Arc::new(
                    Sgp::new(c.inner, Arc::new(DirectedRing::new(c.m)))
                        .with_tag("-static"),
                ) as Arc<dyn BaseAlgorithm>
            },
        );
        r.register(
            "osgp-static",
            "overlapped SGP over a fixed directed ring",
            false,
            |c: &AlgoCtx| {
                Arc::new(
                    Sgp::overlap(c.inner, Arc::new(DirectedRing::new(c.m)))
                        .with_tag("-static"),
                ) as Arc<dyn BaseAlgorithm>
            },
        );
        r.alias("sgp-exp", "sgp");
        r.alias("osgp-exp", "osgp");
        r.register(
            "dpsgd",
            "decentralized parallel SGD over a symmetric ring",
            false,
            |c: &AlgoCtx| Arc::new(Dpsgd::new(c.inner, c.m)) as Arc<dyn BaseAlgorithm>,
        );
        r.register(
            "ar",
            "gradient allreduce every step (AR-SGD / AR-Adam)",
            false,
            |c: &AlgoCtx| Arc::new(AllReduce::new(c.inner)) as Arc<dyn BaseAlgorithm>,
        );
        r.alias("allreduce", "ar");
        r.register(
            "doubleavg",
            "double-averaging momentum (Yu et al. 2019, Alg. 5); \
             ':n' sets the averaging period tau (default 12)",
            true,
            |c: &AlgoCtx| {
                Arc::new(DoubleAvg::new(c.inner, c.arg.unwrap_or(12)))
                    as Arc<dyn BaseAlgorithm>
            },
        );
        r
    }

    /// Register a factory under `key`. `takes_arg` controls whether the
    /// spec string accepts a `:n` suffix. Re-registering a key replaces
    /// the previous factory.
    pub fn register(
        &mut self,
        key: &str,
        help: &str,
        takes_arg: bool,
        factory: impl Fn(&AlgoCtx) -> Arc<dyn BaseAlgorithm> + Send + Sync + 'static,
    ) {
        self.entries.insert(
            key.to_string(),
            AlgoEntry {
                factory: Box::new(factory),
                help: help.to_string(),
                takes_arg,
            },
        );
    }

    /// Register `alias` as another name for the existing `key`.
    pub fn alias(&mut self, alias: &str, key: &str) {
        assert!(
            self.entries.contains_key(key),
            "alias target {key:?} not registered"
        );
        self.aliases.insert(alias.to_string(), key.to_string());
    }

    /// Canonical keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.entries.keys().map(|k| k.as_str()).collect()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.canonical(key).is_some()
    }

    fn canonical(&self, key: &str) -> Option<&str> {
        if let Some((k, _)) = self.entries.get_key_value(key) {
            return Some(k.as_str());
        }
        self.aliases.get(key).map(|k| k.as_str())
    }

    /// Human-readable list of valid spec forms, for error messages and
    /// CLI help.
    pub fn valid_forms(&self) -> String {
        let forms: Vec<String> = self
            .entries
            .iter()
            .map(|(k, e)| {
                if e.takes_arg {
                    format!("{k}[:n]")
                } else {
                    k.clone()
                }
            })
            .collect();
        format!(
            "{} (append -adam for an Adam inner optimizer{})",
            forms.join("|"),
            if self.aliases.is_empty() {
                String::new()
            } else {
                format!(
                    "; aliases: {}",
                    self.aliases
                        .iter()
                        .map(|(a, k)| format!("{a}={k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            }
        )
    }

    /// One line per algorithm, for `--help`-style output.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        for (k, e) in &self.entries {
            s.push_str(&format!("  {:<12} {}\n", k, e.help));
        }
        s
    }

    /// Parse a spec string such as "sgp", "local-adam" or "doubleavg:24".
    ///
    /// Unlike the old `AlgoSpec::parse`, every malformed input is a hard
    /// error (no silent defaulting): unknown keys, `:n` suffixes that are
    /// not unsigned integers, and `:n` suffixes on algorithms that take no
    /// argument all fail with a message listing the valid forms.
    pub fn parse(&self, spec: &str) -> Result<AlgoSel> {
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (spec, None),
        };
        let (base, inner) = match name.strip_suffix("-adam") {
            Some(b) => (b, InnerOpt::adam_default()),
            None => (name, InnerOpt::nesterov_default()),
        };
        let Some(key) = self.canonical(base) else {
            bail!(
                "unknown algorithm {spec:?}; valid forms: {}",
                self.valid_forms()
            );
        };
        let entry = &self.entries[key];
        let arg = match rest {
            None => None,
            Some(r) => {
                if !entry.takes_arg {
                    bail!(
                        "algorithm {base:?} takes no ':' argument \
                         (got {spec:?}); valid forms: {}",
                        self.valid_forms()
                    );
                }
                Some(r.parse::<u64>().map_err(|_| {
                    anyhow!(
                        "malformed argument {r:?} in {spec:?}: expected an \
                         unsigned integer (e.g. \"{base}:12\"); valid \
                         forms: {}",
                        self.valid_forms()
                    )
                })?)
            }
        };
        Ok(AlgoSel {
            key: key.to_string(),
            inner,
            arg,
        })
    }

    /// Instantiate the algorithm `sel` names for an `m`-worker run.
    pub fn build(&self, sel: &AlgoSel, m: usize) -> Result<Arc<dyn BaseAlgorithm>> {
        let key = self.canonical(&sel.key).ok_or_else(|| {
            anyhow!(
                "unknown algorithm key {:?}; registered: {}",
                sel.key,
                self.keys().join(", ")
            )
        })?;
        let entry = &self.entries[key];
        Ok((entry.factory)(&AlgoCtx {
            inner: sel.inner,
            m,
            arg: sel.arg,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_key_round_trips() {
        let r = AlgoRegistry::builtin();
        assert!(!r.keys().is_empty());
        for key in r.keys() {
            let sel = r.parse(key).unwrap();
            assert_eq!(sel.key, key);
            let algo = r.build(&sel, 4).unwrap();
            assert!(
                algo.name().starts_with(key),
                "{} !~ {key}",
                algo.name()
            );
        }
    }

    #[test]
    fn adam_suffix_selects_adam_inner() {
        let r = AlgoRegistry::builtin();
        let sel = r.parse("local-adam").unwrap();
        assert_eq!(sel.key, "local");
        assert!(sel.inner.uses_second_moment());
        let sel = r.parse("sgp").unwrap();
        assert!(!sel.inner.uses_second_moment());
    }

    #[test]
    fn arg_suffix_parses_and_reaches_factory() {
        let r = AlgoRegistry::builtin();
        let sel = r.parse("doubleavg:24").unwrap();
        assert_eq!(sel.arg, Some(24));
        let name = r.build(&sel, 4).unwrap().name();
        assert!(name.contains("tau24"), "{name}");
        // Default τ when no argument is given.
        let sel = r.parse("doubleavg").unwrap();
        assert_eq!(sel.arg, None);
        assert!(r.build(&sel, 4).unwrap().name().contains("tau12"));
    }

    #[test]
    fn malformed_arg_is_a_hard_error() {
        let r = AlgoRegistry::builtin();
        for bad in ["doubleavg:abc", "doubleavg:", "doubleavg:-3",
                    "doubleavg:1.5"] {
            let e = r.parse(bad).unwrap_err().to_string();
            assert!(e.contains("doubleavg"), "{bad}: {e}");
            assert!(e.contains("valid forms"), "{bad}: {e}");
        }
    }

    #[test]
    fn arg_on_argless_algorithm_is_an_error() {
        let r = AlgoRegistry::builtin();
        let e = r.parse("sgp:3").unwrap_err().to_string();
        assert!(e.contains("takes no"), "{e}");
    }

    #[test]
    fn unknown_key_lists_valid_forms() {
        let r = AlgoRegistry::builtin();
        let e = r.parse("bogus").unwrap_err().to_string();
        assert!(e.contains("sgp"), "{e}");
        let e = r
            .build(&AlgoSel::new("bogus"), 4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("registered"), "{e}");
    }

    #[test]
    fn aliases_resolve_to_canonical_key() {
        let r = AlgoRegistry::builtin();
        let sel = r.parse("allreduce").unwrap();
        assert_eq!(sel.key, "ar");
        assert!(r.contains("allreduce") && r.contains("ar"));
        // The default gossip graph is the time-varying exponential one;
        // the -exp aliases make that explicit and spell the contrast with
        // the sgp-static/osgp-static fixed-ring keys.
        assert_eq!(r.parse("sgp-exp").unwrap().key, "sgp");
        assert_eq!(r.parse("osgp-exp").unwrap().key, "osgp");
        let sel = r.parse("sgp-exp-adam").unwrap();
        assert_eq!(sel.key, "sgp");
        assert!(sel.inner.uses_second_moment());
    }

    #[test]
    fn static_graph_variants_build_and_name() {
        let r = AlgoRegistry::builtin();
        for key in ["sgp-static", "osgp-static"] {
            let sel = r.parse(key).unwrap();
            assert_eq!(sel.key, key);
            let algo = r.build(&sel, 4).unwrap();
            assert_eq!(algo.name(), format!("{key}-nesterov-sgd"));
            assert!(algo.needs_debias());
        }
        assert!(r.build(&r.parse("osgp-static").unwrap(), 4)
            .unwrap()
            .name()
            .starts_with("osgp-static"));
    }

    #[test]
    fn custom_registration_and_replacement() {
        let mut r = AlgoRegistry::builtin();
        r.register("mylocal", "test-only", false, |c: &AlgoCtx| {
            Arc::new(Local::new(c.inner)) as Arc<dyn BaseAlgorithm>
        });
        let sel = r.parse("mylocal").unwrap();
        assert!(r.build(&sel, 2).unwrap().name().starts_with("local"));
        assert!(r.valid_forms().contains("mylocal"));
        assert!(r.help_text().contains("test-only"));
    }

    #[test]
    fn sel_spec_round_trips() {
        let r = AlgoRegistry::builtin();
        for spec in ["local", "sgp", "local-adam", "doubleavg:24"] {
            let sel = r.parse(spec).unwrap();
            assert_eq!(sel.spec(), spec);
            assert_eq!(r.parse(&sel.spec()).unwrap(), sel);
        }
    }
}
