//! Decentralized Parallel SGD (D-PSGD, Lian et al. 2017).
//!
//! Undirected gossip with a **doubly-stochastic** mixing matrix (symmetric
//! ring, Metropolis 1/3 weights): each step, every worker takes a local
//! momentum step, exchanges scaled parameters with both ring neighbors,
//! and mixes. Because the matrix is doubly stochastic the plain average is
//! preserved — no push-sum weights needed (w stays 1, z mirrors x).

use super::{apply_inner, BaseAlgorithm, Ctx, WorkerState};
use crate::net::GossipMsg;
use crate::optim::kernels::InnerOpt;
use crate::topology::{SymmetricRing, Topology};
use anyhow::Result;

pub struct Dpsgd {
    inner: InnerOpt,
    topo: SymmetricRing,
}

impl Dpsgd {
    pub fn new(inner: InnerOpt, m: usize) -> Self {
        Self { inner, topo: SymmetricRing::new(m) }
    }

    fn in_degree(&self, m: usize) -> usize {
        match m {
            1 => 0,
            2 => 1,
            _ => 2,
        }
    }
}

impl BaseAlgorithm for Dpsgd {
    fn name(&self) -> String {
        format!("dpsgd-{}", self.inner.name())
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()> {
        apply_inner(ctx, &self.inner, state, g, gamma)?;

        // Topology over the communication scope (local ranks); fabric
        // addresses are global.
        let round = self.topo.round(ctx.local_rank(), k);
        for &(peer_local, p) in &round.out {
            let peer = ctx.to_global(peer_local);
            let mut payload: Vec<f32> =
                state.x.iter().map(|&v| v * p as f32).collect();
            // Per-link EF residual keyed by the destination peer.
            let wire = super::compress_payload(
                ctx.compress,
                &mut state.comp,
                &mut payload,
                crate::compress::site::gossip(peer),
            );
            ctx.fabric.gossip_send_wire(
                peer,
                GossipMsg {
                    from: ctx.worker,
                    step: k,
                    payload,
                    weight: 0.0,
                    send_time: ctx.clock,
                },
                wire,
            );
        }
        crate::optim::scale(&mut state.x, round.self_weight as f32);

        // Blocking receive of exactly the step-k neighbor messages.
        let expect = self.in_degree(ctx.scope_len());
        let mut consumed = 0;
        let mut stash_idx = 0;
        while consumed < expect {
            if stash_idx < state.stash.len() {
                if state.stash[stash_idx].0.step == k {
                    let (msg, arrival) = state.stash.remove(stash_idx);
                    crate::optim::add_assign(&mut state.x, &msg.payload);
                    ctx.clock = ctx.clock.max(arrival);
                    consumed += 1;
                } else {
                    stash_idx += 1;
                }
                continue;
            }
            let (msg, arrival) = ctx.fabric.gossip_recv(ctx.worker);
            if msg.step == k {
                crate::optim::add_assign(&mut state.x, &msg.payload);
                ctx.clock = ctx.clock.max(arrival);
                consumed += 1;
            } else {
                state.stash.push((msg, arrival));
            }
        }
        state.z.copy_from_slice(&state.x);
        Ok(())
    }

    fn lockstep(&self) -> bool {
        true
    }

    fn comm_elems_per_step(&self, d: usize) -> usize {
        self.topo.sends_per_step() * d
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::drive;
    use super::*;
    use crate::exec::run_workers;
    use crate::net::{CostModel, Fabric};
    use crate::optim::kernels::Kernels;

    #[test]
    fn mixing_preserves_global_mean() {
        // Zero gradients: the sum over workers of x must be invariant.
        let m = 5;
        let algo = Dpsgd::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }, m);
        let fabric = Fabric::new(m, CostModel::free());
        let kernels = Kernels::Native;
        let states = run_workers(m, |w| {
            let mut st = WorkerState::new(&[w as f32; 4], algo.inner());
            let mut ctx = Ctx { worker: w, m, fabric: &fabric,
                                kernels: &kernels, compress: None,
                                scope: None, clock: 0.0,
                                scratch: crate::util::Scratch::new() };
            for k in 0..40 {
                algo.step(&mut ctx, &mut st, &[0.0; 4], 0.1, k).unwrap();
            }
            st
        });
        let total: f64 =
            states.iter().map(|s| s.x[0] as f64).sum();
        assert!((total - 10.0).abs() < 1e-4, "sum {total}");
        // And consensus: all near the mean 2.0.
        for s in &states {
            assert!((s.x[0] - 2.0).abs() < 1e-2, "{}", s.x[0]);
        }
    }

    #[test]
    fn converges_to_mean_target() {
        let m = 4;
        let algo = Dpsgd::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }, m);
        let states = drive(&algo, m, 4, 200, 0.2);
        let want = 2.5; // mean of targets 1..=4
        for s in &states {
            for &x in &s.x {
                assert!((x - want).abs() < 0.15, "x={x}");
            }
        }
    }

    #[test]
    fn two_and_one_worker_edge_cases() {
        let algo1 = Dpsgd::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }, 1);
        let s1 = drive(&algo1, 1, 2, 30, 0.5);
        assert!((s1[0].x[0] - 1.0).abs() < 1e-3);
        let algo2 = Dpsgd::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 }, 2);
        let s2 = drive(&algo2, 2, 2, 100, 0.2);
        for s in &s2 {
            assert!((s.x[0] - 1.5).abs() < 0.1, "{}", s.x[0]);
        }
    }

    #[test]
    fn push_sum_weight_untouched() {
        let m = 3;
        let algo = Dpsgd::new(InnerOpt::nesterov_default(), m);
        let states = drive(&algo, m, 2, 10, 0.1);
        for s in &states {
            assert_eq!(s.w, 1.0);
        }
    }
}
