//! Base distributed optimization algorithms (the paper's baselines).
//!
//! Every baseline implements [`BaseAlgorithm`]: one *inner* step consumes
//! this worker's stochastic gradients and updates its [`WorkerState`],
//! possibly communicating over the [`Fabric`]. The SlowMo controller
//! ([`crate::slowmo`]) wraps any of them (paper Alg. 1 line 4).
//!
//! | paper name       | here                                  |
//! |------------------|---------------------------------------|
//! | Local SGD / Adam | [`Local`] (no inner-loop comm)        |
//! | SGP (Alg. 2)     | [`Sgp`] with `overlap=false`          |
//! | OSGP (Alg. 3)    | [`Sgp`] with `overlap=true`           |
//! | D-PSGD           | [`Dpsgd`]                             |
//! | AR-SGD / AR-Adam | [`AllReduce`] (gradient allreduce)    |
//! | double-averaging | [`DoubleAvg`] (Alg. 5, Yu et al.)     |

mod allreduce;
mod double_avg;
mod dpsgd;
mod local;
pub mod registry;
mod sgp;

pub use allreduce::AllReduce;
pub use double_avg::DoubleAvg;
pub use dpsgd::Dpsgd;
pub use local::Local;
pub use registry::{AlgoCtx, AlgoRegistry, AlgoSel};
pub use sgp::Sgp;

use crate::compress::{CompressState, Compressor};
use crate::net::{Fabric, GossipMsg};
use crate::optim::kernels::{InnerOpt, Kernels};
use crate::util::Scratch;
use anyhow::Result;

/// Per-worker mutable optimizer state. Flat `f32[d]` vectors matching the
/// AOT artifacts' flat parameter layout.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Biased parameters x (what gossip mixes).
    pub x: Vec<f32>,
    /// First-moment / momentum buffer h.
    pub h: Vec<f32>,
    /// Second-moment buffer v (Adam only; empty otherwise).
    pub v: Vec<f32>,
    /// Push-sum weight w (SGP family; 1.0 elsewhere).
    pub w: f64,
    /// De-biased parameters z = x / w (SGP family; mirrors x elsewhere).
    pub z: Vec<f32>,
    /// 1-based Adam step counter l (paper Table C.1).
    pub adam_step: u64,
    /// Blocking-gossip stash: early messages from faster senders, kept
    /// with their simulated arrival time (preserves chaos delays).
    pub stash: Vec<(GossipMsg, f64)>,
    /// OSGP: consecutive steps with an empty inbox (Alg. 3
    /// `count_since_last`).
    pub pending_count: u64,
    /// Communication-compression state: per-link error-feedback residuals
    /// and deterministic stream counters (see [`crate::compress`]). The
    /// trainer re-keys it with the run seed and worker rank.
    pub comp: CompressState,
}

/// Which optional [`WorkerState`] buffers a run materializes. The dense
/// default allocates everything; the shared-state trainer mode elides the
/// momentum buffer when the inner optimizer is momentum-free (`beta0 = 0`
/// Nesterov — x is bitwise-unaffected, see
/// [`crate::optim::nesterov_step_nomom`]) and the de-bias mirror `z` when
/// the base algorithm reports [`BaseAlgorithm::needs_debias`] `false`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StateLayout {
    /// Elide the momentum buffer `h` (empty vec).
    pub lean_h: bool,
    /// Elide the de-bias mirror `z` (empty vec).
    pub lean_z: bool,
}

impl StateLayout {
    /// The dense default: every buffer allocated.
    pub fn dense() -> Self {
        Self::default()
    }
}

impl WorkerState {
    pub fn new(init: &[f32], inner: &InnerOpt) -> Self {
        Self::with_layout(init, inner, StateLayout::dense())
    }

    /// Allocate per-worker state under `layout` — the shared-state mode's
    /// entry point. `layout.lean_h`/`lean_z` leave the corresponding
    /// buffers empty; every consumer of an elidable buffer guards on
    /// `is_empty()` (momentum dispatch in
    /// [`crate::optim::kernels::Kernels::inner_step`], the z mirror copies
    /// in comm-free algorithms and [`BaseAlgorithm::on_exact_average`]).
    pub fn with_layout(
        init: &[f32],
        inner: &InnerOpt,
        layout: StateLayout,
    ) -> Self {
        let d = init.len();
        Self {
            x: init.to_vec(),
            h: if layout.lean_h {
                Vec::new()
            } else {
                vec![0.0; d]
            },
            v: if inner.uses_second_moment() {
                vec![0.0; d]
            } else {
                Vec::new()
            },
            w: 1.0,
            z: if layout.lean_z {
                Vec::new()
            } else {
                init.to_vec()
            },
            adam_step: 0,
            stash: Vec::new(),
            pending_count: 0,
            comp: CompressState::default(),
        }
    }

    pub fn d(&self) -> usize {
        self.x.len()
    }

    /// Zero momentum buffers and restart the Adam counter (the "reset"
    /// buffer strategy; paper App. B.4).
    pub fn reset_buffers(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
        self.v.iter_mut().for_each(|v| *v = 0.0);
        self.adam_step = 0;
    }
}

/// Everything an algorithm may touch during one inner step.
pub struct Ctx<'a> {
    /// Global worker rank (mailbox address on the fabric).
    pub worker: usize,
    /// Global worker count.
    pub m: usize,
    pub fabric: &'a Fabric,
    pub kernels: &'a Kernels,
    /// Communication compressor for outbound payloads (`None` = raw f32;
    /// the trainer passes `None` for the identity codec so the default
    /// path stays bit-identical to the pre-compression code).
    pub compress: Option<&'a dyn Compressor>,
    /// Group-local communication scope (hierarchical SlowMo): the sorted
    /// global ranks this worker's base algorithm talks to. `None` = all
    /// `m` workers (the flat topology). Algorithms built for a scope of
    /// size `s` address peers by *local* rank `0..s`, translated to
    /// global mailbox ids through [`Ctx::to_global`].
    pub scope: Option<&'a [usize]>,
    /// Simulated wall-clock for this worker (advanced by comm waits; the
    /// trainer adds compute time).
    pub clock: f64,
    /// Per-worker scratch-buffer pools for the allocation-free hot path
    /// (see [`crate::util::pool`]): codec wire data, collective send
    /// chunks, EF decode temporaries. Owned by the Ctx so every per-step
    /// allocation site reaches steady state after one warmup step —
    /// pinned by the `alloc_gate` integration test. Algorithms must
    /// return what they take within the same step (never hold a pooled
    /// buffer across a boundary).
    pub scratch: Scratch,
}

impl<'a> Ctx<'a> {
    /// Workers in this worker's communication scope.
    pub fn scope_len(&self) -> usize {
        self.scope.map_or(self.m, <[usize]>::len)
    }

    /// This worker's local rank within its scope (== `worker` when flat).
    pub fn local_rank(&self) -> usize {
        match self.scope {
            None => self.worker,
            Some(s) => s
                .iter()
                .position(|&w| w == self.worker)
                .expect("worker must be a member of its own scope"),
        }
    }

    /// Translate a scope-local rank to the global mailbox id.
    pub fn to_global(&self, local: usize) -> usize {
        match self.scope {
            None => local,
            Some(s) => s[local],
        }
    }

    /// The sorted global ranks of this scope (collective group).
    pub fn scope_members(&self) -> Vec<usize> {
        match self.scope {
            None => (0..self.m).collect(),
            Some(s) => s.to_vec(),
        }
    }

    /// [`Ctx::scope_members`] into a recycled buffer (cleared first) —
    /// the allocation-free variant for the step-loop hot path.
    pub fn scope_members_into(&self, out: &mut Vec<usize>) {
        out.clear();
        match self.scope {
            None => out.extend(0..self.m),
            Some(s) => out.extend_from_slice(s),
        }
    }
}

/// A base distributed optimization algorithm (paper Alg. 1 line 4 step).
pub trait BaseAlgorithm: Send + Sync {
    fn name(&self) -> String;

    fn inner(&self) -> &InnerOpt;

    /// Perform one inner step with this worker's gradient `g` (evaluated
    /// at [`BaseAlgorithm::eval_params`]) and fast learning rate `gamma`.
    /// `k` is the global inner-step index (for time-varying topologies).
    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()>;

    /// The parameters gradients should be evaluated at (z for push-sum
    /// methods, x otherwise).
    fn eval_params<'s>(&self, state: &'s WorkerState) -> &'s [f32] {
        &state.x
    }

    /// Whether inner steps require all workers to advance in lockstep
    /// (blocking gossip / collectives). Local methods return false.
    fn lockstep(&self) -> bool;

    /// Called by the SlowMo controller right after the exact average so
    /// push-sum state can be re-synchronized (w=1, z=x).
    fn on_exact_average(&self, state: &mut WorkerState) {
        state.w = 1.0;
        if !state.z.is_empty() {
            state.z.copy_from_slice(&state.x);
        }
    }

    /// Does this algorithm read the de-bias mirror `z`? Push-sum methods
    /// (SGP family) do — their [`BaseAlgorithm::eval_params`] is `z` —
    /// while comm-free and exact-average methods only mirror x into z for
    /// uniformity. Algorithms returning `false` may run with `z` elided
    /// ([`StateLayout::lean_z`], the shared-state trainer mode).
    fn needs_debias(&self) -> bool {
        true
    }

    /// f32 values communicated per worker per inner step (for comm
    /// accounting in benches that don't run a fabric).
    fn comm_elems_per_step(&self, d: usize) -> usize;
}

/// Run the configured compressor over an outbound `payload` in place
/// (error-feedback residual + deterministic stream keyed by `site`),
/// returning the honest wire byte count — raw `4·len` when no codec is
/// active, so the default path is untouched. Takes the
/// [`CompressState`] rather than the whole worker state so callers can
/// compress one `WorkerState` field against another (disjoint borrows).
pub(crate) fn compress_payload(
    compress: Option<&dyn Compressor>,
    comp: &mut CompressState,
    payload: &mut [f32],
    site: u64,
) -> u64 {
    match compress {
        Some(c) if !c.is_identity() => c.transcode(payload, comp, site),
        _ => payload.len() as u64 * 4,
    }
}

/// [`compress_payload`] through the codec's pooled transcode: scratch and
/// wire buffers come from (and return to) `sc`, so a warm pool makes the
/// round-trip allocation-free. Bitwise-identical to the fresh path.
pub(crate) fn compress_payload_pooled(
    compress: Option<&dyn Compressor>,
    comp: &mut CompressState,
    payload: &mut [f32],
    site: u64,
    sc: &mut Scratch,
) -> u64 {
    match compress {
        Some(c) if !c.is_identity() => {
            c.transcode_pooled(payload, comp, site, sc)
        }
        _ => payload.len() as u64 * 4,
    }
}

/// Run the inner optimizer (nesterov/adam) on (x, h, v) in place.
pub(crate) fn apply_inner(
    ctx: &mut Ctx,
    inner: &InnerOpt,
    state: &mut WorkerState,
    g: &[f32],
    gamma: f32,
) -> Result<()> {
    state.adam_step += 1;
    let step = state.adam_step;
    // Split borrows: x/h/v are distinct fields.
    let WorkerState { x, h, v, .. } = state;
    ctx.kernels.inner_step(inner, x, h, v, g, gamma, step)
}

#[doc(hidden)] // test helper, also used by integration tests/benches
pub mod testutil {
    use super::*;
    use crate::net::CostModel;

    /// Drive `m` workers of `algo` for `steps` inner steps on a synthetic
    /// quadratic gradient (g = params - target_w, target_w = w+1),
    /// returning final states. Used by the per-algorithm unit tests.
    pub fn drive(
        algo: &dyn BaseAlgorithm,
        m: usize,
        d: usize,
        steps: u64,
        gamma: f32,
    ) -> Vec<WorkerState> {
        let fabric = Fabric::new(m, CostModel::free());
        let kernels = Kernels::Native;
        let barrier = crate::exec::Barrier::new(m);
        crate::exec::run_workers(m, |w| {
            let init: Vec<f32> = (0..d).map(|i| (i + 1) as f32).collect();
            let mut state = WorkerState::new(&init, algo.inner());
            let mut ctx = Ctx {
                worker: w,
                m,
                fabric: &fabric,
                kernels: &kernels,
                compress: None,
                scope: None,
                clock: 0.0,
                scratch: Scratch::new(),
            };
            let target = vec![(w + 1) as f32; d];
            for k in 0..steps {
                let g: Vec<f32> = algo
                    .eval_params(&state)
                    .iter()
                    .zip(&target)
                    .map(|(&x, &t)| x - t)
                    .collect();
                algo.step(&mut ctx, &mut state, &g, gamma, k).unwrap();
            }
            // Absorb in-flight gossip so push-sum mass checks see the whole
            // system (real OSGP runs end with an exact average anyway).
            barrier.wait();
            for (msg, _) in fabric.gossip_drain(w) {
                crate::optim::add_assign(&mut state.x, &msg.payload);
                state.w += msg.weight;
            }
            let inv_w = (1.0 / state.w) as f32;
            for (z, &x) in state.z.iter_mut().zip(&state.x) {
                *z = x * inv_w;
            }
            state
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_state_init_shapes() {
        let s = WorkerState::new(&[1.0, 2.0], &InnerOpt::nesterov_default());
        assert_eq!(s.d(), 2);
        assert!(s.v.is_empty());
        assert_eq!(s.w, 1.0);
        assert_eq!(s.x, s.z);
        let s = WorkerState::new(&[1.0, 2.0], &InnerOpt::adam_default());
        assert_eq!(s.v.len(), 2);
    }

    #[test]
    fn lean_layout_elides_buffers() {
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let layout = StateLayout { lean_h: true, lean_z: true };
        let s = WorkerState::with_layout(&[1.0, 2.0, 3.0], &inner, layout);
        assert_eq!(s.d(), 3);
        assert!(s.h.is_empty() && s.z.is_empty() && s.v.is_empty());
        assert_eq!(s.x, vec![1.0, 2.0, 3.0]);
        // Dense layout through with_layout matches new() exactly.
        let dense =
            WorkerState::with_layout(&[1.0, 2.0], &inner, StateLayout::dense());
        let plain = WorkerState::new(&[1.0, 2.0], &inner);
        assert_eq!(dense.h, plain.h);
        assert_eq!(dense.z, plain.z);
    }

    #[test]
    fn on_exact_average_tolerates_lean_z() {
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let layout = StateLayout { lean_h: false, lean_z: true };
        let mut s = WorkerState::with_layout(&[1.0; 4], &inner, layout);
        s.w = 0.5;
        let algo = Local::new(inner);
        algo.on_exact_average(&mut s); // must not panic on empty z
        assert_eq!(s.w, 1.0);
        assert!(s.z.is_empty());
    }

    #[test]
    fn needs_debias_splits_push_sum_from_the_rest() {
        use crate::topology::ExponentialGraph;
        use std::sync::Arc;
        let inner = InnerOpt::nesterov_default();
        assert!(!Local::new(inner).needs_debias());
        assert!(!AllReduce::new(inner).needs_debias());
        let topo = Arc::new(ExponentialGraph::new(4));
        assert!(Sgp::new(inner, topo.clone()).needs_debias());
        assert!(Sgp::overlap(inner, topo).needs_debias());
        assert!(Dpsgd::new(inner, 4).needs_debias());
        assert!(DoubleAvg::new(inner, 12).needs_debias());
    }

    #[test]
    fn reset_buffers_zeroes() {
        let mut s = WorkerState::new(&[1.0; 4], &InnerOpt::adam_default());
        s.h[0] = 5.0;
        s.v[1] = 2.0;
        s.adam_step = 9;
        s.reset_buffers();
        assert!(s.h.iter().all(|&x| x == 0.0));
        assert!(s.v.iter().all(|&x| x == 0.0));
        assert_eq!(s.adam_step, 0);
    }
}
