//! Base distributed optimization algorithms (the paper's baselines).
//!
//! Every baseline implements [`BaseAlgorithm`]: one *inner* step consumes
//! this worker's stochastic gradients and updates its [`WorkerState`],
//! possibly communicating over the [`Fabric`]. The SlowMo controller
//! ([`crate::slowmo`]) wraps any of them (paper Alg. 1 line 4).
//!
//! | paper name       | here                                  |
//! |------------------|---------------------------------------|
//! | Local SGD / Adam | [`Local`] (no inner-loop comm)        |
//! | SGP (Alg. 2)     | [`Sgp`] with `overlap=false`          |
//! | OSGP (Alg. 3)    | [`Sgp`] with `overlap=true`           |
//! | D-PSGD           | [`Dpsgd`]                             |
//! | AR-SGD / AR-Adam | [`AllReduce`] (gradient allreduce)    |
//! | double-averaging | [`DoubleAvg`] (Alg. 5, Yu et al.)     |

mod allreduce;
mod double_avg;
mod dpsgd;
mod local;
pub mod registry;
mod sgp;

pub use allreduce::AllReduce;
pub use double_avg::DoubleAvg;
pub use dpsgd::Dpsgd;
pub use local::Local;
pub use registry::{AlgoCtx, AlgoRegistry, AlgoSel};
pub use sgp::Sgp;

use crate::compress::{CompressState, Compressor};
use crate::net::{Fabric, GossipMsg};
use crate::optim::kernels::{InnerOpt, Kernels};
use anyhow::Result;

/// Per-worker mutable optimizer state. Flat `f32[d]` vectors matching the
/// AOT artifacts' flat parameter layout.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Biased parameters x (what gossip mixes).
    pub x: Vec<f32>,
    /// First-moment / momentum buffer h.
    pub h: Vec<f32>,
    /// Second-moment buffer v (Adam only; empty otherwise).
    pub v: Vec<f32>,
    /// Push-sum weight w (SGP family; 1.0 elsewhere).
    pub w: f64,
    /// De-biased parameters z = x / w (SGP family; mirrors x elsewhere).
    pub z: Vec<f32>,
    /// 1-based Adam step counter l (paper Table C.1).
    pub adam_step: u64,
    /// Blocking-gossip stash: early messages from faster senders, kept
    /// with their simulated arrival time (preserves chaos delays).
    pub stash: Vec<(GossipMsg, f64)>,
    /// OSGP: consecutive steps with an empty inbox (Alg. 3
    /// `count_since_last`).
    pub pending_count: u64,
    /// Communication-compression state: per-link error-feedback residuals
    /// and deterministic stream counters (see [`crate::compress`]). The
    /// trainer re-keys it with the run seed and worker rank.
    pub comp: CompressState,
}

impl WorkerState {
    pub fn new(init: &[f32], inner: &InnerOpt) -> Self {
        let d = init.len();
        Self {
            x: init.to_vec(),
            h: vec![0.0; d],
            v: if inner.uses_second_moment() {
                vec![0.0; d]
            } else {
                Vec::new()
            },
            w: 1.0,
            z: init.to_vec(),
            adam_step: 0,
            stash: Vec::new(),
            pending_count: 0,
            comp: CompressState::default(),
        }
    }

    pub fn d(&self) -> usize {
        self.x.len()
    }

    /// Zero momentum buffers and restart the Adam counter (the "reset"
    /// buffer strategy; paper App. B.4).
    pub fn reset_buffers(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
        self.v.iter_mut().for_each(|v| *v = 0.0);
        self.adam_step = 0;
    }
}

/// Everything an algorithm may touch during one inner step.
pub struct Ctx<'a> {
    /// Global worker rank (mailbox address on the fabric).
    pub worker: usize,
    /// Global worker count.
    pub m: usize,
    pub fabric: &'a Fabric,
    pub kernels: &'a Kernels,
    /// Communication compressor for outbound payloads (`None` = raw f32;
    /// the trainer passes `None` for the identity codec so the default
    /// path stays bit-identical to the pre-compression code).
    pub compress: Option<&'a dyn Compressor>,
    /// Group-local communication scope (hierarchical SlowMo): the sorted
    /// global ranks this worker's base algorithm talks to. `None` = all
    /// `m` workers (the flat topology). Algorithms built for a scope of
    /// size `s` address peers by *local* rank `0..s`, translated to
    /// global mailbox ids through [`Ctx::to_global`].
    pub scope: Option<&'a [usize]>,
    /// Simulated wall-clock for this worker (advanced by comm waits; the
    /// trainer adds compute time).
    pub clock: f64,
}

impl<'a> Ctx<'a> {
    /// Workers in this worker's communication scope.
    pub fn scope_len(&self) -> usize {
        self.scope.map_or(self.m, <[usize]>::len)
    }

    /// This worker's local rank within its scope (== `worker` when flat).
    pub fn local_rank(&self) -> usize {
        match self.scope {
            None => self.worker,
            Some(s) => s
                .iter()
                .position(|&w| w == self.worker)
                .expect("worker must be a member of its own scope"),
        }
    }

    /// Translate a scope-local rank to the global mailbox id.
    pub fn to_global(&self, local: usize) -> usize {
        match self.scope {
            None => local,
            Some(s) => s[local],
        }
    }

    /// The sorted global ranks of this scope (collective group).
    pub fn scope_members(&self) -> Vec<usize> {
        match self.scope {
            None => (0..self.m).collect(),
            Some(s) => s.to_vec(),
        }
    }
}

/// A base distributed optimization algorithm (paper Alg. 1 line 4 step).
pub trait BaseAlgorithm: Send + Sync {
    fn name(&self) -> String;

    fn inner(&self) -> &InnerOpt;

    /// Perform one inner step with this worker's gradient `g` (evaluated
    /// at [`BaseAlgorithm::eval_params`]) and fast learning rate `gamma`.
    /// `k` is the global inner-step index (for time-varying topologies).
    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()>;

    /// The parameters gradients should be evaluated at (z for push-sum
    /// methods, x otherwise).
    fn eval_params<'s>(&self, state: &'s WorkerState) -> &'s [f32] {
        &state.x
    }

    /// Whether inner steps require all workers to advance in lockstep
    /// (blocking gossip / collectives). Local methods return false.
    fn lockstep(&self) -> bool;

    /// Called by the SlowMo controller right after the exact average so
    /// push-sum state can be re-synchronized (w=1, z=x).
    fn on_exact_average(&self, state: &mut WorkerState) {
        state.w = 1.0;
        state.z.copy_from_slice(&state.x);
    }

    /// f32 values communicated per worker per inner step (for comm
    /// accounting in benches that don't run a fabric).
    fn comm_elems_per_step(&self, d: usize) -> usize;
}

/// Run the configured compressor over an outbound `payload` in place
/// (error-feedback residual + deterministic stream keyed by `site`),
/// returning the honest wire byte count — raw `4·len` when no codec is
/// active, so the default path is untouched. Takes the
/// [`CompressState`] rather than the whole worker state so callers can
/// compress one `WorkerState` field against another (disjoint borrows).
pub(crate) fn compress_payload(
    compress: Option<&dyn Compressor>,
    comp: &mut CompressState,
    payload: &mut [f32],
    site: u64,
) -> u64 {
    match compress {
        Some(c) if !c.is_identity() => c.transcode(payload, comp, site),
        _ => payload.len() as u64 * 4,
    }
}

/// Run the inner optimizer (nesterov/adam) on (x, h, v) in place.
pub(crate) fn apply_inner(
    ctx: &mut Ctx,
    inner: &InnerOpt,
    state: &mut WorkerState,
    g: &[f32],
    gamma: f32,
) -> Result<()> {
    state.adam_step += 1;
    let step = state.adam_step;
    // Split borrows: x/h/v are distinct fields.
    let WorkerState { x, h, v, .. } = state;
    ctx.kernels.inner_step(inner, x, h, v, g, gamma, step)
}

#[doc(hidden)] // test helper, also used by integration tests/benches
pub mod testutil {
    use super::*;
    use crate::net::CostModel;

    /// Drive `m` workers of `algo` for `steps` inner steps on a synthetic
    /// quadratic gradient (g = params - target_w, target_w = w+1),
    /// returning final states. Used by the per-algorithm unit tests.
    pub fn drive(
        algo: &dyn BaseAlgorithm,
        m: usize,
        d: usize,
        steps: u64,
        gamma: f32,
    ) -> Vec<WorkerState> {
        let fabric = Fabric::new(m, CostModel::free());
        let kernels = Kernels::Native;
        let barrier = crate::exec::Barrier::new(m);
        crate::exec::run_workers(m, |w| {
            let init: Vec<f32> = (0..d).map(|i| (i + 1) as f32).collect();
            let mut state = WorkerState::new(&init, algo.inner());
            let mut ctx = Ctx {
                worker: w,
                m,
                fabric: &fabric,
                kernels: &kernels,
                compress: None,
                scope: None,
                clock: 0.0,
            };
            let target = vec![(w + 1) as f32; d];
            for k in 0..steps {
                let g: Vec<f32> = algo
                    .eval_params(&state)
                    .iter()
                    .zip(&target)
                    .map(|(&x, &t)| x - t)
                    .collect();
                algo.step(&mut ctx, &mut state, &g, gamma, k).unwrap();
            }
            // Absorb in-flight gossip so push-sum mass checks see the whole
            // system (real OSGP runs end with an exact average anyway).
            barrier.wait();
            for (msg, _) in fabric.gossip_drain(w) {
                crate::optim::add_assign(&mut state.x, &msg.payload);
                state.w += msg.weight;
            }
            let inv_w = (1.0 / state.w) as f32;
            for (z, &x) in state.z.iter_mut().zip(&state.x) {
                *z = x * inv_w;
            }
            state
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_state_init_shapes() {
        let s = WorkerState::new(&[1.0, 2.0], &InnerOpt::nesterov_default());
        assert_eq!(s.d(), 2);
        assert!(s.v.is_empty());
        assert_eq!(s.w, 1.0);
        assert_eq!(s.x, s.z);
        let s = WorkerState::new(&[1.0, 2.0], &InnerOpt::adam_default());
        assert_eq!(s.v.len(), 2);
    }

    #[test]
    fn reset_buffers_zeroes() {
        let mut s = WorkerState::new(&[1.0; 4], &InnerOpt::adam_default());
        s.h[0] = 5.0;
        s.v[1] = 2.0;
        s.adam_step = 9;
        s.reset_buffers();
        assert!(s.h.iter().all(|&x| x == 0.0));
        assert!(s.v.iter().all(|&x| x == 0.0));
        assert_eq!(s.adam_step, 0);
    }
}
