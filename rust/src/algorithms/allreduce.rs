//! AR-SGD / AR-Adam: the classic ALLREDUCE-every-step baseline.
//!
//! Gradients are exact-averaged across all m workers with the ring
//! allreduce, then every worker applies the identical inner-optimizer step
//! — so all worker states stay bit-identical (asserted in tests). This is
//! the paper's "traditional Allreduce implementation of parallel
//! SGD/Adam" and the τ=1 anchor of the SlowMo framework.

use super::{
    apply_inner, compress_payload_pooled, BaseAlgorithm, Ctx, WorkerState,
};
use crate::compress::site;
use crate::net::ring_allreduce_mean_group_p;
use crate::optim::kernels::InnerOpt;
use anyhow::Result;

pub struct AllReduce {
    inner: InnerOpt,
}

impl AllReduce {
    pub fn new(inner: InnerOpt) -> Self {
        Self { inner }
    }
}

impl BaseAlgorithm for AllReduce {
    fn name(&self) -> String {
        format!("ar-{}", self.inner.name())
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()> {
        // Hot path: the averaging buffer, the group list and every
        // collective send chunk come from the per-worker scratch pools
        // (and return to them before this step ends), so the steady-state
        // step makes no heap allocations — pinned by the `alloc_gate`
        // integration test. Bitwise-identical to the fresh-buffer path.
        let fabric = ctx.fabric;
        let codec = ctx.compress;
        let mut avg = ctx.scratch.f32s.take();
        avg.extend_from_slice(g);
        // The collective runs over this worker's communication scope: the
        // whole run, or one hierarchy group (group-local gradient
        // averaging).
        let mut group = ctx.scratch.idx.take();
        ctx.scope_members_into(&mut group);
        // Compress the gradient contribution (EF-SGD style: the residual
        // at the GRAD site re-injects whatever this step's codec
        // dropped). A single worker sends nothing, so nothing is lossily
        // transcoded either — no accuracy cost for bytes never on the
        // wire.
        if group.len() > 1 {
            compress_payload_pooled(
                codec, &mut state.comp, &mut avg, site::GRAD,
                &mut ctx.scratch,
            );
        }
        // coll_id = k keys the chaos delay stream per step.
        ctx.clock = ring_allreduce_mean_group_p(
            fabric, ctx.worker, &group, &mut avg, ctx.clock, k,
            codec.filter(|c| !c.is_identity()),
            &mut ctx.scratch.f32s,
        );
        apply_inner(ctx, &self.inner, state, &avg, gamma)?;
        ctx.scratch.f32s.put(avg);
        ctx.scratch.idx.put(group);
        if !state.z.is_empty() {
            state.z.copy_from_slice(&state.x);
        }
        Ok(())
    }

    fn lockstep(&self) -> bool {
        true
    }

    fn needs_debias(&self) -> bool {
        false
    }

    fn comm_elems_per_step(&self, d: usize) -> usize {
        // Ring allreduce moves 2(m-1)/m * d values per worker; report the
        // asymptotic 2d.
        2 * d
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::drive;
    use super::*;

    #[test]
    fn workers_stay_bit_identical() {
        let algo = AllReduce::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 });
        let states = drive(&algo, 4, 8, 30, 0.05);
        for s in &states[1..] {
            assert_eq!(s.x, states[0].x);
            assert_eq!(s.h, states[0].h);
        }
    }

    #[test]
    fn converges_to_mean_target() {
        // Average gradient pulls to the mean of worker targets.
        let algo = AllReduce::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        let m = 4;
        let states = drive(&algo, m, 4, 80, 0.4);
        for s in &states {
            for &x in &s.x {
                assert!((x - 2.5).abs() < 1e-2, "x={x}");
            }
        }
    }

    #[test]
    fn adam_variant_identical_too() {
        let algo = AllReduce::new(InnerOpt::adam_default());
        let states = drive(&algo, 3, 4, 10, 1e-2);
        for s in &states[1..] {
            assert_eq!(s.x, states[0].x);
            assert_eq!(s.v, states[0].v);
        }
        assert_eq!(algo.name(), "ar-adam");
    }
}
