//! Double-averaging momentum Local SGD (Yu et al. 2019a; paper Alg. 5).
//!
//! Like Local SGD, but every τ steps workers ALLREDUCE **both** the
//! parameters and the momentum buffer — restoring the linear-speedup
//! guarantee at the price of doubling the periodic communication. The
//! paper compares this against SlowMo in §4 ("Comparison with
//! Double-Averaging Momentum"); our Table-2/doubleavg bench reproduces
//! the accuracy-vs-time tradeoff.
//!
//! This algorithm is used standalone (not wrapped in SlowMo).

use super::{apply_inner, compress_payload, BaseAlgorithm, Ctx, WorkerState};
use crate::compress::site;
use crate::net::ring_allreduce_mean_group_c;
use crate::optim::kernels::InnerOpt;
use anyhow::Result;

pub struct DoubleAvg {
    inner: InnerOpt,
    pub tau: u64,
}

impl DoubleAvg {
    pub fn new(inner: InnerOpt, tau: u64) -> Self {
        assert!(tau >= 1);
        Self { inner, tau }
    }
}

impl BaseAlgorithm for DoubleAvg {
    fn name(&self) -> String {
        format!("doubleavg-{}-tau{}", self.inner.name(), self.tau)
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()> {
        apply_inner(ctx, &self.inner, state, g, gamma)?;
        if (k + 1) % self.tau == 0 && ctx.scope_len() > 1 {
            // Alg. 5 lines 6-7: average params AND momentum buffers over
            // this worker's communication scope (the whole run, or one
            // hierarchy group).
            // coll_ids 3k..3k+2 key the chaos delay streams per collective.
            // Each buffer is compressed at its own site (independent EF
            // residuals for x, h and v).
            let codec = ctx.compress.filter(|c| !c.is_identity());
            let group = ctx.scope_members();
            compress_payload(
                ctx.compress, &mut state.comp, &mut state.x, site::DAVG_X,
            );
            ctx.clock = ring_allreduce_mean_group_c(
                ctx.fabric, ctx.worker, &group, &mut state.x, ctx.clock,
                3 * k, codec,
            );
            compress_payload(
                ctx.compress, &mut state.comp, &mut state.h, site::DAVG_H,
            );
            ctx.clock = ring_allreduce_mean_group_c(
                ctx.fabric, ctx.worker, &group, &mut state.h, ctx.clock,
                3 * k + 1, codec,
            );
            if !state.v.is_empty() {
                compress_payload(
                    ctx.compress, &mut state.comp, &mut state.v,
                    site::DAVG_V,
                );
                ctx.clock = ring_allreduce_mean_group_c(
                    ctx.fabric, ctx.worker, &group, &mut state.v, ctx.clock,
                    3 * k + 2, codec,
                );
            }
        }
        state.z.copy_from_slice(&state.x);
        Ok(())
    }

    fn lockstep(&self) -> bool {
        true
    }

    fn comm_elems_per_step(&self, d: usize) -> usize {
        let buffers = if self.inner.uses_second_moment() { 3 } else { 2 };
        (buffers * 2 * d) / self.tau as usize
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::drive;
    use super::*;

    #[test]
    fn states_identical_after_average_step() {
        let algo = DoubleAvg::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 },
                                  5);
        // 30 steps = 6 full periods; states were just averaged at k=29.
        let states = drive(&algo, 3, 4, 30, 0.05);
        for s in &states[1..] {
            assert_eq!(s.x, states[0].x);
            assert_eq!(s.h, states[0].h, "momentum buffers must be averaged");
        }
    }

    #[test]
    fn momentum_buffers_diverge_between_averages() {
        let algo = DoubleAvg::new(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 },
                                  100);
        // 30 < 100: no average has happened; buffers differ across workers
        // (different targets).
        let states = drive(&algo, 3, 4, 30, 0.05);
        assert_ne!(states[0].h, states[1].h);
    }

    #[test]
    fn converges_to_mean_target() {
        let algo = DoubleAvg::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
                                  4);
        let states = drive(&algo, 4, 4, 120, 0.2);
        for s in &states {
            for &x in &s.x {
                assert!((x - 2.5).abs() < 0.25, "x={x}");
            }
        }
    }

    #[test]
    fn comm_accounting_doubles_vs_param_only() {
        let nesterov = DoubleAvg::new(InnerOpt::nesterov_default(), 10);
        let adam = DoubleAvg::new(InnerOpt::adam_default(), 10);
        assert_eq!(nesterov.comm_elems_per_step(1000), 400);
        assert_eq!(adam.comm_elems_per_step(1000), 600);
    }
}
