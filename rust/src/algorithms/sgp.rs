//! Stochastic Gradient Push (paper Alg. 2) and Overlap SGP (Alg. 3).
//!
//! Push-sum gossip over the time-varying directed exponential graph:
//! each step, worker i takes a local momentum step on its biased
//! parameters x, splits the result (and its push-sum weight w) between
//! itself and one out-neighbor, merges whatever it receives, and
//! de-biases z = x / w for the next gradient evaluation.
//!
//! - `overlap = false` (SGP): blocking — each worker consumes exactly its
//!   in-degree of step-k messages before proceeding (lockstep).
//! - `overlap = true` (OSGP): non-blocking — send and continue, merging
//!   whatever has arrived; if nothing arrived for `sync_every` consecutive
//!   steps, block until one message shows up (Alg. 3 `count_since_last`).
//!
//! Push-sum mass (Σ_i w_i = m) and average (Σ_i x_i preserved) invariants
//! are property-tested below and in `rust/tests/algorithms.rs`.

use super::{apply_inner, BaseAlgorithm, Ctx, WorkerState};
use crate::net::GossipMsg;
use crate::optim::kernels::InnerOpt;
use crate::topology::Topology;
use anyhow::Result;
use std::sync::Arc;

pub struct Sgp {
    inner: InnerOpt,
    topo: Arc<dyn Topology>,
    pub overlap: bool,
    /// OSGP: block for a message after this many receive-less steps.
    pub sync_every: u64,
    /// Name tag distinguishing registry variants over non-default graphs
    /// ("" for the time-varying exponential default, "-static" for the
    /// fixed directed ring). Purely cosmetic: the mixing behaviour lives
    /// entirely in `topo`.
    tag: &'static str,
}

impl Sgp {
    pub fn new(inner: InnerOpt, topo: Arc<dyn Topology>) -> Self {
        Self { inner, topo, overlap: false, sync_every: 1, tag: "" }
    }

    /// OSGP: `sync_every = 1` bounds staleness to one overlapped step —
    /// matching the reference implementation, where communication of step
    /// k overlaps with compute of step k+1 but is awaited before k+2.
    /// Looser bounds let a fast worker halve its push-sum weight
    /// geometrically while running solo, destabilizing z = x/w.
    pub fn overlap(inner: InnerOpt, topo: Arc<dyn Topology>) -> Self {
        Self { inner, topo, overlap: true, sync_every: 1, tag: "" }
    }

    /// Tag the display name (e.g. "-static" for the fixed-graph registry
    /// variants, so `sgp-static` builds an algorithm named
    /// `sgp-static-<inner>`).
    pub fn with_tag(mut self, tag: &'static str) -> Self {
        self.tag = tag;
        self
    }

    /// Number of step-`k` messages addressed to `worker`.
    fn in_degree(&self, worker: usize, k: u64) -> usize {
        let m = self.topo.m();
        (0..m)
            .filter(|&s| {
                s != worker
                    && self
                        .topo
                        .round(s, k)
                        .out
                        .iter()
                        .any(|&(dst, _)| dst == worker)
            })
            .count()
    }

    fn merge(state: &mut WorkerState, msg: &GossipMsg) {
        crate::optim::add_assign(&mut state.x, &msg.payload);
        state.w += msg.weight;
    }
}

impl BaseAlgorithm for Sgp {
    fn name(&self) -> String {
        format!(
            "{}{}-{}",
            if self.overlap { "osgp" } else { "sgp" },
            self.tag,
            self.inner.name()
        )
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn eval_params<'s>(&self, state: &'s WorkerState) -> &'s [f32] {
        &state.z
    }

    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        k: u64,
    ) -> Result<()> {
        // 1. Local momentum step on the biased parameters x (Alg. 2 l.3-4).
        apply_inner(ctx, &self.inner, state, g, gamma)?;

        // 2. Send scaled (x, w) shares to out-neighbors (Alg. 2 l.5),
        // through the configured compressor (per-link EF residual; the
        // push-sum weight scalar rides uncompressed). The topology is
        // built over the communication scope (the whole run, or one
        // hierarchy group), so it deals in local ranks; the fabric in
        // global mailbox ids.
        let round = self.topo.round(ctx.local_rank(), k);
        for &(peer_local, p) in &round.out {
            let peer = ctx.to_global(peer_local);
            let mut payload: Vec<f32> =
                state.x.iter().map(|&v| v * p as f32).collect();
            let wire = super::compress_payload(
                ctx.compress,
                &mut state.comp,
                &mut payload,
                crate::compress::site::gossip(peer),
            );
            ctx.fabric.gossip_send_wire(
                peer,
                GossipMsg {
                    from: ctx.worker,
                    step: k,
                    payload,
                    weight: p * state.w,
                    send_time: ctx.clock,
                },
                wire,
            );
        }
        // Keep own share (Alg. 2 l.7-8).
        crate::optim::scale(&mut state.x, round.self_weight as f32);
        state.w *= round.self_weight;

        // 3. Receive (Alg. 2 l.6 / Alg. 3 l.9-18).
        if self.overlap {
            let mut got = false;
            for (msg, arrival) in ctx.fabric.gossip_drain(ctx.worker) {
                Self::merge(state, &msg);
                ctx.clock = ctx.clock.max(arrival);
                got = true;
            }
            if got {
                state.pending_count = 0;
            } else {
                state.pending_count += 1;
                if state.pending_count >= self.sync_every {
                    // Staleness bound (Alg. 3 count_since_last): wait for a
                    // message, but with a timeout so a peer that already
                    // finished its run cannot deadlock us.
                    if let Some((msg, arrival)) = ctx
                        .fabric
                        .gossip_recv_timeout(
                            ctx.worker,
                            std::time::Duration::from_millis(20),
                        )
                    {
                        Self::merge(state, &msg);
                        ctx.clock = ctx.clock.max(arrival);
                    }
                    state.pending_count = 0;
                }
            }
        } else {
            // Blocking: consume exactly the in-degree of step-k messages,
            // stashing any early messages from faster senders.
            let expect = self.in_degree(ctx.local_rank(), k);
            let mut consumed = 0;
            let mut stash_idx = 0;
            while consumed < expect {
                // First check the stash for step-k messages.
                if stash_idx < state.stash.len() {
                    if state.stash[stash_idx].0.step == k {
                        let (msg, arrival) = state.stash.remove(stash_idx);
                        Self::merge(state, &msg);
                        ctx.clock = ctx.clock.max(arrival);
                        consumed += 1;
                    } else {
                        stash_idx += 1;
                    }
                    continue;
                }
                let (msg, arrival) = ctx.fabric.gossip_recv(ctx.worker);
                if msg.step == k {
                    Self::merge(state, &msg);
                    ctx.clock = ctx.clock.max(arrival);
                    consumed += 1;
                } else {
                    state.stash.push((msg, arrival));
                }
            }
        }

        // 4. De-bias (Alg. 2 l.9).
        let inv_w = (1.0 / state.w) as f32;
        for (z, &x) in state.z.iter_mut().zip(&state.x) {
            *z = x * inv_w;
        }
        Ok(())
    }

    fn lockstep(&self) -> bool {
        !self.overlap
    }

    fn comm_elems_per_step(&self, d: usize) -> usize {
        self.topo.sends_per_step() * d
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::drive;
    use super::*;
    use crate::topology::ExponentialGraph;
    use crate::util::mean;

    fn sgp(m: usize, overlap: bool) -> Sgp {
        let topo = Arc::new(ExponentialGraph::new(m));
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        if overlap {
            Sgp::overlap(inner, topo)
        } else {
            Sgp::new(inner, topo)
        }
    }

    #[test]
    fn push_sum_mass_conserved() {
        for &overlap in &[false, true] {
            let algo = sgp(4, overlap);
            let states = drive(&algo, 4, 8, 25, 0.1);
            let total_w: f64 = states.iter().map(|s| s.w).sum();
            assert!(
                (total_w - 4.0).abs() < 1e-9,
                "overlap={overlap} mass {total_w}"
            );
        }
    }

    #[test]
    fn blocking_sgp_consensus_on_agreeing_workers() {
        // With zero gradients the workers should reach consensus on the
        // initial value (gossip only mixes).
        let m = 8;
        let algo = sgp(m, false);
        let fabric = crate::net::Fabric::new(m, crate::net::CostModel::free());
        let kernels = crate::optim::kernels::Kernels::Native;
        let states = crate::exec::run_workers(m, |w| {
            let init = vec![w as f32; 4]; // worker-specific values
            let mut st = WorkerState::new(&init, algo.inner());
            let mut ctx = Ctx { worker: w, m, fabric: &fabric,
                                kernels: &kernels, compress: None,
                                scope: None, clock: 0.0,
                                scratch: crate::util::Scratch::new() };
            for k in 0..60 {
                algo.step(&mut ctx, &mut st, &[0.0; 4], 0.1, k).unwrap();
            }
            st
        });
        // Average of initial values is (m-1)/2 = 3.5; all z must be there.
        for s in &states {
            for &z in &s.z {
                assert!((z - 3.5).abs() < 1e-3, "z={z}");
            }
        }
    }

    #[test]
    fn sgp_tracks_mean_of_targets() {
        // Workers pull toward different targets (w+1); SGP consensus should
        // land near the mean target (m+1)/2 + 0.5 = mean of 1..=m.
        let m = 4;
        let algo = sgp(m, false);
        let states = drive(&algo, m, 4, 200, 0.2);
        let want = mean(&(1..=m).map(|x| x as f64).collect::<Vec<_>>());
        for s in &states {
            for &z in &s.z {
                assert!((z as f64 - want).abs() < 0.15, "z={z} want {want}");
            }
        }
    }

    #[test]
    fn osgp_makes_progress_without_blocking() {
        let m = 4;
        let algo = sgp(m, true);
        let states = drive(&algo, m, 4, 200, 0.2);
        let want = mean(&(1..=m).map(|x| x as f64).collect::<Vec<_>>());
        for s in &states {
            for &z in &s.z {
                // Looser: asynchrony adds noise but must stay in range.
                assert!((z as f64 - want).abs() < 0.8, "z={z} want {want}");
            }
        }
    }

    #[test]
    fn in_degree_matches_exponential_graph() {
        let algo = sgp(8, false);
        for k in 0..6 {
            for w in 0..8 {
                assert_eq!(algo.in_degree(w, k), 1);
            }
        }
    }

    #[test]
    fn single_worker_sgp_is_local() {
        let algo = sgp(1, false);
        let states = drive(&algo, 1, 4, 50, 0.5);
        for &x in &states[0].x {
            assert!((x - 1.0).abs() < 1e-3);
        }
        assert_eq!(states[0].w, 1.0);
    }

    #[test]
    fn names() {
        assert_eq!(sgp(2, false).name(), "sgp-nesterov-sgd");
        assert_eq!(sgp(2, true).name(), "osgp-nesterov-sgd");
        assert!(sgp(2, false).lockstep());
        assert!(!sgp(2, true).lockstep());
        assert_eq!(
            sgp(2, false).with_tag("-static").name(),
            "sgp-static-nesterov-sgd"
        );
        assert_eq!(
            sgp(2, true).with_tag("-static").name(),
            "osgp-static-nesterov-sgd"
        );
    }

    #[test]
    fn static_ring_sgp_conserves_mass_and_mixes() {
        use crate::topology::DirectedRing;
        let m = 4;
        let inner = InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 };
        let algo = Sgp::new(inner, Arc::new(DirectedRing::new(m)))
            .with_tag("-static");
        let states = drive(&algo, m, 4, 200, 0.2);
        let total_w: f64 = states.iter().map(|s| s.w).sum();
        assert!((total_w - m as f64).abs() < 1e-9, "mass {total_w}");
        let want = mean(&(1..=m).map(|x| x as f64).collect::<Vec<_>>());
        for s in &states {
            for &z in &s.z {
                assert!((z as f64 - want).abs() < 0.3, "z={z} want {want}");
            }
        }
    }
}
