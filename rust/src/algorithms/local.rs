//! Local SGD / Local Adam base algorithm: no inner-loop communication.
//!
//! Paper equivalences (Section 2): wrapping [`Local`] in the SlowMo
//! controller with α=1, β=0 *is* Local SGD (McDonald et al. 2010; Stich
//! 2019) — the controller's exact average is the periodic ALLREDUCE of
//! Alg. 4 line 6. With β>0 it is BMUF (Chen & Huo 2016); with τ=1 and the
//! "maintain" buffer strategy it is AR-SGD up to where the momentum buffer
//! lives (see [`super::AllReduce`] for the true gradient-allreduce AR).

use super::{apply_inner, BaseAlgorithm, Ctx, WorkerState};
use crate::optim::kernels::InnerOpt;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct Local {
    inner: InnerOpt,
}

impl Local {
    pub fn new(inner: InnerOpt) -> Self {
        Self { inner }
    }
}

impl BaseAlgorithm for Local {
    fn name(&self) -> String {
        format!("local-{}", self.inner.name())
    }

    fn inner(&self) -> &InnerOpt {
        &self.inner
    }

    fn step(
        &self,
        ctx: &mut Ctx,
        state: &mut WorkerState,
        g: &[f32],
        gamma: f32,
        _k: u64,
    ) -> Result<()> {
        apply_inner(ctx, &self.inner, state, g, gamma)?;
        // Keep the de-biased view coherent for uniform eval plumbing
        // (skipped under the lean-z layout: eval_params is x here).
        if !state.z.is_empty() {
            state.z.copy_from_slice(&state.x);
        }
        Ok(())
    }

    fn lockstep(&self) -> bool {
        false
    }

    fn needs_debias(&self) -> bool {
        false
    }

    fn comm_elems_per_step(&self, _d: usize) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::drive;
    use super::*;

    #[test]
    fn workers_converge_to_their_local_targets() {
        let algo = Local::new(InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 });
        // target for worker w is w+1; gamma=0.5 with plain SGD on
        // g = x - t converges geometrically.
        let states = drive(&algo, 3, 4, 60, 0.5);
        for (w, s) in states.iter().enumerate() {
            for &x in &s.x {
                assert!((x - (w + 1) as f32).abs() < 1e-3, "w{w} x={x}");
            }
        }
    }

    #[test]
    fn no_communication_happens() {
        use crate::net::{CostModel, Fabric};
        use crate::optim::kernels::Kernels;
        let fabric = Fabric::new(2, CostModel::free());
        let algo = Local::new(InnerOpt::nesterov_default());
        let kernels = Kernels::Native;
        let mut ctx = Ctx { worker: 0, m: 2, fabric: &fabric,
                            kernels: &kernels, compress: None,
                            scope: None, clock: 0.0,
                            scratch: crate::util::Scratch::new() };
        let mut st = WorkerState::new(&[1.0; 8], algo.inner());
        algo.step(&mut ctx, &mut st, &[0.1; 8], 0.1, 0).unwrap();
        assert_eq!(fabric.msgs_sent(), 0);
        assert_eq!(algo.comm_elems_per_step(8), 0);
        assert!(!algo.lockstep());
    }

    #[test]
    fn adam_variant_counts_steps() {
        let algo = Local::new(InnerOpt::adam_default());
        let states = drive(&algo, 1, 2, 5, 1e-3);
        assert_eq!(states[0].adam_step, 5);
        assert_eq!(algo.name(), "local-adam");
    }
}
