//! Experiment harnesses: one entry point per paper table/figure.
//!
//! Each harness (see DESIGN.md §4 for the full index) builds the workload,
//! runs the baseline grid, prints the same rows/series the paper reports,
//! and writes machine-readable results under `results/`. They are invoked
//! both by the `slowmo exp <id>` CLI and by the `cargo bench` targets in
//! `benches/`.

pub mod experiments;
pub mod micro;

use crate::net::CostModel;
use crate::runtime::{Engine, Manifest};
use crate::session::Session;
use anyhow::Result;

/// Experiment scale. The paper's full workloads (90 epochs of ImageNet on
/// 256 GPUs) are far beyond a single-core CI budget; `quick` reproduces
/// every table's *shape* in minutes, `standard` tightens the statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Smallest: the default for `cargo bench` so the whole suite fits a
    /// single-core CI budget (shapes only, noisy statistics).
    Ci,
    Quick,
    Standard,
    Full,
}

impl std::str::FromStr for Scale {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ci" => Ok(Self::Ci),
            "quick" => Ok(Self::Quick),
            "standard" => Ok(Self::Standard),
            "full" => Ok(Self::Full),
            other => {
                Err(format!("unknown scale {other:?} \
                             (expected ci|quick|standard|full)"))
            }
        }
    }
}

impl Scale {
    /// Canonical lowercase name (the `--scale` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Ci => "ci",
            Scale::Quick => "quick",
            Scale::Standard => "standard",
            Scale::Full => "full",
        }
    }

    /// Workers.
    pub fn m(&self) -> usize {
        match self {
            Scale::Ci | Scale::Quick => 4,
            Scale::Standard => 8,
            Scale::Full => 16,
        }
    }

    /// Inner steps per run.
    pub fn steps(&self) -> u64 {
        match self {
            Scale::Ci => 96,
            Scale::Quick => 240,
            Scale::Standard => 960,
            Scale::Full => 3840,
        }
    }

    /// τ used for gossip bases (paper: 48; scaled down so quick runs still
    /// see ≥10 outer iterations).
    pub fn tau_gossip(&self) -> u64 {
        match self {
            Scale::Ci => 12,
            Scale::Quick => 24,
            Scale::Standard => 48,
            Scale::Full => 48,
        }
    }

    /// τ for Local SGD/Adam (paper: 12).
    pub fn tau_local(&self) -> u64 {
        12
    }

    pub fn eval_every(&self) -> u64 {
        match self {
            // Fewer checkpoints at ci scale: evals are a large fraction of
            // a 96-step run's wall time.
            Scale::Ci => self.steps() / 4,
            _ => self.steps() / 12,
        }
    }

    pub fn eval_batches(&self) -> u64 {
        match self {
            Scale::Ci => 4,
            Scale::Quick => 8,
            _ => 16,
        }
    }

    pub fn seeds(&self) -> u64 {
        match self {
            Scale::Ci | Scale::Quick => 2,
            Scale::Standard => 3,
            Scale::Full => 5,
        }
    }
}

/// Shared context for the harnesses: one [`Session`] (manifest + engine +
/// caches, shared by every cell of a sweep) plus the scale and output dir.
pub struct Env {
    pub session: Session,
    pub scale: Scale,
    pub out_dir: String,
}

impl Env {
    pub fn load(scale: Scale) -> Result<Self> {
        Ok(Self {
            session: Session::open()?,
            scale,
            out_dir: "results".to_string(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.session.manifest()
    }

    pub fn engine(&self) -> &Engine {
        self.session.engine().expect("Env sessions own a PJRT engine")
    }

    pub fn cost(&self) -> CostModel {
        CostModel::ethernet_10g()
    }

    pub fn out_path(&self, name: &str) -> String {
        format!("{}/{}", self.out_dir, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse_and_params() {
        assert_eq!("quick".parse(), Ok(Scale::Quick));
        assert_eq!(Scale::Quick.name(), "quick");
        assert_eq!(Scale::Quick.name().parse(), Ok(Scale::Quick));
        assert_eq!("standard".parse(), Ok(Scale::Standard));
        assert_eq!("full".parse(), Ok(Scale::Full));
        let e = "x".parse::<Scale>().unwrap_err();
        assert!(e.contains("ci|quick|standard|full"), "{e}");
        assert!(Scale::Quick.steps() < Scale::Full.steps());
        assert!(Scale::Quick.steps() / Scale::Quick.tau_gossip() >= 10);
        assert_eq!(Scale::Full.seeds(), 5);
    }
}
