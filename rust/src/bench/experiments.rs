//! The per-table / per-figure experiment implementations.
//!
//! Paper ↔ harness map (DESIGN.md §4):
//! - [`table1`]    — Table 1 (+ Table B.1 NLL column)
//! - [`table2`]    — Table 2a/2b (time per iteration, analytic cost model)
//! - [`fig2`]      — Figure 2 / B.1 (validation + training curves)
//! - [`fig3`]      — Figure 3 (effect of τ: metric + time/iter)
//! - [`figb2`]     — Figure B.2 (α × β sweep)
//! - [`tableb23`]  — Tables B.2/B.3 (buffer strategies)
//! - [`tableb4`]   — Table B.4 (multi-seed std devs)
//! - [`doubleavg`] — §4 double-averaging comparison
//! - [`noaverage`] — §6 SGP-SlowMo-noaverage
//! - [`theory`]    — Theorem 1 / Corollaries 1-2 empirical validation

use super::{Env, Scale};
use crate::algorithms::AlgoSel;
use crate::benchkit::Table;
use crate::net::WorkloadTiming;
use crate::optim::kernels::InnerOpt;
use crate::session::TrainBuilder;
use crate::slowmo::{BufferStrategy, SlowMoCfg};
use crate::trainer::{Schedule, SeedAggregate, StateMode, TrainResult};
use anyhow::Result;

/// Task descriptor: which preset stands in for which paper dataset, and
/// the paper's hyperparameters for it.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub paper_name: &'static str,
    pub preset: String,
    pub inner: InnerOpt,
    pub sched: fn(u64) -> Schedule,
    /// SlowMo β used in Table 1 for this task.
    pub beta: f32,
    pub buffers: BufferStrategy,
}

fn image_sched(total: u64) -> Schedule {
    Schedule::image_default(0.1, total)
}

fn lm_sched(total: u64) -> Schedule {
    Schedule::lm_default(2e-3, total)
}

impl TaskSpec {
    pub fn cifar() -> Self {
        Self {
            paper_name: "CIFAR-10",
            preset: "cifar-mlp".into(),
            inner: InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 },
            sched: image_sched,
            beta: 0.7,
            buffers: BufferStrategy::Reset,
        }
    }

    pub fn imagenet() -> Self {
        Self {
            paper_name: "ImageNet",
            preset: "imagenet-mlp".into(),
            inner: InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 },
            sched: image_sched,
            beta: 0.6,
            buffers: BufferStrategy::Reset,
        }
    }

    pub fn wmt(scale: Scale) -> Self {
        Self {
            paper_name: "WMT'16 En-De",
            // The full transformer analog is used at standard+ scales; the
            // CI-speed transformer keeps quick runs quick.
            preset: if matches!(scale, Scale::Ci | Scale::Quick) {
                "lm-tiny".into()
            } else {
                "wmt-lm".into()
            },
            inner: InnerOpt::adam_default(),
            sched: lm_sched,
            beta: 0.5,
            buffers: BufferStrategy::Maintain,
        }
    }
}

/// Builder for one (task, algo, slowmo) cell. The harnesses chain further
/// overrides onto this before handing it to [`run_cell`].
///
/// §Perf note: the optimizer kernels default to the native mirrors — on
/// CPU-PJRT the artifacts are literal-copy bound (~50x at d=2M, see the
/// micro bench) and the math is identical (equivalence-tested); PJRT
/// kernels stay available through `.pjrt_kernels()`.
pub fn cell<'e>(
    env: &'e Env,
    task: &TaskSpec,
    algo: AlgoSel,
    slowmo: Option<SlowMoCfg>,
    seed: u64,
) -> TrainBuilder<'e> {
    let s = env.scale;
    env.session
        .train(&task.preset)
        .algo_sel(algo)
        .workers(s.m())
        .steps(s.steps())
        .seed(seed)
        .slowmo_opt(slowmo)
        .schedule((task.sched)(s.steps()))
        .eval_every(s.eval_every())
        .eval_batches(s.eval_batches())
        .cost(env.cost())
}

fn run_cell(env: &Env, builder: TrainBuilder) -> Result<TrainResult> {
    let r = builder.run()?;
    crate::info!(
        "{} / {}: train {:.4} metric {:.4} ({:.1}s wall)",
        r.preset, r.algo, r.best_train_loss, r.best_eval_metric,
        r.wall_time
    );
    r.append_jsonl(&env.out_path("runs.jsonl"))?;
    Ok(r)
}

fn slowmo_for(task: &TaskSpec, tau: u64) -> SlowMoCfg {
    SlowMoCfg::new(1.0, task.beta, tau).with_buffers(task.buffers)
}

fn fmt4(x: f64) -> String {
    format!("{x:.4}")
}

fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

// ------------------------------------------------------------------ Table 1

/// Table 1: best training loss + validation metric for each baseline with
/// and without SlowMo, across the three tasks. Also emits validation NLL
/// for the LM task (Table B.1).
pub fn table1(env: &Env, tasks: &[TaskSpec]) -> Result<Table> {
    let mut table = Table::new(
        "Table 1 — best train loss / val metric, orig vs +SlowMo",
        &["dataset", "baseline", "loss(orig)", "loss(slowmo)",
          "metric(orig)", "metric(slowmo)", "val-NLL(orig)",
          "val-NLL(slowmo)"],
    );
    for task in tasks {
        let adam = task.inner.uses_second_moment();
        let rows: Vec<(&str, AlgoSel, u64)> = vec![
            ("Local", AlgoSel::with_inner("local", task.inner),
             env.scale.tau_local()),
            ("OSGP", AlgoSel::with_inner("osgp", task.inner),
             env.scale.tau_gossip()),
            ("SGP", AlgoSel::with_inner("sgp", task.inner),
             env.scale.tau_gossip()),
        ];
        for (name, algo, tau) in rows {
            if adam && name == "OSGP" {
                continue; // paper's WMT table has no OSGP row
            }
            // Baseline: Local runs as SlowMo(α=1, β=0) — that *is* Local
            // SGD (periodic averaging); gossip baselines run bare.
            let orig_slowmo = if algo.key == "local" {
                Some(SlowMoCfg::new(1.0, 0.0, tau)
                    .with_buffers(BufferStrategy::Maintain))
            } else {
                None
            };
            let orig =
                run_cell(env, cell(env, task, algo.clone(), orig_slowmo, 0))?;
            let slow = run_cell(
                env,
                cell(env, task, algo.clone(), Some(slowmo_for(task, tau)), 0),
            )?;
            table.row(&[
                task.paper_name.to_string(),
                name.to_string(),
                fmt4(orig.best_train_loss),
                fmt4(slow.best_train_loss),
                fmt_pct(orig.best_eval_metric),
                fmt_pct(slow.best_eval_metric),
                fmt4(orig.final_eval_loss),
                fmt4(slow.final_eval_loss),
            ]);
        }
        // AR baseline (no SlowMo column in the paper).
        let ar = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("ar", task.inner), None, 0),
        )?;
        table.row(&[
            task.paper_name.to_string(),
            "AR".to_string(),
            fmt4(ar.best_train_loss),
            "-".to_string(),
            fmt_pct(ar.best_eval_metric),
            "-".to_string(),
            fmt4(ar.final_eval_loss),
            "-".to_string(),
        ]);
    }
    table.print();
    table.write_json(&env.out_path("table1.json"))?;
    Ok(table)
}

// ------------------------------------------------------------------ Table 2

/// Table 2: average time per iteration, with and without SlowMo, from the
/// α-β cost model at the paper's hardware scale (analytic; DESIGN.md §2).
pub fn table2(env: &Env) -> Result<Table> {
    let mut table = Table::new(
        "Table 2 — avg time/iteration (ms), cost model at paper scale",
        &["workload", "baseline", "orig", "w/ SlowMo"],
    );
    for w in [WorkloadTiming::imagenet(), WorkloadTiming::wmt()] {
        let tau_local = 12;
        let tau_gossip = 48;
        let ms = |t: f64| format!("{:.0}", t * 1e3);
        let rows: Vec<(&str, f64, f64)> = vec![
            (
                "Local",
                w.iter_local_sgd(tau_local),
                // SlowMo's exact average replaces Local SGD's own.
                w.iter_local_sgd(tau_local) + w.slowmo_overhead(tau_local, true),
            ),
            (
                "OSGP",
                w.iter_osgp(),
                w.iter_osgp() + w.slowmo_overhead(tau_gossip, false),
            ),
            (
                "SGP",
                w.iter_sgp(),
                w.iter_sgp() + w.slowmo_overhead(tau_gossip, false),
            ),
            ("AR", w.iter_allreduce(), f64::NAN),
        ];
        for (name, orig, slow) in rows {
            if w.name.contains("wmt") && name == "OSGP" {
                continue;
            }
            table.row(&[
                w.name.to_string(),
                name.to_string(),
                ms(orig),
                if slow.is_nan() { "-".into() } else { ms(slow) },
            ]);
        }
    }
    table.print();
    table.write_json(&env.out_path("table2.json"))?;
    Ok(table)
}

// ------------------------------------------------------------------ Fig 2

/// Figure 2 (validation curves) + Figure B.1 (training curves) for SGP vs
/// SGP-SlowMo on each task; curves land in results/fig2.<task>.json.
pub fn fig2(env: &Env, tasks: &[TaskSpec]) -> Result<()> {
    for task in tasks {
        let tau = env.scale.tau_local(); // paper fixes τ=12 for Fig. 2
        let sgp = AlgoSel::with_inner("sgp", task.inner);
        let r0 = run_cell(env, cell(env, task, sgp.clone(), None, 0))?;
        let r1 = run_cell(
            env,
            cell(env, task, sgp, Some(slowmo_for(task, tau)), 0),
        )?;
        let obj = crate::jsonx::Json::obj(vec![
            ("task", crate::jsonx::Json::str(task.paper_name)),
            ("sgp", r0.to_json()),
            ("sgp_slowmo", r1.to_json()),
        ]);
        let path = env.out_path(&format!(
            "fig2.{}.json",
            task.preset.replace('/', "-")
        ));
        std::fs::create_dir_all(&env.out_dir)?;
        std::fs::write(&path, crate::jsonx::to_string(&obj))?;
        println!("fig2[{}]:", task.paper_name);
        println!("  step  val-loss(sgp)  val-loss(sgp+slowmo)");
        for (a, b) in r0.eval_curve.iter().zip(&r1.eval_curve) {
            println!(
                "  {:>5}  {:>12.4}  {:>18.4}",
                a.step, a.loss_mean, b.loss_mean
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------ Fig 3

/// Figure 3: effect of τ on validation metric and time/iteration.
pub fn fig3(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "Figure 3 — effect of tau (SGP base)",
        &["tau", "best val metric", "final val loss", "time/iter (ms)"],
    );
    let taus: Vec<u64> = [6u64, 12, 24, 48, 96, 192]
        .into_iter()
        .filter(|&t| t * 4 <= env.scale.steps())
        .collect();
    // Timing column: analytic at paper scale (the paper's right axis).
    let wt = if task.inner.uses_second_moment() {
        WorkloadTiming::wmt()
    } else {
        WorkloadTiming::imagenet()
    };
    for &tau in &taus {
        let r = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("sgp", task.inner),
                 Some(slowmo_for(task, tau)), 0),
        )?;
        let t_iter = wt.iter_sgp() + wt.slowmo_overhead(tau as usize, false);
        table.row(&[
            tau.to_string(),
            fmt_pct(r.best_eval_metric),
            fmt4(r.final_eval_loss),
            format!("{:.0}", t_iter * 1e3),
        ]);
    }
    table.print();
    table.write_json(&env.out_path("fig3.json"))?;
    Ok(table)
}

// ------------------------------------------------------------------ Fig B.2

/// Figure B.2: α × β sweep.
pub fn figb2(env: &Env, task: &TaskSpec, alphas: &[f32], betas: &[f32])
             -> Result<Table> {
    let mut table = Table::new(
        "Figure B.2 — alpha x beta sweep (best val metric)",
        &["alpha", "beta", "best val metric", "best train loss"],
    );
    let tau = env.scale.tau_local();
    let base = if task.inner.uses_second_moment() {
        AlgoSel::with_inner("local", task.inner) // SlowMo-Adam (Fig. B.2b)
    } else {
        AlgoSel::with_inner("osgp", task.inner) // OSGP base (Fig. B.2a)
    };
    for &alpha in alphas {
        for &beta in betas {
            let s = SlowMoCfg::new(alpha, beta, tau)
                .with_buffers(task.buffers);
            let r = run_cell(env, cell(env, task, base.clone(), Some(s), 0))?;
            table.row(&[
                format!("{alpha}"),
                format!("{beta}"),
                fmt_pct(r.best_eval_metric),
                fmt4(r.best_train_loss),
            ]);
        }
    }
    table.print();
    table.write_json(&env.out_path("figb2.json"))?;
    Ok(table)
}

// ------------------------------------------------------------ Tables B.2/3

/// Tables B.2 / B.3: base-optimizer buffer strategies at the outer loop.
pub fn tableb23(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "Tables B.2/B.3 — buffer strategies (avg parameters + X buffers)",
        &["strategy", "train loss", "val loss", "val metric"],
    );
    let tau = env.scale.tau_local();
    for strat in [BufferStrategy::Average, BufferStrategy::Reset,
                  BufferStrategy::Maintain] {
        let s = SlowMoCfg::new(1.0, task.beta, tau).with_buffers(strat);
        let r = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("local", task.inner),
                 Some(s), 0),
        )?;
        table.row(&[
            strat.name().to_string(),
            fmt4(r.best_train_loss),
            fmt4(r.final_eval_loss),
            fmt_pct(r.best_eval_metric),
        ]);
    }
    table.print();
    table.write_json(&env.out_path("tableb23.json"))?;
    Ok(table)
}

// ------------------------------------------------------------- Table B.4

/// Table B.4: multi-seed mean ± std of validation metric on the CIFAR
/// analog.
pub fn tableb4(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "Table B.4 — validation metric, mean ± std over seeds",
        &["baseline", "orig", "w/ SlowMo"],
    );
    let seeds = env.scale.seeds();
    let rows: Vec<(&str, AlgoSel, u64)> = vec![
        ("Local", AlgoSel::with_inner("local", task.inner),
         env.scale.tau_local()),
        ("OSGP", AlgoSel::with_inner("osgp", task.inner),
         env.scale.tau_gossip()),
        ("SGP", AlgoSel::with_inner("sgp", task.inner),
         env.scale.tau_gossip()),
    ];
    let agg = |runs: &[TrainResult]| {
        let a = SeedAggregate::from_runs(runs);
        format!(
            "{} ± {}",
            fmt_pct(a.best_eval_metric_mean),
            fmt_pct(a.best_eval_metric_std)
        )
    };
    for (name, algo, tau) in rows {
        let mut orig_runs = Vec::new();
        let mut slow_runs = Vec::new();
        for seed in 0..seeds {
            let orig_slowmo = if algo.key == "local" {
                Some(SlowMoCfg::new(1.0, 0.0, tau)
                    .with_buffers(BufferStrategy::Maintain))
            } else {
                None
            };
            orig_runs.push(run_cell(
                env,
                cell(env, task, algo.clone(), orig_slowmo, seed),
            )?);
            slow_runs.push(run_cell(
                env,
                cell(env, task, algo.clone(),
                     Some(slowmo_for(task, tau)), seed),
            )?);
        }
        table.row(&[name.to_string(), agg(&orig_runs), agg(&slow_runs)]);
    }
    table.print();
    table.write_json(&env.out_path("tableb4.json"))?;
    Ok(table)
}

// --------------------------------------------------------- double-average

/// §4 comparison with double-averaging momentum (Yu et al. 2019a).
pub fn doubleavg(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "§4 — SlowMo vs double-averaging (accuracy + analytic time/iter)",
        &["method", "best val metric", "time/iter (ms)"],
    );
    let tau = env.scale.tau_local();
    let wt = WorkloadTiming::imagenet();
    // Local SGD + double averaging.
    let da = run_cell(
        env,
        cell(env, task,
             AlgoSel::with_inner("doubleavg", task.inner).arg(tau),
             None, 0),
    )?;
    // Local SGD + SlowMo.
    let sm = run_cell(
        env,
        cell(env, task, AlgoSel::with_inner("local", task.inner),
             Some(slowmo_for(task, tau)), 0),
    )?;
    let t_da = wt.compute_s
        + 2.0 * wt.net.allreduce_time(wt.params, wt.m) / tau as f64;
    let t_sm = wt.iter_local_sgd(tau as usize);
    table.row(&["LocalSGD+double-avg".into(),
                fmt_pct(da.best_eval_metric),
                format!("{:.0}", t_da * 1e3)]);
    table.row(&["LocalSGD+SlowMo".into(), fmt_pct(sm.best_eval_metric),
                format!("{:.0}", t_sm * 1e3)]);
    table.print();
    table.write_json(&env.out_path("doubleavg.json"))?;
    Ok(table)
}

// -------------------------------------------------------------- noaverage

/// §6: SGP-SlowMo-noaverage (skip the exact average at line 6).
pub fn noaverage(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "§6 — SGP-SlowMo vs SGP-SlowMo-noaverage",
        &["method", "best val metric", "final val loss", "time/iter (ms)"],
    );
    let tau = env.scale.tau_gossip();
    let wt = if task.inner.uses_second_moment() {
        WorkloadTiming::wmt()
    } else {
        WorkloadTiming::imagenet()
    };
    let variants: Vec<(&str, SlowMoCfg, f64)> = vec![
        ("SGP+SlowMo", SlowMoCfg::new(1.0, 0.6, tau)
             .with_buffers(task.buffers),
         wt.iter_sgp() + wt.slowmo_overhead(tau as usize, false)),
        ("SGP+SlowMo-noaverage",
         SlowMoCfg::new(1.0, 0.6, tau).with_buffers(task.buffers)
             .no_average(),
         wt.iter_sgp()),
        ("SGP (no SlowMo)", SlowMoCfg::new(1.0, 0.0, tau).no_average(),
         wt.iter_sgp()),
    ];
    for (name, s, t_iter) in variants {
        let r = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("sgp", task.inner),
                 Some(s), 0),
        )?;
        table.row(&[
            name.to_string(),
            fmt_pct(r.best_eval_metric),
            fmt4(r.final_eval_loss),
            format!("{:.0}", t_iter * 1e3),
        ]);
    }
    table.print();
    table.write_json(&env.out_path("noaverage.json"))?;
    Ok(table)
}

// ------------------------------------------------------------ outer rules

/// Outer-optimizer sweep: every rule registered in the session's
/// [`crate::slowmo::OuterRegistry`] (built-ins *and* custom
/// registrations, each at its default arguments) on one task, same base
/// algorithm and τ — the DeMo-style ablation the pluggable
/// [`crate::slowmo::OuterOpt`] API exists for.
pub fn outers(env: &Env, task: &TaskSpec) -> Result<Table> {
    let mut table = Table::new(
        "Outer-optimizer sweep (Local base, fixed tau)",
        &["outer", "best train loss", "best val metric", "final val loss"],
    );
    let tau = env.scale.tau_local();
    let keys: Vec<String> = env
        .session
        .outer_registry()
        .keys()
        .iter()
        .map(|k| k.to_string())
        .collect();
    for key in &keys {
        let sel = env.session.outer_registry().parse(key)?;
        // A registered rule with a required (no-default) argument cannot
        // run at its bare key — label it and keep sweeping.
        let rule = match env.session.outer_registry().build(&sel) {
            Ok(r) => r,
            Err(e) => {
                crate::info!("outers: skipping {key}: {e}");
                table.row(&[
                    key.clone(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let s = SlowMoCfg::with_outer(sel, tau).with_buffers(task.buffers);
        let r = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("local", task.inner),
                 Some(s), 0),
        )?;
        let params = rule.params();
        table.row(&[
            if params.is_empty() {
                key.clone()
            } else {
                format!("{key}({params})")
            },
            fmt4(r.best_train_loss),
            fmt_pct(r.best_eval_metric),
            fmt4(r.final_eval_loss),
        ]);
    }
    table.print();
    table.write_json(&env.out_path("outers.json"))?;
    Ok(table)
}

// ------------------------------------------------------------ compression

/// Specs swept by [`compress`]: the byte/accuracy tradeoff ladder from
/// raw f32 down to ~0.19 B/coord signsgd, with and without error
/// feedback, plus the frequency-domain `demo` codec at three keep
/// fractions bracketing the `topk` byte budgets (per-chunk `ceil` makes
/// `demo:0.25` byte-equal to `topk:0.25` and `demo:0.05` strictly
/// cheaper than `topk:0.1` on chunk-aligned presets).
pub const COMPRESS_SWEEP: &[&str] = &[
    "none",
    "bf16",
    "fp16",
    "topk:0.25",
    "topk:0.1",
    "ef:topk:0.1",
    "randk:0.1",
    "ef:randk:0.1",
    "signsgd",
    "ef:signsgd",
    "demo:0.25",
    "demo:0.1",
    "demo:0.05",
];

/// Communication-compression sweep (Local base + SlowMo, fixed τ): every
/// spec in [`COMPRESS_SWEEP`] on one task, recording the bytes-on-wire vs
/// final-loss frontier. Besides the printed table (and the usual
/// `runs.jsonl` rows), emits `BENCH_compress.json` — schema
/// `bench-compress/v2`, see `results/BENCH_compress.schema.json` — so the
/// perf trajectory records wire bytes alongside loss. The harness itself
/// asserts the DeMo headline: at least one `demo` cell reaches a lower
/// final eval loss than a `topk`-family cell at an equal-or-smaller wire
/// byte budget.
pub fn compress(env: &Env, task: &TaskSpec) -> Result<Table> {
    use crate::jsonx::Json;
    let mut table = Table::new(
        "Compression sweep (Local base + SlowMo, fixed tau)",
        &["compress", "bytes sent", "bytes saved", "best train loss",
          "final val loss", "sim time (s)"],
    );
    let tau = env.scale.tau_local();
    let mut entries: Vec<Json> = Vec::new();
    let mut frontier: Vec<(String, u64, f64)> = Vec::new();
    for spec in COMPRESS_SWEEP {
        // Hard parse errors surface immediately; this also keeps the
        // sweep honest for out-of-crate registrations replacing built-ins.
        env.session.compress_registry().parse(spec)?;
        let s = slowmo_for(task, tau);
        let r = run_cell(
            env,
            cell(env, task, AlgoSel::with_inner("local", task.inner),
                 Some(s), 0)
                .compress(spec),
        )?;
        table.row(&[
            spec.to_string(),
            r.bytes_sent.to_string(),
            r.bytes_saved.to_string(),
            fmt4(r.best_train_loss),
            fmt4(r.final_eval_loss),
            format!("{:.3}", r.sim_time),
        ]);
        frontier.push((spec.to_string(), r.bytes_sent, r.final_eval_loss));
        entries.push(Json::obj(vec![
            ("compress", Json::str(spec)),
            ("bytes_sent", Json::num(r.bytes_sent as f64)),
            ("bytes_saved", Json::num(r.bytes_saved as f64)),
            ("best_train_loss", Json::num(r.best_train_loss)),
            ("final_eval_loss", Json::num(r.final_eval_loss)),
            ("best_eval_metric", Json::num(r.best_eval_metric)),
            ("sim_time", Json::num(r.sim_time)),
        ]));
    }
    // Headline assertion: some demo cell beats some topk-family cell on
    // final eval loss at an equal-or-smaller byte budget. Checked over
    // every (demo, topk/ef:topk) pair so a single frontier crossing
    // anywhere in the sweep satisfies it.
    let wins = frontier
        .iter()
        .filter(|(s, ..)| s.starts_with("demo"))
        .any(|(_, db, dl)| {
            frontier
                .iter()
                .filter(|(s, ..)| {
                    s.starts_with("topk") || s.starts_with("ef:topk")
                })
                .any(|(_, tb, tl)| db <= tb && dl < tl)
        });
    anyhow::ensure!(
        wins,
        "demo never beat a topk-family cell at an equal-or-smaller byte \
         budget; frontier: {frontier:?}"
    );
    table.print();
    table.write_json(&env.out_path("compress.json"))?;
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-compress/v2")),
        ("preset", Json::str(&task.preset)),
        ("m", Json::num(env.scale.m() as f64)),
        ("steps", Json::num(env.scale.steps() as f64)),
        ("tau", Json::num(tau as f64)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = env.out_path("BENCH_compress.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(table)
}

// -------------------------------------------------------------- hierarchy

/// Hierarchical two-level SlowMo sweep (`slowmo exp hier`): a `g × τ`
/// grid on one task, Local base + SlowMo, on a two-tier cluster (fast
/// 10G intra-group links, slow 1G/0.5 ms inter-group links). Each cell
/// runs two or three modes:
///
/// - `flat`    — classic flat SlowMo on the tiered fabric (the honest
///   baseline: per-link costs + inter-group byte accounting, algorithm
///   unchanged);
/// - `hier`    — the two-level reduce (group-local base, leader ring);
/// - `hier-ti` — two-level plus a fast intra-group average every τ/4
///   inner steps.
///
/// Emits `results/BENCH_hier.json` (schema `bench-hier/v1`, checked in
/// at `results/BENCH_hier.schema.json`) and *asserts* the headline
/// claim: at equal steps, hierarchical SlowMo moves strictly fewer
/// bytes over the slow inter-group links than flat SlowMo.
pub fn hier(env: &Env, task: &TaskSpec) -> Result<Table> {
    use crate::jsonx::Json;
    let mut table = Table::new(
        "Hierarchy sweep (Local base + SlowMo, two-tier 10G/1G cluster)",
        &["g", "tau", "mode", "inter bytes", "total bytes",
          "best train loss", "final val loss", "sim time (s)"],
    );
    let m = env.scale.m();
    let (inter_lat, inter_bw) = {
        let c = crate::net::CostModel::ethernet_1g();
        (c.latency_s, c.bandwidth_bps)
    };
    let gs: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&g| g <= m).collect();
    let mut taus: Vec<u64> = vec![env.scale.tau_local(),
                                  env.scale.tau_gossip()];
    taus.dedup();
    taus.retain(|&t| t * 4 <= env.scale.steps());
    let mut entries: Vec<Json> = Vec::new();
    let mut record = |mode: &str,
                      g: usize,
                      tau: u64,
                      tau_inner: u64,
                      r: &TrainResult,
                      table: &mut Table| {
        table.row(&[
            g.to_string(),
            tau.to_string(),
            mode.to_string(),
            r.bytes_inter.to_string(),
            r.bytes_sent.to_string(),
            fmt4(r.best_train_loss),
            fmt4(r.final_eval_loss),
            format!("{:.3}", r.sim_time),
        ]);
        entries.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("g", Json::num(g as f64)),
            ("tau", Json::num(tau as f64)),
            ("tau_inner", Json::num(tau_inner as f64)),
            ("bytes_inter", Json::num(r.bytes_inter as f64)),
            ("bytes_sent", Json::num(r.bytes_sent as f64)),
            ("best_train_loss", Json::num(r.best_train_loss)),
            ("final_eval_loss", Json::num(r.final_eval_loss)),
            ("best_eval_metric", Json::num(r.best_eval_metric)),
            ("sim_time", Json::num(r.sim_time)),
        ]));
    };
    for &tau in &taus {
        for &g in &gs {
            let spec = g.to_string();
            let base = || {
                cell(env, task, AlgoSel::with_inner("local", task.inner),
                     Some(slowmo_for(task, tau)), 0)
                    // Fixed compute charge: sim-time columns compare
                    // communication, not host timing noise.
                    .compute_time(5e-3)
                    .inter_link(inter_lat, inter_bw)
            };
            let hier_run =
                run_cell(env, base().groups(&spec))?;
            record("hier", g, tau, 0, &hier_run, &mut table);
            if g > 1 {
                let flat_run =
                    run_cell(env, base().groups_flat(&spec))?;
                record("flat", g, tau, 0, &flat_run, &mut table);
                // The acceptance claim, enforced: hierarchy strictly cuts
                // slow-link traffic at equal steps whenever grouping
                // actually coarsens the ring (1 < g < m). At g = m the
                // singleton groups ARE the flat topology — the leader
                // ring is the full ring and the byte counts tie exactly
                // (asserted bitwise in rust/tests/equivalences.rs).
                if g < m {
                    anyhow::ensure!(
                        hier_run.bytes_inter < flat_run.bytes_inter,
                        "hier(g={g},tau={tau}) moved {} inter-group \
                         bytes, flat moved {} — hierarchy must cut \
                         slow-link traffic",
                        hier_run.bytes_inter,
                        flat_run.bytes_inter
                    );
                } else {
                    anyhow::ensure!(
                        hier_run.bytes_inter == flat_run.bytes_inter,
                        "hier(g=m={g}) must tie the flat ring byte for \
                         byte ({} vs {})",
                        hier_run.bytes_inter,
                        flat_run.bytes_inter
                    );
                }
                let ti = (tau / 4).max(1);
                let ti_run =
                    run_cell(env, base().groups(&spec).tau_inner(ti))?;
                record("hier-ti", g, tau, ti, &ti_run, &mut table);
            }
        }
    }
    table.print();
    table.write_json(&env.out_path("hier.json"))?;
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-hier/v1")),
        ("preset", Json::str(&task.preset)),
        ("m", Json::num(m as f64)),
        ("steps", Json::num(env.scale.steps() as f64)),
        ("inter_latency_s", Json::num(inter_lat)),
        ("inter_bandwidth_bps", Json::num(inter_bw)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = env.out_path("BENCH_hier.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(table)
}

// --------------------------------------------------------------- semisync

/// Semi-synchronous boundary sweep (`slowmo exp semisync`): a
/// `q × staleness × straggler-severity` grid on one task, Local base +
/// SlowMo, fixed per-step compute so the sim-time column isolates the
/// boundary barrier. Severity `f` runs worker 1 at an `f`-fold compute
/// slowdown via the chaos layer (`straggle=1:f`); `q = m` is the
/// blocking baseline (bitwise-identical to no quorum at all, asserted
/// in `rust/tests/equivalences.rs`).
///
/// Emits `results/BENCH_semisync.json` (schema `bench-semisync/v1`,
/// checked in at `results/BENCH_semisync.schema.json`) and *asserts*
/// the headline claim: under a 4x straggler, every `q < m` cell
/// finishes in strictly less simulated time than the blocking run at
/// equal steps.
pub fn semisync(env: &Env, task: &TaskSpec) -> Result<Table> {
    use crate::jsonx::Json;
    use crate::net::ChaosCfg;
    let mut table = Table::new(
        "Semi-sync boundary sweep (Local base + SlowMo, straggler)",
        &["q", "staleness", "straggle", "sim time (s)", "misses",
          "folds", "best train loss", "final val loss"],
    );
    let m = env.scale.m();
    let tau = env.scale.tau_local();
    // Descending so the q = m blocking baseline for each severity runs
    // first — the q < m cells assert strict sim-time wins against it.
    let qs: Vec<usize> = {
        let mut v = vec![m, m.saturating_sub(1), m / 2 + 1];
        v.retain(|&q| q >= 1);
        v.sort_unstable_by(|a, b| b.cmp(a));
        v.dedup();
        v
    };
    let stalenesses: [u64; 2] = [0, 1];
    let severities: [f64; 2] = [1.0, 4.0];
    let mut entries: Vec<Json> = Vec::new();
    // sim_time of the q = m blocking baseline, keyed by severity index.
    let mut blocking: Vec<f64> = vec![0.0; severities.len()];
    for (si, &sev) in severities.iter().enumerate() {
        for &q in &qs {
            for &s in &stalenesses {
                if q == m && s > 0 {
                    continue; // no late workers to fold at q = m
                }
                let mut b = cell(
                    env,
                    task,
                    AlgoSel::with_inner("local", task.inner),
                    Some(slowmo_for(task, tau)),
                    0,
                )
                // Fixed compute charge: the sim-time column compares
                // barrier behavior, not host timing noise.
                .compute_time(5e-3)
                .quorum(q)
                .staleness(s);
                if sev > 1.0 {
                    b = b.chaos(
                        format!("straggle=1:{sev}")
                            .parse::<ChaosCfg>()
                            .map_err(anyhow::Error::msg)?,
                    );
                }
                let r = run_cell(env, b)?;
                if q == m {
                    blocking[si] = r.sim_time;
                } else if sev > 1.0 {
                    // The acceptance claim, enforced: relaxing the
                    // barrier must strictly beat blocking on simulated
                    // wall-clock under a straggler at equal steps.
                    anyhow::ensure!(
                        r.sim_time < blocking[si],
                        "semisync(q={q},s={s},straggle={sev}) took \
                         {:.3}s sim but blocking took {:.3}s — the \
                         quorum must strictly cut straggler stalls",
                        r.sim_time,
                        blocking[si]
                    );
                }
                table.row(&[
                    q.to_string(),
                    s.to_string(),
                    format!("{sev}"),
                    format!("{:.3}", r.sim_time),
                    r.quorum_misses.to_string(),
                    r.stale_folds.to_string(),
                    fmt4(r.best_train_loss),
                    fmt4(r.final_eval_loss),
                ]);
                entries.push(Json::obj(vec![
                    ("q", Json::num(q as f64)),
                    ("staleness", Json::num(s as f64)),
                    ("straggle", Json::num(sev)),
                    ("sim_time", Json::num(r.sim_time)),
                    ("quorum_misses", Json::num(r.quorum_misses as f64)),
                    ("stale_folds", Json::num(r.stale_folds as f64)),
                    ("best_train_loss", Json::num(r.best_train_loss)),
                    ("final_eval_loss", Json::num(r.final_eval_loss)),
                    ("best_eval_metric", Json::num(r.best_eval_metric)),
                    ("bytes_sent", Json::num(r.bytes_sent as f64)),
                ]));
            }
        }
    }
    table.print();
    table.write_json(&env.out_path("semisync.json"))?;
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-semisync/v1")),
        ("preset", Json::str(&task.preset)),
        ("m", Json::num(m as f64)),
        ("steps", Json::num(env.scale.steps() as f64)),
        ("tau", Json::num(tau as f64)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = env.out_path("BENCH_semisync.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(table)
}

// ------------------------------------------------------------- throughput

/// Wall-clock throughput trajectory (`slowmo exp throughput`): the same
/// quad workload run under both execution backends (`sim` vs
/// `threaded`) over an m × algo × compress grid, measuring real
/// steps/sec and the comm/compute wall-clock phase split. Every cell
/// *asserts* the backend contract — identical parameters, curves,
/// simulated time and wire bytes bit for bit — so the speedup column
/// can only come from the transport, never from different math.
///
/// Emits `results/BENCH_throughput.json` (schema `bench-throughput/v1`,
/// checked in at `results/BENCH_throughput.schema.json`). On machines
/// with ≥ 4 cores the headline claim is enforced: the best threaded
/// speedup at the largest m must reach 2× sim. The deliberately small
/// τ keeps the runs communication-bound — that is the regime the
/// threaded fabric exists for.
pub fn throughput(env: &Env) -> Result<Table> {
    use crate::exec::ExecMode;
    use crate::jsonx::Json;
    let mut table = Table::new(
        "Throughput — sim vs threaded backend (quad, SlowMo, tau=4)",
        &["m", "algo", "compress", "exec", "wall (s)", "steps/s",
          "speedup", "comm (s)", "compute (s)"],
    );
    let steps: u64 = 768;
    let tau: u64 = 4;
    let ms: Vec<usize> = match env.scale {
        Scale::Ci | Scale::Quick => vec![4, 8],
        _ => vec![4, 8, 16],
    };
    let max_m = *ms.last().unwrap();
    // Deterministic-by-construction algorithms only: dpsgd merges two
    // in-edges in arrival order and osgp drains opportunistically, so
    // neither promises bitwise sim == threaded (see ROADMAP §Execution
    // backends). local/sgp/ar do.
    let algos = ["local", "sgp", "ar"];
    let specs = ["none", "fp16"];
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let enforce = cores >= 4;
    let mut best_speedup = 0.0f64;
    let mut entries: Vec<Json> = Vec::new();
    for &m in &ms {
        for algo in algos {
            for spec in specs {
                let build = |mode: ExecMode| {
                    let mut b = env
                        .session
                        .train("quad")
                        .algo_sel(AlgoSel::with_inner(
                            algo,
                            InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
                        ))
                        .workers(m)
                        .steps(steps)
                        .seed(0)
                        .slowmo_cfg(SlowMoCfg::new(1.0, 0.5, tau)
                            .with_buffers(BufferStrategy::Maintain))
                        .schedule(Schedule::Const(0.3))
                        .heterogeneity(1.0)
                        .eval_batches(1)
                        .cost(env.cost())
                        // Fixed simulated compute charge: sim_time must
                        // be host-independent so it can be compared
                        // bitwise across backends.
                        .compute_time(1e-6)
                        .record_params(true)
                        .exec(mode);
                    if spec != "none" {
                        b = b.compress(spec);
                    }
                    b
                };
                let sim = run_cell(env, build(ExecMode::Sim))?;
                let thr = run_cell(env, build(ExecMode::Threaded))?;
                let bits = |v: &Option<Vec<f32>>| -> Vec<u32> {
                    v.as_ref()
                        .map(|p| p.iter().map(|x| x.to_bits()).collect())
                        .unwrap_or_default()
                };
                anyhow::ensure!(
                    bits(&sim.final_params) == bits(&thr.final_params),
                    "threaded diverged from sim on final params \
                     (m={m}, {algo}, {spec})"
                );
                anyhow::ensure!(
                    sim.train_curve.len() == thr.train_curve.len()
                        && sim.train_curve.iter().zip(&thr.train_curve).all(
                            |(a, b)| {
                                a.0 == b.0 && a.1.to_bits() == b.1.to_bits()
                            },
                        ),
                    "threaded diverged from sim on the train curve \
                     (m={m}, {algo}, {spec})"
                );
                anyhow::ensure!(
                    sim.sim_time.to_bits() == thr.sim_time.to_bits(),
                    "threaded diverged from sim on simulated time \
                     (m={m}, {algo}, {spec}): {} vs {}",
                    sim.sim_time,
                    thr.sim_time
                );
                anyhow::ensure!(
                    sim.bytes_sent == thr.bytes_sent,
                    "threaded diverged from sim on wire bytes \
                     (m={m}, {algo}, {spec}): {} vs {}",
                    sim.bytes_sent,
                    thr.bytes_sent
                );
                let speedup = sim.wall_time / thr.wall_time.max(1e-12);
                if m == max_m {
                    best_speedup = best_speedup.max(speedup);
                }
                let sps = |r: &TrainResult| {
                    (r.steps_run * m as u64) as f64 / r.wall_time.max(1e-12)
                };
                let mut row = |r: &TrainResult, speed: Option<f64>| {
                    table.row(&[
                        m.to_string(),
                        algo.to_string(),
                        spec.to_string(),
                        r.exec.clone(),
                        format!("{:.4}", r.wall_time),
                        format!("{:.0}", sps(r)),
                        speed
                            .map(|s| format!("{s:.2}x"))
                            .unwrap_or_else(|| "-".into()),
                        format!("{:.4}", r.comm_wall_time),
                        format!("{:.4}", r.compute_wall_time),
                    ]);
                    let mut pairs = vec![
                        ("exec", Json::str(&r.exec)),
                        ("m", Json::num(m as f64)),
                        ("algo", Json::str(algo)),
                        ("compress", Json::str(spec)),
                        ("wall_time", Json::num(r.wall_time)),
                        ("steps_per_sec", Json::num(sps(r))),
                        ("comm_wall_time", Json::num(r.comm_wall_time)),
                        ("compute_wall_time",
                         Json::num(r.compute_wall_time)),
                        ("sim_time", Json::num(r.sim_time)),
                        ("bytes_sent", Json::num(r.bytes_sent as f64)),
                    ];
                    if let Some(s) = speed {
                        pairs.push(("speedup_vs_sim", Json::num(s)));
                        pairs.push(("bitwise_equal", Json::Bool(true)));
                    }
                    entries.push(Json::obj(pairs));
                };
                row(&sim, None);
                row(&thr, Some(speedup));
            }
        }
    }
    table.print();
    table.write_json(&env.out_path("throughput.json"))?;
    if enforce {
        anyhow::ensure!(
            best_speedup >= 2.0,
            "threaded backend reached only {best_speedup:.2}x sim at \
             m={max_m} on {cores} cores — the comm-bound quad sweep \
             must show >= 2x"
        );
    } else {
        crate::info!(
            "throughput: speedup gate skipped ({cores} cores < 4)"
        );
    }
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-throughput/v1")),
        ("preset", Json::str("quad")),
        ("steps", Json::num(steps as f64)),
        ("tau", Json::num(tau as f64)),
        ("cores", Json::num(cores as f64)),
        ("speedup_gate_enforced", Json::Bool(enforce)),
        ("max_m", Json::num(max_m as f64)),
        ("best_speedup_at_max_m", Json::num(best_speedup)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = env.out_path("BENCH_throughput.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(table)
}

// ------------------------------------------------------------------ scale

/// `"i*s-(i+1)*s-1"` range tokens for `m / size` equal groups — the
/// explicit-spec form [`crate::topology::Groups::parse`] accepts.
fn range_tier(m: usize, size: usize) -> String {
    (0..m / size)
        .map(|i| format!("{}-{}", i * size, (i + 1) * size - 1))
        .collect::<Vec<_>>()
        .join("|")
}

/// The scale sweep's cluster shapes for `m` workers (m a power of two,
/// ≥ 16): 8 racks of `m/8` and, above them, 2 pods of `m/2`. Returns
/// `(leaf tier, full leaves-first tree spec)`.
fn scale_tree_spec(m: usize) -> (String, String) {
    let leaf = range_tier(m, m / 8);
    let tree = format!("{leaf};{}", range_tier(m, m / 2));
    (leaf, tree)
}

/// Scale fabric sweep (`slowmo exp scale`): worker count m × cluster
/// topology on the native quad workload, Local base + SlowMo, fixed
/// per-step compute so the sim-time column isolates communication.
/// Per m the three modes share one physical cluster shape (8 racks × 2
/// pods, 10G intra / 1G rack-to-rack / 0.5G + 2 ms pod-to-pod):
///
/// - `flat` — flat SlowMo on the tiered fabric (honest baseline:
///   per-link costs + inter-tier byte accounting, algorithm unchanged);
/// - `d1`   — two-level hierarchical reduce over the rack partition;
/// - `d2`   — the full depth-2 tree reduce (rack rings → pod rings).
///
/// Small m runs dense worker state; large m (256 → 1024, plus 4096 at
/// `--scale full`, where the sweep takes minutes) runs
/// [`StateMode::Shared`]. Cells run in ascending-footprint order with a
/// [`crate::util::reset_peak_rss`] before each, so every cell's `VmHWM`
/// reading is its own high-water mark.
///
/// Emits `results/BENCH_scale.json` (schema `bench-scale/v1`, checked
/// in at `results/BENCH_scale.schema.json`) and *asserts*:
///
/// - per cell, the depth-2 tree finishes in strictly less simulated
///   time than flat on the same cluster, and the two-level reduce moves
///   strictly fewer inter-tier bytes than flat;
/// - shared-state peak RSS at the largest m sits strictly below the
///   dense-replica projection (dense bytes/worker measured empirically
///   between m = 64 and m = 256, floored at the analytic 5 · d · 4 B
///   state footprint), with at least d · 4 B/worker to spare — half of
///   the two elided buffers — i.e. memory grows sublinearly in m
///   relative to dense replication. Skipped loudly where the kernel
///   doesn't expose `VmHWM`.
pub fn scale(env: &Env) -> Result<Table> {
    use crate::jsonx::Json;
    use std::collections::BTreeMap;
    let mut table = Table::new(
        "Scale sweep (Local base + SlowMo, quad, 8 racks × 2 pods)",
        &["state", "m", "topo", "sim time (s)", "inter bytes",
          "total bytes", "best train loss", "peak rss (MiB)"],
    );
    let d = env.manifest().preset("quad")?.flat_len;
    let steps: u64 = 48;
    let tau: u64 = 12;
    let (inter_lat, inter_bw) = {
        let c = crate::net::CostModel::ethernet_1g();
        (c.latency_s, c.bandwidth_bps)
    };
    let (tier_lat, tier_bw) = (2e-3, inter_bw / 2.0);
    // Ascending footprint: each cell's own allocations dominate every
    // earlier cell's retained allocator pool, so the per-cell VmHWM
    // reset yields a clean own-high-water reading.
    let mut cells: Vec<(StateMode, usize)> = vec![
        (StateMode::Dense, 16),
        (StateMode::Dense, 64),
        (StateMode::Shared, 256),
        (StateMode::Dense, 256),
        (StateMode::Shared, 1024),
    ];
    if env.scale == Scale::Full {
        cells.push((StateMode::Shared, 4096));
    }
    let m_big = cells.last().unwrap().1;
    let mut entries: Vec<Json> = Vec::new();
    let mut rss_by_cell: BTreeMap<(&'static str, usize), Option<u64>> =
        BTreeMap::new();
    for &(state, m) in &cells {
        let (leaf, tree) = scale_tree_spec(m);
        let mut trio: Vec<TrainResult> = Vec::new();
        for topo in ["flat", "d1", "d2"] {
            let b = env
                .session
                .train("quad")
                .algo_sel(AlgoSel::with_inner(
                    "local",
                    InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
                ))
                .workers(m)
                .steps(steps)
                .seed(0)
                .slowmo_cfg(SlowMoCfg::new(1.0, 0.5, tau)
                    .with_buffers(BufferStrategy::Maintain))
                .schedule(Schedule::Const(0.3))
                .heterogeneity(1.0)
                .eval_batches(1)
                .cost(env.cost())
                .compute_time(1e-6)
                .state(state);
            let b = match topo {
                "flat" => b
                    .groups_flat(&tree)
                    .inter_link(inter_lat, inter_bw)
                    .tier_link(tier_lat, tier_bw),
                "d1" => b.groups(&leaf).inter_link(inter_lat, inter_bw),
                _ => b
                    .groups(&tree)
                    .inter_link(inter_lat, inter_bw)
                    .tier_link(tier_lat, tier_bw),
            };
            crate::util::reset_peak_rss();
            let r = run_cell(env, b)?;
            table.row(&[
                state.name().to_string(),
                m.to_string(),
                topo.to_string(),
                format!("{:.3}", r.sim_time),
                r.bytes_inter.to_string(),
                r.bytes_sent.to_string(),
                fmt4(r.best_train_loss),
                r.peak_rss_bytes
                    .map(|b| format!("{:.1}", b as f64 / (1 << 20) as f64))
                    .unwrap_or_else(|| "-".into()),
            ]);
            let mut pairs = vec![
                ("state", Json::str(state.name())),
                ("m", Json::num(m as f64)),
                ("topo", Json::str(topo)),
                ("spec", Json::str(r.groups.as_deref().unwrap_or(""))),
                ("sim_time", Json::num(r.sim_time)),
                ("bytes_inter", Json::num(r.bytes_inter as f64)),
                ("bytes_sent", Json::num(r.bytes_sent as f64)),
                ("best_train_loss", Json::num(r.best_train_loss)),
            ];
            if let Some(rss) = r.peak_rss_bytes {
                pairs.push(("peak_rss_bytes", Json::num(rss as f64)));
            }
            entries.push(Json::obj(pairs));
            trio.push(r);
        }
        let (flat, d1, d2) = (&trio[0], &trio[1], &trio[2]);
        anyhow::ensure!(
            d2.sim_time < flat.sim_time,
            "scale({},m={m}): depth-2 tree took {:.3}s simulated, flat \
             took {:.3}s — the tree reduce must beat flat on its own \
             cluster at equal steps",
            state.name(),
            d2.sim_time,
            flat.sim_time
        );
        anyhow::ensure!(
            d1.bytes_inter < flat.bytes_inter,
            "scale({},m={m}): two-level reduce moved {} inter-tier \
             bytes, flat moved {} — hierarchy must cut slow-link \
             traffic",
            state.name(),
            d1.bytes_inter,
            flat.bytes_inter
        );
        // The depth-2 tree's RSS stands in for the cell: all three
        // topologies hold the same worker state, and `d2` runs last, on
        // top of an allocator pool its equal-sized siblings warmed.
        rss_by_cell.insert((state.name(), m), d2.peak_rss_bytes);
    }
    let rss = |state: StateMode, m: usize| {
        rss_by_cell.get(&(state.name(), m)).copied().flatten()
    };
    // Shared-state memory gate: project dense replication out to the
    // largest m from the measured dense slope and require shared-state
    // to beat the projection with at least half the two elided buffers
    // (h, z — see StateMode) to spare.
    let bytes_per_vec = 4.0 * d as f64;
    let mut gate: Vec<(&str, Json)> = Vec::new();
    let enforced = match (
        rss(StateMode::Dense, 64),
        rss(StateMode::Dense, 256),
        rss(StateMode::Shared, 256),
        rss(StateMode::Shared, m_big),
    ) {
        (Some(d64), Some(d256), Some(s256), Some(sbig)) => {
            let dense_slope = ((d256 as f64 - d64 as f64) / 192.0)
                .max(5.0 * bytes_per_vec);
            let extra = (m_big - 256) as f64;
            let projection = d256 as f64 + extra * dense_slope;
            let margin = extra * bytes_per_vec;
            anyhow::ensure!(
                (sbig as f64) < projection,
                "scale: shared m={m_big} peaked at {sbig} B RSS, dense \
                 projection is {projection:.0} B ({dense_slope:.0} \
                 B/worker from m=64..256) — shared state must stay \
                 strictly below dense replication"
            );
            anyhow::ensure!(
                projection - sbig as f64 >= margin,
                "scale: shared m={m_big} saved only {:.0} B vs the \
                 dense projection; the elided h/z buffers guarantee \
                 {margin:.0} B ({bytes_per_vec:.0} B/worker)",
                projection - sbig as f64
            );
            anyhow::ensure!(
                s256 < d256,
                "scale: shared m=256 peaked at {s256} B RSS, dense \
                 m=256 at {d256} B — shared must be strictly smaller \
                 at equal m"
            );
            gate.push(("dense_slope_bytes_per_worker",
                       Json::num(dense_slope)));
            gate.push(("projection_bytes", Json::num(projection)));
            gate.push(("shared_peak_bytes", Json::num(sbig as f64)));
            gate.push(("margin_bytes", Json::num(margin)));
            true
        }
        _ => {
            crate::info!(
                "scale: peak-RSS gate skipped (no VmHWM on this kernel)"
            );
            false
        }
    };
    gate.insert(0, ("enforced", Json::Bool(enforced)));
    table.print();
    table.write_json(&env.out_path("scale.json"))?;
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-scale/v1")),
        ("preset", Json::str("quad")),
        ("d", Json::num(d as f64)),
        ("steps", Json::num(steps as f64)),
        ("tau", Json::num(tau as f64)),
        ("m_max", Json::num(m_big as f64)),
        ("inter_latency_s", Json::num(inter_lat)),
        ("inter_bandwidth_bps", Json::num(inter_bw)),
        ("tier_latency_s", Json::num(tier_lat)),
        ("tier_bandwidth_bps", Json::num(tier_bw)),
        ("rss_gate", Json::obj(gate)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = env.out_path("BENCH_scale.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(table)
}

// ----------------------------------------------------------------- theory

/// Theorem 1 / Corollary 1-2 validation on the quadratic workload
/// (native fast path): grad-norm² vs worker count m (linear-speedup
/// shape) and the Lookahead special case (m=1, β=0).
pub fn theory(env: &Env) -> Result<Table> {
    let mut table = Table::new(
        "Theory — avg grad-norm² after K steps on the quad workload",
        &["config", "m", "tau", "beta", "avg ||∇f||² (last quarter)"],
    );
    let steps = 2048u64;
    let run_quad = |m: usize, tau: u64, alpha: f32, beta: f32,
                    seed: u64| -> Result<f64> {
        let r = env
            .session
            .train("quad")
            .algo_sel(AlgoSel::with_inner(
                "local",
                InnerOpt::Nesterov { beta0: 0.0, wd: 0.0 },
            ))
            .workers(m)
            .steps(steps)
            .seed(seed)
            .slowmo_cfg(SlowMoCfg::new(alpha, beta, tau)
                .with_buffers(BufferStrategy::Maintain))
            .schedule(Schedule::Const(0.3))
            .heterogeneity(1.0)
            .eval_batches(1)
            .cost(crate::net::CostModel::free())
            .compute_time(1e-6)
            .record_gradnorm(true)
            .run()?;
        let tail: Vec<f64> = r
            .gradnorm_curve
            .iter()
            .skip(r.gradnorm_curve.len() * 3 / 4)
            .map(|&(_, g)| g)
            .collect();
        Ok(crate::util::mean(&tail))
    };
    // Linear speedup: more workers -> lower plateau grad-norm (BMUF).
    for &m in &[1usize, 2, 4, 8] {
        let g = run_quad(m, 16, 1.0, 0.5, 1)?;
        table.row(&["BMUF speedup".into(), m.to_string(), "16".into(),
                    "0.5".into(), format!("{g:.3e}")]);
    }
    // Effect of tau at fixed m (the O(mτ/T) term).
    for &tau in &[4u64, 16, 64, 256] {
        let g = run_quad(4, tau, 1.0, 0.5, 2)?;
        table.row(&["tau effect".into(), "4".into(), tau.to_string(),
                    "0.5".into(), format!("{g:.3e}")]);
    }
    // Lookahead special case: m=1, beta=0, alpha<=1 (Corollary 2).
    for &alpha in &[1.0f32, 0.5] {
        let g = run_quad(1, 8, alpha, 0.0, 3)?;
        table.row(&[format!("Lookahead a={alpha}"), "1".into(), "8".into(),
                    "0".into(), format!("{g:.3e}")]);
    }
    table.print();
    table.write_json(&env.out_path("theory.json"))?;
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_specs_name_presets() {
        assert_eq!(TaskSpec::cifar().preset, "cifar-mlp");
        assert_eq!(TaskSpec::wmt(Scale::Quick).preset, "lm-tiny");
        assert_eq!(TaskSpec::wmt(Scale::Standard).preset, "wmt-lm");
        assert!(TaskSpec::wmt(Scale::Quick).inner.uses_second_moment());
    }

    #[test]
    fn schedules_constructed() {
        let t = TaskSpec::cifar();
        let s = (t.sched)(1000);
        assert!(s.gamma(500) > 0.0);
    }

    #[test]
    fn scale_tree_specs_are_nested_and_parse() {
        let (leaf, tree) = scale_tree_spec(16);
        assert_eq!(leaf, "0-1|2-3|4-5|6-7|8-9|10-11|12-13|14-15");
        assert_eq!(tree, format!("{leaf};0-7|8-15"));
        let t = crate::topology::TierTree::parse(&tree, 16).unwrap();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.leaf().all().len(), 8);
        let (leaf, tree) = scale_tree_spec(1024);
        assert!(leaf.starts_with("0-127|128-255"));
        assert!(tree.ends_with(";0-511|512-1023"));
        let t = crate::topology::TierTree::parse(&tree, 1024).unwrap();
        assert_eq!(t.m(), 1024);
    }
}
