//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Hot-path latencies: model train-step execute, optimizer kernels
//! (PJRT artifact vs native mirror), ring allreduce, gossip mixing, and
//! literal-conversion overhead. Run via `cargo bench --bench micro` or
//! `slowmo exp micro`.

use super::Env;
use crate::benchkit::Bench;
use crate::data::task_for;
use crate::exec::run_workers;
use crate::net::{ring_allreduce_mean, CostModel, Fabric};
use crate::optim::kernels::{InnerOpt, Kernels};
use crate::runtime::engine::Arg;
use crate::trainer::model_exec;
use anyhow::Result;

pub fn run(env: &Env) -> Result<Bench> {
    let mut b = Bench::new();

    // ---- model train step (the dominant per-iteration cost) ----
    for preset in ["cifar-mlp", "lm-tiny", "quad"] {
        let info = env.manifest().preset(preset)?;
        let model = model_exec::build(Some(env.engine()), env.manifest(),
                                      preset, true)?;
        let task = task_for(&info.data, 1, 0, 0.0);
        let params = env.manifest().load_init(info)?;
        let batch = task.train_batch(0, 0);
        b.run(&format!("train-step/{preset}/pjrt"), || {
            model.train_step(&params, &batch).unwrap();
        });
    }
    // Native quad fast path for comparison.
    {
        let info = env.manifest().preset("quad")?;
        let model = model_exec::build(None, env.manifest(), "quad", false)?;
        let task = task_for(&info.data, 1, 0, 0.0);
        let params = env.manifest().load_init(info)?;
        let batch = task.train_batch(0, 0);
        b.run("train-step/quad/native", || {
            model.train_step(&params, &batch).unwrap();
        });
    }

    // ---- optimizer kernels: PJRT artifact vs native mirror ----
    for &d in &[4096usize, 1988736] {
        if env.manifest().optim_for(d).is_err() {
            continue;
        }
        let pjrt = Kernels::pjrt(env.engine(), env.manifest(), d)?;
        let native = Kernels::Native;
        let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 };
        let mut rng = crate::rng::Xoshiro256::seed_from(1);
        let mut x = vec![0.0f32; d];
        rng.fill_normal(&mut x, 1.0);
        let g = x.clone();
        for (name, k) in [("pjrt", &pjrt), ("native", &native)] {
            let mut xx = x.clone();
            let mut hh = vec![0.0f32; d];
            let mut vv = Vec::new();
            b.run(&format!("nesterov/d{d}/{name}"), || {
                k.inner_step(&inner, &mut xx, &mut hh, &mut vv, &g, 0.05, 1)
                    .unwrap();
            });
            let mut x0 = x.clone();
            let mut u = vec![0.0f32; d];
            b.run(&format!("slowmo-update/d{d}/{name}"), || {
                k.slowmo_update(&mut x0, &g, &mut u, 0.05, 1.0, 0.7)
                    .unwrap();
            });
        }
    }

    // ---- collectives ----
    for &(m, d) in &[(4usize, 65536usize), (8, 1048576)] {
        let fabric = Fabric::new(m, CostModel::free());
        b.run(&format!("ring-allreduce/m{m}/d{d}"), || {
            run_workers(m, |w| {
                let mut x = vec![w as f32; d];
                ring_allreduce_mean(&fabric, w, &mut x, 0.0);
            });
        });
    }

    // ---- raw PJRT execute overhead (tiny graph: the axpy kernel) ----
    {
        let d = 4096;
        let opt = env.manifest().optim_for(d)?;
        let exe = env.engine().load(&opt.graphs["axpy"])?;
        let x = vec![1.0f32; d];
        let y = vec![2.0f32; d];
        b.run("pjrt-execute-overhead/axpy-4k", || {
            exe.exec(&[
                Arg::F32(&x, &[d]),
                Arg::F32(&y, &[d]),
                Arg::F32(&[0.5], &[1]),
                Arg::F32(&[0.5], &[1]),
            ])
            .unwrap();
        });
    }

    b.report();
    b.write_jsonl(&env.out_path("micro.jsonl"))?;
    // Checked-in perf trajectory: schema `bench-micro/v1`, validated in
    // CI against results/BENCH_micro.schema.json (`make bench`).
    let bench = crate::jsonx::Json::obj(vec![
        ("schema", crate::jsonx::Json::str("bench-micro/v1")),
        ("scale", crate::jsonx::Json::str(env.scale.name())),
        (
            "entries",
            crate::jsonx::Json::Arr(
                b.results().iter().map(|s| s.to_json()).collect(),
            ),
        ),
    ]);
    let path = env.out_path("BENCH_micro.json");
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");
    Ok(b)
}
