//! Micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Hot-path latencies: model train-step execute, optimizer kernels
//! (PJRT artifact vs native mirror), DCT codec kernels, ring allreduce,
//! gossip mixing, and literal-conversion overhead. Run via
//! `cargo bench --bench micro` or `slowmo exp micro`.
//!
//! Regression gate: when a previous `results/BENCH_micro.json` from the
//! same scale exists, any kernel whose fresh median is more than
//! `SLOWMO_BENCH_TOL` (default 0.25 = 25%) slower than the checked-in
//! run fails the bench — `make bench` is the CI hook.

use super::Env;
use crate::benchkit::{Bench, Stats};
use crate::compress::{site, CompressRegistry, CompressState, Compressor,
                      Demo};
use crate::data::task_for;
use crate::exec::run_workers;
use crate::jsonx::Json;
use crate::net::{ring_allreduce_mean, ring_allreduce_mean_group_p,
                 CostModel, Fabric};
use crate::util::{Pool, Scratch};
use crate::optim::kernels::{dct2_chunked, dct3_chunked, DctPlans, InnerOpt,
                            Kernels};
use crate::runtime::engine::Arg;
use crate::trainer::model_exec;
use anyhow::Result;

pub fn run(env: &Env) -> Result<Bench> {
    let mut b = Bench::new();

    // ---- model train step (the dominant per-iteration cost) ----
    for preset in ["cifar-mlp", "lm-tiny", "quad"] {
        let info = env.manifest().preset(preset)?;
        let model = model_exec::build(Some(env.engine()), env.manifest(),
                                      preset, true)?;
        let task = task_for(&info.data, 1, 0, 0.0);
        let params = env.manifest().load_init(info)?;
        let batch = task.train_batch(0, 0);
        b.run(&format!("train-step/{preset}/pjrt"), || {
            model.train_step(&params, &batch).unwrap();
        });
    }
    // Native quad fast path for comparison.
    {
        let info = env.manifest().preset("quad")?;
        let model = model_exec::build(None, env.manifest(), "quad", false)?;
        let task = task_for(&info.data, 1, 0, 0.0);
        let params = env.manifest().load_init(info)?;
        let batch = task.train_batch(0, 0);
        b.run("train-step/quad/native", || {
            model.train_step(&params, &batch).unwrap();
        });
    }

    // ---- optimizer kernels: PJRT artifact vs native mirror ----
    for &d in &[4096usize, 1988736] {
        if env.manifest().optim_for(d).is_err() {
            continue;
        }
        let pjrt = Kernels::pjrt(env.engine(), env.manifest(), d)?;
        let native = Kernels::Native;
        let inner = InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 };
        let mut rng = crate::rng::Xoshiro256::seed_from(1);
        let mut x = vec![0.0f32; d];
        rng.fill_normal(&mut x, 1.0);
        let g = x.clone();
        for (name, k) in [("pjrt", &pjrt), ("native", &native)] {
            let mut xx = x.clone();
            let mut hh = vec![0.0f32; d];
            let mut vv = Vec::new();
            b.run(&format!("nesterov/d{d}/{name}"), || {
                k.inner_step(&inner, &mut xx, &mut hh, &mut vv, &g, 0.05, 1)
                    .unwrap();
            });
            let mut x0 = x.clone();
            let mut u = vec![0.0f32; d];
            b.run(&format!("slowmo-update/d{d}/{name}"), || {
                k.slowmo_update(&mut x0, &g, &mut u, 0.05, 1.0, 0.7)
                    .unwrap();
            });
        }
    }

    // ---- collectives ----
    for &(m, d) in &[(4usize, 65536usize), (8, 1048576)] {
        let fabric = Fabric::new(m, CostModel::free());
        b.run(&format!("ring-allreduce/m{m}/d{d}"), || {
            run_workers(m, |w| {
                let mut x = vec![w as f32; d];
                ring_allreduce_mean(&fabric, w, &mut x, 0.0);
            });
        });
    }

    // ---- DCT codec kernels (native path of the demo compressor) ----
    {
        let d = 65536usize;
        let plans = DctPlans::new();
        let mut rng = crate::rng::Xoshiro256::seed_from(3);
        let mut x = vec![0.0f32; d];
        rng.fill_normal(&mut x, 1.0);
        let mut f = vec![0.0f32; d];
        b.run("dct2/d65536/c64/native", || {
            dct2_chunked(&plans, &x, &mut f, 64);
        });
        b.run("dct3/d65536/c64/native", || {
            dct3_chunked(&plans, &f, &mut x, 64);
        });
        let demo = Demo::new(0.1, 64);
        let mut st = CompressState::new(1, 0);
        let mut y = x.clone();
        b.run("demo-transcode/d65536/k0.1c64", || {
            demo.transcode(&mut y, &mut st, site::OUTER);
        });
        // Pooled counterpart: the Scratch persists across iterations, so
        // after the first round the transcode is allocation-free.
        let mut st = CompressState::new(1, 0);
        let mut y = x.clone();
        let mut sc = Scratch::new();
        b.run("demo-transcode-pooled/d65536/k0.1c64", || {
            demo.transcode_pooled(&mut y, &mut st, site::OUTER, &mut sc);
        });
    }

    // ---- pooled vs fresh hot paths (ROADMAP 5(b): buffer pools) ----
    {
        let d = 65536usize;
        let reg = CompressRegistry::builtin();
        let ef = reg.build(&reg.parse("ef:topk:0.1")?)?;
        let mut rng = crate::rng::Xoshiro256::seed_from(5);
        let mut x = vec![0.0f32; d];
        rng.fill_normal(&mut x, 1.0);
        let mut st = CompressState::new(1, 0);
        let mut y = x.clone();
        b.run("transcode-fresh/ef-topk0.1/d65536", || {
            ef.transcode(&mut y, &mut st, site::OUTER);
        });
        let mut st = CompressState::new(1, 0);
        let mut y = x.clone();
        let mut sc = Scratch::new();
        b.run("transcode-pooled/ef-topk0.1/d65536", || {
            ef.transcode_pooled(&mut y, &mut st, site::OUTER, &mut sc);
        });
        // Pooled ring allreduce: one pool per worker persists across
        // iterations, so steady-state sends reuse recycled chunk buffers
        // instead of cloning each slice.
        let m = 4usize;
        let fabric = Fabric::new(m, CostModel::free());
        let pools: Vec<std::sync::Mutex<Pool<f32>>> =
            (0..m).map(|_| std::sync::Mutex::new(Pool::new())).collect();
        let group: Vec<usize> = (0..m).collect();
        b.run(&format!("ring-allreduce-pooled/m{m}/d{d}"), || {
            run_workers(m, |w| {
                let mut x = vec![w as f32; d];
                let mut pool = pools[w].lock().unwrap();
                ring_allreduce_mean_group_p(
                    &fabric, w, &group, &mut x, 0.0, 0, None, &mut pool,
                );
            });
        });
    }

    // ---- raw PJRT execute overhead (tiny graph: the axpy kernel) ----
    {
        let d = 4096;
        let opt = env.manifest().optim_for(d)?;
        let exe = env.engine().load(&opt.graphs["axpy"])?;
        let x = vec![1.0f32; d];
        let y = vec![2.0f32; d];
        b.run("pjrt-execute-overhead/axpy-4k", || {
            exe.exec(&[
                Arg::F32(&x, &[d]),
                Arg::F32(&y, &[d]),
                Arg::F32(&[0.5], &[1]),
                Arg::F32(&[0.5], &[1]),
            ])
            .unwrap();
        });
    }

    b.report();
    b.write_jsonl(&env.out_path("micro.jsonl"))?;
    // Checked-in perf trajectory: schema `bench-micro/v2` (v2 added the
    // pooled-vs-fresh rows), validated in CI against
    // results/BENCH_micro.schema.json (`make bench`). The previous run
    // (if any) is loaded *before* the overwrite so it can serve as the
    // regression baseline below.
    let path = env.out_path("BENCH_micro.json");
    let baseline = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| crate::jsonx::parse(&s).ok());
    let bench = Json::obj(vec![
        ("schema", Json::str("bench-micro/v2")),
        ("scale", Json::str(env.scale.name())),
        (
            "entries",
            Json::Arr(b.results().iter().map(|s| s.to_json()).collect()),
        ),
    ]);
    if let Some(dir) = std::path::Path::new(&path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, crate::jsonx::to_string(&bench))?;
    crate::info!("wrote {path}");

    // ---- regression gate vs the previous checked-in run ----
    if let Some(prev) = baseline {
        let tol: f64 = std::env::var("SLOWMO_BENCH_TOL")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.25);
        match regressions(&prev, b.results(), env.scale.name(), tol) {
            None => crate::info!(
                "bench baseline is from a different scale; regression \
                 gate skipped"
            ),
            Some(slow) => anyhow::ensure!(
                slow.is_empty(),
                "kernel regression(s) >{:.0}% vs previous {path} \
                 (override tolerance with SLOWMO_BENCH_TOL): {}",
                tol * 100.0,
                slow.join("; ")
            ),
        }
    }
    Ok(b)
}

/// Compare fresh medians against a previous `bench-micro` document.
/// Returns `None` when the baseline was recorded at a different scale
/// (medians are not comparable), otherwise the list of kernels whose
/// fresh median exceeds the baseline by more than `tol` (relative).
/// Kernels present on only one side are ignored — adding or removing a
/// bench must not trip the gate.
fn regressions(
    prev: &Json,
    fresh: &[Stats],
    scale: &str,
    tol: f64,
) -> Option<Vec<String>> {
    if prev.get("scale").and_then(|s| s.as_str()) != Some(scale) {
        return None;
    }
    let empty: &[Json] = &[];
    let prev_entries =
        prev.get("entries").and_then(|e| e.as_arr()).unwrap_or(empty);
    let mut slow = Vec::new();
    for s in fresh {
        let old = prev_entries
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str())
                    == Some(s.name.as_str())
            })
            .and_then(|e| e.get("median_s"))
            .and_then(|m| m.as_f64());
        let Some(old) = old else { continue };
        let new = s.median();
        if old > 0.0 && new > old * (1.0 + tol) {
            slow.push(format!(
                "{}: {:.3e}s -> {:.3e}s (+{:.0}%)",
                s.name,
                old,
                new,
                (new / old - 1.0) * 100.0
            ));
        }
    }
    Some(slow)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(scale: &str, entries: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("schema", Json::str("bench-micro/v2")),
            ("scale", Json::str(scale)),
            (
                "entries",
                Json::Arr(
                    entries
                        .iter()
                        .map(|(n, m)| {
                            Json::obj(vec![
                                ("name", Json::str(n)),
                                ("median_s", Json::num(*m)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn stat(name: &str, median: f64) -> Stats {
        Stats { name: name.into(), samples: vec![median] }
    }

    #[test]
    fn regression_gate_flags_only_slowdowns_beyond_tol() {
        let prev = doc("ci", &[("a", 1.0), ("b", 1.0), ("c", 1.0)]);
        // a: within tolerance; b: over; c: faster — only b trips.
        let fresh = [stat("a", 1.2), stat("b", 1.3), stat("c", 0.5)];
        let slow = regressions(&prev, &fresh, "ci", 0.25).unwrap();
        assert_eq!(slow.len(), 1, "{slow:?}");
        assert!(slow[0].starts_with("b:"), "{slow:?}");
    }

    #[test]
    fn regression_gate_skips_on_scale_mismatch() {
        let prev = doc("full", &[("a", 1.0)]);
        assert!(regressions(&prev, &[stat("a", 9.0)], "ci", 0.25).is_none());
    }

    #[test]
    fn regression_gate_ignores_added_and_removed_kernels() {
        let prev = doc("ci", &[("gone", 1.0)]);
        let slow =
            regressions(&prev, &[stat("new", 9.0)], "ci", 0.25).unwrap();
        assert!(slow.is_empty(), "{slow:?}");
    }
}
