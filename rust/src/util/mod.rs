//! Small shared utilities: float vector math helpers, formatting, logging.

pub mod pool;
pub use pool::{Pool, Scratch};

use std::time::{SystemTime, UNIX_EPOCH};

/// Squared L2 norm of a slice.
pub fn sqnorm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// L2 norm of a slice.
pub fn norm(xs: &[f32]) -> f64 {
    sqnorm(xs).sqrt()
}

/// Max |a_i - b_i| over two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// `a && b` elementwise allclose with rtol/atol (numpy semantics).
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(&x, &y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration from seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// Parse the `VmHWM` line out of a `/proc/self/status` dump, returning
/// bytes. Factored out of [`peak_rss_bytes`] so the parser is unit-testable
/// on every platform; kernel format is `VmHWM:\t  123456 kB`.
pub fn parse_vmhwm_bytes(status: &str) -> Option<u64> {
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .strip_prefix("VmHWM:")?
        .trim()
        .strip_suffix("kB")?
        .trim()
        .parse()
        .ok()?;
    Some(kb * 1024)
}

/// Peak resident set size ("high-water mark") of this process in bytes:
/// `VmHWM` from `/proc/self/status` on Linux, `None` elsewhere. The scale
/// bench uses this for its memory headline; [`reset_peak_rss`] rebases the
/// mark between cells.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    parse_vmhwm_bytes(&status)
}

/// Reset the peak-RSS high-water mark to the process's *current* RSS
/// (writes `5` to `/proc/self/clear_refs`; no privilege needed for self).
/// Returns `false` where unsupported — callers that depend on per-phase
/// peaks must then fall back to ascending-footprint run ordering, which
/// keeps the monotone mark meaningful.
pub fn reset_peak_rss() -> bool {
    std::fs::write("/proc/self/clear_refs", b"5").is_ok()
}

/// A copy-on-write `f32` vector: starts as a view of a shared read-only
/// base ([`CowVec::shared`]) and materializes a private copy only on
/// first mutation ([`CowVec::make_mut`]). The shared-state trainer mode
/// hands every worker the *same* `Arc` of the initial parameters, so
/// `m` outer iterates cost one `d`-vector until a worker's first outer
/// boundary actually writes — the copy-on-write half of the scale
/// tentpole (the lean state layouts are the other half).
///
/// Reads go through `Deref<Target = [f32]>`, so `&cow[..]`, indexing and
/// slice methods all work on either representation. Equality, `Clone`
/// and `Debug` compare/copy the *logical contents* — a shared and an
/// owned `CowVec` with equal elements are equal.
#[derive(Clone)]
pub struct CowVec {
    base: std::sync::Arc<Vec<f32>>,
    own: Option<Vec<f32>>,
}

impl CowVec {
    /// A fully private vector (the dense-replica representation).
    pub fn owned(v: Vec<f32>) -> Self {
        Self { base: std::sync::Arc::new(Vec::new()), own: Some(v) }
    }

    /// A view of `base`; no copy until [`Self::make_mut`].
    pub fn shared(base: std::sync::Arc<Vec<f32>>) -> Self {
        Self { base, own: None }
    }

    /// Still borrowing the shared base (no private copy materialized)?
    pub fn is_shared(&self) -> bool {
        self.own.is_none()
    }

    /// Mutable access, materializing a private copy of the base on first
    /// use (and dropping this handle's claim on the shared allocation).
    pub fn make_mut(&mut self) -> &mut Vec<f32> {
        if self.own.is_none() {
            self.own = Some(self.base.as_ref().clone());
            self.base = std::sync::Arc::new(Vec::new());
        }
        self.own.as_mut().expect("just materialized")
    }

    /// A detached plain copy of the contents.
    pub fn to_vec(&self) -> Vec<f32> {
        self[..].to_vec()
    }
}

impl std::ops::Deref for CowVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.own.as_deref().unwrap_or(&self.base)
    }
}

impl std::fmt::Debug for CowVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CowVec")
            .field("shared", &self.is_shared())
            .field("data", &&self[..])
            .finish()
    }
}

impl PartialEq for CowVec {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f32>> for CowVec {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self[..] == other[..]
    }
}

/// Wall-clock seconds since the epoch (for log stamps).
pub fn now_epoch_secs() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Read a `u64` from the environment, falling back to `default` when the
/// variable is unset or unparsable (examples use this for CI-sized runs:
/// `SLOWMO_EXAMPLE_STEPS=24 cargo run --example quickstart`).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Minimal leveled logger gated by `SLOWMO_LOG` (error|warn|info|debug).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn log_level() -> Level {
    match std::env::var("SLOWMO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    }
}

/// Log a message at `level` to stderr if enabled.
pub fn log(level: Level, msg: &str) {
    if level <= log_level() {
        eprintln!("[{:?}] {}", level, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(sqnorm(&[3.0, 4.0]), 25.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
        assert_eq!(norm(&[]), 0.0);
    }

    #[test]
    fn close() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }

    #[test]
    fn max_diff() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138)
            .abs()
            < 1e-3);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn vmhwm_parser() {
        let status = "Name:\tslowmo\nVmPeak:\t  999 kB\nVmHWM:\t  \
                      123456 kB\nVmRSS:\t  100 kB\n";
        assert_eq!(parse_vmhwm_bytes(status), Some(123456 * 1024));
        // Missing line, malformed number, wrong unit: all None, no panic.
        assert_eq!(parse_vmhwm_bytes("Name:\tx\n"), None);
        assert_eq!(parse_vmhwm_bytes("VmHWM:\t  lots kB\n"), None);
        assert_eq!(parse_vmhwm_bytes("VmHWM:\t  12 MB\n"), None);
    }

    #[test]
    fn peak_rss_reads_on_linux() {
        let peak = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // Any live process has touched at least a page.
            assert!(peak.unwrap() > 0);
        } else {
            assert!(peak.is_none());
        }
    }

    #[test]
    fn reset_peak_rss_never_raises_the_mark() {
        let Some(before) = peak_rss_bytes() else {
            assert!(!reset_peak_rss() || !cfg!(target_os = "linux"));
            return;
        };
        // Touch a buffer large enough to move the high-water mark, drop
        // it, then rebase: the mark must not exceed the pre-reset peak.
        let buf = vec![1u8; 8 << 20];
        assert!(buf.iter().map(|&b| b as u64).sum::<u64>() > 0);
        drop(buf);
        let peak = peak_rss_bytes().unwrap().max(before);
        if reset_peak_rss() {
            assert!(peak_rss_bytes().unwrap() <= peak);
        }
    }

    #[test]
    fn cow_vec_materializes_on_first_write_only() {
        let base = std::sync::Arc::new(vec![1.0f32, 2.0, 3.0]);
        let mut a = CowVec::shared(std::sync::Arc::clone(&base));
        let b = CowVec::shared(std::sync::Arc::clone(&base));
        assert!(a.is_shared() && b.is_shared());
        assert_eq!(a[1], 2.0);
        assert_eq!(a, b);
        // 2 handles + 1 local Arc, zero copies so far.
        assert_eq!(std::sync::Arc::strong_count(&base), 3);
        a.make_mut()[0] = 9.0;
        assert!(!a.is_shared());
        assert_eq!(std::sync::Arc::strong_count(&base), 2);
        assert_eq!(a[0], 9.0);
        assert_eq!(b[0], 1.0, "the base and other handles are untouched");
        assert_ne!(a, b);
        // Logical equality ignores representation.
        assert_eq!(CowVec::owned(vec![1.0, 2.0, 3.0]), b);
        assert_eq!(b, vec![1.0f32, 2.0, 3.0]);
        assert_eq!(b.to_vec(), vec![1.0f32, 2.0, 3.0]);
        // Cloning a shared handle stays shared; cloning owned stays owned.
        assert!(b.clone().is_shared());
        assert!(!a.clone().is_shared());
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_bytes(100), "100 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
        assert!(fmt_secs(300.0).contains("min"));
        assert!(fmt_secs(1e-5).contains("µs"));
    }
}
