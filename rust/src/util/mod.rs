//! Small shared utilities: float vector math helpers, formatting, logging.

use std::time::{SystemTime, UNIX_EPOCH};

/// Squared L2 norm of a slice.
pub fn sqnorm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// L2 norm of a slice.
pub fn norm(xs: &[f32]) -> f64 {
    sqnorm(xs).sqrt()
}

/// Max |a_i - b_i| over two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// `a && b` elementwise allclose with rtol/atol (numpy semantics).
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(&x, &y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration from seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// Wall-clock seconds since the epoch (for log stamps).
pub fn now_epoch_secs() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Read a `u64` from the environment, falling back to `default` when the
/// variable is unset or unparsable (examples use this for CI-sized runs:
/// `SLOWMO_EXAMPLE_STEPS=24 cargo run --example quickstart`).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Minimal leveled logger gated by `SLOWMO_LOG` (error|warn|info|debug).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn log_level() -> Level {
    match std::env::var("SLOWMO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    }
}

/// Log a message at `level` to stderr if enabled.
pub fn log(level: Level, msg: &str) {
    if level <= log_level() {
        eprintln!("[{:?}] {}", level, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(sqnorm(&[3.0, 4.0]), 25.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
        assert_eq!(norm(&[]), 0.0);
    }

    #[test]
    fn close() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }

    #[test]
    fn max_diff() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138)
            .abs()
            < 1e-3);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_bytes(100), "100 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
        assert!(fmt_secs(300.0).contains("min"));
        assert!(fmt_secs(1e-5).contains("µs"));
    }
}
