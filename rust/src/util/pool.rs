//! Buffer pools for the allocation-free hot path (ROADMAP item 5(b)).
//!
//! A [`Pool`] is a FIFO free-list of `Vec<T>` buffers: `take` hands out a
//! cleared buffer (capacity retained from its previous life), `put`
//! recycles one. After a warmup pass every hot-path site that draws from
//! a pool reaches steady state — the same few buffers cycle forever and
//! the global allocator is never touched again. The `alloc_gate`
//! integration test pins this with a counting `GlobalAlloc`.
//!
//! Obligations for pool users (see ROADMAP "Buffer pools & the
//! allocation gate"):
//! - never hold a pooled buffer across an outer boundary — take, use,
//!   put within one step so pools cannot grow without bound;
//! - pools change *where* bytes live, never their values: a pooled
//!   variant of any routine must be bitwise-identical to the fresh one.

use std::collections::VecDeque;

/// FIFO free-list of reusable `Vec<T>` buffers.
///
/// FIFO (not LIFO) so that when buffers of several sizes circulate
/// through one pool, every buffer rotates through every role: after a
/// bounded warmup each buffer has served the largest role once and
/// carries its capacity forever after, so the steady state is
/// allocation-free regardless of which buffer lands in which role.
#[derive(Debug)]
pub struct Pool<T> {
    free: VecDeque<Vec<T>>,
}

impl<T> Default for Pool<T> {
    fn default() -> Self {
        Self { free: VecDeque::new() }
    }
}

impl<T> Pool<T> {
    pub fn new() -> Self {
        Self { free: VecDeque::new() }
    }

    /// Number of buffers currently resting in the free list.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Take a buffer: empty (`len == 0`) but with whatever capacity it
    /// accumulated in previous lives. Allocation-free once the pool is
    /// warm; returns a fresh `Vec::new()` when the pool is empty.
    pub fn take(&mut self) -> Vec<T> {
        self.free.pop_front().unwrap_or_default()
    }

    /// Return a buffer to the pool. The contents are dropped (`clear`);
    /// the capacity is retained for the next `take`.
    pub fn put(&mut self, mut buf: Vec<T>) {
        buf.clear();
        self.free.push_back(buf);
    }
}

impl<T: Clone + Default> Pool<T> {
    /// Take a buffer resized to `len`, every slot `T::default()`.
    /// Allocation-free when a warm buffer with `capacity >= len` is
    /// available.
    pub fn take_filled(&mut self, len: usize) -> Vec<T> {
        let mut buf = self.take();
        buf.resize(len, T::default());
        buf
    }
}

/// Per-worker scratch buffers handed down through `algorithms::Ctx`.
///
/// One instance per worker thread — pools are not shared or locked; the
/// buffers themselves migrate freely between workers through the fabric
/// (a send buffer drawn from worker A's pool is recycled into worker
/// B's pool on receipt, keeping the total population constant).
#[derive(Debug, Default)]
pub struct Scratch {
    /// Payload-sized float buffers: codec wire data, EF decode
    /// temporaries, demo spectra, ring-allreduce send chunks.
    pub f32s: Pool<f32>,
    /// Index scratch: top-k order buffers, kept-coefficient lists,
    /// collective group membership.
    pub idx: Pool<usize>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_recycle_order() {
        let mut p: Pool<f32> = Pool::new();
        let mut a = Vec::with_capacity(10);
        a.push(1.0);
        let b = Vec::with_capacity(20);
        p.put(a);
        p.put(b);
        assert_eq!(p.idle(), 2);
        // First in, first out — and contents were cleared on put.
        let first = p.take();
        assert_eq!(first.capacity(), 10);
        assert!(first.is_empty());
        assert_eq!(p.take().capacity(), 20);
        assert_eq!(p.idle(), 0);
    }

    #[test]
    fn capacity_is_retained_across_lives() {
        let mut p: Pool<f32> = Pool::new();
        let mut buf = p.take();
        buf.extend_from_slice(&[0.0; 4096]);
        let cap = buf.capacity();
        assert!(cap >= 4096);
        let ptr = buf.as_ptr();
        p.put(buf);
        let again = p.take();
        assert_eq!(again.capacity(), cap);
        assert_eq!(again.as_ptr(), ptr, "no reallocation on recycle");
    }

    #[test]
    fn take_filled_zeroes_every_slot() {
        let mut p: Pool<f32> = Pool::new();
        let mut buf = p.take();
        buf.extend_from_slice(&[7.0; 64]);
        p.put(buf);
        let z = p.take_filled(64);
        assert_eq!(z.len(), 64);
        assert!(z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn cross_size_safety() {
        // A buffer used at one size then recycled for a different size
        // never leaks stale contents or mis-sizes.
        let mut p: Pool<f32> = Pool::new();
        let mut big = p.take();
        big.resize(1000, 3.5);
        p.put(big);
        let small = p.take_filled(10);
        assert_eq!(small.len(), 10);
        assert!(small.iter().all(|&v| v == 0.0));
        p.put(small);
        let big_again = p.take_filled(2000);
        assert_eq!(big_again.len(), 2000);
        assert!(big_again.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_pool_hands_out_fresh_buffers() {
        let mut p: Pool<usize> = Pool::new();
        assert_eq!(p.idle(), 0);
        let buf = p.take();
        assert!(buf.is_empty());
        assert_eq!(buf.capacity(), 0);
    }
}
