//! α-β communication cost model + per-workload timing constants.
//!
//! Transfer time for a b-byte message: `α + b/β` (latency + serialization).
//! Ring allreduce of b bytes over m nodes: `2(m-1)·α + 2·(m-1)/m · b/β`
//! (reduce-scatter + allgather, the NCCL schedule the paper's testbed
//! uses). Defaults model the paper's fabric: commodity 10 Gbps Ethernet.

/// Network cost model (simulated seconds).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Per-message latency α (s). 50 µs is typical for commodity Ethernet.
    pub latency_s: f64,
    /// Bandwidth β in bytes/s. 10 Gbps ≈ 1.25e9 B/s.
    pub bandwidth_bps: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self::ethernet_10g()
    }
}

impl CostModel {
    pub fn ethernet_10g() -> Self {
        Self { latency_s: 50e-6, bandwidth_bps: 1.25e9 }
    }

    /// Commodity 1 Gbps Ethernet with WAN-ish latency — the default slow
    /// *inter-group* link of the two-tier cluster model (BMUF's
    /// fast-intra-node / slow-inter-node shape).
    pub fn ethernet_1g() -> Self {
        Self { latency_s: 500e-6, bandwidth_bps: 1.25e8 }
    }

    /// An idealized zero-cost network (for algorithm-only tests).
    pub fn free() -> Self {
        Self { latency_s: 0.0, bandwidth_bps: f64::INFINITY }
    }

    /// Point-to-point transfer time for `elems` raw f32 values.
    pub fn xfer_time(&self, elems: usize) -> f64 {
        self.xfer_time_bytes(elems as u64 * 4)
    }

    /// Point-to-point transfer time for a `bytes`-byte message — the
    /// general form: communication compression charges its true wire
    /// size through here instead of assuming 4 bytes per element.
    pub fn xfer_time_bytes(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Default retransmission timeout for the chaos layer when the plan
    /// does not set one: a few RTTs on this network, floored at 1 ms
    /// (so drops cost time even on the idealized free network).
    pub fn retransmit_timeout(&self) -> f64 {
        (4.0 * self.latency_s).max(1e-3)
    }

    /// Ring-allreduce time for `elems` raw f32 values over `m` nodes.
    pub fn allreduce_time(&self, elems: usize, m: usize) -> f64 {
        self.allreduce_time_bytes(elems as u64 * 4, m)
    }

    /// Ring-allreduce time for a `bytes`-byte vector over `m` nodes —
    /// the general form used by compressed collectives.
    pub fn allreduce_time_bytes(&self, bytes: u64, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        2.0 * (m - 1) as f64 * self.latency_s
            + 2.0 * ((m - 1) as f64 / m as f64) * bytes as f64
                / self.bandwidth_bps
    }
}

/// Per-iteration timing for one paper workload, used by the Table-2 /
/// Fig-3 analytic benches. `compute_s` is the pure fwd+bwd+local-update
/// time per iteration on the paper's hardware (derived from the paper's
/// AR rows minus the AR allreduce cost).
#[derive(Clone, Debug)]
pub struct WorkloadTiming {
    pub name: &'static str,
    /// Model parameters (paper scale, for comm volume).
    pub params: usize,
    /// Workers (nodes) in the paper's setup.
    pub m: usize,
    /// Local compute per iteration (s).
    pub compute_s: f64,
    pub net: CostModel,
}

impl WorkloadTiming {
    /// ImageNet / ResNet-50, 32 nodes (paper Table 2a): AR-SGD measured
    /// 420 ms/iter. ResNet-50 has ~25.5M params; ring allreduce of 102 MB
    /// over 10 Gbps ≈ 158 ms, leaving ~262 ms of compute.
    pub fn imagenet() -> Self {
        Self {
            name: "imagenet-resnet50",
            params: 25_500_000,
            m: 32,
            compute_s: 0.262,
            net: CostModel::ethernet_10g(),
        }
    }

    /// WMT'16 En-De big transformer, 8 nodes (paper Table 2b): AR-Adam
    /// measured 1648 ms/iter. Big transformer ~210M params; allreduce of
    /// 840 MB over 10 Gbps ≈ 1.18 s, leaving ~0.47 s compute.
    pub fn wmt() -> Self {
        Self {
            name: "wmt16-transformer-big",
            params: 210_000_000,
            m: 8,
            compute_s: 0.47,
            net: CostModel::ethernet_10g(),
        }
    }

    /// Time/iter for AR-SGD (allreduce every step).
    pub fn iter_allreduce(&self) -> f64 {
        self.compute_s + self.net.allreduce_time(self.params, self.m)
    }

    /// Time/iter for Local SGD with period τ (allreduce amortized).
    pub fn iter_local_sgd(&self, tau: usize) -> f64 {
        self.compute_s
            + self.net.allreduce_time(self.params, self.m) / tau as f64
    }

    /// Time/iter for blocking SGP (one gossip send+recv per step, on the
    /// critical path).
    pub fn iter_sgp(&self) -> f64 {
        self.compute_s + self.net.xfer_time(self.params)
    }

    /// Time/iter for OSGP (communication overlapped with compute; the
    /// critical path is whichever is longer).
    pub fn iter_osgp(&self) -> f64 {
        self.compute_s.max(self.net.xfer_time(self.params))
    }

    /// Additional per-iteration cost of SlowMo at period τ: one exact
    /// average (ring allreduce) amortized over τ inner steps. The slow
    /// update itself is a fused elementwise kernel — negligible (paper §4
    /// "Communication Cost"). For Local SGD the exact average replaces the
    /// one the base algorithm already does, so the increment is zero.
    pub fn slowmo_overhead(&self, tau: usize, base_has_average: bool) -> f64 {
        if base_has_average {
            0.0
        } else {
            self.net.allreduce_time(self.params, self.m) / tau as f64
        }
    }

    /// Time/iter for double-averaging momentum SGP (Yu et al. 2019a):
    /// parameters *and* momentum buffers averaged — twice the allreduce
    /// payload every τ steps on top of gossip.
    pub fn iter_double_avg_sgp(&self, tau: usize) -> f64 {
        self.iter_sgp()
            + 2.0 * self.net.allreduce_time(self.params, self.m) / tau as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_scales_with_bytes() {
        let c = CostModel::ethernet_10g();
        assert!(c.xfer_time(1000) < c.xfer_time(1_000_000));
        // 1.25 GB at 1.25 GB/s = 1 s (+ latency).
        let t = c.xfer_time(312_500_000);
        assert!((t - 1.0).abs() < 1e-3, "{t}");
    }

    #[test]
    fn allreduce_formula() {
        let c = CostModel { latency_s: 0.0, bandwidth_bps: 4.0 };
        // 2 elems (8 bytes), m=2: 2*(1/2)*8/4 = 2 s.
        assert!((c.allreduce_time(2, 2) - 2.0).abs() < 1e-12);
        assert_eq!(c.allreduce_time(1000, 1), 0.0);
    }

    #[test]
    fn byte_forms_match_elem_forms_exactly() {
        // The f32-element helpers are thin wrappers over the byte forms;
        // 4*elems bytes must charge bit-identical time (the compress=none
        // equivalence rests on this).
        let c = CostModel::ethernet_10g();
        for elems in [0usize, 1, 7, 1000, 25_500_000] {
            assert_eq!(c.xfer_time(elems), c.xfer_time_bytes(elems as u64 * 4));
            for m in [1usize, 2, 8, 32] {
                assert_eq!(
                    c.allreduce_time(elems, m),
                    c.allreduce_time_bytes(elems as u64 * 4, m)
                );
            }
        }
        // Compressed transfers charge proportionally less serialization.
        assert!(c.xfer_time_bytes(1000) < c.xfer_time_bytes(4000));
        assert!(
            c.allreduce_time_bytes(1000, 4) < c.allreduce_time_bytes(4000, 4)
        );
    }

    #[test]
    fn free_network_is_free() {
        let c = CostModel::free();
        assert_eq!(c.xfer_time(1_000_000), 0.0);
        assert_eq!(c.allreduce_time(1_000_000, 32), 0.0);
    }

    #[test]
    fn retransmit_timeout_scales_with_latency_with_floor() {
        assert_eq!(CostModel::free().retransmit_timeout(), 1e-3);
        let slow = CostModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
        assert!((slow.retransmit_timeout() - 4e-3).abs() < 1e-15);
    }

    #[test]
    fn monotone_in_elems_and_m() {
        let c = CostModel::ethernet_10g();
        assert!(c.allreduce_time(100, 4) < c.allreduce_time(200, 4));
        assert!(c.allreduce_time(1_000_000, 2)
            < c.allreduce_time(1_000_000, 16));
    }

    #[test]
    fn imagenet_timing_matches_paper_shape() {
        // Paper Table 2a: AR-SGD 420, SGP 304, OSGP 271, LocalSGD(12) 294.
        let w = WorkloadTiming::imagenet();
        let ar = w.iter_allreduce() * 1e3;
        let sgp = w.iter_sgp() * 1e3;
        let osgp = w.iter_osgp() * 1e3;
        let local = w.iter_local_sgd(12) * 1e3;
        assert!((380.0..460.0).contains(&ar), "ar {ar}");
        assert!((300.0..380.0).contains(&sgp), "sgp {sgp}");
        assert!(osgp < sgp);
        assert!(local < ar && local > w.compute_s * 1e3);
        // Ordering the paper reports: OSGP < LocalSGD < SGP < AR.
        assert!(osgp < local && local < sgp && sgp < ar);
    }

    #[test]
    fn slowmo_overhead_amortizes() {
        let w = WorkloadTiming::imagenet();
        let at48 = w.slowmo_overhead(48, false);
        let at12 = w.slowmo_overhead(12, false);
        assert!(at48 < at12);
        assert!(at48 < 0.01 * w.iter_sgp(), "overhead {at48}");
        assert_eq!(w.slowmo_overhead(12, true), 0.0);
    }

    #[test]
    fn double_avg_costs_more_than_slowmo() {
        let w = WorkloadTiming::imagenet();
        let slowmo = w.iter_sgp() + w.slowmo_overhead(48, false);
        let davg = w.iter_double_avg_sgp(12);
        assert!(davg > slowmo);
    }
}
